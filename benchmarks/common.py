"""Shared workload definitions (paper Table 2) + fitting helpers.

Datasets are the synthetic stand-ins from repro.data (offline container —
same shapes as paper Table 9; accuracies are proxies, system-level numbers
are faithful).  Feature budgets per system come from paper Tables 3/4.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.mlmodels import (
    DecisionTree,
    LinearSVM,
    Quantizer,
    RandomForest,
)
from repro.data import load_dataset

# (workload id, dataset, model kind) — paper Table 2.
WORKLOADS = [
    ("1", "nsl-kdd", "dt"),
    ("2", "nsl-kdd", "svm"),
    ("3", "unsw-iot", "rf"),
    ("4", "cicids-17", "dt"),
    ("5", "unsw-nb15", "dt"),
    ("6", "iscxvpn16", "rf"),
    ("7", "cicids-17", "svm"),
    ("8", "vcaml", "rf"),
    ("9", "iris", "svm"),
    ("10", "digits", "rf"),
    ("11", "mnist", "dt"),
    ("12", "satdap", "dt"),
]

# Per-system feature budgets for tree workloads (paper Tables 3/4).
FEATURE_BUDGET = {"switchtree": 16, "leo": 10, "dinc": 32, "acorn": 46}

# Sample-count scales (1 CPU core; shapes preserved).
SCALE = {
    "nsl-kdd": 0.04, "unsw-iot": 0.008, "cicids-17": 0.05, "unsw-nb15": 0.03,
    "iscxvpn16": 1.0, "vcaml": 0.5, "iris": 1.0, "digits": 1.0,
    "mnist": 0.15, "satdap": 1.0,
}


@dataclasses.dataclass
class Fitted:
    model: object
    Xtr: np.ndarray
    ytr: np.ndarray
    Xte: np.ndarray
    yte: np.ndarray
    cols: np.ndarray
    fit_s: float


def topk_features(Xq, y, k: int) -> np.ndarray:
    """Importance-based selection (fast stand-in for the paper's RFE —
    identical intent: pick the k most informative columns)."""
    if Xq.shape[1] <= k:
        return np.arange(Xq.shape[1])
    probe = DecisionTree(max_depth=8, max_leaf_nodes=128, random_state=0).fit(Xq, y)
    imp = probe.feature_importances_()
    order = np.argsort(-imp, kind="stable")
    return np.sort(order[:k])


def fit_workload(dataset: str, kind: str, n_features: int, *,
                 max_leaf_nodes: int = 128, n_estimators: int = 3,
                 seed: int = 0) -> Fitted:
    Xtr, ytr, Xte, yte = load_dataset(dataset, scale=SCALE[dataset],
                                      max_train=6000, max_test=2000)
    q = Quantizer(8).fit(Xtr)
    Xtrq, Xteq = q.transform(Xtr), q.transform(Xte)
    cols = topk_features(Xtrq, ytr, n_features)
    Xtrq, Xteq = Xtrq[:, cols], Xteq[:, cols]
    t0 = time.perf_counter()
    if kind == "dt":
        model = DecisionTree(max_depth=12, max_leaf_nodes=max_leaf_nodes,
                             random_state=seed).fit(Xtrq, ytr)
    elif kind == "rf":
        model = RandomForest(n_estimators=n_estimators, max_depth=8,
                             max_leaf_nodes=max_leaf_nodes // 2,
                             random_state=seed).fit(Xtrq, ytr)
    else:
        model = LinearSVM(epochs=250, random_state=seed).fit(Xtrq, ytr)
    return Fitted(model, Xtrq, ytr, Xteq, yte, cols, time.perf_counter() - t0)

"""Paper Figs. 6/7: request serving time, ACORN vs server-based.

Server prediction latency is *measured* (wall-clock single-request predicts
of the numpy models, as the paper measures sklearn on a server); network
terms come from the documented latency model; the ACORN side is the
planner's J_L on a fat-tree path.  Also reports the engine's measured
per-packet classification cost on this CPU for reference."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import WORKLOADS, fit_workload
from repro.core import packets
from repro.core.netsim import (
    acorn_serving_time,
    measure_inference_time,
    server_serving_time,
    simulate_serving,
)
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.core.planner import plan_program
from repro.core.topology import fat_tree
from repro.core.translator import translate


def run(workloads=("1", "3", "9", "12")) -> list[str]:
    out = ["fig67,workload,kind,acorn_ms,server_ms,speedup,pred_ms_server,hops"]
    net = fat_tree(4)
    h = net.hosts()
    for wid, ds, kind in WORKLOADS:
        if wid not in workloads:
            continue
        f = fit_workload(ds, kind, 24)
        prog = translate(f.model)
        plan = plan_program(prog, net, h[0], h[-1], solver="dp")
        t_acorn = acorn_serving_time(plan)
        t_pred = measure_inference_time(f.model, f.Xte, n_requests=60)
        rq = packets.request_bytes(prog.n_features, n_trees=prog.n_trees,
                                   n_hyperplanes=prog.n_hyperplanes)
        t_server = server_serving_time(t_pred, rq)
        samples = simulate_serving(t_acorn, n=500)
        out.append(
            f"fig67,{wid},{kind},{np.median(samples)*1e3:.4f},"
            f"{t_server*1e3:.4f},{t_server/t_acorn:.1f}x,"
            f"{t_pred*1e3:.4f},{plan.breakdown['hops']}")
    # prediction-latency breakdown (Fig. 7): plane batch throughput on CPU
    f = fit_workload("satdap", "rf", 24)
    prog = translate(f.model)
    prof = PlaneProfile(max_features=36, max_trees=8, max_layers=16,
                        max_entries_per_layer=256, max_leaves=256,
                        max_classes=8, max_hyperplanes=8)
    eng = SwitchEngine(prof)
    packed = eng.install(eng.empty(), prog)
    pb = PacketBatch.make_request(f.Xte[:512], mid=prog.mid, max_features=36,
                                  n_trees=8, n_hyperplanes=8)
    eng.classify(packed, pb).rslt.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        eng.classify(packed, pb).rslt.block_until_ready()
    per_pkt = (time.perf_counter() - t0) / 5 / 512
    out.append(f"fig67,engine,rf,per_packet_us={per_pkt*1e6:.2f},"
               f"(XLA-CPU engine; Tofino pipeline ~1us/packet at line rate),,,")
    return out

"""Paper Fig. 8: optimizer solve time across datacenter topologies.

Paper setups (Table 6) are Fat-Tree k=12/16/20, DCell/BCube/Jellyfish of
similar switch counts.  We run the same families; sizes are trimmed to this
container's single core (documented), plus the beyond-paper DP-vs-MILP
speedup on identical subproblems."""
from __future__ import annotations

import time

from benchmarks.common import fit_workload
from repro.core.planner import DeviceModel, plan_program
from repro.core.topology import bcube, dcell, fat_tree, jellyfish
from repro.core.translator import translate

SETUPS = [
    ("fat-tree", lambda: fat_tree(8)),
    ("fat-tree", lambda: fat_tree(12)),
    ("dcell", lambda: dcell(3, 1)),
    ("dcell", lambda: dcell(4, 1)),
    ("bcube", lambda: bcube(4, 1)),
    ("bcube", lambda: bcube(5, 1)),
    ("jellyfish", lambda: jellyfish(80, 3)),
    ("jellyfish", lambda: jellyfish(125, 4)),
]


def run() -> list[str]:
    out = ["fig8,topology,switches,model,solver,solve_s,devices_used"]
    f_small = fit_workload("satdap", "dt", 24, max_leaf_nodes=64)
    f_big = fit_workload("nsl-kdd", "rf", 40, max_leaf_nodes=128, n_estimators=4)
    for name, mk in SETUPS:
        net = mk()
        h = net.hosts()
        src, dst = h[0], h[-1]
        for label, f in (("dt", f_small), ("rf", f_big)):
            prog = translate(f.model)
            for solver in ("dp", "milp"):
                t0 = time.perf_counter()
                try:
                    plan = plan_program(prog, net, src, dst,
                                        default_device=DeviceModel(n_stages=8),
                                        solver=solver)
                    dt = time.perf_counter() - t0
                    out.append(f"fig8,{name},{net.n_switches},{label},{solver},"
                               f"{dt:.3f},{len(plan.breakdown['devices_used'])}")
                    assert dt < 10.0  # the paper's Fig. 8 bound
                except RuntimeError as e:
                    out.append(f"fig8,{name},{net.n_switches},{label},{solver},"
                               f"infeasible,{e}")
    return out

"""Paper Fig. 9 (+ Fig. 12): TCAM/SRAM entries vs feature count per system."""
from __future__ import annotations

from benchmarks.common import fit_workload
from repro.core.baselines import (
    acorn_resources,
    dinc_resources,
    leo_resources,
    switchtree_resources,
)
from repro.core.translator import translate

DATASETS = ["cicids-17", "digits", "nsl-kdd", "unsw-nb15"]
FEATURES = [5, 15, 25, 45]


def run(datasets=None) -> list[str]:
    out = ["fig9,dataset,features,system,tcam,sram,feasible"]
    for ds in datasets or DATASETS:
        for nf in FEATURES:
            f = fit_workload(ds, "dt", nf, max_leaf_nodes=128)
            for fn in (acorn_resources, switchtree_resources, leo_resources,
                       dinc_resources):
                r = fn(f.model)
                out.append(f"fig9,{ds},{f.Xtr.shape[1]},{r.system},"
                           f"{r.tcam_entries},{r.sram_entries},{r.feasible}")
    # Fig. 12: SVM SRAM — ACORN == DINC by design (same representation)
    for nf in (4, 8, 16, 46):
        f = fit_workload("nsl-kdd", "svm", nf)
        prog = translate(f.model)
        sram = prog.total_sram_entries()
        out.append(f"fig9,svm-sram,{f.Xtr.shape[1]},acorn==dinc,0,{sram},True")
    return out

"""Fleet chaos serving: availability and tail latency across fault schedules.

An open-loop Poisson client drives a two-model zoo deployed by the ILP
planner across a fat-tree(4) — ``FleetRuntime`` + ``ControlLoop`` — while a
scripted chaos schedule kills switches mid-run: ``none`` (baseline),
``one_kill`` (an aggregation switch on the serving path), ``two_kills``
(the agg, then the core the replan moved traffic onto).  Every response is
compared against the ``mode="ref"`` oracle; a single non-identical answer
fails the run — self-healing must never trade correctness for liveness.

Reported per schedule: request count, wrong answers (must be 0), p50/p99
end-to-end latency (healing holds included), the slowest heal cycle, total
control-plane downtime, measured availability (uptime fraction of the
run's wall-clock span), and modeled availability (``netsim`` latency
samples with the session's heal windows applied as downtime, fraction
within SLO).

Acceptance pins asserted here (both schedules with kills): zero wrong
answers, and every heal cycle within ``HEAL_BUDGET_S``.  The CI chaos row
sets ``FLEET_SMOKE=1``, which shrinks the request count but still asserts
both pins.

  PYTHONPATH=src python -m benchmarks.run --only fleet_serve
"""
from __future__ import annotations

import asyncio
import os
import time

HEADER = ("fleet_serve,schedule,kills,requests,wrong,p50_ms,p99_ms,"
          "heal_ms,downtime_s,availability,modeled_avail")

BATCH = 16                 # rows per client request (one admission bucket)
MAX_BATCH = 64
MAX_WAIT_US = 500.0
HEAL_BUDGET_S = 10.0       # generous: first heal pays image-install jit
SLO_S = 1e-3               # modeled-availability SLO (paper ~0.12 ms + slack)

# schedule name -> request-progress fractions at which to kill a switch
SCHEDULES = {
    "none": (),
    "one_kill": (1 / 3,),
    "two_kills": (1 / 3, 2 / 3),
}


def _next_victim(fleet) -> str:
    """First kill takes the path's aggregation hop, later kills take the
    core the replan rerouted onto — never an edge switch (hosts_per_edge=1
    makes those cut vertices, and honesty-on-infeasible is pinned by
    tests/test_fleet.py, not benchmarked here)."""
    hop = 2 if not fleet.down else 3
    return fleet.path[hop]


async def _trial(fleet, oracle, oracle_packed, X, *, kill_at, rate_rps,
                 n_requests, rng):
    import numpy as np

    kill_idx = {int(f * n_requests) for f in kill_at}
    wrong = 0
    kills_done = 0

    async def one(pb, want):
        nonlocal wrong
        out = await fleet.submit_batch(pb)
        if not np.array_equal(np.asarray(out.rslt), want):
            wrong += 1

    async with fleet.serving():
        loop = asyncio.get_running_loop()
        t0 = loop.time()
        arrivals = rng.exponential(1.0 / rate_rps, n_requests).cumsum()
        tasks = []
        for i, t_arr in enumerate(arrivals):
            delay = t0 + t_arr - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            if i in kill_idx:
                # space kills one heal cycle apart (a chaos schedule, not a
                # correlated failure): wait out the previous heal first
                deadline = loop.time() + 30.0
                while fleet.counters.reinstalls < kills_done:
                    if loop.time() > deadline:
                        raise AssertionError("previous heal never completed")
                    await asyncio.sleep(0.01)
                fleet.kill(_next_victim(fleet))
                kills_done += 1
            lo = int(rng.integers(0, X.shape[0] - BATCH))
            vid = int(rng.integers(0, 2))
            pb = fleet.make_request(X[lo:lo + BATCH], mid=0, vid=vid)
            want = np.asarray(oracle.classify(oracle_packed, pb).rslt)
            tasks.append(asyncio.create_task(one(pb, want)))
        await asyncio.gather(*tasks)
        span = loop.time() - t0
        stats = fleet.latency_stats()
    return stats, span, wrong


def run() -> list[str]:
    import numpy as np

    from benchmarks.common import fit_workload
    from repro.core.plane import (
        PlaneProfile,
        SwitchEngine,
        empty_program,
        install_program,
    )
    from repro.core.netsim import serving_availability
    from repro.core.planner import DeviceModel
    from repro.core.topology import fat_tree
    from repro.core.translator import translate
    from repro.serving import FleetRuntime

    smoke = os.environ.get("FLEET_SMOKE") == "1"
    n_requests = 40 if smoke else 200

    prof = PlaneProfile(max_features=36, max_trees=4, max_layers=13,
                        max_entries_per_layer=128, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=2)
    dt = fit_workload("satdap", "dt", 36, max_leaf_nodes=64)
    rf = fit_workload("satdap", "rf", 36, max_leaf_nodes=64, n_estimators=3)
    zoo = [translate(dt.model, vid=0), translate(rf.model, vid=1)]
    X = dt.Xte

    oracle = SwitchEngine(prof, mode="ref")
    oracle_packed = empty_program(prof)
    for p in zoo:
        oracle_packed = install_program(oracle_packed, p, prof, vid=p.vid)

    out = [HEADER]
    for schedule, kill_at in SCHEDULES.items():
        # fresh fleet per schedule: kills and replans must not leak across;
        # a tight per-device budget spreads the zoo over several hops, but
        # fall back to the default device if this zoo doesn't fit in 6 stages
        try:
            fleet = FleetRuntime(fat_tree(4), prof, zoo, src="h0_0_0",
                                 dst="h2_0_0", solver="dp",
                                 default_device=DeviceModel(n_stages=6))
        except RuntimeError:
            fleet = FleetRuntime(fat_tree(4), prof, zoo, src="h0_0_0",
                                 dst="h2_0_0", solver="dp")
        # warm every bucket the policy can cut, plus the per-vid oracles
        B = BATCH
        while B <= MAX_BATCH * 2:
            for vid in (0, 1):
                fleet.classify(X[:min(B, X.shape[0])], mid=0, vid=vid)
            B *= 2
        t1 = min(_timed(fleet, X) for _ in range(5))
        stats, span, wrong = asyncio.run(_trial(
            fleet, oracle, oracle_packed, X, kill_at=kill_at,
            rate_rps=1.0 / t1, n_requests=n_requests,
            rng=np.random.default_rng(23)))

        ctl = stats["control"]
        avail = max(0.0, 1.0 - ctl["total_downtime_s"] / span)
        modeled = serving_availability(
            fleet.modeled_latencies(n=2000, arrival_rate_rps=1.0 / t1,
                                    seed=23), SLO_S)
        out.append(
            f"fleet_serve,{schedule},{len(kill_at)},{stats['requests']},"
            f"{wrong},{stats['p50_ms']:.2f},{stats['p99_ms']:.2f},"
            f"{ctl['last_heal_ms']:.0f},{ctl['total_downtime_s']:.3f},"
            f"{avail:.4f},{modeled:.4f}")

        if wrong:
            raise AssertionError(
                f"{schedule}: {wrong} responses diverged from the ref "
                "oracle — healing must never corrupt answers")
        if kill_at:
            if ctl["reinstalls"] != len(kill_at):
                raise AssertionError(
                    f"{schedule}: expected {len(kill_at)} heal cycles, "
                    f"control counters recorded {ctl['reinstalls']}")
            worst = max(t1 - t0 for t0, t1 in ctl["downtime_windows"])
            if worst > HEAL_BUDGET_S:
                raise AssertionError(
                    f"{schedule}: slowest heal {worst:.1f}s exceeds the "
                    f"{HEAL_BUDGET_S:.0f}s availability budget")
    return out


def _timed(fleet, X) -> float:
    t0 = time.perf_counter()
    fleet.classify(X[:BATCH], mid=0, vid=0)
    return time.perf_counter() - t0


if __name__ == "__main__":
    for line in run():
        print(line)

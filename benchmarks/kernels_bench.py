"""Kernel micro-bench: XLA-ref path wall time on CPU (us/call) + the
VMEM/MXU tiling parameters the Pallas versions claim on TPU.

The classify sweep (``run_classify_fused``) is the perf trajectory seed:
fused megakernel vs the pre-fusion three-launch classify, per (mode, V, L),
each row carrying us/packet plus the roofline's achieved-vs-peak bytes and
flops (``repro.analysis.hlocost`` on the compiled module +
``repro.analysis.roofline`` HW peaks).  ``run()`` also writes the rows
machine-readable to ``BENCH_kernels.json`` (CI uploads it as a workflow
artifact).  ``KERNELS_BENCH_SMOKE=1`` shrinks the sweep to the single
fused-vs-unfused L=32 comparison CI gates on.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlocost import parse_hlo_cost
from repro.analysis.roofline import HW, roofline_terms
from repro.kernels import ops

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")


def _time(fn, *args, n=10):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[str]:
    rng = np.random.default_rng(0)
    out = ["kernels,name,us_per_call,config"]
    B, T, E, F = 2048, 8, 128, 46
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    cv = jnp.asarray(rng.integers(0, 64, (T, E)), jnp.uint32)
    cm = jnp.asarray(rng.integers(0, 64, (T, E)), jnp.uint32)
    fid = jnp.asarray(rng.integers(0, F, (T, E)), jnp.int32)
    flo = jnp.zeros((T, E), jnp.int32)
    fhi = jnp.full((T, E), 128, jnp.int32)
    bit = jnp.asarray(rng.integers(0, 2, (T, E)), jnp.uint32)
    valid = jnp.ones((T, E), bool)
    us = _time(lambda *a: ops.tcam_match(*a, mode="ref"),
               codes, feats, cv, cm, fid, flo, fhi, bit, valid, jnp.int32(3))
    out.append(f"kernels,tcam_match,{us:.1f},B={B} T={T} E={E} F={F} "
               f"(Pallas: block_b=256 E_pad=128 f-sel MXU matmul)")

    H, L = 10, 256
    lut = jnp.asarray(rng.integers(-50000, 50000, (H, F, L)), jnp.int32)
    bias = jnp.zeros((H,), jnp.int32)
    us = _time(lambda *a: ops.svm_lookup(*a, mode="ref"), feats, lut, bias)
    out.append(f"kernels,svm_lookup,{us:.1f},B={B} H={H} F={F} L={L} "
               f"(Pallas: chunk_f=8 one-hot MXU, int-exact accum)")

    P, C = 256, 25
    pc = jnp.asarray(np.sort(rng.choice(2**16, (T, P), replace=False)
                             .astype(np.uint32), axis=1))
    pl = jnp.asarray(rng.integers(0, C, (T, P)), jnp.int32)
    pv = jnp.ones((T, P), bool)
    w = jnp.ones((T,), jnp.float32)
    us = _time(lambda *a: ops.forest_predict_vote(*a, C, mode="ref"),
               codes, pc, pl, pv, w)
    out.append(f"kernels,forest_predict_vote,{us:.1f},B={B} T={T} P={P} C={C} "
               f"(Pallas: compare-reduce CAM, block_b=256)")

    Bq, Hq, Hkv, D, S = 8, 16, 8, 128, 4096
    q = jnp.asarray(rng.normal(size=(Bq, Hq, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(Bq, S, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(Bq, S, Hkv, D)), jnp.bfloat16)
    kvl = jnp.full((Bq,), S, jnp.int32)
    us = _time(lambda *a: ops.decode_attn(*a, mode="ref"), q, k, v, kvl)
    out.append(f"kernels,decode_attn,{us:.1f},B={Bq} Hq={Hq} Hkv={Hkv} S={S} "
               f"(Pallas: flash-decode, block_s=512, VMEM scratch accum)")

    out.extend(run_tree_walk(rng))
    classify_rows, json_rows = run_classify_fused(rng)
    out.extend(classify_rows)
    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "kernels", "rows": json_rows}, f, indent=1)
        f.write("\n")
    out.append(f"# wrote {len(json_rows)} rows to BENCH_kernels.json")
    return out


def run_tree_walk(rng) -> list[str]:
    """Fused single-launch tree walk vs the pre-fusion per-layer scan, and
    install-time prepped operands vs per-call prep.

    Reports, per (L, V): Pallas launch count per classify (counted in the
    traced jaxpr — 1 fused vs L layerwise), the count of table-shaped prep
    ops left in the trace (0 when the exec image is bound), and wall-clock /
    packets-per-sec for the *actual kernel paths* in interpret mode, where
    the per-launch overhead the fusion removes is real.  ``fused-prepped``
    binds operands built once by ``tiling.prep_tree_walk`` — the engine's
    install-time exec-image path — so its delta vs ``fused`` is the per-call
    prep cost that moved to install time.  (The XLA `mode="ref"` paths of the
    two walks are the identical scan computation on CPU, so timing them would
    report measurement noise as a delta; on TPU rerun with `mode="pallas"` /
    `"layerwise-pallas"` to time the compiled kernels.)
    """
    from repro.kernels import tiling

    out = ["tree_walk,name,L,V,launches,prep_ops,us_per_batch,pkts_per_sec,config"]
    B, T, E, F = 512, 8, 128, 46
    for L in (8, 16, 32):
        for V in (1, 4):
            codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
            feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
            vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
            cv = jnp.asarray(rng.integers(0, 64, (V, L, T, E)), jnp.uint32)
            cm = jnp.asarray(rng.integers(0, 64, (V, L, T, E)), jnp.uint32)
            fid = jnp.asarray(rng.integers(0, F, (V, L, T, E)), jnp.int32)
            flo = jnp.zeros((V, L, T, E), jnp.int32)
            fhi = jnp.full((V, L, T, E), 128, jnp.int32)
            bit = jnp.asarray(rng.integers(0, 2, (V, L, T, E)), jnp.uint32)
            valid = jnp.ones((V, L, T, E), bool)
            shift = jnp.arange(L, dtype=jnp.int32)
            args = (codes, feats, vid, cv, cm, fid, flo, fhi, bit, valid, shift)
            prep = jax.tree.map(  # install-time compile, outside the timed fn
                lambda x: x.block_until_ready(),
                tiling.prep_tree_walk(cv, cm, fid, flo, fhi, bit, valid,
                                      tiling.lane_pad(F)))
            for name, mode, kw in (
                    ("fused", "interpret", {}),
                    ("fused-prepped", "interpret", {"prep": prep}),
                    ("layerwise", "layerwise-interpret", {})):
                launches = ops.count_pallas_launches(
                    lambda *a, m=mode, k=kw: ops.tree_walk_v(*a, mode=m, **k),
                    *args)
                prep_ops = ops.count_operand_prep_ops(
                    lambda *a, m=mode, k=kw: ops.tree_walk_v(*a, mode=m, **k),
                    *args)
                fn = jax.jit(
                    lambda *a, m=mode, k=kw: ops.tree_walk_v(*a, mode=m, **k))
                us = _time(fn, *args, n=3)
                pps = B / (us * 1e-6)
                out.append(
                    f"tree_walk,{name},{L},{V},{launches},{prep_ops},{us:.1f},"
                    f"{pps:.0f},B={B} T={T} E={E} F={F} "
                    f"(interpret-mode kernel paths)")
    return out


def run_classify_fused(rng) -> tuple[list[str], list[dict]]:
    """Whole-classify megakernel vs the pre-fusion three-launch path.

    Per (mode, V, L) row: launch count (1 fused vs 3 unfused — the jaxpr
    pin), us/packet in interpret mode (where per-launch overhead is real),
    and the roofline view of the *compiled module*: HLO matmul flops +
    HBM-model traffic bytes (``parse_hlo_cost``), the achieved rates at the
    measured wall time, and the step lower bound against the TPU HW peaks.
    The fused kernel deletes the f32 ``fsel`` operand stream and the
    codes/feature HBM round-trips, so its bytes term — not just its launch
    count — drops; the before/after table at the end shows both.
    """
    from repro.kernels import tiling

    smoke = bool(os.environ.get("KERNELS_BENCH_SMOKE"))
    hw = HW()
    out = ["classify,mode,V,L,B,launches,us_per_batch,us_per_packet,"
           "hlo_mflops,hlo_mbytes,achieved_gflops,achieved_gbps,"
           "roofline_lb_us,dominant,config"]
    json_rows: list[dict] = []
    B, T, E, F = 512, 8, 128, 46
    P, C, H, levels = 256, 8, 8, 256
    l_sweep = (32,) if smoke else (8, 16, 32)
    v_sweep = (1,) if smoke else (1, 4)
    speedups: dict[tuple[int, int], dict[str, float]] = {}
    for L in l_sweep:
        for V in v_sweep:
            codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
            feats = jnp.asarray(rng.integers(0, levels, (B, F)), jnp.int32)
            vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
            cv = jnp.asarray(rng.integers(0, 64, (V, L, T, E)), jnp.uint32)
            cm = jnp.asarray(rng.integers(0, 64, (V, L, T, E)), jnp.uint32)
            fid = jnp.asarray(rng.integers(0, F, (V, L, T, E)), jnp.int32)
            flo = jnp.zeros((V, L, T, E), jnp.int32)
            fhi = jnp.full((V, L, T, E), 128, jnp.int32)
            bit = jnp.asarray(rng.integers(0, 2, (V, L, T, E)), jnp.uint32)
            valid = jnp.ones((V, L, T, E), bool)
            shift = jnp.arange(L, dtype=jnp.int32)
            pc = jnp.asarray(np.sort(
                rng.choice(2**16, (V, T, P), replace=False).astype(np.uint32),
                axis=2))
            plab = jnp.asarray(rng.integers(0, C, (V, T, P)), jnp.int32)
            pv = jnp.ones((V, T, P), bool)
            w = jnp.ones((V, T), jnp.float32)
            lut = jnp.asarray(rng.integers(-50_000, 50_000,
                                           (V, H, F, levels)), jnp.int32)
            bias = jnp.zeros((V, H), jnp.int32)
            args = (codes, feats, vid, cv, cm, fid, flo, fhi, bit, valid,
                    shift, pc, plab, pv, w, lut, bias)
            prep = jax.tree.map(   # install-time prep, outside the timed fn
                lambda x: x.block_until_ready(),
                tiling.prep_classify_fused(cv, cm, fid, flo, fhi, bit, valid,
                                           pc, plab, pv, w, lut, bias))
            for name, mode, kw in (
                    ("fused", "interpret", {}),
                    ("fused-prepped", "interpret", {"prep": prep}),
                    ("unfused", "unfused-interpret", {})):
                call = lambda *a, m=mode, k=kw: ops.classify_fused_v(
                    *a, C, mode=m, **k)
                launches = ops.count_pallas_launches(call, *args)
                fn = jax.jit(call)
                cost = parse_hlo_cost(fn.lower(*args).compile().as_text())
                us = _time(fn, *args, n=2 if smoke else 3)
                us_pkt = us / B
                t_s = us * 1e-6
                terms = roofline_terms(
                    hlo_flops=cost["matmul_flops"],
                    hlo_bytes=cost["traffic_bytes"],
                    collective_wire_bytes=0.0, chips=1, hw=hw)
                row = {
                    "mode": name, "V": V, "L": L, "B": B,
                    "launches": launches,
                    "us_per_batch": round(us, 1),
                    "us_per_packet": round(us_pkt, 4),
                    "hlo_flops": cost["matmul_flops"],
                    "hlo_bytes": cost["traffic_bytes"],
                    "achieved_gflops": cost["matmul_flops"] / t_s / 1e9,
                    "achieved_gbps": cost["traffic_bytes"] / t_s / 1e9,
                    "peak_gflops": hw.peak_flops / 1e9,
                    "peak_gbps": hw.hbm_gbps / 1e9,
                    "roofline_lb_us": terms["step_s_lower_bound"] * 1e6,
                    "dominant": terms["dominant"],
                    "config": f"B={B} T={T} E={E} F={F} P={P} levels={levels}",
                }
                json_rows.append(row)
                out.append(
                    f"classify,{name},{V},{L},{B},{launches},{us:.1f},"
                    f"{us_pkt:.3f},{cost['matmul_flops'] / 1e6:.1f},"
                    f"{cost['traffic_bytes'] / 1e6:.1f},"
                    f"{row['achieved_gflops']:.3f},{row['achieved_gbps']:.3f},"
                    f"{row['roofline_lb_us']:.2f},{terms['dominant']},"
                    f"{row['config']}")
                speedups.setdefault((L, V), {})[name] = us_pkt
    # before/after roofline table: what the fusion + quantized layouts buy
    out.append("classify_roofline,L,V,fused_us_pkt,unfused_us_pkt,speedup,"
               "fused_mbytes,unfused_mbytes,bytes_saved_pct")
    for (L, V), times in sorted(speedups.items()):
        f_row = next(r for r in json_rows
                     if r["mode"] == "fused" and r["L"] == L and r["V"] == V)
        u_row = next(r for r in json_rows
                     if r["mode"] == "unfused" and r["L"] == L and r["V"] == V)
        ratio = times["unfused"] / times["fused"]
        saved = 100.0 * (1 - f_row["hlo_bytes"] / max(u_row["hlo_bytes"], 1))
        out.append(
            f"classify_roofline,{L},{V},{times['fused']:.3f},"
            f"{times['unfused']:.3f},{ratio:.2f}x,"
            f"{f_row['hlo_bytes'] / 1e6:.1f},{u_row['hlo_bytes'] / 1e6:.1f},"
            f"{saved:.1f}")
    return out, json_rows

"""Kernel micro-bench: XLA-ref path wall time on CPU (us/call) + the
VMEM/MXU tiling parameters the Pallas versions claim on TPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, n=10):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[str]:
    rng = np.random.default_rng(0)
    out = ["kernels,name,us_per_call,config"]
    B, T, E, F = 2048, 8, 128, 46
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    cv = jnp.asarray(rng.integers(0, 64, (T, E)), jnp.uint32)
    cm = jnp.asarray(rng.integers(0, 64, (T, E)), jnp.uint32)
    fid = jnp.asarray(rng.integers(0, F, (T, E)), jnp.int32)
    flo = jnp.zeros((T, E), jnp.int32)
    fhi = jnp.full((T, E), 128, jnp.int32)
    bit = jnp.asarray(rng.integers(0, 2, (T, E)), jnp.uint32)
    valid = jnp.ones((T, E), bool)
    us = _time(lambda *a: ops.tcam_match(*a, mode="ref"),
               codes, feats, cv, cm, fid, flo, fhi, bit, valid, jnp.int32(3))
    out.append(f"kernels,tcam_match,{us:.1f},B={B} T={T} E={E} F={F} "
               f"(Pallas: block_b=256 E_pad=128 f-sel MXU matmul)")

    H, L = 10, 256
    lut = jnp.asarray(rng.integers(-50000, 50000, (H, F, L)), jnp.int32)
    bias = jnp.zeros((H,), jnp.int32)
    us = _time(lambda *a: ops.svm_lookup(*a, mode="ref"), feats, lut, bias)
    out.append(f"kernels,svm_lookup,{us:.1f},B={B} H={H} F={F} L={L} "
               f"(Pallas: chunk_f=8 one-hot MXU, int-exact accum)")

    P, C = 256, 25
    pc = jnp.asarray(np.sort(rng.choice(2**16, (T, P), replace=False)
                             .astype(np.uint32), axis=1))
    pl = jnp.asarray(rng.integers(0, C, (T, P)), jnp.int32)
    pv = jnp.ones((T, P), bool)
    w = jnp.ones((T,), jnp.float32)
    us = _time(lambda *a: ops.forest_predict_vote(*a, C, mode="ref"),
               codes, pc, pl, pv, w)
    out.append(f"kernels,forest_predict_vote,{us:.1f},B={B} T={T} P={P} C={C} "
               f"(Pallas: compare-reduce CAM, block_b=256)")

    Bq, Hq, Hkv, D, S = 8, 16, 8, 128, 4096
    q = jnp.asarray(rng.normal(size=(Bq, Hq, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(Bq, S, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(Bq, S, Hkv, D)), jnp.bfloat16)
    kvl = jnp.full((Bq,), S, jnp.int32)
    us = _time(lambda *a: ops.decode_attn(*a, mode="ref"), q, k, v, kvl)
    out.append(f"kernels,decode_attn,{us:.1f},B={Bq} Hq={Hq} Hkv={Hkv} S={S} "
               f"(Pallas: flash-decode, block_s=512, VMEM scratch accum)")

    out.extend(run_tree_walk(rng))
    return out


def run_tree_walk(rng) -> list[str]:
    """Fused single-launch tree walk vs the pre-fusion per-layer scan, and
    install-time prepped operands vs per-call prep.

    Reports, per (L, V): Pallas launch count per classify (counted in the
    traced jaxpr — 1 fused vs L layerwise), the count of table-shaped prep
    ops left in the trace (0 when the exec image is bound), and wall-clock /
    packets-per-sec for the *actual kernel paths* in interpret mode, where
    the per-launch overhead the fusion removes is real.  ``fused-prepped``
    binds operands built once by ``tiling.prep_tree_walk`` — the engine's
    install-time exec-image path — so its delta vs ``fused`` is the per-call
    prep cost that moved to install time.  (The XLA `mode="ref"` paths of the
    two walks are the identical scan computation on CPU, so timing them would
    report measurement noise as a delta; on TPU rerun with `mode="pallas"` /
    `"layerwise-pallas"` to time the compiled kernels.)
    """
    from repro.kernels import tiling

    out = ["tree_walk,name,L,V,launches,prep_ops,us_per_batch,pkts_per_sec,config"]
    B, T, E, F = 512, 8, 128, 46
    for L in (8, 16, 32):
        for V in (1, 4):
            codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
            feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
            vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
            cv = jnp.asarray(rng.integers(0, 64, (V, L, T, E)), jnp.uint32)
            cm = jnp.asarray(rng.integers(0, 64, (V, L, T, E)), jnp.uint32)
            fid = jnp.asarray(rng.integers(0, F, (V, L, T, E)), jnp.int32)
            flo = jnp.zeros((V, L, T, E), jnp.int32)
            fhi = jnp.full((V, L, T, E), 128, jnp.int32)
            bit = jnp.asarray(rng.integers(0, 2, (V, L, T, E)), jnp.uint32)
            valid = jnp.ones((V, L, T, E), bool)
            shift = jnp.arange(L, dtype=jnp.int32)
            args = (codes, feats, vid, cv, cm, fid, flo, fhi, bit, valid, shift)
            prep = jax.tree.map(  # install-time compile, outside the timed fn
                lambda x: x.block_until_ready(),
                tiling.prep_tree_walk(cv, cm, fid, flo, fhi, bit, valid,
                                      tiling.lane_pad(F)))
            for name, mode, kw in (
                    ("fused", "interpret", {}),
                    ("fused-prepped", "interpret", {"prep": prep}),
                    ("layerwise", "layerwise-interpret", {})):
                launches = ops.count_pallas_launches(
                    lambda *a, m=mode, k=kw: ops.tree_walk_v(*a, mode=m, **k),
                    *args)
                prep_ops = ops.count_operand_prep_ops(
                    lambda *a, m=mode, k=kw: ops.tree_walk_v(*a, mode=m, **k),
                    *args)
                fn = jax.jit(
                    lambda *a, m=mode, k=kw: ops.tree_walk_v(*a, mode=m, **k))
                us = _time(fn, *args, n=3)
                pps = B / (us * 1e-6)
                out.append(
                    f"tree_walk,{name},{L},{V},{launches},{prep_ops},{us:.1f},"
                    f"{pps:.0f},B={B} T={T} E={E} F={F} "
                    f"(interpret-mode kernel paths)")
    return out

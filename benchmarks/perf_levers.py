"""§Perf hillclimb record: baseline vs optimized roofline terms for the three
hillclimbed cells (reads the tagged dry-run JSONs; see EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

CELLS = [
    ("qwen3-moe-235b-a22b", "train_4k", [
        ("baseline(onehot-dispatch)", ""),
        ("sort-dispatch", "perf1"),
        ("sort+sharded-buffers", "perf2"),
        ("sharded+capacity1.0", "perf4"),
    ]),
    ("grok-1-314b", "train_4k", [
        ("baseline(onehot-dispatch)", ""),
        ("sort-dispatch", "perf1"),
        ("sort+sharded-buffers", "perf2"),
        ("sharded+flash-attn", "perf4"),
    ]),
    ("internlm2-20b", "decode_32k", [
        ("baseline(replicated-cache)", "perf0"),
        ("split-KV", "perf1"),
        ("split-KV+mxu-native", "perf2"),
    ]),
]


def _load(arch, shape, tag):
    suffix = f"__{tag}" if tag else ""
    p = os.path.join(RESULTS, f"{arch}__{shape}__1pod{suffix}.json")
    with open(p) as f:
        return json.load(f)


def run() -> list[str]:
    out = ["perf,cell,variant,compute_s,memory_s,collective_s,lower_bound_s,"
           "useful,speedup_vs_baseline"]
    for arch, shape, variants in CELLS:
        # Note: the decode baseline is the tagged pre-default record if the
        # untagged one was re-run with split-KV on.
        base_lb = None
        for label, tag in variants:
            try:
                r = _load(arch, shape, tag)
            except FileNotFoundError:
                out.append(f"perf,{arch}x{shape},{label},missing,,,,,")
                continue
            rl = r["roofline"]
            lb = rl["step_s_lower_bound"]
            if base_lb is None:
                base_lb = lb
            out.append(
                f"perf,{arch}x{shape},{label},{rl['compute_s']:.4g},"
                f"{rl['memory_s']:.4g},{rl['collective_s']:.4g},{lb:.4g},"
                f"{r['useful_flops_ratio']:.3f},{base_lb/lb:.2f}x")
    return out

"""§Roofline: read the dry-run JSON records into the per-cell table.

Single-pod (16x16 = 256 chips) per the brief; the 2-pod records prove the
pod axis shards (status column only)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(pods: int = 1, tag: str = "") -> list[dict]:
    recs = []
    suffix = f"__{tag}" if tag else ""
    for p in sorted(glob.glob(os.path.join(RESULTS, f"*__{pods}pod{suffix}.json"))):
        if not tag and "pod__" in os.path.basename(p):
            continue  # skip tagged (perf-iteration) records in the baseline table
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run() -> list[str]:
    out = ["roofline,arch,shape,status,compute_s,memory_s,collective_s,"
           "dominant,useful_ratio,bytes_per_dev_GB"]
    for r in load_records(1):
        if r["status"] != "ok":
            out.append(f"roofline,{r['arch']},{r['shape']},{r['status']},,,,,,"
                       + r.get("reason", r.get("error", ""))[:60])
            continue
        rl = r["roofline"]
        ur = r.get("useful_flops_ratio")
        out.append(
            f"roofline,{r['arch']},{r['shape']},ok,"
            f"{rl['compute_s']:.4g},{rl['memory_s']:.4g},"
            f"{rl['collective_s']:.4g},{rl['dominant']},"
            f"{ur:.3f},"
            f"{r['meta']['analytic_bytes_per_device']/1e9:.2f}")
    ok2 = sum(1 for r in load_records(2) if r["status"] == "ok")
    skip2 = sum(1 for r in load_records(2) if r["status"] == "skip")
    out.append(f"roofline,multi-pod,2x16x16,ok={ok2} skip={skip2},,,,,,")
    return out

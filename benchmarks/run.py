"""Benchmark harness: one module per paper table/figure. Prints CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--only table3,fig8,...]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    ("table3", "benchmarks.table3_features"),
    ("table45", "benchmarks.table45_accuracy"),
    ("fig67", "benchmarks.fig67_latency"),
    ("fig8", "benchmarks.fig8_planner"),
    ("fig9", "benchmarks.fig9_resources"),
    ("table78", "benchmarks.table78_usage"),
    ("roofline", "benchmarks.roofline_table"),
    ("perf", "benchmarks.perf_levers"),
    ("kernels", "benchmarks.kernels_bench"),
    ("zoo", "benchmarks.zoo_swap"),
    ("runtime_scale", "benchmarks.runtime_scale"),
    ("serve_async", "benchmarks.serve_async"),
    ("fleet_serve", "benchmarks.fleet_serve"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if only:
        valid = {name for name, _ in MODULES}
        unknown = only - valid
        if unknown:
            print(f"error: unknown --only module(s) {sorted(unknown)}; "
                  f"valid names: {sorted(valid)}", file=sys.stderr)
            sys.exit(2)
    failures = 0
    for name, mod in MODULES:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            lines = importlib.import_module(mod).run()
            for line in lines:
                print(line)
            print(f"# {name}: {len(lines)} rows in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# {name}: FAILED {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Runtime scaling: ``ShardedExecutor`` throughput vs port count.

Weak scaling along the data-parallel port axis: the per-port batch is fixed
at ``B_PORT`` and total traffic is ``B_PORT * P`` for ``P ∈ {1, 2, 4, 8}``
port lanes — the "many ingress ports feeding one line-rate switch" model.
Reported per row: total batch, best-of-``REPS`` wall time per classified
batch, packets/sec, and the throughput speedup vs the 1-port lane.

Acceptance pin (ISSUE 4): throughput scales ≥ 1.5x from 1 → 4 ports on an
8-device host.  The emulated devices share the host's cores, so the floor is
asserted only where 4 lanes can actually run in parallel
(``os.cpu_count() >= 4``); below that the rows still print, with a comment
naming the host's parallel ceiling (a 2-core box tops out around the
1->2-core speedup of a plain matmul, ~1.3x).  Override the floor with
``RUNTIME_SCALE_MIN_SPEEDUP``; ``RUNTIME_SCALE_SMOKE=1`` shrinks the batch,
drops to 2 timing reps, and skips the assertion — the CI smoke row.

The measurement needs 8 devices, so ``run()`` launches a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the benchmark
harness itself stays on 1 device, same rule as the test suite.

  PYTHONPATH=src python -m benchmarks.run --only runtime_scale
"""
from __future__ import annotations

import os
import subprocess
import sys

PORTS = (1, 2, 4, 8)
HEADER = "runtime_scale,ports,batch,ms_per_batch,kpps,speedup_vs_1port"


def run() -> list[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = [os.path.join(root, "src")]
    if env.get("PYTHONPATH"):
        path.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(path)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.runtime_scale", "--child"],
        capture_output=True, text=True, env=env, cwd=root, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(
            f"runtime_scale child failed:\n{r.stderr[-4000:]}")
    return [l for l in r.stdout.splitlines() if l.strip()]


def _child() -> list[str]:
    import time

    import jax
    import numpy as np

    from benchmarks.common import fit_workload
    from repro.core.packets import PacketBatch
    from repro.core.plane import PlaneProfile, SwitchEngine
    from repro.core.translator import translate
    from repro.runtime import DataplaneRuntime, ShardedExecutor

    smoke = os.environ.get("RUNTIME_SCALE_SMOKE") == "1"
    b_port = 512 if smoke else 2048
    reps = 2 if smoke else 5

    f = fit_workload("satdap", "dt", 36)
    prof = PlaneProfile(max_features=36, max_trees=4, max_layers=12,
                        max_entries_per_layer=128, max_leaves=128,
                        max_classes=8, max_hyperplanes=8)
    eng = SwitchEngine(prof)
    packed = eng.install(eng.empty(), translate(f.model))
    n_dev = len(jax.devices())

    out = [HEADER]
    speedups = {}
    base_kpps = None
    for P in PORTS:
        if P > n_dev:
            out.append(f"# runtime_scale: skipping P={P} ({n_dev} devices)")
            continue
        rt = DataplaneRuntime(ShardedExecutor(
            [packed], n_classes=prof.max_classes, n_ports=P, n_micro=1))
        B = b_port * P
        X = np.tile(f.Xte, (B // f.Xte.shape[0] + 1, 1))[:B]
        pb = PacketBatch.make_request(
            X, mid=0, max_features=36, n_trees=prof.max_trees,
            n_hyperplanes=prof.max_hyperplanes)
        res = rt.run(pb)
        res.rslt.block_until_ready()          # compile + warm
        assert (np.asarray(res.rslt) == f.model.predict(X)).all(), \
            "sharded answers must match the model"
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            rt.run(pb).rslt.block_until_ready()
            best = min(best, time.perf_counter() - t0)
        kpps = B / best / 1e3
        if base_kpps is None:
            base_kpps = kpps
        speedups[P] = kpps / base_kpps
        out.append(f"runtime_scale,{P},{B},{best*1e3:.2f},{kpps:.1f},"
                   f"{speedups[P]:.2f}")

    floor = float(os.environ.get("RUNTIME_SCALE_MIN_SPEEDUP", "1.5"))
    cores = os.cpu_count() or 1
    if smoke or 4 not in speedups:
        pass
    elif cores < 4:
        out.append(f"# runtime_scale: host has {cores} cores — 4 port lanes "
                   f"cannot run in parallel, speedup floor {floor} not "
                   f"asserted (measured 1->4: {speedups[4]:.2f}x)")
    elif speedups[4] < floor:
        raise AssertionError(
            f"1 -> 4 port throughput speedup {speedups[4]:.2f} < {floor} "
            "(set RUNTIME_SCALE_MIN_SPEEDUP to relax on constrained hosts)")
    return out


if __name__ == "__main__":
    if "--child" in sys.argv:
        # set before any jax import so the 8 emulated devices exist
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        lines = _child()
    else:
        lines = run()
    for line in lines:
        print(line)

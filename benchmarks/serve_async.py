"""Async serving: tail latency vs offered load per batching policy.

An open-loop Poisson client (arrivals never wait for responses — fixed
offered load, like wire traffic) drives the ``AsyncZooServer`` at several
multiples of the host's single-request dispatch rate, once per
``BatchingPolicy``.  Reported per row: offered and achieved request rate,
p50/p99 end-to-end latency, and the mean coalesced batch size.

The story the table tells: ``ImmediatePolicy`` (one request per dispatch)
holds the lowest p50 while offered load stays under its service rate, then
its queue — and p99 — blow up; ``SizeOrDeadlinePolicy`` and
``AdaptiveBucketPolicy`` amortize the dispatch across an admission bucket
and keep tail latency bounded through overload.  The ISSUE-5 acceptance pin
— size-or-deadline p99 < immediate p99 at the highest offered load — is
asserted here (skipped under ``SERVE_ASYNC_SMOKE=1``, the CI row, which
shrinks the request count and skips the assertion).

All admission buckets a policy can dispatch into are warmed before timing,
so rows measure serving, not first-touch compilation.

  PYTHONPATH=src python -m benchmarks.run --only serve_async
"""
from __future__ import annotations

import asyncio
import os
import time

HEADER = ("serve_async,policy,load_x,offered_rps,achieved_rps,requests,"
          "p50_ms,p99_ms,mean_batch")

LOADS = (0.25, 1.0, 4.0)      # multiples of the per-request dispatch rate
MAX_BATCH = 64
MAX_WAIT_US = 3_000.0
REQ_PKTS = 2                  # packets per client request


def _policies():
    from repro.runtime import (
        AdaptiveBucketPolicy,
        ImmediatePolicy,
        SizeOrDeadlinePolicy,
    )

    return {
        "immediate": lambda: ImmediatePolicy(),
        "size_or_deadline": lambda: SizeOrDeadlinePolicy(
            max_batch=MAX_BATCH, max_wait_us=MAX_WAIT_US),
        "adaptive_bucket": lambda: AdaptiveBucketPolicy(
            max_batch=MAX_BATCH, max_wait_us=MAX_WAIT_US),
    }


async def _trial(zoo, policy, X, *, rate_rps: float, n_requests: int,
                 rng) -> dict:
    from repro.serving import AsyncZooServer

    async with AsyncZooServer(zoo, policy=policy) as srv:
        loop = asyncio.get_running_loop()
        t0 = loop.time()
        arrivals = rng.exponential(1.0 / rate_rps, n_requests).cumsum()
        tasks = []
        for t_arr in arrivals:
            delay = t0 + t_arr - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            lo = int(rng.integers(0, X.shape[0] - REQ_PKTS))
            tasks.append(asyncio.create_task(
                srv.submit(X[lo:lo + REQ_PKTS], mid=0, vid=0)))
        await asyncio.gather(*tasks)
        span = loop.time() - t0
        stats = srv.latency_stats()
    stats["achieved_rps"] = n_requests / span
    return stats


def run() -> list[str]:
    import numpy as np

    from benchmarks.common import fit_workload
    from repro.core.plane import PlaneProfile
    from repro.core.translator import translate
    from repro.serving import ZooServer

    smoke = os.environ.get("SERVE_ASYNC_SMOKE") == "1"
    n_requests = 60 if smoke else 400

    f = fit_workload("satdap", "dt", 36)
    prof = PlaneProfile(max_features=36, max_trees=4, max_layers=12,
                        max_entries_per_layer=128, max_leaves=128,
                        max_classes=8, max_hyperplanes=8)
    zoo = ZooServer(prof)
    zoo.install(translate(f.model), vid=0)
    X = f.Xte

    # warm every bucket up to the largest a policy can cut, plus the oracle
    B = 1
    while B <= MAX_BATCH * 2:
        zoo.classify(X[:min(B, X.shape[0])], mid=0, vid=0)
        B *= 2

    # calibrate: best-of-5 single-request dispatch -> the baseline rate
    t1 = min(_timed(zoo, X) for _ in range(5))
    base_rps = 1.0 / t1

    out = [HEADER,
           f"# serve_async: single-request dispatch {t1 * 1e3:.2f} ms "
           f"({base_rps:.0f} req/s), {n_requests} requests/trial"]
    p99 = {}
    for name, mk_policy in _policies().items():
        for load_x in LOADS:
            stats = asyncio.run(_trial(
                zoo, mk_policy(), X, rate_rps=load_x * base_rps,
                n_requests=n_requests, rng=np.random.default_rng(17)))
            p99[(name, load_x)] = stats["p99_ms"]
            out.append(
                f"serve_async,{name},{load_x:g},{load_x * base_rps:.0f},"
                f"{stats['achieved_rps']:.0f},{stats['requests']},"
                f"{stats['p50_ms']:.2f},{stats['p99_ms']:.2f},"
                f"{stats['mean_batch_packets']:.1f}")

    top = max(LOADS)
    if smoke:
        out.append("# serve_async: SMOKE=1 — p99 ordering not asserted")
    elif not p99[("size_or_deadline", top)] < p99[("immediate", top)]:
        raise AssertionError(
            f"at {top}x load, size_or_deadline p99 "
            f"{p99[('size_or_deadline', top)]:.2f} ms must beat immediate "
            f"p99 {p99[('immediate', top)]:.2f} ms — coalescing failed to "
            "amortize dispatch under overload")
    return out


def _timed(zoo, X) -> float:
    t0 = time.perf_counter()
    zoo.classify(X[:1], mid=0, vid=0)
    return time.perf_counter() - t0


if __name__ == "__main__":
    for line in run():
        print(line)

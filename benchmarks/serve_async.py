"""Async serving saturation sweep: tail latency vs offered load, per engine
and batching policy.

The open-loop generator (``repro.serving.loadgen``) fixes every arrival up
front — Poisson (and burst clumps for the coalescing story) at multiples of
the host's single-request dispatch rate — and charges latency from the
*scheduled* arrival, so a saturated server cannot hide queueing delay
(coordinated omission).  Two engines run the same policies over the same
zoo:

* ``coalescing``  — ``AsyncZooServer`` (PR 5): cut, await the dispatch,
  demux, only then cut again.
* ``continuous``  — ``ContinuousZooServer``: cutter + slot pool; a new
  batch cuts while the previous result demuxes, and the warmed-bucket
  cache means no live dispatch pays first-touch compile.

Reported per row (and mirrored to ``BENCH_serve.json``, the serving
counterpart of ``BENCH_kernels.json``): offered and achieved request rate,
p50/p99/p99.9 end-to-end latency, and the mean coalesced batch size.

Pins (skipped under ``SERVE_BENCH_SMOKE=1``, the CI row, which shrinks the
request count):

* size-or-deadline p99 < immediate p99 at the highest load on the
  coalescing engine — the ISSUE-5 acceptance pin, kept verbatim;
* continuous p99 <= size-or-deadline coalescing p99 at the highest load —
  asserted only where the overlap is measurable (``os.cpu_count() >= 4``,
  the ``runtime_scale`` caveat: on a 2-vCPU runner slot overlap buys
  nothing because the executor calls serialize on the GIL-side cores);
  below that the comparison still prints as a comment row.  The margin is
  tunable via ``SERVE_BENCH_P99_MARGIN`` (default 1.0 = strictly no worse).

  PYTHONPATH=src python -m benchmarks.run --only serve_async
"""
from __future__ import annotations

import asyncio
import json
import os
import time

HEADER = ("serve_async,engine,policy,process,load_x,offered_rps,"
          "achieved_rps,requests,p50_ms,p99_ms,p999_ms,mean_batch")

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")

LOADS = (0.25, 1.0, 4.0)      # multiples of the per-request dispatch rate
MAX_BATCH = 64
MAX_WAIT_US = 3_000.0
REQ_PKTS = 2                  # packets per client request
N_SLOTS = 2                   # continuous engine's in-flight dispatch slots


def _policies():
    from repro.runtime import (
        AdaptiveBucketPolicy,
        ImmediatePolicy,
        SizeOrDeadlinePolicy,
    )

    return {
        "immediate": lambda: ImmediatePolicy(),
        "size_or_deadline": lambda: SizeOrDeadlinePolicy(
            max_batch=MAX_BATCH, max_wait_us=MAX_WAIT_US),
        "adaptive_bucket": lambda: AdaptiveBucketPolicy(
            max_batch=MAX_BATCH, max_wait_us=MAX_WAIT_US),
    }


def _engines():
    from repro.serving import AsyncZooServer, ContinuousZooServer

    return {
        "coalescing": lambda zoo, policy: AsyncZooServer(zoo, policy=policy),
        # warm=False: the sweep warms every bucket once up front (below), so
        # per-trial re-warming would only re-hit the executor's jit cache
        "continuous": lambda zoo, policy: ContinuousZooServer(
            zoo, policy=policy, n_slots=N_SLOTS, warm=False),
    }


async def _trial(mk_server, zoo, policy, X, *, rate_rps: float,
                 n_requests: int, process: str, seed: int) -> dict:
    from repro.serving import open_loop

    span = X.shape[0] - REQ_PKTS

    async with mk_server(zoo, policy) as srv:
        async def submit(i: int) -> None:
            lo = (i * 13) % span
            await srv.submit(X[lo:lo + REQ_PKTS], mid=0, vid=0)

        report = await open_loop(submit, rate_rps=rate_rps,
                                 n_requests=n_requests, process=process,
                                 seed=seed)
        stats = srv.latency_stats()
    row = report.row()
    row["mean_batch_packets"] = round(stats["mean_batch_packets"], 2)
    row["dispatches"] = stats["dispatches"]
    return row


def run() -> list[str]:
    from benchmarks.common import fit_workload
    from repro.core.plane import PlaneProfile
    from repro.core.translator import translate
    from repro.serving import ZooServer

    smoke = (os.environ.get("SERVE_BENCH_SMOKE") == "1"
             or os.environ.get("SERVE_ASYNC_SMOKE") == "1")
    n_requests = 60 if smoke else 400

    f = fit_workload("satdap", "dt", 36)
    prof = PlaneProfile(max_features=36, max_trees=4, max_layers=12,
                        max_entries_per_layer=128, max_leaves=128,
                        max_classes=8, max_hyperplanes=8)
    zoo = ZooServer(prof)
    zoo.install(translate(f.model), vid=0)
    X = f.Xte

    # warm every bucket up to the largest a policy can cut, plus the oracle
    B = 1
    while B <= MAX_BATCH * 2:
        zoo.classify(X[:min(B, X.shape[0])], mid=0, vid=0)
        B *= 2

    # calibrate: best-of-5 single-request dispatch -> the baseline rate
    t1 = min(_timed(zoo, X) for _ in range(5))
    base_rps = 1.0 / t1

    out = [HEADER,
           f"# serve_async: single-request dispatch {t1 * 1e3:.2f} ms "
           f"({base_rps:.0f} req/s), {n_requests} requests/trial, "
           f"continuous n_slots={N_SLOTS}"]
    json_rows: list[dict] = []
    p99: dict[tuple[str, str, float], float] = {}

    def trial(engine: str, policy: str, load_x: float,
              process: str = "poisson") -> None:
        row = asyncio.run(_trial(
            _engines()[engine], zoo, _policies()[policy](), X,
            rate_rps=load_x * base_rps, n_requests=n_requests,
            process=process, seed=17))
        row.update(engine=engine, policy=policy, process=process,
                   load_x=load_x)
        json_rows.append(row)
        p99[(engine, policy, load_x)] = row["p99_ms"]
        out.append(
            f"serve_async,{engine},{policy},{process},{load_x:g},"
            f"{row['offered_rps']:.0f},{row['achieved_rps']:.0f},"
            f"{row['requests']},{row['p50_ms']:.2f},{row['p99_ms']:.2f},"
            f"{row['p999_ms']:.2f},{row['mean_batch_packets']:.1f}")

    top = max(LOADS)
    for engine in _engines():
        for policy in _policies():
            for load_x in LOADS:
                trial(engine, policy, load_x)
        # the coalescing story is sharpest under clumped arrivals: one
        # burst row per engine at the top load
        trial(engine, "size_or_deadline", top, process="burst")

    with open(BENCH_JSON, "w") as fh:
        json.dump({"bench": "serve", "rows": json_rows}, fh, indent=1)
        fh.write("\n")
    out.append(f"# wrote {len(json_rows)} rows to BENCH_serve.json")

    if smoke:
        out.append("# serve_async: SMOKE=1 — p99 pins not asserted")
        return out

    # pin 1 (ISSUE 5, kept): coalescing beats per-request under overload
    if not p99[("coalescing", "size_or_deadline", top)] < \
            p99[("coalescing", "immediate", top)]:
        raise AssertionError(
            f"at {top}x load, size_or_deadline p99 "
            f"{p99[('coalescing', 'size_or_deadline', top)]:.2f} ms must "
            f"beat immediate p99 "
            f"{p99[('coalescing', 'immediate', top)]:.2f} ms — coalescing "
            "failed to amortize dispatch under overload")

    # pin 2 (ISSUE 10): the continuous engine's overlap must not lose to
    # the stop-and-wait coalescing loop at the top load — asserted only
    # where slot overlap is measurable (>= 4 cores), reported otherwise
    cont, coal = (p99[("continuous", "size_or_deadline", top)],
                  p99[("coalescing", "size_or_deadline", top)])
    margin = float(os.environ.get("SERVE_BENCH_P99_MARGIN", "1.0"))
    cores = os.cpu_count() or 1
    if cores < 4:
        out.append(
            f"# serve_async: continuous p99 {cont:.2f} ms vs coalescing "
            f"{coal:.2f} ms at {top}x — not asserted on {cores} core(s) "
            "(slot overlap needs >= 4)")
    elif cont > coal * margin:
        raise AssertionError(
            f"at {top}x load, continuous p99 {cont:.2f} ms must be <= "
            f"coalescing p99 {coal:.2f} ms * {margin:g} — the slot pool "
            "failed to overlap dispatch with demux")
    return out


def _timed(zoo, X) -> float:
    t0 = time.perf_counter()
    zoo.classify(X[:1], mid=0, vid=0)
    return time.perf_counter() - t0


if __name__ == "__main__":
    for line in run():
        print(line)

"""Paper Table 3: maximum supported features per model type per system.

ACORN's limits are *verified constructively*: a 46-feature DT/RF and a
46-feature SVM are translated and checked against the plane profile + a
Tofino-class DeviceModel; baselines' limits come from their representation
models (feasibility flips exactly at the published budgets)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fit_workload
from repro.core.baselines import (
    MAX_FEATURES,
    dinc_resources,
    leo_resources,
    switchtree_resources,
)
from repro.core.plane import PlaneProfile, install_program, empty_program
from repro.core.translator import translate


def run() -> list[str]:
    out = ["table3,system,model,max_features,verified"]
    # constructive ACORN check at 46 features
    f = fit_workload("nsl-kdd", "dt", 46)
    prog = translate(f.model)
    prof = PlaneProfile(max_features=60, max_trees=8, max_layers=32,
                        max_entries_per_layer=512, max_leaves=512)
    install_program(empty_program(prof), prog, prof)  # raises if it didn't fit
    fsvm = fit_workload("nsl-kdd", "svm", 46)
    prog_svm = translate(fsvm.model)
    install_program(empty_program(prof), prog_svm, prof)
    out.append("table3,acorn,dt,46,constructive(installed 46-feature DT)")
    out.append("table3,acorn-simulator,svm,46,constructive(native 46-feature SVM"
               " — the paper needed a simulator; no Tofino compiler bug here)")
    for sys_, lims in MAX_FEATURES.items():
        for mt, lim in lims.items():
            out.append(f"table3,{sys_},{mt},{lim if lim else 'N/A'},published")
    # baselines flip to infeasible right above their budgets
    assert not switchtree_resources(f.model).feasible
    assert not leo_resources(f.model).feasible
    assert not dinc_resources(f.model, entry_cap=1 << 20).feasible
    out.append("table3,baselines,dt,-,infeasible at 46 features (checked)")
    return out

"""Paper Tables 4/5: classification quality per system per workload.

Per system we train under that system's feature budget (Tables 3/4), apply
its representation constraints (DINC: decision-table cap -> shrink-to-fit =
the paper's observed underfitting), run the model through the ACORN plane
(in-network predictions) and report Acc / Macro-F1 / Cohen's kappa between
in-network and server-side predictions.

Synthetic datasets => absolute accuracies are proxies; the *orderings and
mechanisms* (more features -> better; DINC shrink -> worse; kappa == 1 for
trees) are the reproduced claims.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FEATURE_BUDGET, WORKLOADS, fit_workload
from repro.core.baselines import dinc_resources
from repro.core.mlmodels import DecisionTree, RandomForest, accuracy, cohen_kappa, macro_f1
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.core.translator import translate

PROF = PlaneProfile(max_features=60, max_trees=8, max_layers=16,
                    max_entries_per_layer=512, max_leaves=512,
                    max_classes=32, max_hyperplanes=12)


def _through_plane(model, f):
    prog = translate(model)
    eng = SwitchEngine(PROF)
    packed = eng.install(eng.empty(), prog)
    pb = PacketBatch.make_request(f.Xte, mid=prog.mid,
                                  max_features=PROF.max_features,
                                  n_trees=PROF.max_trees,
                                  n_hyperplanes=PROF.max_hyperplanes)
    return np.asarray(eng.classify(packed, pb).rslt)


def run(workloads=None) -> list[str]:
    out = ["table45,workload,system,acc,macro_f1,kappa,features"]
    for wid, ds, kind in WORKLOADS:
        if workloads and wid not in workloads:
            continue
        systems = (("acorn", 46),) if kind == "svm" else tuple(
            FEATURE_BUDGET.items())
        for sys_, nf in systems:
            if kind != "dt" and sys_ in ("switchtree", "leo"):
                continue  # Table 3: N/A
            try:
                f = fit_workload(ds, kind, nf)
            except Exception as e:  # pragma: no cover
                out.append(f"table45,{wid},{sys_},err,{e},,")
                continue
            model = f.model
            if sys_ == "dinc" and kind in ("dt", "rf"):
                # representation cap: shrink until Planter's table fits
                leaves = 128
                while leaves >= 4 and not dinc_resources(
                        model, entry_cap=1 << 20).feasible:
                    leaves //= 2
                    if kind == "dt":
                        model = DecisionTree(max_depth=12,
                                             max_leaf_nodes=leaves).fit(f.Xtr, f.ytr)
                    else:
                        model = RandomForest(n_estimators=3, max_depth=8,
                                             max_leaf_nodes=max(leaves // 2, 2)
                                             ).fit(f.Xtr, f.ytr)
            server_pred = model.predict(f.Xte)
            if sys_ == "acorn":
                net_pred = _through_plane(model, f)
            else:
                net_pred = server_pred  # baselines: representation-exact
            out.append(
                f"table45,{wid},{sys_},{accuracy(f.yte, net_pred):.3f},"
                f"{macro_f1(f.yte, net_pred):.3f},"
                f"{cohen_kappa(net_pred, server_pred):.3f},{f.Xtr.shape[1]}")
    return out

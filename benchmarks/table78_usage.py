"""Paper Tables 7/8: static resource usage % and pipeline-stage scaling.

Table 7 reports per-component usage against Tofino-1 capacities; we report
the analogous shares of our DeviceModel budget from the real translator
output.  Table 8: stages used vs feature count (the headline claim: stage
usage does NOT grow with features — fewer features force deeper trees)."""
from __future__ import annotations

from benchmarks.common import fit_workload
from repro.core.planner import DeviceModel
from repro.core.translator import translate

# Tofino-1-class budgets used for the % columns.
TOFINO_TCAM = 24 * 2048       # 24 TCAM blocks x 2k entries
TOFINO_SRAM = 48 * 4096
TOFINO_STAGES = 12


def run() -> list[str]:
    out = ["table7,component,tcam_pct,sram_pct,stages"]
    f = fit_workload("nsl-kdd", "dt", 46, max_leaf_nodes=256)
    prog = translate(f.model)
    specs = prog.stages()
    lay = [s for s in specs if any(t.kind == "dt_layer" for t in s.tables)]
    pred = [s for s in specs if any(t.kind == "dt_predict" for t in s.tables)]
    out.append(
        f"table7,dt_layer(x{len(lay)}),"
        f"{100*sum(s.tcam_entries for s in lay)/TOFINO_TCAM:.2f},"
        f"{100*sum(s.sram_entries for s in lay)/TOFINO_SRAM:.2f},{len(lay)}")
    out.append(
        f"table7,dt_predict,0.00,"
        f"{100*sum(s.sram_entries for s in pred)/TOFINO_SRAM:.2f},{len(pred)}")
    fs = fit_workload("nsl-kdd", "svm", 46)
    ps = translate(fs.model)
    out.append(
        f"table7,svm_mul+predict,0.00,"
        f"{100*ps.total_sram_entries()/TOFINO_SRAM:.2f},{ps.n_stages}")

    out.append("table8,dataset,features,stages")
    for ds in ("cicids-17", "digits", "nsl-kdd", "mnist"):
        for nf in (5, 15, 25, 45):
            f = fit_workload(ds, "dt", nf, max_leaf_nodes=128)
            prog = translate(f.model)
            out.append(f"table8,{ds},{f.Xtr.shape[1]},{prog.n_stages}")
    return out

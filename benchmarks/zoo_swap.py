"""Model-zoo scaling: install latency + classify throughput vs V (zoo size),
and the install-vs-classify cost split of the exec image.

For V ∈ {1, 2, 4, 8} version slots, measures

* ``install_ms``    — control-plane latency of writing one version slot
                      (translate excluded: entry-array update + the slot's
                      exec-image compile + transfer);
* ``swap_ms``       — same, overwriting an occupied slot (the hot-swap path);
* ``classify_us``   — per-packet classify time with the exec image bound
                      (zero per-call operand prep; XLA ref path on CPU),
                      batch of mixed-VID requests spread uniformly over all
                      resident versions — the **after** side of the split;
* ``percall_prep_us`` — per-packet cost of one full operand-prep pass (the
                      jitted source-tables -> exec-image compile, amortized
                      over the batch): the extra work a ``use_image=False``
                      engine re-traces into **every** classify on the kernel
                      path (pallas/interpret — the XLA ref oracle always
                      works from source tables), i.e. the **before** side.
                      before ≈ classify_us + percall_prep_us; after moves
                      that cost into ``install_ms`` (which includes the
                      slot's image compile).  Measured directly because on
                      the CPU interpreter the kernel-simulation cost drowns
                      the delta; on TPU the same bytes are HBM traffic ahead
                      of the fused launch;
* ``image_mib``     — resident exec-image size = the operand bytes a
                      prep-per-call classify re-materializes (and, on TPU,
                      re-streams through HBM) every launch;
* ``traces``        — engine trace count after all installs/swaps (must be 1:
                      the §6 compile-once property is independent of V).

  PYTHONPATH=src python -m benchmarks.run --only zoo
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import fit_workload
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile, SwitchEngine, build_exec_image
from repro.core.translator import translate


def _block(packed) -> None:
    for leaf in jax.tree.leaves(packed):
        leaf.block_until_ready()


def _time_classify(eng, packed, pb, B, reps=5) -> float:
    eng.classify(packed, pb).rslt.block_until_ready()   # warm the trace
    t0 = time.perf_counter()
    for _ in range(reps):
        eng.classify(packed, pb).rslt.block_until_ready()
    return (time.perf_counter() - t0) / reps / B * 1e6


def run() -> list[str]:
    out = ["zoo,V,install_ms,swap_ms,classify_us_per_pkt,"
           "percall_prep_us_per_pkt,image_mib,batch,traces"]
    f = fit_workload("satdap", "dt", 36)
    B = 2048
    X = np.tile(f.Xte, (B // f.Xte.shape[0] + 1, 1))[:B]
    rng = np.random.default_rng(0)

    for V in (1, 2, 4, 8):
        prof = PlaneProfile(max_features=36, max_trees=4, max_layers=12,
                            max_entries_per_layer=256, max_leaves=128,
                            max_classes=8, max_hyperplanes=8, max_versions=V)
        eng = SwitchEngine(prof)
        progs = [translate(f.model, vid=v) for v in range(V)]

        packed = eng.empty()
        _block(packed)
        t0 = time.perf_counter()
        for prog in progs:                      # fill every slot
            packed = eng.install(packed, prog)
        _block(packed)
        install_ms = (time.perf_counter() - t0) / V * 1e3

        t0 = time.perf_counter()
        for prog in progs:                      # overwrite every slot (swap)
            packed = eng.install(packed, prog)
        _block(packed)
        swap_ms = (time.perf_counter() - t0) / V * 1e3

        image_mib = sum(l.nbytes for l in jax.tree.leaves(packed.image)) / 2**20

        vids = rng.integers(0, V, B)
        pb = PacketBatch.make_request(
            X, mid=progs[0].mid, vid=vids, max_features=36,
            n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
            max_versions=V)
        classify_us = _time_classify(eng, packed, pb, B)

        # the before side: one full operand-prep pass over the source tables
        # — exactly what a use_image=False classify re-traces per call
        prep_pass = jax.jit(lambda pk: build_exec_image(pk, prof))
        _block(prep_pass(packed))               # warm the trace
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            _block(prep_pass(packed))
        percall_prep_us = (time.perf_counter() - t0) / reps / B * 1e6

        want = f.model.predict(X)
        got = np.asarray(eng.classify(packed, pb).rslt)
        assert (got == want).all(), "zoo answers must match the model"
        # image-vs-prep agreement must be checked on the *kernel* path (the
        # ref oracle ignores the image, so a ref-mode comparison would be
        # vacuous): interpret on CPU, pallas on TPU, small sub-batch.
        kmode = "pallas" if jax.default_backend() == "tpu" else "interpret"
        B_k = 256
        pb_k = PacketBatch.make_request(
            X[:B_k], mid=progs[0].mid, vid=vids[:B_k], max_features=36,
            n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
            max_versions=V)
        got_img = np.asarray(
            SwitchEngine(prof, mode=kmode).classify(packed, pb_k).rslt)
        got_prep = np.asarray(
            SwitchEngine(prof, mode=kmode, use_image=False)
            .classify(packed, pb_k).rslt)
        assert (got_img == want[:B_k]).all(), "image path must match the model"
        assert (got_prep == got_img).all(), "prep path must agree with the image"
        out.append(f"zoo,{V},{install_ms:.2f},{swap_ms:.2f},{classify_us:.2f},"
                   f"{percall_prep_us:.2f},{image_mib:.1f},{B},"
                   f"{eng.cache_size()}")
    return out


if __name__ == "__main__":
    for line in run():
        print(line)

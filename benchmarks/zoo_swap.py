"""Model-zoo scaling: install latency + classify throughput vs V (zoo size).

For V ∈ {1, 2, 4, 8} version slots, measures

* ``install_ms``   — control-plane latency of writing one version slot
                     (translate excluded: pure entry-array update + transfer);
* ``swap_ms``      — same, overwriting an occupied slot (the hot-swap path);
* ``classify_us``  — per-packet classify time, batch of mixed-VID requests
                     spread uniformly over all resident versions;
* ``traces``       — engine trace count after all installs/swaps (must be 1:
                     the §6 compile-once property is independent of V).

The classify column is the cost of the VID gather at each table lookup; on
the XLA-CPU ref path the per-packet table gather grows the working set, so
throughput vs V quantifies what the Pallas version-grid kernels avoid keeping
off VMEM.

  PYTHONPATH=src python -m benchmarks.run --only zoo
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fit_workload
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.core.translator import translate


def _block(packed) -> None:
    packed.dt_cv.block_until_ready()
    packed.svm_lut.block_until_ready()


def run() -> list[str]:
    out = ["zoo,V,install_ms,swap_ms,classify_us_per_pkt,batch,traces"]
    f = fit_workload("satdap", "dt", 36)
    B = 2048
    X = np.tile(f.Xte, (B // f.Xte.shape[0] + 1, 1))[:B]
    rng = np.random.default_rng(0)

    for V in (1, 2, 4, 8):
        prof = PlaneProfile(max_features=36, max_trees=4, max_layers=12,
                            max_entries_per_layer=256, max_leaves=128,
                            max_classes=8, max_hyperplanes=8, max_versions=V)
        eng = SwitchEngine(prof)
        progs = [translate(f.model, vid=v) for v in range(V)]

        packed = eng.empty()
        _block(packed)
        t0 = time.perf_counter()
        for prog in progs:                      # fill every slot
            packed = eng.install(packed, prog)
        _block(packed)
        install_ms = (time.perf_counter() - t0) / V * 1e3

        t0 = time.perf_counter()
        for prog in progs:                      # overwrite every slot (swap)
            packed = eng.install(packed, prog)
        _block(packed)
        swap_ms = (time.perf_counter() - t0) / V * 1e3

        vids = rng.integers(0, V, B)
        pb = PacketBatch.make_request(
            X, mid=progs[0].mid, vid=vids, max_features=36,
            n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
            max_versions=V)
        eng.classify(packed, pb).rslt.block_until_ready()   # warm the trace
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            eng.classify(packed, pb).rslt.block_until_ready()
        classify_us = (time.perf_counter() - t0) / reps / B * 1e6

        want = f.model.predict(X)
        got = np.asarray(eng.classify(packed, pb).rslt)
        assert (got == want).all(), "zoo answers must match the model"
        out.append(f"zoo,{V},{install_ms:.2f},{swap_ms:.2f},"
                   f"{classify_us:.2f},{B},{eng.cache_size()}")
    return out


if __name__ == "__main__":
    for line in run():
        print(line)

"""Scenario: a live request stream against the async serving front.

The batch examples hand the plane a ready-made batch; real ACORN ingress is
a *stream* — many clients, small ragged requests, Poisson arrivals.  This
example drives an ``AsyncZooServer`` with an open-loop Poisson client (the
arrival process never waits for responses — offered load is fixed, like
traffic hitting a switch port) and compares the pluggable batching policies:

* ``ImmediatePolicy``       — every request dispatches alone: lowest
  possible queueing delay at low load, collapses at high load;
* ``SizeOrDeadlinePolicy``  — coalesce up to 64 packets or 3 ms;
* ``AdaptiveBucketPolicy``  — the flush target widens to the next
  power-of-two admission bucket under sustained load and snaps back down
  when a deadline flush shows the load dropped.

Whatever the policy did to the stream, every response is bit-identical to a
synchronous classify of the same packets — coalescing and admission padding
are semantically invisible (the conformance harness pins this; here we
assert it on every single response).

    PYTHONPATH=src python examples/async_serving.py
"""
import asyncio

import numpy as np

from repro.core.mlmodels import DecisionTree, Quantizer
from repro.core.plane import PlaneProfile
from repro.data import load_dataset
from repro.runtime import (
    AdaptiveBucketPolicy,
    ImmediatePolicy,
    SizeOrDeadlinePolicy,
)
from repro.serving import AsyncZooServer, ZooServer

Xtr, ytr, Xte, yte = load_dataset("cicids-17", scale=0.04, max_train=4000)
q = Quantizer(8).fit(Xtr)
Xtrq, Xteq = q.transform(Xtr)[:, :36], q.transform(Xte)[:, :36]

prof = PlaneProfile(max_features=36, max_trees=4, max_layers=12,
                    max_entries_per_layer=256, max_leaves=256,
                    max_classes=8, max_hyperplanes=8, max_versions=2)
zoo = ZooServer(prof)
zoo.install(DecisionTree(max_depth=6, max_leaf_nodes=48).fit(Xtrq, ytr),
            vid=0, tag="ids-v1")
sync_all = zoo.classify(Xteq, mid=0, vid=0)     # the bit-identity oracle
# warm every admission bucket a policy can dispatch into, so the latency
# table below measures serving, not first-touch compilation
B = 1
while B <= 128:
    zoo.classify(Xteq[:B], mid=0, vid=0)
    B *= 2

N_REQUESTS = 300
MEAN_REQ_PKTS = 2


async def poisson_client(srv, rate_rps: float, rng: np.random.Generator):
    """Open-loop Poisson arrivals: fire-and-gather, never wait in between."""
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, N_REQUESTS))
    tasks, spans = [], []
    for t_arr in arrivals:
        delay = t0 + t_arr - loop.time()
        if delay > 0:
            await asyncio.sleep(delay)
        lo = int(rng.integers(0, Xteq.shape[0] - MEAN_REQ_PKTS))
        n = int(rng.integers(1, 2 * MEAN_REQ_PKTS))
        spans.append((lo, lo + n))
        tasks.append(asyncio.create_task(
            srv.submit(Xteq[lo:lo + n], mid=0, vid=0)))
    outs = await asyncio.gather(*tasks)
    # every response bit-identical to the synchronous classify of its span
    for (lo, hi), out in zip(spans, outs):
        assert (out.rslt == sync_all[lo:hi]).all(), \
            "async response diverged from synchronous classify"
    return outs


async def main():
    rng = np.random.default_rng(0)
    # calibrate offered load to this host: a single-request dispatch time
    import time
    for _ in range(3):
        t0 = time.perf_counter()
        zoo.classify(Xteq[:1], mid=0, vid=0)
        t1 = time.perf_counter() - t0
    rate = 2.0 / t1          # 2x what per-request dispatch can serve
    print(f"single-request dispatch ~{t1 * 1e3:.2f} ms "
          f"-> offered load {rate:.0f} req/s ({N_REQUESTS} requests)\n")
    print(f"{'policy':<18} {'p50 ms':>8} {'p99 ms':>8} {'mean batch':>11} "
          f"{'dispatches':>11}")
    policies = {
        "immediate": ImmediatePolicy(),
        "size-or-deadline": SizeOrDeadlinePolicy(max_batch=64,
                                                 max_wait_us=3_000),
        "adaptive-bucket": AdaptiveBucketPolicy(max_batch=128,
                                                max_wait_us=3_000),
    }
    for name, policy in policies.items():
        async with AsyncZooServer(zoo, policy=policy) as srv:
            await poisson_client(srv, rate, np.random.default_rng(42))
            stats = srv.latency_stats()
        print(f"{name:<18} {stats['p50_ms']:>8.2f} {stats['p99_ms']:>8.2f} "
              f"{stats['mean_batch_packets']:>11.1f} "
              f"{stats['dispatches']:>11d}")
    print("\nevery response checked bit-identical to synchronous classify; "
          f"plane traces: {zoo.cache_size()} (one per admission bucket)")


asyncio.run(main())

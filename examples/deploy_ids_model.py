"""Scenario: an IDS operator runs a random forest + an SVM side by side,
hot-swaps model versions at runtime, and survives a switch failure.

Demonstrates the paper's three pillars on one network:
  * runtime programmability — version swap = entry rewrite, zero recompile
    (engine trace count stays 1);
  * multi-model data plane — tree + SVM pipelines coexist (Fig. 5);
  * beyond-paper fault tolerance — replan around a dead switch.

    PYTHONPATH=src python examples/deploy_ids_model.py
"""
import numpy as np

from repro.core.distributed_plane import build_device_programs, run_sequential
from repro.core.mlmodels import LinearSVM, Quantizer, RandomForest, accuracy
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.core.planner import DeviceModel, plan_program, replan
from repro.core.topology import fat_tree
from repro.core.translator import translate
from repro.data import load_dataset

Xtr, ytr, Xte, yte = load_dataset("cicids-17", scale=0.04, max_train=4000)
q = Quantizer(8).fit(Xtr)
Xtrq, Xteq = q.transform(Xtr)[:, :36], q.transform(Xte)[:, :36]

prof = PlaneProfile(max_features=36, max_trees=8, max_layers=12,
                    max_entries_per_layer=256, max_leaves=256,
                    max_classes=8, max_hyperplanes=8, max_versions=4)
eng = SwitchEngine(prof)
state = eng.empty()

# v1 forest + an SVM tenant on the same plane
rf_v1 = RandomForest(n_estimators=4, max_depth=6, max_leaf_nodes=40,
                     random_state=1).fit(Xtrq, ytr)
svm = LinearSVM(epochs=150).fit(Xtrq, ytr)
state = eng.install(state, translate(rf_v1, vid=1))
state = eng.install(state, translate(svm, vid=1))

mk = lambda mid, vid: PacketBatch.make_request(
    Xteq, mid=mid, vid=vid, max_features=36, n_trees=8, n_hyperplanes=8,
    max_versions=prof.max_versions)
acc_rf = accuracy(yte, np.asarray(eng.classify(state, mk(1, 1)).rslt))
acc_svm = accuracy(yte, np.asarray(eng.classify(state, mk(2, 1)).rslt))
print(f"v1 forest acc={acc_rf:.3f} | svm tenant acc={acc_svm:.3f} "
      f"(one plane, two pipelines)")

# deploy a stronger v2 forest into its own zoo slot — no recompilation, and
# v1 stays resident: requests pick their version by VID
rf_v2 = RandomForest(n_estimators=8, max_depth=8, max_leaf_nodes=100,
                     random_state=2).fit(Xtrq, ytr)
state = eng.install(state, translate(rf_v2, vid=2))
acc_v2 = accuracy(yte, np.asarray(eng.classify(state, mk(1, 2)).rslt))
acc_v1_still = accuracy(yte, np.asarray(eng.classify(state, mk(1, 1)).rslt))
print(f"v2 forest acc={acc_v2:.3f} after runtime install "
      f"(v1 still serving: acc={acc_v1_still:.3f}); "
      f"engine traces = {eng.cache_size()} (no recompile)")

# distributed deployment + failure recovery
net = fat_tree(4)
h = net.hosts()
dev = DeviceModel(n_stages=10)
prog = translate(rf_v2, vid=2)
plan = plan_program(prog, net, h[0], h[-1], default_device=dev, solver="dp")
print(f"deployed across {plan.breakdown['devices_used']}")
dead = plan.breakdown["devices_used"][-1]
plan2 = replan(prog, net, h[0], h[-1], {dead}, default_device=dev, solver="dp")
print(f"switch {dead} died -> replanned onto {plan2.breakdown['devices_used']} "
      f"in {plan2.solve_time*1e3:.1f}ms")
_, dps = build_device_programs(prog, plan2, prof)
out = run_sequential(dps, mk(1, 2), n_classes=prof.max_classes)
assert (np.asarray(out.rslt) == rf_v2.predict(Xteq)).all()
print("post-failure answers identical — service uninterrupted.")

"""The paper's Figure 2 on a device mesh: a forest distributed across
"switches" (devices), packets hopping via collective-permute, GPipe-style
pipelining so every switch processes a different in-flight microbatch.

Needs >= 2 emulated devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/distributed_inference.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import time

import jax
import numpy as np

from repro.core.distributed_plane import PipelinedPlane, build_device_programs
from repro.core.mlmodels import Quantizer, RandomForest, accuracy
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile
from repro.core.planner import DeviceModel, plan_program
from repro.core.topology import fat_tree
from repro.core.translator import translate
from repro.data import load_dataset

print(f"devices: {len(jax.devices())}")
Xtr, ytr, Xte, yte = load_dataset("satdap", scale=0.3)
q = Quantizer(8).fit(Xtr)
Xtrq, Xteq = q.transform(Xtr), q.transform(Xte)
rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30).fit(Xtrq, ytr)
prog = translate(rf)

net = fat_tree(4)
h = net.hosts()
plan = plan_program(prog, net, h[0], h[-1],
                    default_device=DeviceModel(n_stages=4), solver="dp")
print(f"plan: {len(plan.device_stages())} switches on path {plan.path}")

prof = PlaneProfile(max_features=36, max_trees=4, max_layers=8,
                    max_entries_per_layer=64, max_leaves=64,
                    max_classes=8, max_hyperplanes=8)
devices, dps = build_device_programs(prog, plan, prof)
n_dev = min(len(dps), len(jax.devices()))
plane = PipelinedPlane(dps[:n_dev], n_classes=prof.max_classes)

n_micro, B = 8, 64
Xm = np.tile(Xteq, (4, 1))[: n_micro * B]
mbs = PacketBatch.make_request(Xm, mid=prog.mid, max_features=36, n_trees=4,
                               n_hyperplanes=8)
mbs = jax.tree.map(lambda x: x.reshape((n_micro, B) + x.shape[1:]), mbs)
out = plane.run(mbs)  # compile + run
t0 = time.perf_counter()
out = plane.run(mbs)
jax.block_until_ready(out.rslt)
dt = time.perf_counter() - t0
got = np.asarray(out.rslt)  # run() returns the flat [n_micro * B] batch
assert got.shape == (n_micro * B,)
assert (got == rf.predict(Xm)).all()
print(f"pipelined {n_micro}x{B} packets across {n_dev} 'switches' in "
      f"{dt*1e3:.1f} ms — answers match the forest exactly")

# runtime reprogram the whole distributed plane
rf2 = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30,
                   random_state=9).fit(Xtrq, ytr)
_, dps2 = build_device_programs(translate(rf2), plan, prof)
plane.swap_model(dps2[:n_dev])
out2 = plane.run(mbs)
assert (np.asarray(out2.rslt) == rf2.predict(Xm)).all()
print("hot-swapped the model on every switch — same compiled pipeline.")

"""The paper's Figure 2 on a device mesh, driven through the runtime layer:
a forest distributed across "switches" (devices), packets hopping via
collective-permute, GPipe-style pipelining — and then the same traffic
data-parallel across "port" lanes on a 2D (switch x port) mesh, the
"aggregate traffic from many ingress ports" model.

Needs >= 4 emulated devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/distributed_inference.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import time

import jax
import numpy as np

from repro.core.distributed_plane import build_device_programs
from repro.core.mlmodels import Quantizer, RandomForest, accuracy
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile
from repro.core.planner import DeviceModel, plan_program
from repro.core.topology import fat_tree
from repro.core.translator import translate
from repro.data import load_dataset
from repro.runtime import DataplaneRuntime, PipelinedExecutor, ShardedExecutor

print(f"devices: {len(jax.devices())}")
Xtr, ytr, Xte, yte = load_dataset("satdap", scale=0.3)
q = Quantizer(8).fit(Xtr)
Xtrq, Xteq = q.transform(Xtr), q.transform(Xte)
rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30).fit(Xtrq, ytr)
prog = translate(rf)

net = fat_tree(4)
h = net.hosts()
plan = plan_program(prog, net, h[0], h[-1],
                    default_device=DeviceModel(n_stages=4), solver="dp")
print(f"plan: {len(plan.device_stages())} switches on path {plan.path}")

prof = PlaneProfile(max_features=36, max_trees=4, max_layers=8,
                    max_entries_per_layer=64, max_leaves=64,
                    max_classes=8, max_hyperplanes=8)
devices, dps = build_device_programs(prog, plan, prof)
n_dev = min(len(dps), len(jax.devices()))

# ---- pipeline-parallel along the path: one executor behind the runtime ----
runtime = DataplaneRuntime(PipelinedExecutor(dps[:n_dev], n_micro=8,
                                             n_classes=prof.max_classes))
B = 509  # deliberately ragged: admission pads to the power-of-two bucket
Xm = np.tile(Xteq, (4, 1))[:B]
pb = PacketBatch.make_request(Xm, mid=prog.mid, max_features=36, n_trees=4,
                              n_hyperplanes=8)
out = runtime.run(pb)  # compile + run
t0 = time.perf_counter()
out = runtime.run(pb)
jax.block_until_ready(out.rslt)
dt = time.perf_counter() - t0
got = np.asarray(out.rslt)
assert got.shape == (B,)
assert (got == rf.predict(Xm)).all()
print(f"pipelined {B} ragged packets (bucket {runtime.bucket(B)}) across "
      f"{n_dev} 'switches' in {dt*1e3:.1f} ms — answers match the forest "
      "exactly")

# ---- runtime reprogram the whole distributed plane, same compiled runs ----
rf2 = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30,
                   random_state=9).fit(Xtrq, ytr)
_, dps2 = build_device_programs(translate(rf2), plan, prof)
runtime.swap(dps2[:n_dev])
out2 = runtime.run(pb)
assert (np.asarray(out2.rslt) == rf2.predict(Xm)).all()
print(f"hot-swapped the model on every switch — still "
      f"{runtime.cache_size()} compiled pipeline(s).")

# ---- data-parallel across port lanes: 2D (switch x port) mesh ------------
# One switch worth of tables replicated over every port lane; the packet
# batch itself is sharded — aggregate throughput scales with port count
# (benchmarks/runtime_scale.py measures the curve).
n_ports = len(jax.devices())
from repro.core.plane import SwitchEngine

eng = SwitchEngine(prof)
full = eng.install(eng.empty(), translate(rf2))
sharded = DataplaneRuntime(ShardedExecutor(
    [full], n_classes=prof.max_classes, n_ports=n_ports, n_micro=1))
out3 = sharded.run(pb)
assert (np.asarray(out3.rslt) == rf2.predict(Xm)).all()
ym = np.tile(yte, 4)[:B]
print(f"same {B} packets sharded over {n_ports} port lanes "
      f"(bucket {sharded.bucket(B)}) — bit-identical answers, "
      f"acc={accuracy(ym, np.asarray(out3.rslt)):.3f}")

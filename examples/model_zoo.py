"""Scenario: A/B rollout of a new model version with zero recompilation.

The paper's headline usability claim — "every model (re)deployment only
rewrites match-action entries" (§6) — extended along the Appendix A VID axis:
one ``SwitchEngine`` hosts a *model zoo*, and a rollout is nothing but the
request writer shifting a traffic fraction to a new VID.

  1. train v1, install at vid=0 — 100% of traffic on v1;
  2. train a stronger v2, install at vid=1 *while v1 keeps serving*;
  3. canary: shift 10% → 50% → 100% of requests to v2 by rewriting ``vid``
     in the requests (the plane is untouched);
  4. evict v1 — its slot empties, stragglers get RSLT=-1 (no match), and the
     engine never recompiled: one trace per admission bucket, nothing more.

``ZooServer`` serves through a ``DataplaneRuntime``: every classify is
admission-bucketed (ragged batch sizes pad into power-of-two buckets of
passthrough packets), so arbitrary traffic costs at most O(log B) compiles.

    PYTHONPATH=src python examples/model_zoo.py
"""
import numpy as np

from repro.core.mlmodels import DecisionTree, Quantizer, accuracy
from repro.core.plane import PlaneProfile
from repro.data import load_dataset
from repro.serving import ZooServer

Xtr, ytr, Xte, yte = load_dataset("cicids-17", scale=0.04, max_train=4000)
q = Quantizer(8).fit(Xtr)
Xtrq, Xteq = q.transform(Xtr)[:, :36], q.transform(Xte)[:, :36]

prof = PlaneProfile(max_features=36, max_trees=8, max_layers=12,
                    max_entries_per_layer=256, max_leaves=256,
                    max_classes=8, max_hyperplanes=8, max_versions=4)
zoo = ZooServer(prof)

# ---- 1. v1 in production ----
v1 = DecisionTree(max_depth=5, max_leaf_nodes=24).fit(Xtrq, ytr)
zoo.install(v1, vid=0, tag="ids-v1")
r, _ = zoo.classify_split(Xteq, mid=0, split={0: 1.0})
print(f"v1 serving 100%: acc={accuracy(yte, r):.3f}")

# ---- 2. v2 trained and installed alongside — v1 keeps serving ----
v2 = DecisionTree(max_depth=10, max_leaf_nodes=120).fit(Xtrq, ytr)
zoo.install(v2, vid=1, tag="ids-v2")

# ---- 3. canary rollout: rewrite vid in requests, nothing else ----
for frac in (0.1, 0.5, 1.0):
    split = {1: 1.0} if frac == 1.0 else {0: 1.0 - frac, 1: frac}
    r, vids = zoo.classify_split(Xteq, mid=0, split=split)
    cohorts = []
    for v in sorted(split):
        sel = vids == v
        cohorts.append(
            f"v{v+1} acc={accuracy(yte[sel], r[sel]):.3f} ({int(sel.sum())} pkts)"
        )
    print(f"canary {int(frac*100):3d}% on v2: " + " | ".join(cohorts))

# ---- 4. retire v1 ----
zoo.evict(vid=0, kind="tree")
straggler = zoo.classify(Xteq, mid=0, vid=0)
assert (straggler == -1).all(), "evicted slot must answer RSLT=-1"
final = zoo.classify(Xteq, mid=0, vid=1)
print(f"v1 evicted (stragglers get RSLT=-1) | v2 acc={accuracy(yte, final):.3f}")
print(f"engine traces across install/rollout/evict: {zoo.cache_size()} "
      f"(compile-once — §6)")
assert zoo.cache_size() == 1

# ---- 5. ragged traffic: admission bucketing, O(log B) compiles ----
# Real request streams don't arrive in one fixed batch size.  The runtime
# pads each batch into its power-of-two bucket of passthrough packets, so
# five ragged sizes share two new buckets here — and replays are free.
buckets = {zoo.runtime.bucket(Xteq.shape[0])}
for B in (1, 7, 63, 64, 65):
    r = zoo.classify(Xteq[:B], mid=0, vid=1)
    assert (r == final[:B]).all(), "padding must not change any answer"
    buckets.add(zoo.runtime.bucket(B))
print(f"ragged batches {{1,7,63,64,65}} + full {Xteq.shape[0]} -> "
      f"{len(buckets)} buckets {sorted(buckets)} = {zoo.cache_size()} traces")
assert zoo.cache_size() == len(buckets)

# device-out serving: keep results on device for runtime-stacked callers
dev = zoo.classify(Xteq, mid=0, vid=1, device_out=True)
assert (np.asarray(dev.rslt) == final).all()
print("device_out=True returns the on-device PacketBatch — no host "
      "round-trip per batch")

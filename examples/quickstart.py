"""Quickstart: the paper's headline workflow in ~40 lines.

Train a Python model -> submit it to ACORN -> it is translated, planned, and
deployed across a fat-tree network -> send inference request packets ->
answers match the server-side model exactly (Cohen's kappa = 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.distributed_plane import build_device_programs, run_sequential
from repro.core.mlmodels import DecisionTree, Quantizer, accuracy, cohen_kappa
from repro.core.netsim import acorn_serving_time
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile
from repro.core.planner import DeviceModel, plan_program
from repro.core.topology import fat_tree
from repro.core.translator import translate
from repro.data import load_dataset

# 1. An ML developer trains an ordinary Python model (46 features).
Xtr, ytr, Xte, yte = load_dataset("nsl-kdd", scale=0.03, max_train=5000)
q = Quantizer(8).fit(Xtr)
model = DecisionTree(max_depth=12, max_leaf_nodes=200).fit(q.transform(Xtr)[:, :46], ytr)
print(f"trained DT: {model.tree_.n_nodes} nodes, depth {model.tree_.max_depth}, "
      f"server-side acc {accuracy(yte, model.predict(q.transform(Xte)[:, :46])):.3f}")

# 2. ACORN translates it into match-action tables...
prog = translate(model)
print(f"translated: {prog.n_stages} stages, {prog.total_tcam_entries()} TCAM + "
      f"{prog.total_sram_entries()} SRAM entries")

# 3. ...plans an optimal deployment over the network (ILP / exact DP)...
net = fat_tree(4)
hosts = net.hosts()
plan = plan_program(prog, net, hosts[0], hosts[-1],
                    default_device=DeviceModel(n_stages=8), solver="dp")
print(f"plan: path={plan.path}")
print(f"      devices={plan.breakdown['devices_used']}, "
      f"J_L={acorn_serving_time(plan)*1e6:.1f}us, solved in {plan.solve_time*1e3:.1f}ms")

# 4. ...and installs entries on each switch (runtime-programmable plane).
profile = PlaneProfile(max_features=46, max_trees=1, max_layers=16,
                       max_entries_per_layer=512, max_leaves=256)
devices, device_programs = build_device_programs(prog, plan, profile)

# 5. Clients send ACORN request packets; the network classifies in-path.
Xteq = q.transform(Xte)[:, :46]
packets = PacketBatch.make_request(Xteq, mid=prog.mid, max_features=46)
out = run_sequential(device_programs, packets, n_classes=profile.max_classes)
in_network = np.asarray(out.rslt)
server_side = model.predict(Xteq)
print(f"in-network acc {accuracy(yte, in_network):.3f}, "
      f"kappa(in-network, server) = {cohen_kappa(in_network, server_side):.3f}")
assert cohen_kappa(in_network, server_side) == 1.0
print("OK: the network computes exactly the trained model.")

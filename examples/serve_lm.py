"""LM serving driver: prefill a batch of prompts, then batched greedy decode
with the KV-cache/recurrent-state engine — fixed shapes, so tenant/model
swaps never retrace (same discipline as the ACORN plane).

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import decode_step, forward, init_decode_state, init_params
from repro.serving.serve import greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = init_params(cfg, jax.random.key(0))
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab)

    # prefill: run the prompt through decode steps to warm the cache
    state = init_decode_state(cfg, B, P + args.gen)
    if cfg.family == "encdec":
        from repro.models.transformer import encode_kv
        enc = jax.random.normal(jax.random.key(2), (B, cfg.enc_seq, cfg.d_model),
                                cfg.jdtype)
        state["ek"], state["ev"] = encode_kv(params, enc, cfg)
    step = jax.jit(lambda p, s, t, pos: decode_step(p, s, t, pos, cfg))
    t0 = time.perf_counter()
    logits = None
    for t in range(P):
        logits, state = step(params, state, prompts[:, t:t + 1], jnp.int32(t))
    print(f"prefill {B}x{P} in {(time.perf_counter()-t0)*1e3:.0f} ms")

    first = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(prompts.dtype)
    t0 = time.perf_counter()
    toks = greedy_decode(params, state, first, jnp.int32(P), cfg, args.gen)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"decoded {B}x{args.gen} tokens in {dt*1e3:.0f} ms "
          f"({B*args.gen/dt:.0f} tok/s on CPU; serving batch stays fixed-shape)")
    print("sample continuation ids:", np.asarray(toks[0, :12]))

    # weight hot-swap: same compiled decode, new model version
    params2 = init_params(cfg, jax.random.key(7))
    logits2, _ = step(params2, state, prompts[:, :1], jnp.int32(P))
    print("weight swap OK — no retrace "
          f"(cache size {step._cache_size()})")


if __name__ == "__main__":
    main()

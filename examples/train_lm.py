"""End-to-end LM training driver: a ~small config for a few hundred steps on
CPU with checkpoint/restart mid-run (the framework's (b) deliverable).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch internlm2-1.8b]

The arch's *smoke* config is used on CPU; the full config is exercised by the
multi-pod dry-run (src/repro/launch/dryrun.py).
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data import TokenPipeline
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.checkpoint import Checkpointer
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--n-micro", type=int, default=2)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).scaled(d_model=128, d_ff=256, n_layers=4 if
                                         smoke_config(args.arch).family != "hybrid" else 6)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params, ocfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)
    step_fn = jax.jit(make_train_step(cfg, ocfg, n_micro=args.n_micro))
    ckdir = tempfile.mkdtemp(prefix="acorn_lm_ck_")
    ck = Checkpointer(ckdir, keep=2)

    def batch():
        b = pipe.next_batch()
        return {
            "tokens": jnp.asarray(b["tokens"]).reshape(args.n_micro, -1, args.seq),
            "labels": jnp.asarray(b["labels"]).reshape(args.n_micro, -1, args.seq),
        }

    t0 = time.time()
    first = last = None
    for s in range(1, args.steps // 2 + 1):
        params, opt, m = step_fn(params, opt, batch())
        if s == 1:
            first = float(m["loss"])
        if s % 50 == 0:
            print(f"step {s:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0)/s*1e3:.0f} ms/step)")
    ck.save(args.steps // 2, params, opt, extra={"data": pipe.state_dict()})
    ck.wait()
    print(f"--- simulated preemption at step {args.steps // 2}; restarting from "
          f"{ckdir} ---")

    # restart path: fresh process state, restore everything
    params2 = init_params(cfg, jax.random.key(0))
    opt2 = adamw_init(params2, ocfg)
    s0, params2, opt2, extra = ck.restore(params2, opt2)
    pipe2 = TokenPipeline(vocab_size=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    pipe2.load_state_dict(extra["data"])
    pipe = pipe2
    for s in range(s0 + 1, args.steps + 1):
        params2, opt2, m = step_fn(params2, opt2, batch())
        if s % 50 == 0:
            print(f"step {s:4d} loss {float(m['loss']):.4f}")
        last = float(m["loss"])
    print(f"loss {first:.4f} -> {last:.4f} across a restart "
          f"({'OK' if last < first else 'NOT DECREASING'})")


if __name__ == "__main__":
    main()

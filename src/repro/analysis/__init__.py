"""Analysis tools: the roofline model and the planelint contract checker.

Roofline re-exports are lazy (PEP 562): ``repro.analysis.roofline`` imports
jax, and the planelint CLI (``python -m repro.analysis.lint``) must stay
importable in a bare CI environment with no accelerator runtime.
"""
_ROOFLINE = ("HW", "collective_bytes_from_hlo", "roofline_terms",
             "model_flops")

__all__ = list(_ROOFLINE)


def __getattr__(name):
    if name in _ROOFLINE:
        from repro.analysis import roofline

        return getattr(roofline, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

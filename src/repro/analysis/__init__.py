from repro.analysis.roofline import (
    HW,
    collective_bytes_from_hlo,
    roofline_terms,
    model_flops,
)

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms", "model_flops"]

"""Deterministic cost model parsed from optimized HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend proved unreliable for
SPMD modules (flops shrink while dot count grows — see EXPERIMENTS.md
§Dry-run notes), so the roofline uses our own parser:

* ``matmul_flops`` — for every ``dot`` op: 2 * prod(result dims) *
  prod(lhs contracting dims).  Batch dims are already in the result.
  (Elementwise flops are ignored: <2% for these models, documented.)
* ``traffic_bytes`` — HBM traffic model: for every *top-level* instruction in
  ENTRY and while-body computations, result bytes + operand bytes, skipping
  ops that do not touch HBM (parameter/constant/tuple plumbing/bitcast).
  Fusion internals are excluded — a fusion's operands/results are exactly
  its HBM traffic.
* ``collective_bytes`` — same per-op accounting as
  ``roofline.collective_bytes_from_hlo`` (kept there).

On unrolled probe modules (no ``while``) both measures are exact; the
dry-run's scan-correction probes rely on that.
"""
from __future__ import annotations

import re

__all__ = ["parse_hlo_cost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|[\w\[\],{}\s/]+?)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[\d,]*\})?))")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-done",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "opt-barrier", "custom-call",
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int] | None:
    """Dims of a non-tuple shape string like 'f32[2,3,4]{...}'."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d] or [1]


def _split_computations(text: str) -> list[tuple[str, list[str]]]:
    comps: list[tuple[str, list[str]]] = []
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        if line and not line[0].isspace() and "{" in line and ("(" in line):
            if cur_name is not None:
                comps.append((cur_name, cur_lines))
            cur_name, cur_lines = line, []
        elif cur_name is not None:
            if line.startswith("}"):
                comps.append((cur_name, cur_lines))
                cur_name, cur_lines = None, []
            else:
                cur_lines.append(line)
    if cur_name is not None:
        comps.append((cur_name, cur_lines))
    return comps


_TRANSPARENT_OPS = {
    "convert", "copy", "bitcast", "transpose", "reshape", "parameter",
    "tuple", "get-tuple-element", "broadcast", "constant", "slice", "bitcast-convert",
}
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")


def _dus_root_fusions(comps) -> set[str]:
    """Fused computations whose ROOT is a dynamic-update-slice: XLA updates
    these in place (donated KV caches), so the aliased full-size read+write
    must be discounted — only the slice actually moves."""
    out = set()
    for header, lines in comps:
        name_m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)", header)
        if not name_m:
            continue
        for line in lines:
            if line.strip().startswith("ROOT"):
                dm = _DEF_RE.match(line)
                if dm and dm.group(3) == "dynamic-update-slice":
                    out.add(name_m.group(1))
    return out


def _transparent_fusions(comps) -> set[str]:
    """Fused computations that only move/convert data.  The CPU backend has
    no native bf16 matmul, so it wraps every dot in bf16<->f32 convert
    fusions; on the TPU target these do not exist, so they are excluded from
    the HBM-traffic model."""
    out = set()
    for header, lines in comps:
        name_m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)", header)
        if not name_m:
            continue
        ops = set()
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                ops.add(dm.group(3))
        if ops and ops <= _TRANSPARENT_OPS:
            out.add(name_m.group(1))
    return out


def parse_hlo_cost(text: str) -> dict:
    flops = 0.0
    traffic = 0.0
    # which computations are while bodies/conditions (traffic counted once)
    while_calls = set(re.findall(r"while\(.*?\)[^\n]*?body=%([\w.\-]+)", text))
    while_conds = set(re.findall(r"condition=%([\w.\-]+)", text))

    comps = _split_computations(text)
    transparent = _transparent_fusions(comps)
    dus_fusions = _dus_root_fusions(comps)
    for header, lines in comps:
        name_m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)", header)
        cname = name_m.group(1) if name_m else ""
        is_entry = header.startswith("ENTRY")
        count_traffic = is_entry or cname in while_calls or cname in while_conds

        symtab: dict[str, str] = {}
        par = header[header.find("(") + 1:]
        for pm in _PARAM_RE.finditer(par):
            symtab[pm.group(1)] = pm.group(2)
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                symtab[dm.group(1)] = dm.group(2).strip()

        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rname, rshape, op = dm.group(1), dm.group(2).strip(), dm.group(3)
            if op == "dot":
                args = line[line.find("dot(") + 4:]
                args = args[: args.find(")")]
                ops = _OPERAND_RE.findall(args)
                cd = _CDIMS_RE.search(line)
                rdims = _shape_dims(rshape)
                if ops and cd and rdims is not None:
                    lhs_shape = symtab.get(ops[0])
                    ldims = _shape_dims(lhs_shape) if lhs_shape else None
                    if ldims:
                        k = 1
                        for ci in cd.group(1).split(","):
                            if ci:
                                idx = int(ci)
                                if idx < len(ldims):
                                    k *= ldims[idx]
                        r = 1
                        for d in rdims:
                            r *= d
                        flops += 2.0 * r * k
            if count_traffic and op not in _SKIP_TRAFFIC and op != "while":
                in_place = op == "dynamic-update-slice"
                if op == "fusion":
                    cm = _CALLS_RE.search(line)
                    if cm and cm.group(1) in transparent:
                        continue  # CPU-backend convert/copy artifact
                    if cm and cm.group(1) in dus_fusions:
                        in_place = True
                result_b = _shape_bytes(rshape)
                bts = result_b
                paren = line.find(op + "(")
                operand_bs = []
                if paren >= 0:
                    args = line[paren + len(op) + 1:]
                    depth = 1
                    end = 0
                    for i, ch in enumerate(args):
                        if ch == "(":
                            depth += 1
                        elif ch == ")":
                            depth -= 1
                            if depth == 0:
                                end = i
                                break
                    for oname in _OPERAND_RE.findall(args[:end]):
                        oshape = symtab.get(oname)
                        if oshape:
                            operand_bs.append(_shape_bytes(oshape))
                bts += sum(operand_bs)
                if in_place and operand_bs:
                    # discount the aliased full-size read+write: keep only
                    # the updated slice (the remaining small operands) moving
                    big = max(operand_bs)
                    if big >= result_b // 2:
                        bts -= result_b + big
                traffic += max(bts, 0)
    return {"matmul_flops": flops, "traffic_bytes": traffic}

"""planelint — AST contract checker for the ARCHITECTURE invariants.

Run it as a module::

    PYTHONPATH=src python -m repro.analysis.lint [--rule PL001 ...]
                                                 [--format text|json|github]
                                                 [--cache [PATH]]
                                                 [--changed-only [BASE]]
                                                 [paths]

or call :func:`run_lint` (stable two-value API) / :func:`lint_project` (the
whole-project engine: incremental cache, git changed-only mode, parse
accounting) directly.  Rules are pluggable — per-file rules implement
``core.Rule``; cross-file rules implement ``project.ProjectRule`` against
the ``ProjectContext`` module/import graph.  The shipped set is documented
in ``repro.analysis.lint.rules`` and in ``docs/ARCHITECTURE.md`` ("Static
contracts").  Per-line suppression: ``planelint: disable=PL002``
(comma-separate ids; ``disable=all``) — PL008 reports pragmas that
suppress nothing.
"""
from repro.analysis.lint.core import (
    REGISTRY,
    FileContext,
    Finding,
    Rule,
    all_rules,
    iter_files,
    register,
    resolve_rules,
    run_lint,
)
from repro.analysis.lint.project import (
    LintRun,
    ModuleSummary,
    ProjectContext,
    ProjectRule,
    lint_project,
)

__all__ = [
    "REGISTRY",
    "FileContext",
    "Finding",
    "Rule",
    "all_rules",
    "iter_files",
    "register",
    "resolve_rules",
    "run_lint",
    "LintRun",
    "ModuleSummary",
    "ProjectContext",
    "ProjectRule",
    "lint_project",
]

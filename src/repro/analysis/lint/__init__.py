"""planelint — AST contract checker for the ARCHITECTURE invariants.

Run it as a module::

    PYTHONPATH=src python -m repro.analysis.lint [--rule PL001 ...]
                                                 [--format text|json] [paths]

or call :func:`run_lint` directly.  Rules are pluggable (see
``repro.analysis.lint.core.Rule`` and ``@register``); the shipped set is
documented in ``repro.analysis.lint.rules`` and in ``docs/ARCHITECTURE.md``
("Static contracts").  Per-line suppression:
``# planelint: disable=PL002`` (comma-separate ids; ``disable=all``).
"""
from repro.analysis.lint.core import (
    REGISTRY,
    FileContext,
    Finding,
    Rule,
    all_rules,
    iter_files,
    register,
    resolve_rules,
    run_lint,
)

__all__ = [
    "REGISTRY",
    "FileContext",
    "Finding",
    "Rule",
    "all_rules",
    "iter_files",
    "register",
    "resolve_rules",
    "run_lint",
]

"""planelint CLI: ``python -m repro.analysis.lint``.

Exit codes: 0 clean, 1 findings, 2 usage/IO error (argparse convention).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint.core import all_rules, run_lint


def _default_path() -> Path:
    # .../src/repro/analysis/lint/__main__.py -> .../src/repro
    return Path(__file__).resolve().parents[2]


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Statically check the ARCHITECTURE contracts "
                    "(shard_map containment, hot-path numpy glue, VMEM "
                    "budgets, async-safety, retrace hazards).")
    parser.add_argument(
        "paths", nargs="*", type=Path,
        help="files or directories to lint (default: the repro package)")
    parser.add_argument(
        "--rule", action="append", metavar="ID",
        help="run only this rule (id or name; repeatable)")
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format (default: text)")
    parser.add_argument(
        "--no-pragmas", action="store_true",
        help="ignore '# planelint: disable=...' suppressions")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the registered rules and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id}  {rule.name:24s} {rule.description}")
        return 0

    paths = args.paths or [_default_path()]
    try:
        findings, checked = run_lint(
            paths, args.rule, respect_pragmas=not args.no_pragmas)
    except (ValueError, FileNotFoundError) as e:
        print(f"planelint: error: {e}", file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps({
            "version": 1,
            "rules": [r.id for r in all_rules()],
            "files_checked": checked,
            "findings": [f.to_json() for f in findings],
        }, indent=2))
    else:
        for f in findings:
            print(f.format())
        print(f"planelint: {checked} file(s) checked, "
              f"{len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""planelint CLI: ``python -m repro.analysis.lint``.

Exit codes: 0 clean, 1 findings, 2 usage/IO error (argparse convention).

Incremental CI shape: PR jobs restore the cache and run
``--cache .planelint-cache.json --changed-only origin/<base> --format
github`` (annotations on the diff, only the changed files' reverse-import
closure re-parses); main runs the full tree with ``--format json``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint.core import all_rules
from repro.analysis.lint.project import lint_project


def _default_path() -> Path:
    # .../src/repro/analysis/lint/__main__.py -> .../src/repro
    return Path(__file__).resolve().parents[2]


def _github_escape(s: str) -> str:
    return s.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Statically check the ARCHITECTURE contracts "
                    "(shard_map containment, hot-path numpy glue, VMEM "
                    "budgets, async-safety, retrace hazards, kernel "
                    "oracle-parity, concretization hazards, pragma "
                    "hygiene).")
    parser.add_argument(
        "paths", nargs="*", type=Path,
        help="files or directories to lint (default: the repro package)")
    parser.add_argument(
        "--rule", action="append", metavar="ID",
        help="run only this rule (id or name; repeatable)")
    parser.add_argument(
        "--format", choices=("text", "json", "github"), default="text",
        help="output format (default: text; 'github' emits workflow "
             "::error annotations)")
    parser.add_argument(
        "--cache", nargs="?", const=".planelint-cache.json", default=None,
        metavar="PATH",
        help="incremental cache file keyed by file-content hash: only "
             "changed files + their reverse-import closure re-lint "
             "(default path when given bare: .planelint-cache.json)")
    parser.add_argument(
        "--changed-only", nargs="?", const="HEAD", default=None,
        metavar="BASE",
        help="report per-file findings only for files changed vs this git "
             "ref (worktree + untracked included) and their reverse-import "
             "closure; cross-file rules still cover the whole tree "
             "(default ref when given bare: HEAD)")
    parser.add_argument(
        "--no-pragmas", action="store_true",
        help="ignore '# planelint: disable=...' suppressions")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the registered rules and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id}  {rule.name:24s} {rule.description}")
        return 0

    paths = args.paths or [_default_path()]
    try:
        run = lint_project(
            paths, args.rule, respect_pragmas=not args.no_pragmas,
            cache_path=args.cache, changed_only=args.changed_only)
    except (ValueError, FileNotFoundError) as e:
        print(f"planelint: error: {e}", file=sys.stderr)
        return 2

    findings = run.findings
    if args.format == "json":
        print(json.dumps({
            "version": 1,
            "rules": [r.id for r in all_rules()],
            "files_checked": run.checked,
            "files_parsed": len(run.parsed),
            "files_cached": run.cached,
            "changed_only": args.changed_only,
            "findings": [f.to_json() for f in findings],
        }, indent=2))
    elif args.format == "github":
        for f in findings:
            print(f"::error file={f.path},line={f.line},col={f.col + 1},"
                  f"title=planelint {f.rule} [{f.name}]::"
                  f"{_github_escape(f.message)}")
        print(f"planelint: {run.checked} file(s) checked, "
              f"{len(findings)} finding(s)")
    else:
        for f in findings:
            print(f.format())
        print(f"planelint: {run.checked} file(s) checked, "
              f"{len(findings)} finding(s)")
        if args.cache is not None:
            print(f"planelint: {len(run.parsed)} file(s) parsed, "
                  f"{run.cached} served from cache")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

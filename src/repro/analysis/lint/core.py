"""planelint core: findings, file contexts, the rule registry, the runner.

ACORN front-loads deployment correctness: the translator/planner validate a
model against the hardware *before* anything reaches the data plane (paper
§5).  This package does the same for the reproduction's own architectural
contracts — the prose invariants in ``docs/ARCHITECTURE.md`` ("Static
contracts") become AST-checked rules with stable IDs that run in CI and fail
with ``path:line`` diagnostics instead of regressing silently.

Pieces:

* ``Finding``      — one diagnostic: ``path:line:col: PLxxx [name] message``.
* ``FileContext``  — a parsed file handed to every rule: source, AST,
  parent links, module path (relative to the ``repro`` package when the file
  lives inside one, else to the lint root), and the per-line
  ``# planelint: disable=<rule>[,<rule>...]`` pragma table.
* ``Rule``         — the plug-in protocol: ``id``/``name``/``description``
  attributes plus ``check(ctx) -> Iterable[Finding]``.  Concrete rules live
  in ``repro.analysis.lint.rules`` and self-register via ``@register``.
* ``run_lint``     — walk files, run rules, apply pragmas, return sorted
  findings.

The linter is deliberately dependency-free (pure ``ast``): it must run in a
bare CI step, and importing the modules it checks would defeat the point of
a *static* gate.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, Protocol, Sequence, runtime_checkable

__all__ = [
    "Finding",
    "FileContext",
    "Rule",
    "REGISTRY",
    "register",
    "all_rules",
    "resolve_rules",
    "iter_files",
    "run_lint",
]

# ``planelint: disable=PL001`` or ``disable=PL001,PL004`` (same line as the
# finding; ``disable=all`` mutes every rule on that line).  Trailing prose
# after the id list is fine — the id charset ends the match.
_PRAGMA = re.compile(
    r"#\s*planelint:\s*disable=([A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic, ordered for stable output: (path, line, col, rule)."""

    path: str
    line: int
    col: int
    rule: str      # stable id, e.g. "PL001"
    name: str      # slug, e.g. "shard-map-containment"
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"[{self.name}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """One parsed source file, shared by every rule that checks it."""

    def __init__(self, path: Path, display: str, modpath: str) -> None:
        self.path = path
        self.display = display          # the path findings report
        self.modpath = modpath          # package-relative, "/"-separated
        self.text = path.read_text()
        self.tree = ast.parse(self.text)   # SyntaxError propagates to runner
        self._parents: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self.disabled: dict[int, set[str]] = {}
        for lineno, line in enumerate(self.text.splitlines(), start=1):
            m = _PRAGMA.search(line)
            if m:
                self.disabled[lineno] = {
                    r.strip().upper() for r in m.group(1).split(",")}

    # ------------------------------------------------------------ AST nav
    def parent(self, node: ast.AST) -> ast.AST | None:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST):
        """Yield (child, parent) pairs walking from ``node`` to the module —
        the child lets callers see *which slot* of the parent was entered
        (e.g. a decorator list vs. a function body)."""
        cur = node
        while True:
            parent = self._parents.get(cur)
            if parent is None:
                return
            yield cur, parent
            cur = parent

    def enclosing_functions(self, node: ast.AST) -> list[ast.AST]:
        """Innermost-first function defs *lexically executing* ``node``.

        A decorator expression runs in the scope *containing* the def, not
        inside it, so a def reached from its own ``decorator_list`` is
        skipped and the walk continues outward.
        """
        out = []
        for child, parent in self.ancestors(node):
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(child is d for d in parent.decorator_list):
                    continue
                out.append(parent)
        return out

    def statement_of(self, node: ast.AST) -> ast.stmt | None:
        """The nearest enclosing statement (``node`` itself if one)."""
        cur: ast.AST | None = node
        while cur is not None and not isinstance(cur, ast.stmt):
            cur = self._parents.get(cur)
        return cur

    # ----------------------------------------------------------- findings
    def finding(self, rule: "Rule", node: ast.AST | int,
                message: str) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 1)
        col = 0 if isinstance(node, int) else getattr(node, "col_offset", 0)
        return Finding(path=self.display, line=line, col=col, rule=rule.id,
                       name=rule.name, message=message)

    def is_disabled(self, line: int, rule_id: str) -> bool:
        ids = self.disabled.get(line)
        return bool(ids) and (rule_id.upper() in ids or "ALL" in ids)


@runtime_checkable
class Rule(Protocol):
    """A pluggable contract check.  Register instances via ``@register``."""

    id: str            # stable: "PL" + 3 digits, never reused
    name: str          # kebab-case slug for human output
    description: str   # one line, shown by ``--list-rules``

    def check(self, ctx: FileContext) -> Iterable[Finding]: ...


REGISTRY: dict[str, Rule] = {}


def register(cls: type) -> type:
    """Class decorator: instantiate and register a rule by its stable id."""
    rule = cls()
    if rule.id in REGISTRY:
        raise ValueError(f"duplicate planelint rule id {rule.id}")
    REGISTRY[rule.id] = rule
    return cls


def all_rules() -> list[Rule]:
    # Importing the rules package runs every @register decorator once.
    import repro.analysis.lint.rules  # noqa: F401

    return [REGISTRY[k] for k in sorted(REGISTRY)]


def resolve_rules(rule_ids: Sequence[str] | None) -> list[Rule]:
    rules = all_rules()
    if not rule_ids:
        return rules
    by_id = {r.id.upper(): r for r in rules}
    by_name = {r.name.lower(): r for r in rules}
    out = []
    for rid in rule_ids:
        rule = by_id.get(rid.upper()) or by_name.get(rid.lower())
        if rule is None:
            known = ", ".join(sorted(by_id))
            raise ValueError(f"unknown planelint rule {rid!r} (known: {known})")
        if rule not in out:
            out.append(rule)
    return out


def _modpath(path: Path, root: Path) -> str:
    """Path of ``path`` relative to its ``repro`` package when inside one
    (so the rule scopes — ``runtime/``, ``serving/``, ``kernels/`` — are
    layout-independent), else relative to the lint root (so fixture trees
    laid out like the package get the same scoping)."""
    resolved = path.resolve()
    parts = resolved.parts
    if "repro" in parts:
        i = len(parts) - 1 - parts[::-1].index("repro")
        rel = parts[i + 1:]
        if rel:
            return "/".join(rel)
    base = root if root.is_dir() else root.parent
    try:
        return resolved.relative_to(base.resolve()).as_posix()
    except ValueError:
        return path.name


def iter_files(paths: Sequence[str | Path]) -> list[tuple[Path, Path]]:
    """Expand files/directories into (file, lint root) pairs.

    ``__pycache__`` and hidden directories (and hidden files) under a lint
    root are never walked: cached bytecode and venv/tool droppings must not
    become lint input even when a ``*.py`` file ends up inside them.
    """
    out: list[tuple[Path, Path]] = []
    for p in paths:
        root = Path(p)
        if root.is_dir():
            for f in sorted(root.rglob("*.py")):
                rel = f.relative_to(root)
                if any(part == "__pycache__" or part.startswith(".")
                       for part in rel.parts):
                    continue
                out.append((f, root))
        elif root.is_file():
            out.append((root, root.parent))
        else:
            raise FileNotFoundError(f"planelint: no such path: {root}")
    return out


def run_lint(paths: Sequence[str | Path],
             rule_ids: Sequence[str] | None = None, *,
             respect_pragmas: bool = True) -> tuple[list[Finding], int]:
    """Lint ``paths`` with the selected rules.

    Returns ``(findings, files_checked)``; findings are deduplicated and
    sorted by (path, line, col, rule).  A file that does not parse yields a
    single ``PL000`` finding rather than aborting the run.

    This is the stable two-value wrapper around the whole-project engine
    (``repro.analysis.lint.project.lint_project``), which additionally
    supports the on-disk incremental cache and git ``--changed-only`` mode
    and reports which files were actually (re-)parsed.
    """
    from repro.analysis.lint.project import lint_project

    run = lint_project(paths, rule_ids, respect_pragmas=respect_pragmas)
    return run.findings, run.checked

"""planelint whole-project engine: ProjectContext, incremental cache, runner.

PR 6's planelint mechanized the *per-file* ARCHITECTURE contracts; the
invariants protecting the next roadmap moves are **cross-file** properties a
``FileContext`` cannot see — every kernel entry needs a bit-identical
``ref`` oracle wired through ``ops.py`` into the conformance gate, and a
host-side ``float()`` is only a hazard when the value it concretizes flows
from a parameter of a jit/pallas-reachable function *somewhere else*.

This module grows the runner into a whole-project analysis:

* ``ModuleSummary``  — the JSON-serializable per-module facts every
  cross-file rule consumes: import targets, local alias bindings, top-level
  defs with line numbers, per-function call lists and parameter staticness,
  names wrapped by ``jax.jit``/``pallas_call``, and the pragma table.
  Summaries are built from an AST once and then *cached*, so a warmed run
  reconstructs the project view without re-parsing clean files.
* ``ProjectContext`` — the project built once per run: module/import graph
  over the linted tree (plus the conformance test as an auxiliary node),
  symbol resolution with one-level call resolution (``ops.tree_walk_v`` in
  ``core/plane.py`` resolves to the def in ``kernels/ops.py``), forward and
  reverse import closures, and the global jit/pallas-reachable function set.
* ``ProjectRule``    — the cross-file rule protocol.  ``check_project``
  runs once per run from summaries alone (PL006 oracle-parity, PL008
  pragma-hygiene); ``check_file(project, ctx)`` is the per-file hook for
  rules that need an AST *and* project facts (PL007 concretization-hazard),
  and participates in the incremental cache via ``file_facts`` — when a
  clean file's project-derived facts change (a new caller made one of its
  functions jit-reachable), the file is re-linted even though its bytes
  did not.
* ``lint_project``   — the runner: content-hash incremental cache on disk
  (re-lint only changed files + their reverse-import closure), git
  ``--changed-only`` mode, and parse accounting (``LintRun.parsed`` is the
  exact set of files read this run — the incrementality acceptance test
  asserts on it).

Like ``core``, this module is dependency-free (``ast`` + stdlib): it must
run in a bare CI step without importing jax or the modules it checks.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import subprocess
from pathlib import Path
from typing import Any, Iterable, Protocol, Sequence, runtime_checkable

from repro.analysis.lint.core import (
    FileContext,
    Finding,
    Rule,
    _modpath,
    iter_files,
    resolve_rules,
)

__all__ = [
    "ModuleSummary",
    "FunctionInfo",
    "ProjectContext",
    "ProjectRule",
    "LintRun",
    "lint_project",
    "summarize",
]

CACHE_SCHEMA = 1

# Parameter annotations naming only these are static Python scalars, not
# traced arrays — ``n_classes: int`` is a trace-time constant, so ``int()``
# on it concretizes nothing.
_STATIC_ANN_IDS = {"int", "float", "bool", "str", "bytes", "None",
                   "Optional", "Union"}
_JIT_CTORS = {"jit", "pallas_call"}

# The auxiliary project node: the conformance gate lives outside the linted
# package but PL006's reachability leg is *about* it, so the engine walks up
# from each lint root and adopts it (summaries only — per-file rules never
# run on auxiliary files).
_AUX_RELPATH = ("tests", "test_conformance.py")


# ==========================================================================
# Module summaries
# ==========================================================================
@dataclasses.dataclass
class FunctionInfo:
    """One top-level or class-level function: the def-use facts rules need."""

    qual: str                  # "fn" or "Class.fn"
    cls: str | None
    line: int
    params: list[str]          # non-static parameter names, in order
    static_params: list[str]   # annotated scalar / static_argnames params
    jit: bool                  # jit/pallas decorated (incl. partial(jax.jit))
    calls: list[str]           # dotted call targets as written, deduplicated

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "FunctionInfo":
        return cls(**d)


@dataclasses.dataclass
class ModuleSummary:
    """Per-module facts, buildable from an AST and round-trippable as JSON."""

    modpath: str                     # package-relative, "/"-separated
    display: str                     # the path findings report
    aux: bool = False                # auxiliary node (conformance test)
    parse_error: bool = False
    aliases: dict[str, str] = dataclasses.field(default_factory=dict)
    import_targets: list[str] = dataclasses.field(default_factory=list)
    defs: dict[str, dict] = dataclasses.field(default_factory=dict)
    functions: list[FunctionInfo] = dataclasses.field(default_factory=list)
    jit_wrapped: list[str] = dataclasses.field(default_factory=list)
    pragmas: dict[int, list[str]] = dataclasses.field(default_factory=dict)

    def function(self, qual: str) -> FunctionInfo | None:
        for f in self.functions:
            if f.qual == qual:
                return f
        return None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["functions"] = [f.to_json() for f in self.functions]
        d["pragmas"] = {str(k): sorted(v) for k, v in self.pragmas.items()}
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ModuleSummary":
        d = dict(d)
        d["functions"] = [FunctionInfo.from_json(f) for f in d["functions"]]
        d["pragmas"] = {int(k): list(v) for k, v in d["pragmas"].items()}
        return cls(**d)


def _dotted_chain(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain rooted at a Name, else None."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def _is_static_annotation(node: ast.AST | None) -> bool:
    if node is None:
        return False
    ids = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            ids.add(n.id)
        elif isinstance(n, ast.Attribute):
            ids.add(n.attr)
        elif isinstance(n, ast.Constant):
            if n.value is None:
                ids.add("None")
            elif isinstance(n.value, str):
                # string annotation: "int | None"
                ids.update(t.strip() for t in
                           n.value.replace("|", " ").replace("[", " ")
                           .replace("]", " ").replace(",", " ").split())
    return bool(ids) and ids <= _STATIC_ANN_IDS


def _decorator_static_argnames(fn: ast.AST) -> set[str]:
    """Names pinned static by ``@functools.partial(jax.jit,
    static_argnames=(...))`` / ``static_argnums=(...)`` decorators."""
    out: set[str] = set()
    args = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for n in ast.walk(kw.value):
                    if isinstance(n, ast.Constant) and isinstance(n.value, str):
                        out.add(n.value)
            elif kw.arg == "static_argnums":
                for n in ast.walk(kw.value):
                    if isinstance(n, ast.Constant) and isinstance(n.value, int):
                        if 0 <= n.value < len(args):
                            out.add(args[n.value])
    return out


def _fn_params(fn: ast.AST) -> tuple[list[str], list[str]]:
    """Split a def's parameters into (traced-candidate, static) name lists."""
    static = _decorator_static_argnames(fn)
    a = fn.args
    params, static_out = [], []
    for arg in a.posonlyargs + a.args + a.kwonlyargs:
        if arg.arg in ("self", "cls"):
            continue
        if arg.arg in static or _is_static_annotation(arg.annotation):
            static_out.append(arg.arg)
        else:
            params.append(arg.arg)
    return params, static_out


def _has_jit_decorator(fn: ast.AST) -> bool:
    from repro.analysis.lint.rules.common import has_decorator_id

    return has_decorator_id(fn, _JIT_CTORS)


def _calls_in(node: ast.AST) -> list[str]:
    seen: list[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            name = _dotted_chain(n.func)
            if name and name not in seen:
                seen.append(name)
    return seen


def _wrapped_names(tree: ast.AST) -> list[str]:
    """Names of functions passed (possibly through ``functools.partial``) to
    a ``jit(...)``/``pallas_call(...)`` construction anywhere in the module:
    ``jax.jit(functools.partial(_classify_impl, ...))`` yields
    ``_classify_impl``; ``pl.pallas_call(_kernel, ...)`` yields ``_kernel``.
    A call *result* passed to jit (``jax.jit(self._build(n))``) wraps the
    returned closure, not the builder, and is deliberately not recorded.
    """
    out: list[str] = []

    def harvest(arg: ast.AST) -> None:
        if isinstance(arg, ast.Name):
            out.append(arg.id)
        elif isinstance(arg, ast.Attribute):
            out.append(arg.attr)
        elif isinstance(arg, ast.Call):
            fname = _dotted_chain(arg.func) or ""
            if fname.rsplit(".", 1)[-1] == "partial":
                for a in arg.args:
                    harvest(a)

    for n in ast.walk(tree):
        if not isinstance(n, ast.Call):
            continue
        fname = _dotted_chain(n.func) or ""
        if fname.rsplit(".", 1)[-1] in _JIT_CTORS:
            for a in n.args:
                harvest(a)
    return sorted(set(out))


def summarize(ctx: FileContext, *, aux: bool = False) -> ModuleSummary:
    """Build the ModuleSummary of a parsed file."""
    s = ModuleSummary(modpath=ctx.modpath, display=ctx.display, aux=aux)
    s.pragmas = {line: sorted(ids) for line, ids in ctx.disabled.items()}
    # the package a relative import resolves against: path minus the file
    # (which for ``pkg/__init__.py`` is the package itself — same formula)
    pkg = ctx.modpath.rsplit("/", 1)[0].split("/") if "/" in ctx.modpath else []

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                s.import_targets.append(a.name)
                s.aliases[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg[:len(pkg) - (node.level - 1)] if node.level > 1 \
                    else list(pkg)
            else:
                base = []
            base += (node.module or "").split(".") if node.module else []
            base = [b for b in base if b]
            if base:
                s.import_targets.append(".".join(base))
            for a in node.names:
                if a.name == "*":
                    continue
                target = ".".join(base + [a.name])
                s.import_targets.append(target)
                s.aliases[a.asname or a.name] = target

    module_calls: list[str] = []
    units: list[tuple[ast.AST, str | None]] = []
    for stmt in ctx.tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            units.append((stmt, None))
            s.defs[stmt.name] = {"kind": "function", "line": stmt.lineno}
        elif isinstance(stmt, ast.ClassDef):
            s.defs[stmt.name] = {"kind": "class", "line": stmt.lineno}
            for item in stmt.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    units.append((item, stmt.name))
        else:
            module_calls.extend(_calls_in(stmt))

    for fn, cls in units:
        params, static = _fn_params(fn)
        s.functions.append(FunctionInfo(
            qual=f"{cls}.{fn.name}" if cls else fn.name, cls=cls,
            line=fn.lineno, params=params, static_params=static,
            jit=_has_jit_decorator(fn), calls=_calls_in(fn)))
    if module_calls:
        s.functions.append(FunctionInfo(
            qual="<module>", cls=None, line=1, params=[], static_params=[],
            jit=False, calls=sorted(set(module_calls))))
    s.jit_wrapped = _wrapped_names(ctx.tree)
    return s


# ==========================================================================
# ProjectContext
# ==========================================================================
class ProjectContext:
    """The whole linted tree as one graph, built once per run.

    Rules consume: ``modules`` (modpath -> ModuleSummary), symbol/call
    resolution (``resolve``), forward/reverse import closures, and the
    jit/pallas-reachable function sets.  ``context_of`` parses a file on
    demand (recorded in ``parsed`` — the incrementality accounting).
    """

    def __init__(self, *, rules_run: Sequence[str] = (),
                 respect_pragmas: bool = True,
                 full_rules: bool = True) -> None:
        self.modules: dict[str, ModuleSummary] = {}
        self.rules_run = list(rules_run)
        self.respect_pragmas = respect_pragmas
        self.full_rules = full_rules
        # engine-populated accounting consumed by PL008:
        self.suppressed: dict[str, set[tuple[int, str]]] = {}
        self.linted: set[str] = set()
        self.parsed: list[str] = []
        self._files: dict[str, Path] = {}
        self._displays: dict[str, str] = {}
        self._ctx_cache: dict[str, FileContext] = {}
        self._by_parts: dict[tuple[str, ...], str] = {}
        self._edges: dict[str, set[str]] | None = None
        self._reach: dict[str, set[str]] | None = None
        self._ext_reach: dict[str, set[str]] | None = None

    # ------------------------------------------------------------- build
    def register_file(self, modpath: str, path: Path, display: str) -> None:
        """Make a file parseable via ``context_of`` before its summary
        exists (the engine registers every record up front)."""
        self._files[modpath] = path
        self._displays[modpath] = display

    def add(self, summary: ModuleSummary, path: Path) -> None:
        self.modules[summary.modpath] = summary
        self._files[summary.modpath] = path
        self._displays[summary.modpath] = summary.display
        parts = summary.modpath[:-3].split("/") \
            if summary.modpath.endswith(".py") else summary.modpath.split("/")
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        if parts:
            self._by_parts[tuple(parts)] = summary.modpath
        self._edges = self._reach = self._ext_reach = None

    def module(self, modpath: str) -> ModuleSummary | None:
        return self.modules.get(modpath)

    def path_of(self, modpath: str) -> Path | None:
        return self._files.get(modpath)

    def context_of(self, modpath: str) -> FileContext:
        """Parse (once) and return the FileContext — SyntaxError propagates.

        Every first parse is recorded in ``parsed``: the warmed-cache
        acceptance test asserts this is exactly the edited file's
        reverse-import closure.
        """
        if modpath not in self._ctx_cache:
            self._ctx_cache[modpath] = FileContext(
                self._files[modpath], self._displays[modpath], modpath)
            self.parsed.append(modpath)
        return self._ctx_cache[modpath]

    # -------------------------------------------------------- resolution
    def _module_for(self, dotted_parts: Sequence[str]) \
            -> tuple[str, str | None] | None:
        """Longest-prefix match of a dotted path against project modules;
        a leading ``repro`` package wrapper is stripped so absolute
        ``repro.kernels.ref`` imports resolve in package-relative and
        fixture-relative trees alike."""
        parts = list(dotted_parts)
        if parts and parts[0] == "repro":
            parts = parts[1:]
        for i in range(len(parts), 0, -1):
            mp = self._by_parts.get(tuple(parts[:i]))
            if mp is not None:
                return mp, ".".join(parts[i:]) or None
        return None

    def resolve(self, modpath: str, dotted: str) \
            -> tuple[str, str | None] | None:
        """Resolve a dotted reference written in ``modpath`` to
        ``(target modpath, symbol-or-None)`` — one-level call resolution.

        ``ops.tree_walk_v`` under ``from repro.kernels import ops`` resolves
        to ``("kernels/ops.py", "tree_walk_v")``; a bare name defined in the
        module resolves to itself; anything leaving the project is ``None``.
        """
        summ = self.modules.get(modpath)
        if summ is None:
            return None
        parts = dotted.split(".")
        head = parts[0]
        target = summ.aliases.get(head)
        if target is not None:
            full = target.split(".") + parts[1:]
        elif head in summ.defs:
            return (modpath, ".".join(parts)) if len(parts) == 1 \
                else (modpath, head)
        else:
            full = parts
        return self._module_for(full)

    # ------------------------------------------------------ import graph
    def _build_edges(self) -> dict[str, set[str]]:
        if self._edges is None:
            edges: dict[str, set[str]] = {m: set() for m in self.modules}
            for mp, summ in self.modules.items():
                for target in summ.import_targets:
                    hit = self._module_for(target.split("."))
                    if hit and hit[0] != mp:
                        edges[mp].add(hit[0])
            self._edges = edges
        return self._edges

    def imports_of(self, modpath: str) -> set[str]:
        return set(self._build_edges().get(modpath, ()))

    def import_closure(self, modpath: str) -> set[str]:
        """Forward closure: everything ``modpath`` (transitively) imports,
        including itself."""
        edges = self._build_edges()
        seen, todo = set(), [modpath]
        while todo:
            m = todo.pop()
            if m in seen or m not in edges:
                continue
            seen.add(m)
            todo.extend(edges[m])
        return seen

    def importers_closure(self, modpaths: Iterable[str]) -> set[str]:
        """Reverse closure: the seeds plus everything that (transitively)
        imports them — the invalidation set of an edit."""
        edges = self._build_edges()
        rev: dict[str, set[str]] = {m: set() for m in edges}
        for src, dsts in edges.items():
            for d in dsts:
                rev.setdefault(d, set()).add(src)
        seen: set[str] = set()
        todo = [m for m in modpaths if m in self.modules]
        while todo:
            m = todo.pop()
            if m in seen:
                continue
            seen.add(m)
            todo.extend(rev.get(m, ()))
        return seen

    # --------------------------------------------- jit/pallas reachability
    def _build_reach(self) -> None:
        if self._reach is not None:
            return
        entries: dict[str, set[str]] = {}
        for mp, summ in self.modules.items():
            wrapped = set(summ.jit_wrapped)
            mod_entries = set()
            for fn in summ.functions:
                last = fn.qual.rsplit(".", 1)[-1]
                if fn.jit or last in wrapped:
                    mod_entries.add(fn.qual)
            entries[mp] = mod_entries
        reach = {mp: set(e) for mp, e in entries.items()}
        ext: dict[str, set[str]] = {mp: set() for mp in self.modules}
        for mp, summ in self.modules.items():
            for fn in summ.functions:
                if fn.qual not in entries[mp]:
                    continue
                for call in fn.calls:
                    if call.startswith("self.") and fn.cls:
                        qual = f"{fn.cls}.{call.split('.', 1)[1]}"
                        if self.modules[mp].function(qual):
                            reach[mp].add(qual)
                        continue
                    hit = self.resolve(mp, call)
                    if hit is None or hit[1] is None:
                        continue
                    tmod, sym = hit
                    target = self.modules[tmod].function(sym)
                    if target is not None:
                        reach[tmod].add(target.qual)
                        if tmod != mp:
                            ext[tmod].add(target.qual)
        self._reach, self._ext_reach = reach, ext

    def jit_reachable(self, modpath: str) -> set[str]:
        """Quals in ``modpath`` that are jit/pallas entries or called
        (one level) from an entry anywhere in the project."""
        self._build_reach()
        return set(self._reach.get(modpath, set()))

    def external_jit_reachable(self, modpath: str) -> set[str]:
        """The cross-file slice of ``jit_reachable`` — quals made reachable
        by *other* modules.  This is the per-file cache-invalidation fact
        for PL007: a clean file whose external set changed must re-lint."""
        self._build_reach()
        return set(self._ext_reach.get(modpath, set()))


@runtime_checkable
class ProjectRule(Protocol):
    """A cross-file contract check (registered via ``@core.register``).

    Implement any of:

    * ``check_project(project)`` — run once per run from summaries alone;
    * ``check_file(project, ctx)`` — per-file, with project facts; cached
      per file and invalidated by content hash *or* a ``file_facts`` change.
    """

    id: str
    name: str
    description: str

    def check_project(self, project: ProjectContext) -> Iterable[Finding]: ...


def _rule_kinds(rules: Sequence[Rule]) \
        -> tuple[list[Rule], list[Rule], list[Rule]]:
    per_file = [r for r in rules if callable(getattr(r, "check", None))]
    hybrid = [r for r in rules if callable(getattr(r, "check_file", None))]
    project = [r for r in rules if callable(getattr(r, "check_project", None))]
    return per_file, hybrid, project


# ==========================================================================
# Incremental cache
# ==========================================================================
def _tool_digest() -> str:
    """Digest of the lint package's own sources — any rule/engine edit
    invalidates the whole cache (stale findings are worse than a re-run)."""
    pkg = Path(__file__).resolve().parent
    h = hashlib.sha256()
    for f in sorted(pkg.rglob("*.py")):
        if "__pycache__" in f.parts:
            continue
        h.update(f.name.encode())
        h.update(f.read_bytes())
    return h.hexdigest()[:16]


def _file_hash(path: Path) -> str:
    return hashlib.sha256(path.read_bytes()).hexdigest()[:16]


def _load_cache(cache_path: Path | None, rules: Sequence[Rule],
                respect_pragmas: bool) -> dict:
    empty = {"schema": CACHE_SCHEMA, "tool": _tool_digest(),
             "rules": sorted(r.id for r in rules),
             "respect_pragmas": respect_pragmas, "files": {}}
    if cache_path is None or not cache_path.is_file():
        return empty
    try:
        doc = json.loads(cache_path.read_text())
    except (ValueError, OSError):
        return empty
    for key in ("schema", "tool", "rules", "respect_pragmas"):
        if doc.get(key) != empty[key]:
            return empty
    if not isinstance(doc.get("files"), dict):
        return empty
    return doc


def _git_changed_files(base: str, anchor: Path) -> set[Path] | None:
    """Absolute paths changed vs ``base`` (committed + worktree + untracked)
    in the repo containing ``anchor``; None when git is unavailable."""
    anchor_dir = anchor if anchor.is_dir() else anchor.parent

    def git(*args: str) -> str | None:
        try:
            proc = subprocess.run(
                ["git", "-C", str(anchor_dir), *args],
                capture_output=True, text=True, timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return None
        return proc.stdout if proc.returncode == 0 else None

    top = git("rev-parse", "--show-toplevel")
    if top is None:
        return None
    root = Path(top.strip())
    diff = git("diff", "--name-only", base, "--")
    if diff is None:
        return None
    untracked = git("ls-files", "--others", "--exclude-standard") or ""
    return {(root / line).resolve()
            for line in (diff + untracked).splitlines() if line.strip()}


def _discover_aux(roots: Iterable[Path], have: set[Path]) \
        -> list[tuple[Path, str]]:
    """Walk up (<= 3 levels) from each lint root for the conformance test —
    the auxiliary node PL006's reachability leg is anchored to."""
    out, seen = [], set()
    for root in roots:
        base = root if root.is_dir() else root.parent
        for up in (base, base.parent, base.parent.parent):
            cand = (up / Path(*_AUX_RELPATH)).resolve()
            if cand in have or cand in seen or not cand.is_file():
                continue
            seen.add(cand)
            out.append((cand, "/".join(_AUX_RELPATH)))
    return out


# ==========================================================================
# The runner
# ==========================================================================
@dataclasses.dataclass
class LintRun:
    """One engine run: findings plus the incrementality accounting."""

    findings: list[Finding]
    checked: int                 # lint-target files considered (aux excluded)
    parsed: list[str]            # modpaths actually read+parsed this run
    cached: int                  # files whose findings came from the cache
    changed: list[str]           # modpaths with new content (or no cache)
    reported_paths: set[str] = dataclasses.field(default_factory=set)
    project: "ProjectContext | None" = None   # the graph rules ran against


def lint_project(paths: Sequence[str | Path],
                 rule_ids: Sequence[str] | None = None, *,
                 respect_pragmas: bool = True,
                 cache_path: str | Path | None = None,
                 changed_only: str | None = None) -> LintRun:
    """Whole-project lint with incremental caching.

    ``cache_path``   — on-disk JSON cache keyed by file-content hash; only
    changed files, their reverse-import closure, and files whose
    project-derived facts changed are re-parsed and re-linted.
    ``changed_only`` — a git ref: per-file findings are reported only for
    files changed vs that ref (worktree + untracked included) plus their
    reverse-import closure; cross-file (project-rule) findings are always
    reported.  Project summaries still cover the whole tree, so PL006-class
    invariants cannot be dodged by a narrow diff.
    """
    from repro.analysis.lint.core import all_rules

    rules = resolve_rules(rule_ids)
    full = {r.id for r in rules} == {r.id for r in all_rules()}
    per_file_rules, hybrid_rules, project_rules = _rule_kinds(rules)
    cache_path = Path(cache_path) if cache_path is not None else None

    files = iter_files(paths)
    records: list[dict] = []
    have: set[Path] = set()
    for path, root in files:
        resolved = path.resolve()
        if resolved in have:
            continue
        have.add(resolved)
        try:
            display = str(path.relative_to(Path.cwd()))
        except ValueError:
            display = str(path)
        records.append({"path": resolved, "display": display,
                        "modpath": _modpath(path, root), "aux": False})
    roots = [Path(p) for p in paths]
    for path, modpath in _discover_aux(roots, have):
        try:
            display = str(path.relative_to(Path.cwd()))
        except ValueError:
            display = str(path)
        records.append({"path": path, "display": display,
                        "modpath": modpath, "aux": True})

    cache = _load_cache(cache_path, rules, respect_pragmas)
    old_files: dict[str, dict] = cache["files"]

    project = ProjectContext(rules_run=[r.id for r in rules],
                             respect_pragmas=respect_pragmas,
                             full_rules=full)

    # -- pass 1: hashes + summaries (cached summaries skip the parse) ------
    content_changed: set[str] = set()
    parse_errors: dict[str, Finding] = {}

    def parse_and_summarize(rec: dict) -> ModuleSummary:
        try:
            ctx = project.context_of(rec["modpath"])
        except SyntaxError as e:
            project.parsed.append(rec["modpath"])   # read+failed still counts
            parse_errors[rec["modpath"]] = Finding(
                path=rec["display"], line=e.lineno or 1, col=e.offset or 0,
                rule="PL000", name="parse-error",
                message=f"file does not parse: {e.msg}")
            return ModuleSummary(modpath=rec["modpath"],
                                 display=rec["display"], aux=rec["aux"],
                                 parse_error=True)
        return summarize(ctx, aux=rec["aux"])

    for rec in records:
        project.register_file(rec["modpath"], rec["path"], rec["display"])
    for rec in records:
        rec["hash"] = _file_hash(rec["path"])
        entry = old_files.get(str(rec["path"]))
        if entry is not None and entry.get("hash") == rec["hash"] \
                and entry.get("summary") is not None:
            summary = ModuleSummary.from_json(entry["summary"])
            # display paths are cwd-relative; refresh if cwd moved
            summary.display = rec["display"]
            project.add(summary, rec["path"])
            rec["cached"] = entry
        else:
            content_changed.add(rec["modpath"])
            rec["cached"] = None
            summary = parse_and_summarize(rec)
            project.add(summary, rec["path"])
        rec["summary"] = summary
        if summary.parse_error and rec["cached"] is not None:
            # cached parse error: replay the stored PL000 finding
            for fd in rec["cached"].get("findings") or []:
                if fd["rule"] == "PL000":
                    parse_errors[rec["modpath"]] = Finding(**{
                        **fd, "path": rec["display"]})

    # -- pass 2: invalidation = changed + reverse closure + fact drift -----
    # Per-file rules depend on the file's bytes alone; hybrid rules also
    # depend on project-derived facts (e.g. which of the file's functions
    # other modules made jit-reachable), so a clean file re-lints when its
    # facts digest drifts even though its hash did not.
    needs_lint = project.importers_closure(content_changed)
    fact_drift: set[str] = set()
    for rec in records:
        mp = rec["modpath"]
        if rec["aux"] or rec["summary"].parse_error or mp in content_changed:
            continue
        entry = rec["cached"]
        if entry is None or entry.get("findings") is None:
            needs_lint.add(mp)       # summary cached but never fully linted
            continue
        old_facts = entry.get("facts") or {}
        for rule in hybrid_rules:
            fact_fn = getattr(rule, "file_facts", None)
            if fact_fn is None:
                continue
            if fact_fn(project, mp) != old_facts.get(rule.id):
                fact_drift.add(mp)
                break
    needs_lint |= fact_drift

    # -- changed-only: which files' per-file findings get reported ---------
    report_scope: set[str] | None = None
    if changed_only is not None:
        git_changed = _git_changed_files(changed_only, records[0]["path"]
                                         if records else Path.cwd())
        if git_changed is not None:
            seeds = {rec["modpath"] for rec in records
                     if rec["path"] in git_changed}
            report_scope = project.importers_closure(seeds)
            # files outside the diff scope never re-lint in this mode
            # (fact-drifted and content-changed files still do, so their
            # cache entries never go stale); their old entries are kept
            needs_lint &= report_scope | content_changed | fact_drift

    # -- pass 3: per-file rules on the invalidated set ---------------------
    findings: set[Finding] = set()
    file_findings: dict[str, list[Finding]] = {}
    cached_count = 0
    for rec in records:
        mp = rec["modpath"]
        summary = rec["summary"]
        if summary.parse_error:
            if mp in parse_errors:
                file_findings[mp] = [parse_errors[mp]]
                project.linted.add(mp)
            continue
        if rec["aux"]:
            continue     # auxiliary nodes feed summaries only
        if mp in needs_lint:
            ctx = project.context_of(mp)
            raw: list[Finding] = []
            for rule in per_file_rules:
                raw.extend(rule.check(ctx))
            for rule in hybrid_rules:
                raw.extend(rule.check_file(project, ctx))
            kept, suppressed = [], set()
            for f in raw:
                if respect_pragmas and ctx.is_disabled(f.line, f.rule):
                    suppressed.add((f.line, f.rule))
                else:
                    kept.append(f)
            file_findings[mp] = kept
            project.suppressed[mp] = suppressed
            project.linted.add(mp)
        elif rec["cached"] is not None \
                and rec["cached"].get("findings") is not None:
            file_findings[mp] = [Finding(**{**fd, "path": rec["display"]})
                                 for fd in rec["cached"]["findings"]]
            project.suppressed[mp] = {
                (int(l), r) for l, r in rec["cached"].get("suppressed", [])}
            project.linted.add(mp)
            cached_count += 1
        # else: summary-only (changed-only mode skipped it)

    for mp, fs in file_findings.items():
        findings.update(fs)

    # -- pass 4: project rules (summaries + suppression accounting) --------
    project_findings: set[Finding] = set()
    display_to_mod = {rec["summary"].display: rec["modpath"]
                      for rec in records}
    for rule in project_rules:
        for f in rule.check_project(project):
            mp = display_to_mod.get(f.path)
            if respect_pragmas and mp is not None:
                ids = set(project.modules[mp].pragmas.get(f.line, ()))
                ids = {i.upper() for i in ids}
                # 'disable=all' must not swallow the PL008 finding reporting
                # that very pragma (self-silencing loop); naming PL008
                # explicitly is the sanctioned keep-this-pragma escape hatch
                blanket = "ALL" in ids and f.rule.upper() != "PL008"
                if f.rule.upper() in ids or blanket:
                    continue
            project_findings.add(f)
    findings.update(project_findings)

    # -- save cache --------------------------------------------------------
    if cache_path is not None:
        out_files = {}
        for rec in records:
            mp = rec["modpath"]
            entry: dict[str, Any] = {
                "hash": rec["hash"],
                "summary": rec["summary"].to_json(),
                "findings": None, "suppressed": [], "facts": {},
            }
            if mp in file_findings or (mp in project.linted
                                       and not rec["summary"].parse_error):
                entry["findings"] = [f.to_json()
                                     for f in file_findings.get(mp, [])]
                entry["suppressed"] = sorted(
                    list(t) for t in project.suppressed.get(mp, ()))
                for rule in hybrid_rules:
                    fact_fn = getattr(rule, "file_facts", None)
                    if fact_fn is not None and not rec["aux"]:
                        entry["facts"][rule.id] = fact_fn(project, mp)
            elif rec["summary"].parse_error and mp in parse_errors:
                entry["findings"] = [parse_errors[mp].to_json()]
            elif rec["cached"] is not None:
                entry["findings"] = rec["cached"].get("findings")
                entry["suppressed"] = rec["cached"].get("suppressed", [])
                entry["facts"] = rec["cached"].get("facts", {})
            out_files[str(rec["path"])] = entry
        cache["files"] = out_files
        try:
            cache_path.parent.mkdir(parents=True, exist_ok=True)
            cache_path.write_text(json.dumps(cache))
        except OSError:
            pass     # an unwritable cache degrades to a full run next time

    # -- report ------------------------------------------------------------
    reported: set[Finding] = set(project_findings)
    if report_scope is None:
        reported.update(f for fs in file_findings.values() for f in fs)
    else:
        for mp, fs in file_findings.items():
            if mp in report_scope:
                reported.update(fs)

    checked = sum(1 for rec in records if not rec["aux"])
    return LintRun(
        findings=sorted(reported), checked=checked,
        parsed=list(project.parsed), cached=cached_count,
        changed=sorted(content_changed),
        reported_paths={f.path for f in reported}, project=project)

"""planelint built-in rules.  Importing this package registers every rule.

| id    | name                  | contract it mechanizes                      |
|-------|-----------------------|---------------------------------------------|
| PL001 | shard-map-containment | only ``repro.runtime`` builds shard_map     |
| PL002 | numpy-glue            | serving hot-path shape glue stays numpy     |
| PL003 | vmem-budget           | kernel VMEM footprints match budgets.py     |
| PL004 | async-blocking        | no blocking calls inside ``async def``      |
| PL005 | retrace-hazard        | jit/pallas_call construction is memoized    |

Adding a rule: drop a module here that defines a class with ``id``/``name``/
``description`` and ``check(ctx)``, decorate it with ``@core.register``, and
import it below.  IDs are stable and never reused.
"""
from repro.analysis.lint.rules import (  # noqa: F401  (import = register)
    pl001_shard_map,
    pl002_numpy_glue,
    pl003_vmem_budget,
    pl004_async_blocking,
    pl005_retrace,
)

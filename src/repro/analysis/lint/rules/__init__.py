"""planelint built-in rules.  Importing this package registers every rule.

| id    | name                  | contract it mechanizes                      |
|-------|-----------------------|---------------------------------------------|
| PL001 | shard-map-containment | only ``repro.runtime`` builds shard_map     |
| PL002 | numpy-glue            | serving hot-path shape glue stays numpy     |
| PL003 | vmem-budget           | kernel VMEM footprints match budgets.py     |
| PL004 | async-blocking        | no blocking calls inside ``async def``      |
| PL005 | retrace-hazard        | jit/pallas_call construction is memoized    |
| PL006 | oracle-parity         | every ``*_v`` kernel entry has a ref oracle,|
|       |                       | ops dispatch, and conformance reachability  |
| PL007 | concretization-hazard | no float()/int()/.item()/np.asarray on      |
|       |                       | values from jit/pallas-reachable params     |
| PL008 | pragma-hygiene        | no ``disable=`` pragma that suppresses      |
|       |                       | nothing                                     |

PL001-PL005 are per-file rules (``check(ctx)``); PL006-PL008 run on the
whole-project engine (``repro.analysis.lint.project``): PL006/PL008 via
``check_project`` from cached module summaries, PL007 via
``check_file(project, ctx)`` with cross-file cache invalidation.

Adding a rule: drop a module here that defines a class with ``id``/``name``/
``description`` and ``check(ctx)`` (or ``check_project``/``check_file``),
decorate it with ``@core.register``, and import it below.  IDs are stable
and never reused.
"""
from repro.analysis.lint.rules import (  # noqa: F401  (import = register)
    pl001_shard_map,
    pl002_numpy_glue,
    pl003_vmem_budget,
    pl004_async_blocking,
    pl005_retrace,
    pl006_oracle_parity,
    pl007_concretize,
    pl008_pragma_hygiene,
)

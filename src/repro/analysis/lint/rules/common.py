"""Shared AST idioms for planelint rules."""
from __future__ import annotations

import ast

__all__ = ["dotted_ids", "has_decorator_id", "import_aliases"]


def dotted_ids(node: ast.AST) -> set[str]:
    """Every bare identifier appearing in an expression — ``Name`` ids and
    ``Attribute`` attrs — so ``functools.partial(jax.jit, ...)`` yields
    ``{"functools", "partial", "jax", "jit"}``."""
    out: set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def has_decorator_id(fn: ast.AST, ids: set[str]) -> bool:
    """True if any decorator of ``fn`` mentions one of ``ids`` anywhere in
    its expression (covers ``@jax.jit``, ``@jit``, ``@functools.partial(
    jax.jit, ...)``, ``@functools.lru_cache(maxsize=8)``)."""
    return any(dotted_ids(d) & ids
               for d in getattr(fn, "decorator_list", []))


def import_aliases(tree: ast.AST, module: str,
                   names: tuple[str, ...] = ()) -> set[str]:
    """Local bindings referring to ``module`` (or to ``names`` imported from
    it): ``import queue`` -> {"queue"}, ``import queue as q`` -> {"q"},
    ``from queue import Queue as Q`` -> {"Q"} (only for listed ``names``)."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == module:
                    if a.asname:
                        out.add(a.asname)
                    elif "." not in module:
                        out.add(module)
                    # plain ``import a.b`` binds only the root ``a``; dotted
                    # uses are matched structurally by the rules themselves
        elif isinstance(node, ast.ImportFrom):
            if node.module == module:
                for a in node.names:
                    if not names or a.name in names:
                        out.add(a.asname or a.name)
    return out

"""PL001 — shard-map-containment.

``docs/ARCHITECTURE.md`` ("Runtime layer"): **no ``src/repro`` module outside
``runtime/`` may construct a ``shard_map`` classify loop**.  The runtime
package is the one seam where device meshes, collective permutes, and
sharding live; any other module referencing ``shard_map`` — an import, an
attribute lookup, even a ``getattr(jax, "shard_map")`` string — is either a
new classify substrate growing outside the executor protocol or dead code
pretending to be one.

This rule generalizes (and is the single source of truth for) the original
ad-hoc AST scan in ``tests/test_runtime.py::test_no_shard_map_outside_runtime``;
the test is now a thin wrapper asserting this rule finds nothing.

Docstrings and comments mentioning shard_map are fine: the AST walk only
sees imports, names, attributes, and *exact* ``"shard_map"`` string
constants.
"""
from __future__ import annotations

import ast

from repro.analysis.lint.core import FileContext, Finding, register

_TOKEN = "shard_map"  # planelint: disable=PL001 (the rule names its own token)


@register
class ShardMapContainment:
    id = "PL001"
    name = "shard-map-containment"
    description = ("only repro.runtime may import or reference shard_map "
                   "(ARCHITECTURE 'Runtime layer')")

    def check(self, ctx: FileContext) -> list[Finding]:
        if ctx.modpath.startswith("runtime/"):
            return []
        out = []
        for node in ast.walk(ctx.tree):
            hit = (
                (isinstance(node, ast.ImportFrom)
                 and _TOKEN in (node.module or ""))
                or (isinstance(node, ast.Import)
                    and any(_TOKEN in a.name for a in node.names))
                or (isinstance(node, ast.Attribute) and node.attr == _TOKEN)
                or (isinstance(node, ast.Name) and node.id == _TOKEN)
                or (isinstance(node, ast.Constant) and node.value == _TOKEN)
            )
            if hit:
                out.append(ctx.finding(
                    self, node,
                    "shard_map reference outside repro.runtime — classify "
                    "substrates live behind the Executor protocol in "
                    "runtime/executors.py (ARCHITECTURE 'Runtime layer')"))
        return out

"""PL002 — numpy-glue.

``docs/ARCHITECTURE.md`` ("Async serving", the glue rules): on the serving
hot path, shape glue — concatenating per-client batches, padding admission
tails — must be **numpy**, not ``jnp``.  A ``jnp.concatenate``/``jnp.pad``
executed *outside* a jit-compiled function is dispatched op-by-op through
XLA and lazily compiles once per (operand count, shapes) signature; on a
live request stream nearly every coalesced dispatch has a new ragged size,
so each one stalls ~10-100x the warmed classify trace in glue compilation
before the classify even starts.

Scope — the modules a request crosses between the wire and the executor:

* everything under ``serving/``;
* ``runtime/admission.py`` (bucketing/coalescing) and
  ``runtime/policies.py`` (batching policies).

Calls inside jit-compiled functions (any enclosing def decorated with
``jit``/``pallas_call``, where the op is traced once per shape) are exempt.
A deliberate device-side branch (e.g. admission's device-resident-leaf
padding, which must not force a host round-trip) carries a
``planelint: disable=PL002`` pragma with its justification.
"""
from __future__ import annotations

import ast

from repro.analysis.lint.core import FileContext, Finding, register
from repro.analysis.lint.rules.common import has_decorator_id, import_aliases

_GLUE = {"concatenate", "concat", "pad", "stack", "asarray"}
_JIT_IDS = {"jit", "pallas_call"}
_HOT_FILES = {"runtime/admission.py", "runtime/policies.py"}


def _jnp_aliases(tree: ast.AST) -> set[str]:
    return (import_aliases(tree, "jax.numpy")
            | import_aliases(tree, "jax", ("numpy",)))


def _is_jnp(value: ast.AST, aliases: set[str]) -> bool:
    if isinstance(value, ast.Name):
        return value.id in aliases
    # the un-aliased chain: ``jax.numpy.<glue>``
    return (isinstance(value, ast.Attribute) and value.attr == "numpy"
            and isinstance(value.value, ast.Name) and value.value.id == "jax")


@register
class NumpyGlue:
    id = "PL002"
    name = "numpy-glue"
    description = ("serving hot-path shape glue (concatenate/pad/stack/"
                   "asarray) must be numpy outside jit "
                   "(ARCHITECTURE 'Async serving')")

    def check(self, ctx: FileContext) -> list[Finding]:
        hot = (ctx.modpath.startswith("serving/")
               or ctx.modpath in _HOT_FILES)
        if not hot:
            return []
        aliases = _jnp_aliases(ctx.tree)
        out = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _GLUE
                    and _is_jnp(node.func.value, aliases)):
                continue
            if any(has_decorator_id(fn, _JIT_IDS)
                   for fn in ctx.enclosing_functions(node)):
                continue   # traced once per shape — not eager glue
            out.append(ctx.finding(
                self, node,
                f"jnp.{node.func.attr} outside jit on the serving hot path "
                "lazily XLA-compiles per ragged shape (~10-100x the warmed "
                "classify) — use numpy for host-side glue "
                "(ARCHITECTURE 'Async serving')"))
        return out

"""PL003 — vmem-budget.

``docs/ARCHITECTURE.md`` ("Kernel memory plans") pins a per-grid-step VMEM
footprint for every Pallas kernel; ``repro/kernels/budgets.py`` holds the
machine-readable copy.  This rule closes the loop **statically**: it parses
each kernel module's ``pl.pallas_call``, evaluates every ``BlockSpec`` block
shape and ``scratch_shapes`` entry under the manifest's reference bindings
(no jax import, no tracing), adds the manifest-declared in-kernel
intermediates (e.g. ``tree_walk``'s VMEM-resident ``fv_all`` matmul product),
and fails when the recomputed bytes

* exceed ``budget_bytes`` (16 MiB/core — the kernel cannot fit), or
* drift more than ``tolerance`` (1%) from ``pinned_bytes`` — someone resized
  a block without re-budgeting the doc table and manifest.

It also flags kernels with no manifest entry, shapes it cannot statically
evaluate (add the free variable to ``bindings``), and — on ``budgets.py``
itself — manifest entries whose kernel module no longer exists.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.lint.core import FileContext, Finding, register
from repro.kernels.budgets import BUDGETS, KernelBudget

__all__ = ["VmemBudget", "kernel_footprints"]

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}


class _Unknown(Exception):
    """A BlockSpec dim references a name with no reference binding."""

    def __init__(self, name: str):
        super().__init__(name)
        self.name = name


def _eval_dim(node: ast.AST, bindings: dict) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in bindings:
            return int(bindings[node.id])
        raise _Unknown(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval_dim(node.operand, bindings)
    if isinstance(node, ast.BinOp):
        lhs = _eval_dim(node.left, bindings)
        rhs = _eval_dim(node.right, bindings)
        if isinstance(node.op, ast.Add):
            return lhs + rhs
        if isinstance(node.op, ast.Sub):
            return lhs - rhs
        if isinstance(node.op, ast.Mult):
            return lhs * rhs
        if isinstance(node.op, (ast.FloorDiv, ast.Div)):
            return lhs // rhs
        if isinstance(node.op, ast.Pow):
            return lhs ** rhs
    raise _Unknown(ast.dump(node))


def _call_name(node: ast.Call) -> str | None:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _block_elems(spec: ast.Call, bindings: dict) -> int:
    """Element count of one ``pl.BlockSpec((d0, d1, ...), index_map)``."""
    if not spec.args:
        raise _Unknown("<BlockSpec with no block shape>")
    shape = spec.args[0]
    dims = shape.elts if isinstance(shape, ast.Tuple) else [shape]
    n = 1
    for d in dims:
        n *= _eval_dim(d, bindings)
    return n


def _specs_of(kw_value: ast.AST):
    """BlockSpec calls from an ``in_specs=[...]`` / ``out_specs=...`` value."""
    nodes = kw_value.elts if isinstance(kw_value, (ast.List, ast.Tuple)) \
        else [kw_value]
    return [n for n in nodes
            if isinstance(n, ast.Call) and _call_name(n) == "BlockSpec"]


def _scratch_bytes(kw_value: ast.AST, bindings: dict) -> int:
    """Bytes of VMEM ``scratch_shapes`` (``pltpu.VMEM(shape, dtype)``)."""
    nodes = kw_value.elts if isinstance(kw_value, (ast.List, ast.Tuple)) \
        else [kw_value]
    total = 0
    for n in nodes:
        if not (isinstance(n, ast.Call) and _call_name(n) == "VMEM"):
            continue   # SMEM/semaphores live outside the VMEM budget
        shape = n.args[0] if n.args else None
        dims = shape.elts if isinstance(shape, ast.Tuple) else [shape]
        elems = 1
        for d in dims:
            elems *= _eval_dim(d, bindings)
        dt = n.args[1] if len(n.args) > 1 else None
        dt_name = dt.attr if isinstance(dt, ast.Attribute) else (
            dt.id if isinstance(dt, ast.Name) else "")
        total += elems * _DTYPE_BYTES.get(dt_name, 4)
    return total


def _footprint(call: ast.Call, entry: KernelBudget) -> int:
    """Static per-grid-step VMEM bytes of one ``pl.pallas_call``."""
    specs, scratch = [], 0
    for kw in call.keywords:
        if kw.arg in ("in_specs", "out_specs"):
            specs.extend(_specs_of(kw.value))
        elif kw.arg == "scratch_shapes":
            scratch += _scratch_bytes(kw.value, entry.bindings)
    sizes = entry.spec_itemsizes or (entry.itemsize,) * len(specs)
    if len(sizes) != len(specs):
        raise _Unknown(
            f"spec_itemsizes has {len(sizes)} entries for {len(specs)} "
            "parsed BlockSpecs")
    total = sum(_block_elems(spec, entry.bindings) * size
                for spec, size in zip(specs, sizes))
    return total + scratch + sum(entry.intermediates.values())


def _pallas_calls(tree: ast.AST) -> list[ast.Call]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.Call) and _call_name(n) == "pallas_call"]


def _entries_for(stem: str, budgets: dict) -> dict[str, KernelBudget]:
    """Manifest entries budgeting the module ``stem`` — usually one, keyed
    by the stem itself, but a module may carry several (e.g. the quantized
    and f32 operand widths of ``classify_fused``)."""
    return {k: e for k, e in budgets.items() if (e.module or k) == stem}


def kernel_footprints(path: pathlib.Path | str,
                      budgets: dict | None = None) -> dict[str, int]:
    """Recompute the static footprint of every budgeted ``pallas_call`` in
    ``path`` — the same arithmetic PL003 runs, exposed so tests can check the
    KiB numbers quoted in ``docs/ARCHITECTURE.md``.  Returns
    ``{budget_key: bytes}`` (one entry per manifest row matching the
    module; multi-row modules yield one footprint per operand width)."""
    path = pathlib.Path(path)
    budgets = BUDGETS if budgets is None else budgets
    entries = _entries_for(path.stem, budgets)
    if not entries:
        return {}
    tree = ast.parse(path.read_text(encoding="utf-8"))
    calls = _pallas_calls(tree)
    if not calls:
        return {}
    return {key: max(_footprint(c, entry) for c in calls)
            for key, entry in entries.items()}


@register
class VmemBudget:
    id = "PL003"
    name = "vmem-budget"
    description = ("static BlockSpec/scratch footprint of every kernel must "
                   "match the pinned budget in kernels/budgets.py "
                   "(ARCHITECTURE 'Kernel memory plans')")

    def check(self, ctx: FileContext) -> list[Finding]:
        if not ctx.modpath.startswith("kernels/"):
            return []
        out = []
        if ctx.path.name == "budgets.py":
            # Reverse direction: every manifest entry names a live module.
            for key in sorted(BUDGETS):
                mod = BUDGETS[key].module or key
                if not (ctx.path.parent / f"{mod}.py").exists():
                    out.append(ctx.finding(
                        self, 1,
                        f"budget entry '{key}' has no kernels/{mod}.py — "
                        "remove the stale manifest row"))
            return out
        calls = _pallas_calls(ctx.tree)
        if not calls:
            return []
        entries = _entries_for(ctx.path.stem, BUDGETS)
        if not entries:
            out.append(ctx.finding(
                self, calls[0],
                f"pallas_call in unbudgeted kernel '{ctx.path.stem}' — add "
                "a KernelBudget entry to kernels/budgets.py and a row to "
                "the ARCHITECTURE 'Kernel memory plans' table"))
            return out
        for key, entry in entries.items():
            for call in calls:
                try:
                    got = _footprint(call, entry)
                except _Unknown as e:
                    out.append(ctx.finding(
                        self, call,
                        f"cannot statically evaluate block shape: '{e.name}'"
                        f" has no reference binding in BUDGETS['{key}']"
                        ".bindings"))
                    continue
                if got > entry.budget_bytes:
                    out.append(ctx.finding(
                        self, call,
                        f"static VMEM footprint {got} B of '{key}' exceeds "
                        f"the {entry.budget_bytes} B per-core budget at the "
                        "reference config — shrink the batch/block tiles"))
                elif abs(got - entry.pinned_bytes) > \
                        entry.tolerance * entry.pinned_bytes:
                    out.append(ctx.finding(
                        self, call,
                        f"static VMEM footprint {got} B of '{key}' drifted >"
                        f"{entry.tolerance:.0%} from the pinned "
                        f"{entry.pinned_bytes} B — re-budget "
                        "kernels/budgets.py and the ARCHITECTURE 'Kernel "
                        "memory plans' table"))
        return out

"""PL004 — async-blocking.

The async serving front (``serving/async_server.py``) runs every client and
the dispatch loop on **one** asyncio event loop; the only blocking work —
the executor call itself — is explicitly pushed to a worker thread via
``loop.run_in_executor``.  Anything else that blocks inside an ``async def``
stalls every pending submit and every deadline timer at once: a 2 ms
``time.sleep`` inside the dispatch loop is a 2 ms p99 floor for the whole
server.

Flagged inside ``async def`` bodies (innermost non-async defs are opaque —
a nested sync helper may legitimately be shipped to an executor thread):

* ``time.sleep(...)``                — use ``await asyncio.sleep(...)``;
* ``<future>.result(...)``          — synchronous ``concurrent.futures``
  result wait; ``await`` the future instead;
* any use of the stdlib ``queue`` module (``queue.Queue().get()/.put()``
  block the thread) — use ``asyncio.Queue`` or a ``collections.deque``
  drained by the event loop.
"""
from __future__ import annotations

import ast

from repro.analysis.lint.core import FileContext, Finding, register
from repro.analysis.lint.rules.common import import_aliases


def _async_body(fn: ast.AsyncFunctionDef):
    """Walk an async def's body without descending into nested defs (each
    nested ``async def`` is visited as its own root by the caller; nested
    sync defs are out of scope for this rule)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


@register
class AsyncBlocking:
    id = "PL004"
    name = "async-blocking"
    description = ("no time.sleep / Future.result() / stdlib queue use "
                   "inside async def (the event loop must never block)")

    def check(self, ctx: FileContext) -> list[Finding]:
        time_mods = import_aliases(ctx.tree, "time")
        time_sleeps = import_aliases(ctx.tree, "time", ("sleep",)) - {"time"}
        queue_names = (import_aliases(ctx.tree, "queue")
                       | import_aliases(ctx.tree, "queue",
                                        ("Queue", "LifoQueue",
                                         "PriorityQueue", "SimpleQueue")))
        out = []
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, ast.AsyncFunctionDef):
                continue
            for node in _async_body(fn):
                if isinstance(node, ast.Call):
                    f = node.func
                    if (isinstance(f, ast.Attribute) and f.attr == "sleep"
                            and isinstance(f.value, ast.Name)
                            and f.value.id in time_mods):
                        out.append(ctx.finding(
                            self, node,
                            "time.sleep blocks the event loop — "
                            "await asyncio.sleep(...) instead"))
                    elif isinstance(f, ast.Name) and f.id in time_sleeps:
                        out.append(ctx.finding(
                            self, node,
                            "time.sleep blocks the event loop — "
                            "await asyncio.sleep(...) instead"))
                    elif isinstance(f, ast.Attribute) and f.attr == "result":
                        out.append(ctx.finding(
                            self, node,
                            ".result() is a synchronous future wait that "
                            "blocks the event loop — await the future (or "
                            "wrap the blocking call in run_in_executor)"))
                elif (isinstance(node, ast.Name) and node.id in queue_names
                        and queue_names):
                    out.append(ctx.finding(
                        self, node,
                        "stdlib queue ops block the thread they run on — "
                        "use asyncio.Queue (or a deque drained by the "
                        "event loop) inside async code"))
        return out

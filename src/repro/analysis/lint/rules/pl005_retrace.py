"""PL005 — retrace-hazard.

``jax.jit`` and ``pl.pallas_call`` *constructions* mint fresh compile caches:
a jitted callable built inside a plain function body is rebuilt — and
retraced from scratch — on every call.  This is the compile-cache-thrashing
bug class the runtime layer fixed in the old ``PipelinedPlane`` (it held one
``_run`` slot and rebuilt the pipeline each time ``n_micro`` alternated);
the fix — memoize compiled pipelines per ``n_micro`` — is now a lintable
discipline.

A jit/pallas_call construction is **allowed** when it demonstrably happens
once per distinct key:

* at module level (including decorators on module-level defs);
* inside a function that is itself jit-decorated — the construction is part
  of a trace, paid once per shape, not once per call;
* inside a ``functools.lru_cache``/``cache``-decorated function;
* inside ``__init__`` — once per object, the engine/executor pattern
  (``SwitchEngine.__init__``, ``SequentialPathExecutor.__init__``);
* when the constructed callable is stored into a subscript — the memo-table
  pattern (``self._runs[n_micro] = jax.jit(...)``).

Everything else is a hazard.  Deploy-time launchers (``launch/``) are out of
scope: they construct one jitted step per process by design.
"""
from __future__ import annotations

import ast

from repro.analysis.lint.core import FileContext, Finding, register
from repro.analysis.lint.rules.common import has_decorator_id

_CTOR = {"jit", "pallas_call"}
_MEMO_IDS = {"lru_cache", "cache"}


def _ctor_name(node: ast.Call) -> str | None:
    f = node.func
    if isinstance(f, ast.Name) and f.id in _CTOR:
        return f.id
    if isinstance(f, ast.Attribute) and f.attr in _CTOR:
        return f.attr
    return None


def _stored_in_subscript(stmt: ast.stmt | None) -> bool:
    if isinstance(stmt, ast.Assign):
        return any(isinstance(t, ast.Subscript) for t in stmt.targets)
    if isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        return isinstance(stmt.target, ast.Subscript)
    return False


@register
class RetraceHazard:
    id = "PL005"
    name = "retrace-hazard"
    description = ("jax.jit / pallas_call constructed in a non-memoized "
                   "function body rebuilds its compile cache per call")

    def check(self, ctx: FileContext) -> list[Finding]:
        if ctx.modpath.startswith("launch/"):
            return []
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            ctor = _ctor_name(node)
            if ctor is None:
                continue
            fns = ctx.enclosing_functions(node)
            if not fns:
                continue   # module level: constructed once per import
            if any(has_decorator_id(fn, _CTOR) for fn in fns):
                continue   # inside a traced function: once per shape
            if any(has_decorator_id(fn, _MEMO_IDS) for fn in fns):
                continue   # the enclosing function is memoized
            if fns[0].name == "__init__":
                continue   # once per object
            if _stored_in_subscript(ctx.statement_of(node)):
                continue   # memo-table store: cache[key] = jit(...)
            out.append(ctx.finding(
                self, node,
                f"{ctor}(...) constructed inside {fns[0].name}() rebuilds "
                "its compile cache every call (the PipelinedPlane thrash "
                "bug) — hoist to module level / __init__, or store it in a "
                "memo table keyed by its static config"))
        return out

"""PL006 — oracle-parity.

ACORN's usability claim is that deployment correctness is validated *before*
anything reaches the data plane (paper §5); IIsy and pForest document how an
in-network model silently diverges from its host-side twin once the mapping
layer drifts.  This repo's equivalent contract: **every public
version-indexed kernel entry ships pre-gated** — a ``*_v`` def in one of the
classify kernel modules (including the fused megakernel) must have

1. a bit-identical oracle: ``kernels/ref.py`` defines the matching base name
   (``tree_walk_pallas_v`` -> ``ref.tree_walk_v``);
2. a dispatch seam: ``kernels/ops.py`` defines the base-name wrapper and its
   body calls *both* the ref oracle and the Pallas entry (so ``mode="ref"``
   and the device path stay swappable per call);
3. conformance reachability: some module in the import closure of
   ``tests/test_conformance.py`` calls the ``ops`` wrapper — the 204-draw
   random-program gate actually exercises it (a call *inside*
   ``kernels/ops.py`` counts when ops itself is in the closure, which is how
   the layerwise tree-walk fallback reaches ``tcam_match_v``).

Any fused-megakernel work that adds a new ``*_v`` entry therefore fails CI
until the oracle, the dispatch table, and the conformance wiring exist — the
cross-file property PR 6's per-file ``FileContext`` could not see.

This is a pure ``check_project`` rule: it runs every run from cached
``ModuleSummary`` facts alone and never forces a re-parse.
"""
from __future__ import annotations

from repro.analysis.lint.core import Finding, register
from repro.analysis.lint.project import ProjectContext

KERNEL_MODULES = (
    "kernels/tree_walk.py",
    "kernels/forest_vote.py",
    "kernels/svm_lookup.py",
    "kernels/tcam_match.py",
    "kernels/classify_fused.py",
)
REF_MODULE = "kernels/ref.py"
OPS_MODULE = "kernels/ops.py"
CONFORMANCE_FILE = "test_conformance.py"


def _conformance_modpath(project: ProjectContext) -> str | None:
    for mp in project.modules:
        if mp.split("/")[-1] == CONFORMANCE_FILE:
            return mp
    return None


def parity_report(project: ProjectContext) -> dict[str, dict]:
    """Audit every public ``*_v`` kernel entry: which of the three legs
    (ref oracle, ops dispatch, conformance reachability) hold.

    Exposed for the acceptance test, which asserts all four shipped entries
    pass all three legs — the rule's findings are this report's failures.
    """
    ref = project.module(REF_MODULE)
    ops = project.module(OPS_MODULE)
    conf = _conformance_modpath(project)
    closure = project.import_closure(conf) if conf else set()

    # every call in the closure resolved once: (target modpath, symbol)
    called: set[tuple[str, str]] = set()
    for mp in closure:
        summ = project.module(mp)
        if summ is None:
            continue
        for fn in summ.functions:
            for call in fn.calls:
                hit = project.resolve(mp, call)
                if hit and hit[1]:
                    called.add((hit[0], hit[1]))

    report: dict[str, dict] = {}
    for kmod in KERNEL_MODULES:
        summ = project.module(kmod)
        if summ is None or summ.parse_error:
            continue
        for name, d in sorted(summ.defs.items()):
            if d["kind"] != "function" or name.startswith("_") \
                    or not name.endswith("_v"):
                continue
            base = name.replace("_pallas", "")
            has_ref = bool(
                ref and ref.defs.get(base, {}).get("kind") == "function")
            dispatcher = ops.function(base) if ops else None
            has_dispatch = False
            if dispatcher is not None:
                resolved = {project.resolve(OPS_MODULE, c)
                            for c in dispatcher.calls}
                has_dispatch = ((REF_MODULE, base) in resolved
                                and (kmod, name) in resolved)
            reachable = conf is not None and (OPS_MODULE, base) in called
            report[name] = {
                "module": kmod, "line": d["line"], "base": base,
                "ref": has_ref, "dispatch": has_dispatch,
                "reachable": reachable,
                "conformance": conf,
            }
    return report


@register
class OracleParity:
    id = "PL006"
    name = "oracle-parity"
    description = ("every public *_v kernel entry needs a kernels/ref.py "
                   "oracle, an ops.py dispatcher calling both paths, and a "
                   "call chain from tests/test_conformance.py")

    def check_project(self, project: ProjectContext) -> list[Finding]:
        out = []
        for name, r in parity_report(project).items():
            summ = project.module(r["module"])
            where = f"{name} ({r['module']})"
            if not r["ref"]:
                out.append(Finding(
                    path=summ.display, line=r["line"], col=0, rule=self.id,
                    name=self.name,
                    message=f"kernel entry {where} has no oracle: "
                            f"{REF_MODULE} defines no {r['base']} — the "
                            "conformance gate has nothing bit-identical to "
                            "pin this kernel against"))
            if not r["dispatch"]:
                out.append(Finding(
                    path=summ.display, line=r["line"], col=0, rule=self.id,
                    name=self.name,
                    message=f"kernel entry {where} is not dispatched: "
                            f"{OPS_MODULE} needs a {r['base']} wrapper whose "
                            f"body calls both ref.{r['base']} and {name} so "
                            "mode='ref' stays swappable per call"))
            if not r["reachable"]:
                why = (f"no module in the import closure of "
                       f"{r['conformance']} calls ops.{r['base']}"
                       if r["conformance"] else
                       "tests/test_conformance.py was not found in or near "
                       "the linted tree")
                out.append(Finding(
                    path=summ.display, line=r["line"], col=0, rule=self.id,
                    name=self.name,
                    message=f"kernel entry {where} is unreachable from the "
                            f"conformance gate: {why} — the random-program "
                            "parity sweep never exercises it"))
        return out

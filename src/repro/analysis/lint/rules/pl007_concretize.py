"""PL007 — concretization-hazard.

``float(x)`` / ``int(x)`` / ``bool(x)`` / ``x.item()`` / ``np.asarray(x)``
on a **traced** value silently forces a device->host transfer and, under
``jit``, either a ``TracerConversionError`` at best or — the nastier serving
class PR 5 hit — an eager fallback path that re-dispatches per call.  The
hazard is a *dataflow* property: the same ``float()`` is fine in a latency
accounting helper and a bug in anything the classify trace can reach.

The rule therefore runs on the whole-project engine:

* a function is **jit/pallas-reachable** when it is jit-decorated (incl.
  ``functools.partial(jax.jit, ...)``), passed to a ``jit(...)``/
  ``pallas_call(...)`` construction (``jax.jit(functools.partial(
  _classify_impl, ...))``), or called — one level of call resolution,
  across modules — from such an entry;
* inside a reachable function, an intraprocedural def-use pass follows
  values flowing from its parameters (assignments, tuple unpacking, loop
  targets); parameters annotated as static scalars (``n_classes: int``,
  ``mode: str | None``) and names listed in ``static_argnames``/
  ``static_argnums`` are exempt, as are flows through ``.shape``/``.ndim``/
  ``.dtype``/``.size``/``len()`` — those are trace-time constants;
* a flagged call concretizes a value whose def-use chain roots in a traced
  parameter.

Cross-file incrementality: the per-file verdicts are cached; the cache key
includes this file's *externally* jit-reachable set (``file_facts``), so a
new caller in another module re-lints this file even though its bytes did
not change.
"""
from __future__ import annotations

import ast

from repro.analysis.lint.core import FileContext, Finding, register
from repro.analysis.lint.project import ProjectContext
from repro.analysis.lint.rules.common import import_aliases

_CAST_FUNCS = {"float", "int", "bool"}
# attribute reads that yield trace-time constants, not traced values
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_STATIC_CALLS = {"len", "isinstance", "type"}


def _np_aliases(tree: ast.AST) -> tuple[set[str], set[str]]:
    """(module aliases of numpy, names asarray was from-imported as)."""
    mods = import_aliases(tree, "numpy")
    funcs = import_aliases(tree, "numpy", ("asarray",)) - mods
    return mods, funcs


class _Scan:
    """One reachable function: forward def-use pass + hazard collection."""

    def __init__(self, rule: "ConcretizationHazard", ctx: FileContext,
                 fn: ast.AST, qual: str, params: list[str],
                 np_mods: set[str], np_funcs: set[str]) -> None:
        self.rule = rule
        self.ctx = ctx
        self.qual = qual
        self.np_mods = np_mods
        self.np_funcs = np_funcs
        self.taint: dict[str, str] = {p: p for p in params}
        self.findings: list[Finding] = []
        for stmt in fn.body:
            self.visit(stmt)

    # --------------------------------------------------------- taint query
    def _origin(self, expr: ast.AST) -> str | None:
        """The parameter a value in ``expr`` flows from, or None.

        Flows through ``.shape``/``.ndim``/``.dtype``/``.size`` or
        ``len(...)`` are static under trace and do not propagate taint.
        """
        for node in ast.walk(expr):
            if not (isinstance(node, ast.Name) and node.id in self.taint):
                continue
            static = False
            cur = node
            while cur is not expr:
                parent = self.ctx.parent(cur)
                if parent is None:
                    break
                if isinstance(parent, ast.Attribute) \
                        and parent.value is cur \
                        and parent.attr in _STATIC_ATTRS:
                    static = True
                    break
                if isinstance(parent, ast.Call) \
                        and isinstance(parent.func, ast.Name) \
                        and parent.func.id in _STATIC_CALLS \
                        and cur is not parent.func:
                    static = True
                    break
                cur = parent
            if not static:
                return self.taint[node.id]
        return None

    # ------------------------------------------------------- hazard check
    def _flag(self, call: ast.Call) -> None:
        f = call.func
        hazard = origin = None
        if isinstance(f, ast.Name) and f.id in _CAST_FUNCS:
            origin = next((o for o in map(self._origin, call.args) if o),
                          None)
            hazard = f"{f.id}()"
        elif isinstance(f, ast.Name) and f.id in self.np_funcs and call.args:
            origin = self._origin(call.args[0])
            hazard = f"{f.id}()"
        elif isinstance(f, ast.Attribute) and f.attr == "item" \
                and not call.args:
            origin = self._origin(f.value)
            hazard = ".item()"
        elif isinstance(f, ast.Attribute) and f.attr == "asarray" \
                and isinstance(f.value, ast.Name) \
                and f.value.id in self.np_mods and call.args:
            origin = self._origin(call.args[0])
            hazard = f"{f.value.id}.asarray()"
        if hazard and origin:
            self.findings.append(self.ctx.finding(
                self.rule, call,
                f"{hazard} concretizes a value flowing from parameter "
                f"'{origin}' of jit/pallas-reachable {self.qual}() — under "
                "trace this forces a device sync (or an eager per-call "
                "fallback, the PR 5 serving bug class); keep the math in "
                "jnp or move the host-side read out of the traced path"))

    def _scan_expr(self, expr: ast.AST | None) -> None:
        if expr is None:
            return
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._flag(node)

    # ------------------------------------------------------ statement walk
    def _assign_names(self, target: ast.AST) -> list[str]:
        return [n.id for n in ast.walk(target)
                if isinstance(n, ast.Name)
                and isinstance(n.ctx, (ast.Store, ast.Del))]

    def visit(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            self._scan_expr(stmt.value)
            origin = self._origin(stmt.value) if stmt.value is not None \
                else None
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for t in targets:
                for name in self._assign_names(t):
                    if origin:
                        self.taint[name] = origin
                    elif not isinstance(stmt, ast.AugAssign):
                        self.taint.pop(name, None)
            return
        if isinstance(stmt, ast.For):
            self._scan_expr(stmt.iter)
            origin = self._origin(stmt.iter)
            for name in self._assign_names(stmt.target):
                if origin:
                    self.taint[name] = origin
            for s in stmt.body + stmt.orelse:
                self.visit(s)
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: a closure traced with the parent — scan its body
            # with the captured taint (its own params shadow)
            saved = dict(self.taint)
            for a in (stmt.args.posonlyargs + stmt.args.args
                      + stmt.args.kwonlyargs):
                self.taint.pop(a.arg, None)
            for s in stmt.body:
                self.visit(s)
            self.taint = saved
            return
        if isinstance(stmt, ast.ClassDef):
            return
        # generic statement: scan embedded expressions, recurse into bodies
        for field in ("value", "test", "iter", "exc", "msg"):
            self._scan_expr(getattr(stmt, field, None))
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._scan_expr(item.context_expr)
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            pass     # already scanned via the "value" field above
        for field in ("body", "orelse", "finalbody"):
            for s in getattr(stmt, field, []) or []:
                if isinstance(s, ast.stmt):
                    self.visit(s)
        for handler in getattr(stmt, "handlers", []) or []:
            for s in handler.body:
                self.visit(s)


def _units(tree: ast.Module):
    """(fn node, qual) for top-level and class-level defs — the same unit
    walk ``project.summarize`` uses, so quals line up with summaries."""
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield stmt, stmt.name
        elif isinstance(stmt, ast.ClassDef):
            for item in stmt.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield item, f"{stmt.name}.{item.name}"


@register
class ConcretizationHazard:
    id = "PL007"
    name = "concretization-hazard"
    description = ("float()/int()/bool()/.item()/np.asarray on values "
                   "flowing from parameters of jit/pallas-reachable "
                   "functions force device syncs on the classify path")

    def file_facts(self, project: ProjectContext, modpath: str) -> list[str]:
        """The cross-file cache key: which of this file's functions other
        modules made jit-reachable.  Drift here re-lints a clean file."""
        return sorted(project.external_jit_reachable(modpath))

    def check_file(self, project: ProjectContext,
                   ctx: FileContext) -> list[Finding]:
        reach = project.jit_reachable(ctx.modpath)
        if not reach:
            return []
        summ = project.module(ctx.modpath)
        np_mods, np_funcs = _np_aliases(ctx.tree)
        out: list[Finding] = []
        for fn, qual in _units(ctx.tree):
            if qual not in reach:
                continue
            info = summ.function(qual) if summ else None
            params = info.params if info else \
                [a.arg for a in fn.args.args if a.arg not in ("self", "cls")]
            out.extend(_Scan(self, ctx, fn, qual, params,
                             np_mods, np_funcs).findings)
        return out

"""PL008 — pragma-hygiene.

``# planelint: disable=<rule>`` is a justified exception, not a mute button.
Every sweep that fixes the underlying code (or every rule whose scope
tightens) can leave a pragma behind that no longer suppresses anything — and
a dead pragma is worse than dead code, because it *pre-silences* the next
real violation introduced on that line.

This is a runner-accounting rule: the engine records, per file, which
``(line, rule)`` findings the pragmas actually swallowed
(``ProjectContext.suppressed`` — cached across runs with the per-file
findings), and this rule reports pragmas that swallowed nothing.

Judgement is scoped to what actually ran:

* a pragma naming rules that were not selected this run is skipped (a
  ``--rule PL003`` pass cannot call a PL002 pragma dead);
* ``disable=all`` is judged only when the full registry ran;
* a pragma naming only PL008 itself is skipped (self-reference);
* with ``--no-pragmas`` the whole rule is skipped — there is no suppression
  to account.

A ``disable=all`` pragma cannot mute the PL008 finding that reports it
(the engine exempts PL008 from blanket suppression — otherwise a stale
``disable=all`` would be unreportable by construction).  To keep a pragma
that is legitimately dormant, name PL008 in its id list:
``disable=PL002,PL008``.
"""
from __future__ import annotations

from repro.analysis.lint.core import Finding, register
from repro.analysis.lint.project import ProjectContext


@register
class PragmaHygiene:
    id = "PL008"
    name = "pragma-hygiene"
    description = ("a '# planelint: disable=...' pragma that suppressed "
                   "nothing this run is stale — remove it")

    def check_project(self, project: ProjectContext) -> list[Finding]:
        if not project.respect_pragmas:
            return []
        rules_ran = {r.upper() for r in project.rules_run} - {self.id}
        out: list[Finding] = []
        for mp, summ in sorted(project.modules.items()):
            # only files whose per-file rules actually ran (live or cached)
            # have suppression accounting to judge against
            if summ.aux or summ.parse_error or mp not in project.linted:
                continue
            sup = project.suppressed.get(mp, set())
            for line, ids in sorted(summ.pragmas.items()):
                ids = {i.upper() for i in ids}
                if ids <= {self.id}:
                    continue
                if "ALL" in ids:
                    if not project.full_rules:
                        continue
                    used = any(l == line for l, _ in sup)
                    label = "all"
                else:
                    relevant = ids & rules_ran
                    if not relevant:
                        continue
                    used = any(l == line and r in relevant for l, r in sup)
                    label = ",".join(sorted(relevant))
                if not used:
                    out.append(Finding(
                        path=summ.display, line=line, col=0, rule=self.id,
                        name=self.name,
                        message=f"pragma 'planelint: disable={label}' "
                                "suppressed nothing — the violation it "
                                "excused is gone; remove the pragma so it "
                                "cannot pre-silence the next real finding "
                                "on this line"))
        return out

"""Three-term roofline model from the compiled dry-run artifact.

    compute    = HLO_FLOPs      / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes      / (chips * 819e9   HBM B/s)
    collective = wire_bytes     / (chips * 50e9    ICI B/s per link)

``cost_analysis()`` supplies FLOPs / bytes-accessed of the *per-device* SPMD
module (CPU backend convention, validated in tests/test_roofline.py against
6·N·D) — so ``chips`` divides only the collective term's aggregate wire
bytes, while compute/memory terms use the per-device numbers directly.

collective_bytes parses the optimized HLO: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we take the
result shape bytes and apply the ring cost factor over the participant group
parsed from ``replica_groups``:

    all-reduce      2 (n-1)/n        all-gather     (n-1)/n
    reduce-scatter  (n-1)/n          all-to-all     (n-1)/n
    collective-permute  1

DCN (pod axis) collectives are charged at ``dcn_gbps`` instead of ICI.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip (TPU v5e)
    hbm_gbps: float = 819e9           # bytes/s / chip
    ici_gbps: float = 50e9            # bytes/s / link
    dcn_gbps: float = 25e9            # bytes/s / chip cross-pod
    hbm_bytes: float = 16e9           # capacity / chip


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum wire bytes per collective kind from optimized HLO text."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "n_ops": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        if size == 0:
            continue
        g = _GROUPS_RE.search(line)
        n = len(g.group(1).split(",")) if g else 2
        n = max(n, 2)
        if kind == "all-reduce":
            wire = 2.0 * size * (n - 1) / n
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = size * (n - 1) / n
        else:  # collective-permute
            wire = float(size)
        out[kind] += wire
        out["n_ops"] += 1
    out["total"] = sum(v for k, v in out.items() if k not in ("n_ops", "total"))
    return out


def model_flops(cfg, seq_len: int, global_batch: int, kind: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token/seq."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n * tokens
    # decode: one new token per sequence
    return 2.0 * n * global_batch


def roofline_terms(
    *,
    hlo_flops: float,            # per-device (cost_analysis convention)
    hlo_bytes: float,            # per-device bytes accessed
    collective_wire_bytes: float,  # aggregate across devices
    chips: int,
    hw: HW = HW(),
) -> dict:
    compute_s = hlo_flops / hw.peak_flops
    memory_s = hlo_bytes / hw.hbm_gbps
    coll_s = collective_wire_bytes / chips / hw.ici_gbps
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "step_s_lower_bound": max(compute_s, memory_s, coll_s),
    }

"""Assigned architectures (10) + shape grid; ``get_config(name)`` registry.

Every entry reproduces the exact public config given in the assignment
(``[source; tier]`` noted per file).  ``smoke_config(name)`` returns the
reduced same-family variant used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ArchConfig

ARCH_IDS = [
    "internlm2-1.8b",
    "internlm2-20b",
    "starcoder2-15b",
    "granite-20b",
    "recurrentgemma-2b",
    "whisper-tiny",
    "grok-1-314b",
    "qwen3-moe-235b-a22b",
    "rwkv6-7b",
    "chameleon-34b",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.ARCH


def smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.SMOKE


def applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §3 skip table)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k decode cache infeasible (skip per spec)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]

"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818; unverified].

Early fusion means image patches arrive as ordinary token ids from a frozen
VQ tokenizer — the modality frontend is a STUB; the backbone is a dense GQA
decoder whose vocab already contains the VQ codes.
"""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8, d_ff=22016, vocab=65536,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256)

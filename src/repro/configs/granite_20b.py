"""granite-20b [dense] — llama-arch, MQA (kv=1), code [arXiv:2405.04324; hf]."""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24576, vocab=49152,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=1, d_ff=128, vocab=256)

"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified]."""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, moe_d_ff=32768,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                    vocab=256, n_experts=4, top_k=2, moe_d_ff=128)

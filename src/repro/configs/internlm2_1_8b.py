"""internlm2-1.8b [dense] — GQA kv=8 [arXiv:2403.17297; hf]."""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192, vocab=92544,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256)

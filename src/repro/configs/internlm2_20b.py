"""internlm2-20b [dense] — GQA kv=8 [arXiv:2403.17297; hf]."""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=92544,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256)

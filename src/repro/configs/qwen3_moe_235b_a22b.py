"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

head_dim=128 per the HF config (q/k/v projections are decoupled from
d_model in qwen3).
"""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=1536, vocab=151936,
    head_dim=128, n_experts=128, top_k=8, moe_d_ff=1536,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                    vocab=256, head_dim=16, n_experts=8, top_k=2, moe_d_ff=64)

"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf].

26 layers = 8 (rec, rec, attn) superblocks + 2 trailing recurrent layers;
local-attention window 2048; RG-LRU width = d_model.  Sub-quadratic =>
runs long_500k.
"""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, d_ff=7680, vocab=256000,
    head_dim=256, window=2048, lru_dim=2560, subquadratic=True,
)
SMOKE = ARCH.scaled(n_layers=5, d_model=64, n_heads=4, n_kv=1, d_ff=128,
                    vocab=256, head_dim=16, window=8, lru_dim=64)

"""rwkv6-7b [ssm] — Finch, data-dependent decay [arXiv:2404.05892; hf].

Attention-free; 64 heads of size 64.  Constant-size state => runs long_500k.
"""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_ff=14336, vocab=65536,
    subquadratic=True,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256)

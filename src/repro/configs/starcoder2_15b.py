"""starcoder2-15b [dense] — GQA kv=4, RoPE [arXiv:2402.19173; hf]."""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, d_ff=24576, vocab=49152,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256)

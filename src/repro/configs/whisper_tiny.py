"""whisper-tiny [audio] — enc-dec; conv frontend STUB [arXiv:2212.04356; unverified].

input_specs() provides precomputed frame embeddings [B, 1500, 384] — the
modality frontend is a stub per the assignment; the transformer backbone
(4L encoder + 4L decoder with cross-attention) is real.
"""
from repro.models.common import ArchConfig

ARCH = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51865,
    n_enc_layers=4, enc_seq=1500, rope_theta=10_000.0,
)
SMOKE = ARCH.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
                    vocab=256, n_enc_layers=2, enc_seq=16)

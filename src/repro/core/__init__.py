"""ACORN core: the paper's contribution as a composable system.

    mlmodels/            trainable model classes (CART, forest, SVM)
    tables.py            the 5 pre-defined MAT types + TCAM prefix expansion
    translator.py        trained model -> TableProgram (stages + entries)
    plane.py             jit-once runtime-programmable switch engine
    packets.py           ACORN header as a packet-batch pytree
    planner.py           MILP (paper) + exact DP deployment optimizer
    topology.py          fat-tree / DCell / BCube / Jellyfish
    netsim.py            latency/overhead model (J_L / J_D / J_O)
    distributed_plane.py per-device program slicing (+ deprecated shims;
                         execution substrates live in repro.runtime)
    baselines/           SwitchTree / LEO / DINC representation models
"""

"""Benchmark systems the paper compares against (§7.2).

Each module implements the system's *table representation* — the entry-count
and feasibility model that drives paper Table 3 (max features), Fig. 9
(TCAM/SRAM scaling) and the accuracy Tables 4/5 (feature limits + DINC's
feasibility-driven model shrinking).

* ``switchtree``  — per-node direct lookups (Lee & Singh 2020)
* ``leo``         — sub-tree multiplexing, <=10 features (Jafri et al. NSDI'24)
* ``dinc``        — Planter/IIsy encoding: per-feature range->code + exact
                    decision table with factorial entry growth (Zheng et al.)
"""
from repro.core.baselines.dinc import dinc_resources, dinc_shrink_to_fit
from repro.core.baselines.leo import leo_resources
from repro.core.baselines.switchtree import switchtree_resources
from repro.core.baselines.common import MAX_FEATURES, BaselineReport, acorn_resources

__all__ = [
    "BaselineReport",
    "MAX_FEATURES",
    "acorn_resources",
    "switchtree_resources",
    "leo_resources",
    "dinc_resources",
    "dinc_shrink_to_fit",
]

"""Shared reporting types + ACORN's own resource model for comparisons."""
from __future__ import annotations

import dataclasses

from repro.core.mlmodels.cart import DecisionTree
from repro.core.mlmodels.forest import RandomForest

__all__ = ["BaselineReport", "MAX_FEATURES", "acorn_resources", "trees_of"]


# Paper Table 3: maximum supported features per model type per system.
MAX_FEATURES: dict[str, dict[str, int | None]] = {
    "switchtree": {"dt": 16, "rf": None, "svm": None},
    "leo": {"dt": 10, "rf": None, "svm": None},
    "dinc": {"dt": 40, "rf": 20, "svm": 8},
    "acorn": {"dt": 46, "rf": 46, "svm": 8},          # hardware run (compiler bug caps SVM)
    "acorn-simulator": {"dt": 46, "rf": 46, "svm": 46},  # paper's simulator path; native here
}


@dataclasses.dataclass
class BaselineReport:
    system: str
    tcam_entries: int
    sram_entries: int
    stages: int
    feasible: bool = True
    notes: str = ""


def trees_of(model) -> list[DecisionTree]:
    if isinstance(model, RandomForest):
        return model.trees_
    if isinstance(model, DecisionTree):
        return [model]
    raise TypeError(type(model).__name__)


def acorn_resources(model, *, feature_width: int = 8) -> BaselineReport:
    """ACORN's own footprint, from the real translator (used in Fig. 9)."""
    from repro.core.translator import translate

    prog = translate(model, feature_width=feature_width)
    return BaselineReport(
        system="acorn",
        tcam_entries=prog.total_tcam_entries(),
        sram_entries=prog.total_sram_entries(),
        stages=prog.n_stages,
    )

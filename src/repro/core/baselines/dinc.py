"""DINC (Zheng et al. 2023) = Planter/IIsy encoding + ILP distribution.

Representation (paper §7.3 analysis): per-feature *range→code* TCAM tables
(one code per threshold-bounded segment of the feature axis) feeding one
exact-match **decision table** that enumerates all code combinations that map
to a leaf.  TCAM is small ("DINC produces the fewest TCAM entries", Fig. 9)
but the decision table's entry count is the product of per-feature segment
counts — "factorial-like growth" that is exactly what blocks >40-feature
models (the paper's 3*10^11-entry Digits example).

Decision-table accounting: IIsy/Planter enumerate the *cells of the threshold
grid* (product of segments) rather than one entry per leaf, because one leaf
region is an axis-aligned box that may span many code combinations on
features it never tested.  We count ``min(prod_f segments_f, cap)`` and mark
infeasibility beyond the cap; ``dinc_shrink_to_fit`` reproduces the paper's
observed behaviour — DINC "forces models to underfit" (§7.3) — by capping
tree leaves until the decision table fits.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines.common import BaselineReport, trees_of
from repro.core.mlmodels.cart import DecisionTree
from repro.core.mlmodels.forest import RandomForest
from repro.core.tables import range_to_prefixes

__all__ = ["dinc_resources", "dinc_shrink_to_fit"]

DEFAULT_ENTRY_CAP = 1 << 22  # ~4M entries: beyond any real switch's SRAM


def _per_feature_segments(trees, n_features: int) -> list[np.ndarray]:
    """Distinct thresholds per feature across the model's trees."""
    thr: list[set[int]] = [set() for _ in range(n_features)]
    for t in trees:
        ta = t.tree_
        for n in range(ta.n_nodes):
            f = int(ta.feature[n])
            if f >= 0:
                thr[f].add(int(ta.threshold[n]))
    return [np.sort(np.asarray(sorted(s), dtype=np.int64)) for s in thr]


def dinc_resources(model, *, feature_width: int = 8,
                   entry_cap: int = DEFAULT_ENTRY_CAP) -> BaselineReport:
    trees = trees_of(model)
    n_features = trees[0].n_features_
    segments = _per_feature_segments(trees, n_features)
    full = (1 << feature_width) - 1

    # Per-feature range->code TCAM tables.
    tcam = 0
    seg_counts = []
    for ths in segments:
        bounds = [-1, *ths.tolist(), full]
        n_seg = len(bounds) - 1
        seg_counts.append(max(n_seg, 1))
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            tcam += len(range_to_prefixes(lo + 1, hi, feature_width))

    # Exact-match decision table: product of segment counts (capped).
    log_entries = float(np.sum(np.log(np.asarray(seg_counts, dtype=np.float64))))
    overflow = log_entries > np.log(entry_cap)
    decision_entries = int(entry_cap) if overflow else int(np.prod(seg_counts))
    # Forests: one decision table per tree + voting — approximated as per-tree
    # products (DINC plans per tree, like ACORN).
    stages = n_features // 8 + len(trees) + 1  # code tables (8/stage) + decisions + vote
    return BaselineReport(
        system="dinc",
        tcam_entries=tcam,
        sram_entries=decision_entries,
        stages=stages,
        feasible=not overflow,
        notes=(f"decision table ~e^{log_entries:.1f} entries > cap {entry_cap}"
               if overflow else ""),
    )


def dinc_shrink_to_fit(
    model_factory,
    Xq: np.ndarray,
    y: np.ndarray,
    *,
    feature_width: int = 8,
    entry_cap: int = DEFAULT_ENTRY_CAP,
    start_leaves: int = 256,
    min_leaves: int = 4,
):
    """Reproduce the paper's DINC underfitting: halve ``max_leaf_nodes`` until
    the Planter decision table fits, then return the (weakened) model.

    ``model_factory(max_leaf_nodes)`` must return an unfit DT/RF.
    """
    leaves = start_leaves
    while leaves >= min_leaves:
        model = model_factory(leaves)
        model.fit(Xq, y)
        rep = dinc_resources(model, feature_width=feature_width, entry_cap=entry_cap)
        if rep.feasible:
            return model, rep, leaves
        leaves //= 2
    model = model_factory(min_leaves)
    model.fit(Xq, y)
    return model, dinc_resources(model, feature_width=feature_width,
                                 entry_cap=entry_cap), min_leaves

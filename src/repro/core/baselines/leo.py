"""LEO (Jafri et al., NSDI'24) representation model: sub-tree multiplexing.

LEO splits a decision tree into sub-trees of ``subtree_size`` internal nodes
and multiplexes the sub-trees of one level through shared tables, saving
stages.  The cost: one sub-tree table matches on the values of *all* features
tested inside the sub-tree, so its ternary entries are the **product** of the
per-node range expansions ("each table matches three inputs, and the
combination of inputs increases the entry usage and offsets the benefits",
paper Fig. 9d).  Feature support is capped at 10 (paper Table 3).
"""
from __future__ import annotations

from repro.core.baselines.common import BaselineReport, trees_of
from repro.core.tables import range_to_prefixes

__all__ = ["leo_resources"]


def _branch_expansion(tree, n, width: int, right: bool) -> int:
    """Prefixes to express one branch condition of node n."""
    t = int(tree.threshold[n])
    full = (1 << width) - 1
    if right:
        return len(range_to_prefixes(t + 1, full, width))
    return len(range_to_prefixes(0, t, width))


def _subtree_entries(tree, group: set[int], root: int, width: int) -> int:
    """One LEO sub-tree table: one ternary entry per leaf-path through the
    sub-tree, each a *combination* of the branch conditions along the path —
    entries = sum over paths of the product of per-branch expansions (the
    Fig. 9d combination blow-up)."""

    def rec(n: int) -> int:
        if n < 0 or n not in group or tree.feature[n] < 0:
            return 1  # exit point of the sub-tree: one entry tail
        left = _branch_expansion(tree, n, width, False) * rec(int(tree.left[n]))
        right = _branch_expansion(tree, n, width, True) * rec(int(tree.right[n]))
        return left + right

    return rec(root)


def leo_resources(model, *, feature_width: int = 8, subtree_size: int = 3,
                  max_stages: int = 20) -> BaselineReport:
    trees = trees_of(model)
    if len(trees) > 1:
        return BaselineReport("leo", 0, 0, 0, False,
                              "LEO is single-tree (Table 3: RF N/A)")
    ta = trees[0].tree_
    # Greedy BFS partition into sub-trees of <= subtree_size internal nodes.
    tcam = 0
    n_subtrees = 0
    visited = set()
    frontier = [0]
    while frontier:
        root = frontier.pop(0)
        if root in visited or ta.feature[root] < 0:
            continue
        group = []
        q = [root]
        while q and len(group) < subtree_size:
            n = q.pop(0)
            if n in visited or ta.feature[n] < 0:
                continue
            visited.add(n)
            group.append(n)
            q.extend([int(ta.left[n]), int(ta.right[n])])
        # children not absorbed become new sub-tree roots
        for n in group:
            for ch in (int(ta.left[n]), int(ta.right[n])):
                if ch >= 0 and ch not in visited and ta.feature[ch] >= 0:
                    frontier.append(ch)
        if group:
            n_subtrees += 1
            tcam += _subtree_entries(ta, set(group), root, feature_width)
    # Multiplexed stages: ceil(depth / subtree depth) with subtrees of one
    # level sharing a stage.
    import math

    sub_depth = max(1, int(math.ceil(math.log2(subtree_size + 1))))
    stages = math.ceil(ta.max_depth / sub_depth)
    n_feat = trees[0].n_features_
    feasible = n_feat <= 10 and stages <= max_stages
    notes = "" if n_feat <= 10 else f"{n_feat} features > LEO max 10"
    return BaselineReport(
        system="leo",
        tcam_entries=tcam,
        sram_entries=ta.n_leaves,
        stages=stages,
        feasible=feasible,
        notes=notes,
    )

"""SwitchTree (Lee & Singh 2020) representation model.

SwitchTree embeds each tree level as if/else match logic over per-node
comparisons realized with SRAM direct lookups: every node's threshold test is
a range lookup on the feature value, so its SRAM usage "is related to the
precision of the inputs and the total number of nodes" (paper §7.6).

Model used here (documented assumption): each internal node costs
``feature_width`` SRAM entries (a bit-serial range-decomposition lookup) plus
one result entry per leaf; one pipeline stage per tree level.  Max 16
features (paper Table 3), decision trees / per-tree forests only.
"""
from __future__ import annotations

from repro.core.baselines.common import BaselineReport, trees_of

__all__ = ["switchtree_resources"]


def switchtree_resources(model, *, feature_width: int = 8,
                         max_stages: int = 20) -> BaselineReport:
    trees = trees_of(model)
    sram = 0
    stages = 0
    for t in trees:
        ta = t.tree_
        n_internal = int((ta.feature >= 0).sum())
        sram += n_internal * feature_width + ta.n_leaves
        stages += ta.max_depth
    n_feat = trees[0].n_features_
    feasible = n_feat <= 16 and stages <= max_stages and len(trees) == 1
    notes = []
    if n_feat > 16:
        notes.append(f"{n_feat} features > SwitchTree max 16")
    if len(trees) > 1:
        notes.append("general multi-tree voting unsupported (Table 3: RF N/A)")
    if stages > max_stages:
        notes.append(f"needs {stages} stages > {max_stages}")
    return BaselineReport(
        system="switchtree",
        tcam_entries=0,                # SwitchTree is SRAM-lookup based
        sram_entries=sram,
        stages=stages,
        feasible=feasible,
        notes="; ".join(notes),
    )

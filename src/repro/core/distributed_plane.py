"""Distributed ACORN data plane over a device mesh (paper Fig. 2 on TPUs).

The deployment plan assigns program stages to switches along a path; here the
"switches" are mesh devices.  Each device holds only *its* table entries (a
partial ``PackedProgram``); the packet batch's intermediates (status codes,
SVM partial sums) ride along between hops — exactly the paper's in-packet
intermediate transport — realized as ``lax.ppermute`` (collective-permute =
the wire).

Two execution modes:

* ``run_sequential``  — functional reference: apply device programs in path
  order on one device.  Used by tests to prove the distributed decomposition
  is semantically identical to the single-switch plane.
* ``PipelinedPlane``  — ``shard_map`` over a ``("switch",)`` mesh axis with a
  GPipe-style ring: microbatch m enters device 0 at step m, hops via
  ppermute, exits device n-1 at step m+n-1.  Steady-state: every "switch"
  processes a different in-flight microbatch each step — the data plane
  pipeline model (TNA), not run-to-completion.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.packets import PacketBatch
from repro.core.plane import PackedProgram, PlaneProfile, _classify_impl, empty_program, install_program
from repro.core.planner import DeploymentPlan
from repro.core.translator import TableProgram

__all__ = [
    "build_device_programs",
    "build_zoo_device_programs",
    "run_sequential",
    "PipelinedPlane",
]


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` moved over jax versions: new jax exposes it at the
    top level (with ``check_vma``), jax<=0.4.x only under
    ``jax.experimental.shard_map`` (with ``check_rep``).  Support both."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp

    return sm_exp(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def build_device_programs(
    program: TableProgram,
    plan: DeploymentPlan,
    profile: PlaneProfile,
) -> tuple[list[str], list[PackedProgram]]:
    """One partial PackedProgram per programmable device on the plan's path,
    in path order (the control plane's per-switch entry updates, §6.2).

    Each partial program carries its own exec image, compiled at this install
    step from exactly the entries the device owns — hops do no per-call
    operand prep, same as the single-switch plane."""
    per_dev = plan.device_stages()
    devices = [d for d in plan.path if d in per_dev]
    progs = []
    for d in devices:
        packed = empty_program(profile)
        packed = install_program(packed, program, profile, stages=per_dev[d])
        progs.append(packed)
    return devices, progs


def build_zoo_device_programs(
    programs: list[TableProgram],
    plans: list[DeploymentPlan],
    profile: PlaneProfile,
) -> tuple[list[str], list[PackedProgram]]:
    """Merge per-version deployment plans into per-device *partial zoos*.

    Each version's plan may place its stages on different devices of the path
    (``plan_zoo`` carries capacity over between versions), so a device ends up
    hosting only the slots of the versions whose stages landed on it.  All
    plans must share one path — the packet still visits devices in one wire
    order, and its intermediates ride the same ppermute ring regardless of
    which versions each hop serves.

    Each merged zoo carries its exec image (rebuilt per installed slot, like
    any install), so distributed classify binds precomputed operands too.
    """
    if len(programs) != len(plans):
        raise ValueError("one plan per program version required")
    if not plans:
        return [], []
    path = plans[0].path
    for p in plans[1:]:
        if p.path != path:
            raise ValueError(
                "zoo plans must share a path (plan them with plan_zoo, which "
                "pins later versions to the first version's path)"
            )
    devices = [
        d for d in path
        if any(d in p.device_stages() for p in plans)
    ]
    progs = []
    for d in devices:
        packed = empty_program(profile)
        for program, plan in zip(programs, plans):
            stages = plan.device_stages().get(d)
            if stages:
                packed = install_program(packed, program, profile,
                                         stages=stages, vid=program.vid)
        progs.append(packed)
    return devices, progs


def run_sequential(
    device_programs: list[PackedProgram],
    batch: PacketBatch,
    *,
    n_classes: int,
    mode: str | None = None,
) -> PacketBatch:
    """Reference semantics: the batch visits each device in path order."""
    for packed in device_programs:
        batch = _classify_impl(packed, batch, n_classes=n_classes, mode=mode)
    return batch


class PipelinedPlane:
    """shard_map ring pipeline across a 'switch' mesh axis."""

    def __init__(
        self,
        device_programs: list[PackedProgram],
        *,
        n_classes: int,
        mode: str | None = None,
        devices=None,
    ) -> None:
        self.n_dev = len(device_programs)
        if devices is None:
            devices = jax.devices()[: self.n_dev]
        if len(devices) < self.n_dev:
            raise ValueError(f"need {self.n_dev} devices, have {len(devices)}")
        self.mesh = Mesh(devices, ("switch",))
        self.n_classes = n_classes
        self.mode = mode
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *device_programs)
        sharding = NamedSharding(self.mesh, P("switch"))
        self.packed = jax.tree.map(lambda x: jax.device_put(x, sharding), stacked)
        self._run = None

    def _build(self, n_micro: int):
        n_dev, n_classes, mode = self.n_dev, self.n_classes, self.mode
        n_steps = n_micro + n_dev - 1
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        @functools.partial(
            _shard_map,
            mesh=self.mesh,
            in_specs=(P("switch"), P(None)),
            out_specs=P(None, "switch"),
        )
        def pipeline(packed_stack, micro):
            packed = jax.tree.map(lambda x: x[0], packed_stack)
            idx = jax.lax.axis_index("switch")

            def step(state, s):
                inj = jax.tree.map(
                    lambda x: jnp.take(x, jnp.minimum(s, n_micro - 1), axis=0), micro
                )
                mb = jax.tree.map(
                    lambda a, b: jnp.where(idx == 0, a, b), inj, state
                )
                out = _classify_impl(packed, mb, n_classes=n_classes, mode=mode)
                nxt = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, "switch", perm), out
                )
                return nxt, out

            init = jax.tree.map(
                lambda x: jnp.zeros_like(x[0]), micro
            )
            _, outs = jax.lax.scan(step, init, jnp.arange(n_steps))
            # leading axis: steps; device axis added by out_specs on axis 1
            return jax.tree.map(lambda x: x[:, None], outs)

        return jax.jit(pipeline)

    def run(self, microbatches: PacketBatch) -> PacketBatch:
        """``microbatches`` has leading axis [n_micro, B_mb]. Returns the
        classified packets re-concatenated in microbatch order: one flat
        [n_micro * B_mb] batch, matching the input packet order."""
        n_micro = microbatches.packet_id.shape[0]
        if self._run is None or self._n_micro != n_micro:
            self._run = self._build(n_micro)
            self._n_micro = n_micro
        outs = self._run(self.packed, microbatches)
        n_dev = self.n_dev
        # microbatch m exits the last device at step m + n_dev - 1
        sel = jax.tree.map(
            lambda x: x[n_dev - 1 :, n_dev - 1], outs
        )  # [n_micro, B_mb, ...]
        return jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), sel)

    def swap_model(self, device_programs: list[PackedProgram]) -> None:
        """Runtime reprogram: new entry arrays + their install-time exec
        images (stacked and resharded with the tables), same compiled
        pipeline."""
        if len(device_programs) != self.n_dev:
            raise ValueError("device count changed — replan instead")
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *device_programs)
        sharding = NamedSharding(self.mesh, P("switch"))
        self.packed = jax.tree.map(lambda x: jax.device_put(x, sharding), stacked)

"""Distributed ACORN plane: per-device program slicing (paper Fig. 2 on TPUs).

The deployment plan assigns program stages to switches along a path; here the
"switches" are mesh devices.  Each device holds only *its* table entries (a
partial ``PackedProgram``); the packet batch's intermediates (status codes,
SVM partial sums) ride along between hops — exactly the paper's in-packet
intermediate transport.

This module owns the **install side** of that story — slicing a
``TableProgram`` (or a whole zoo of them) into per-device partial programs.
The **execution side** lives in the ``repro.runtime`` package: a
``SequentialPathExecutor`` is the functional reference, a
``PipelinedExecutor`` runs the shard_map ring pipeline, and a
``ShardedExecutor`` adds data-parallel port lanes on a 2D mesh.
``run_sequential`` and ``PipelinedPlane`` survive here only as thin
deprecated shims over those executors.
"""
from __future__ import annotations

from repro.core.packets import PacketBatch
from repro.core.plane import PackedProgram, PlaneProfile, empty_program, install_program
from repro.core.planner import DeploymentPlan
from repro.core.translator import TableProgram
from repro.runtime.executors import PipelinedExecutor, SequentialPathExecutor

__all__ = [
    "build_device_programs",
    "build_zoo_device_programs",
    "run_sequential",
    "PipelinedPlane",
]


def build_device_programs(
    program: TableProgram,
    plan: DeploymentPlan,
    profile: PlaneProfile,
) -> tuple[list[str], list[PackedProgram]]:
    """One partial PackedProgram per programmable device on the plan's path,
    in path order (the control plane's per-switch entry updates, §6.2).

    Each partial program carries its own exec image, compiled at this install
    step from exactly the entries the device owns — hops do no per-call
    operand prep, same as the single-switch plane."""
    per_dev = plan.device_stages()
    devices = [d for d in plan.path if d in per_dev]
    progs = []
    for d in devices:
        packed = empty_program(profile)
        packed = install_program(packed, program, profile, stages=per_dev[d])
        progs.append(packed)
    return devices, progs


def build_zoo_device_programs(
    programs: list[TableProgram],
    plans: list[DeploymentPlan],
    profile: PlaneProfile,
) -> tuple[list[str], list[PackedProgram]]:
    """Merge per-version deployment plans into per-device *partial zoos*.

    Each version's plan may place its stages on different devices of the path
    (``plan_zoo`` carries capacity over between versions), so a device ends up
    hosting only the slots of the versions whose stages landed on it.  All
    plans must share one path — the packet still visits devices in one wire
    order, and its intermediates ride the same ppermute ring regardless of
    which versions each hop serves.

    Each merged zoo carries its exec image (rebuilt per installed slot, like
    any install), so distributed classify binds precomputed operands too.
    """
    if len(programs) != len(plans):
        raise ValueError("one plan per program version required")
    if not plans:
        return [], []
    path = plans[0].path
    for p in plans[1:]:
        if p.path != path:
            raise ValueError(
                "zoo plans must share a path (plan them with plan_zoo, which "
                "pins later versions to the first version's path)"
            )
    devices = [
        d for d in path
        if any(d in p.device_stages() for p in plans)
    ]
    progs = []
    for d in devices:
        packed = empty_program(profile)
        for program, plan in zip(programs, plans):
            stages = plan.device_stages().get(d)
            if stages:
                packed = install_program(packed, program, profile,
                                         stages=stages, vid=program.vid)
        progs.append(packed)
    return devices, progs


def run_sequential(
    device_programs: list[PackedProgram],
    batch: PacketBatch,
    *,
    n_classes: int,
    mode: str | None = None,
) -> PacketBatch:
    """Deprecated shim — reference semantics: the batch visits each device in
    path order.  New code should hold a ``repro.runtime``
    ``SequentialPathExecutor`` (jitted, swap-able) behind a
    ``DataplaneRuntime`` instead of re-tracing this eager loop per call."""
    return SequentialPathExecutor(
        device_programs, n_classes=n_classes, mode=mode, jit=False
    ).classify(batch)


class PipelinedPlane:
    """Deprecated shim over ``repro.runtime.PipelinedExecutor``.

    Kept for source compatibility only; the executor owns the shard_map ring
    and memoizes compiled pipelines per ``n_micro`` (the old single-slot
    ``_run`` rebuilt whenever the microbatch count alternated)."""

    def __init__(
        self,
        device_programs: list[PackedProgram],
        *,
        n_classes: int,
        mode: str | None = None,
        devices=None,
    ) -> None:
        self._executor = PipelinedExecutor(
            device_programs, n_classes=n_classes, mode=mode, devices=devices)
        self.n_dev = self._executor.n_switch

    @property
    def mesh(self):
        return self._executor.mesh

    @property
    def packed(self):
        return self._executor.packed

    def run(self, microbatches: PacketBatch) -> PacketBatch:
        """``microbatches`` has leading axis [n_micro, B_mb]. Returns the
        classified packets re-concatenated in microbatch order: one flat
        [n_micro * B_mb] batch, matching the input packet order."""
        return self._executor.run(microbatches)

    def swap_model(self, device_programs: list[PackedProgram]) -> None:
        """Runtime reprogram: new entry arrays + their install-time exec
        images (stacked and resharded with the tables), same compiled
        pipelines."""
        self._executor.swap(device_programs)

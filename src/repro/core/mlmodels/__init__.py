"""Classical-ML substrate for ACORN (paper §4): the model classes the data
plane can host.

No sklearn in this container — CART decision trees, bagging random forests and
linear SVMs are implemented from scratch on numpy, with the *quantization-first*
twist that makes them data-plane-translatable: features are min-max scaled and
quantized to ``precision_bits`` fixed-point integers **before** training, so
every learned threshold is an integer the switch can ternary-match.
"""
from repro.core.mlmodels.cart import DecisionTree, TreeArrays
from repro.core.mlmodels.forest import RandomForest
from repro.core.mlmodels.linsvm import LinearSVM
from repro.core.mlmodels.metrics import accuracy, cohen_kappa, confusion_matrix, macro_f1
from repro.core.mlmodels.preprocess import Quantizer, rfe_select

__all__ = [
    "DecisionTree",
    "TreeArrays",
    "RandomForest",
    "LinearSVM",
    "Quantizer",
    "rfe_select",
    "accuracy",
    "macro_f1",
    "cohen_kappa",
    "confusion_matrix",
]

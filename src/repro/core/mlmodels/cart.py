"""CART decision tree on quantized integer features (no sklearn).

ACORN's data plane tests ``x[f] <= t`` with integer thresholds, so the tree is
trained *directly on quantized features* (see ``preprocess.Quantizer``): every
learned threshold is an exact integer the switch can ternary-match, which is
what keeps the in-network model and the trained model identical (Cohen's
kappa = 1 against itself by construction).

The trainer is histogram-CART: features live in ``[0, levels)`` so per-node
split search is a ``bincount`` over (level, class) followed by a vectorized
Gini sweep over all thresholds — O(levels * classes) per (node, feature),
orders faster than sort-based CART and exact for integer features.

Trees grow *best-first* (largest impurity decrease first, like sklearn with
``max_leaf_nodes``), bounded by ``max_depth`` / ``max_leaf_nodes`` /
``min_samples_*``.  Every node carries its ``path`` code — bit ``d`` of the
code is the left(0)/right(1) decision taken at depth ``d`` — which is exactly
the status code ACORN's ``dt_layer`` tables accumulate in the packet header
(paper §4.1, Figure 3).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator

import numpy as np

__all__ = ["DecisionTree", "TreeArrays"]


@dataclasses.dataclass
class TreeArrays:
    """Struct-of-arrays tree; index 0 is the root.

    ``feature[i] == -1`` marks a leaf.  Internal nodes test
    ``x[feature[i]] <= threshold[i]`` → go left, else right.
    ``path[i]`` packs the root→node decisions: bit ``d`` is the branch taken
    at depth ``d`` (0 = left).  ``label[i]`` is the majority class of the
    training samples that reached the node (defined for internal nodes too —
    used for early-exit/truncated inference).
    """

    feature: np.ndarray    # int32 [n]
    threshold: np.ndarray  # int32 [n]
    left: np.ndarray       # int32 [n], -1 at leaves
    right: np.ndarray      # int32 [n]
    label: np.ndarray      # int32 [n]
    depth: np.ndarray      # int32 [n]
    path: np.ndarray       # uint64 [n]
    n_node_samples: np.ndarray  # int64 [n]
    value: np.ndarray      # float64 [n, n_classes] class distribution

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(np.sum(self.feature < 0))

    @property
    def max_depth(self) -> int:
        return int(self.depth.max(initial=0))

    def leaves(self) -> np.ndarray:
        return np.flatnonzero(self.feature < 0)

    def internal_by_depth(self) -> Iterator[tuple[int, np.ndarray]]:
        """Yield (depth, node_indices) for internal nodes, shallow→deep."""
        internal = np.flatnonzero(self.feature >= 0)
        if internal.size == 0:
            return
        for d in range(int(self.depth[internal].max()) + 1):
            sel = internal[self.depth[internal] == d]
            if sel.size:
                yield d, sel


class _Node:
    __slots__ = ("idx", "sample_idx", "depth", "path", "hist")

    def __init__(self, idx, sample_idx, depth, path, hist):
        self.idx = idx
        self.sample_idx = sample_idx
        self.depth = depth
        self.path = path
        self.hist = hist  # class histogram, int64 [n_classes]


def _gini(counts: np.ndarray) -> float:
    n = counts.sum()
    if n == 0:
        return 0.0
    p = counts / n
    return float(1.0 - np.sum(p * p))


class DecisionTree:
    """Histogram-CART over integer features in ``[0, levels)``."""

    def __init__(
        self,
        max_depth: int = 8,
        *,
        levels: int = 256,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_leaf_nodes: int | None = None,
        max_features: int | float | str | None = None,
        min_impurity_decrease: float = 0.0,
        random_state: int | np.random.Generator | None = 0,
    ) -> None:
        if max_depth < 1 or max_depth > 64:
            raise ValueError("max_depth must be in [1, 64] (path codes are 64-bit)")
        self.max_depth = int(max_depth)
        self.levels = int(levels)
        self.min_samples_split = int(min_samples_split)
        self.min_samples_leaf = int(min_samples_leaf)
        self.max_leaf_nodes = max_leaf_nodes
        self.max_features = max_features
        self.min_impurity_decrease = float(min_impurity_decrease)
        self._rng = (
            random_state
            if isinstance(random_state, np.random.Generator)
            else np.random.default_rng(random_state)
        )
        self.tree_: TreeArrays | None = None
        self.n_classes_: int | None = None
        self.n_features_: int | None = None

    # ------------------------------------------------------------------ fit
    def fit(self, Xq: np.ndarray, y: np.ndarray) -> "DecisionTree":
        Xq = np.asarray(Xq)
        y = np.asarray(y, dtype=np.int64)
        if Xq.ndim != 2:
            raise ValueError("Xq must be 2-D")
        if Xq.min(initial=0) < 0 or Xq.max(initial=0) >= self.levels:
            raise ValueError(f"features must be quantized ints in [0, {self.levels})")
        Xq = Xq.astype(np.int64, copy=False)
        n, F = Xq.shape
        self.n_features_ = F
        C = int(y.max()) + 1 if y.size else 1
        self.n_classes_ = C

        feature = [0]
        threshold = [0]
        left = [-1]
        right = [-1]
        label = [0]
        depth_a = [0]
        path_a = [np.uint64(0)]
        nsamp = [n]
        value = [np.zeros(C)]

        def node_hist(sample_idx):
            return np.bincount(y[sample_idx], minlength=C)

        root = _Node(0, np.arange(n), 0, np.uint64(0), node_hist(np.arange(n)))
        feature[0] = -1
        label[0] = int(np.argmax(root.hist))
        value[0] = root.hist.astype(np.float64)

        # Best-first frontier: (-gain, tiebreak, node, split)
        heap: list = []
        tiebreak = 0

        def push(node: _Node) -> None:
            nonlocal tiebreak
            split = self._best_split(Xq, y, node)
            if split is not None:
                gain, f, t = split
                heapq.heappush(heap, (-gain, tiebreak, node, f, t))
                tiebreak += 1

        push(root)
        n_leaves = 1
        max_leaves = self.max_leaf_nodes if self.max_leaf_nodes is not None else 1 << 62

        while heap and n_leaves < max_leaves:
            neg_gain, _, node, f, t = heapq.heappop(heap)
            if -neg_gain < self.min_impurity_decrease:
                break
            mask = Xq[node.sample_idx, f] <= t
            li, ri = node.sample_idx[mask], node.sample_idx[~mask]
            # Turn `node` into an internal node, create two leaf children.
            feature[node.idx] = f
            threshold[node.idx] = t
            kids = []
            for branch, sidx in ((0, li), (1, ri)):
                cidx = len(feature)
                h = node_hist(sidx)
                cpath = np.uint64(node.path) | (np.uint64(branch) << np.uint64(node.depth))
                feature.append(-1)
                threshold.append(0)
                left.append(-1)
                right.append(-1)
                label.append(int(np.argmax(h)))
                depth_a.append(node.depth + 1)
                path_a.append(cpath)
                nsamp.append(len(sidx))
                value.append(h.astype(np.float64))
                kids.append(_Node(cidx, sidx, node.depth + 1, cpath, h))
            left[node.idx], right[node.idx] = kids[0].idx, kids[1].idx
            n_leaves += 1
            for kid in kids:
                push(kid)

        self.tree_ = TreeArrays(
            feature=np.asarray(feature, np.int32),
            threshold=np.asarray(threshold, np.int32),
            left=np.asarray(left, np.int32),
            right=np.asarray(right, np.int32),
            label=np.asarray(label, np.int32),
            depth=np.asarray(depth_a, np.int32),
            path=np.asarray(path_a, np.uint64),
            n_node_samples=np.asarray(nsamp, np.int64),
            value=np.asarray(value, np.float64),
        )
        return self

    def _feature_subset(self, F: int) -> np.ndarray:
        mf = self.max_features
        if mf is None:
            return np.arange(F)
        if mf == "sqrt":
            k = max(1, int(np.sqrt(F)))
        elif mf == "log2":
            k = max(1, int(np.log2(F)))
        elif isinstance(mf, float):
            k = max(1, int(mf * F))
        else:
            k = min(int(mf), F)
        return self._rng.choice(F, size=k, replace=False)

    def _best_split(self, Xq, y, node: _Node):
        """Return (gain, feature, threshold) or None."""
        sidx = node.sample_idx
        n = sidx.size
        if (
            n < self.min_samples_split
            or node.depth >= self.max_depth
            or _gini(node.hist) == 0.0
        ):
            return None
        C = self.n_classes_
        L = self.levels
        parent_gini = _gini(node.hist)
        ysub = y[sidx]
        best = None  # (gain, f, t)
        for f in self._feature_subset(Xq.shape[1]):
            col = Xq[sidx, f]
            hist = np.bincount(col * C + ysub, minlength=L * C).reshape(L, C)
            cum = np.cumsum(hist, axis=0)          # [L, C]; cum[t] = counts with x<=t
            nl = cum.sum(axis=1)                   # [L]
            nr = n - nl
            valid = (nl >= self.min_samples_leaf) & (nr >= self.min_samples_leaf)
            valid[-1] = False                      # t == L-1 sends all left
            if not valid.any():
                continue
            with np.errstate(divide="ignore", invalid="ignore"):
                pl = cum / np.maximum(nl, 1)[:, None]
                pr = (node.hist[None, :] - cum) / np.maximum(nr, 1)[:, None]
                gl = 1.0 - np.sum(pl * pl, axis=1)
                gr = 1.0 - np.sum(pr * pr, axis=1)
            w = (nl * gl + nr * gr) / n
            w = np.where(valid, w, np.inf)
            t = int(np.argmin(w))
            gain = parent_gini - w[t]
            if gain > 0 and (best is None or gain > best[0]):
                best = (float(gain), int(f), t)
        return best

    # -------------------------------------------------------------- predict
    def decision_path_codes(self, Xq: np.ndarray, *, max_layers: int | None = None):
        """Vectorized tree walk.

        Returns ``(leaf_idx, codes)`` where ``codes`` is the accumulated
        status code per sample (bit d = branch at depth d) — the oracle for
        ACORN's data-plane status codes.
        """
        t = self._require_tree()
        Xq = np.asarray(Xq, dtype=np.int64)
        n = Xq.shape[0]
        cur = np.zeros(n, dtype=np.int64)
        codes = np.zeros(n, dtype=np.uint64)
        layers = t.max_depth if max_layers is None else min(max_layers, t.max_depth)
        for d in range(layers):
            f = t.feature[cur]
            active = f >= 0
            if not active.any():
                break
            fx = Xq[np.arange(n), np.where(active, f, 0)]
            go_right = active & (fx > t.threshold[cur])
            go_left = active & ~go_right
            codes |= (go_right.astype(np.uint64) << np.uint64(d))
            nxt = np.where(go_left, t.left[cur], np.where(go_right, t.right[cur], cur))
            cur = nxt
        return cur, codes

    def predict(self, Xq: np.ndarray) -> np.ndarray:
        t = self._require_tree()
        leaf, _ = self.decision_path_codes(Xq)
        return t.label[leaf].astype(np.int64)

    def predict_proba(self, Xq: np.ndarray) -> np.ndarray:
        t = self._require_tree()
        leaf, _ = self.decision_path_codes(Xq)
        v = t.value[leaf]
        return v / np.maximum(v.sum(axis=1, keepdims=True), 1)

    def _require_tree(self) -> TreeArrays:
        if self.tree_ is None:
            raise RuntimeError("fit() before predict()")
        return self.tree_

    # ------------------------------------------------------------- metadata
    @property
    def n_layers(self) -> int:
        """Pipeline stages a switch needs for this tree (one per layer)."""
        return self._require_tree().max_depth

    def feature_importances_(self) -> np.ndarray:
        """Impurity-decrease importances (for RFE, paper §7.2)."""
        t = self._require_tree()
        imp = np.zeros(self.n_features_, dtype=np.float64)
        total = t.n_node_samples[0]
        for i in range(t.n_nodes):
            f = t.feature[i]
            if f < 0:
                continue
            l, r = t.left[i], t.right[i]
            gi = _gini(t.value[i])
            gl = _gini(t.value[l])
            gr = _gini(t.value[r])
            nl, nr, nn = t.n_node_samples[l], t.n_node_samples[r], t.n_node_samples[i]
            imp[f] += (nn * gi - nl * gl - nr * gr) / total
        s = imp.sum()
        return imp / s if s > 0 else imp

"""Bagging random forest over histogram-CART trees (paper §4.2).

ACORN decomposes a forest into independent per-tree ``dt_layer`` pipelines plus
one ``multitree_voting`` exact-match table.  The trainer here mirrors sklearn's
``RandomForestClassifier`` defaults closely enough for the paper's workloads:
bootstrap sampling + sqrt-feature subsetting per split, majority vote at
inference.  Weighted voting (paper: "majority voting and weighted summation
can all be represented as voting") is supported through ``tree_weights``.
"""
from __future__ import annotations

import numpy as np

from repro.core.mlmodels.cart import DecisionTree

__all__ = ["RandomForest"]


class RandomForest:
    def __init__(
        self,
        n_estimators: int = 5,
        max_depth: int = 8,
        *,
        levels: int = 256,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_leaf_nodes: int | None = None,
        max_features: int | float | str | None = "sqrt",
        bootstrap: bool = True,
        tree_weights: np.ndarray | None = None,
        random_state: int = 0,
    ) -> None:
        self.n_estimators = int(n_estimators)
        self.max_depth = int(max_depth)
        self.levels = int(levels)
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_leaf_nodes = max_leaf_nodes
        self.max_features = max_features
        self.bootstrap = bootstrap
        self.tree_weights = tree_weights
        self.random_state = int(random_state)
        self.trees_: list[DecisionTree] = []
        self.n_classes_: int | None = None
        self.n_features_: int | None = None

    def fit(self, Xq: np.ndarray, y: np.ndarray) -> "RandomForest":
        Xq = np.asarray(Xq)
        y = np.asarray(y, dtype=np.int64)
        n = Xq.shape[0]
        self.n_features_ = Xq.shape[1]
        self.n_classes_ = int(y.max()) + 1
        rng = np.random.default_rng(self.random_state)
        self.trees_ = []
        for _ in range(self.n_estimators):
            idx = rng.integers(0, n, size=n) if self.bootstrap else np.arange(n)
            tree = DecisionTree(
                max_depth=self.max_depth,
                levels=self.levels,
                min_samples_split=self.min_samples_split,
                min_samples_leaf=self.min_samples_leaf,
                max_leaf_nodes=self.max_leaf_nodes,
                max_features=self.max_features,
                random_state=np.random.default_rng(rng.integers(0, 2**63)),
            )
            tree.fit(Xq[idx], y[idx])
            # Forest trees must share the class space even if a bootstrap
            # sample misses a class.
            tree.n_classes_ = self.n_classes_
            self.trees_.append(tree)
        return self

    # -------------------------------------------------------------- predict
    def tree_votes(self, Xq: np.ndarray) -> np.ndarray:
        """Per-tree labels, shape [n_samples, n_estimators] — the inputs to
        ACORN's ``multitree_voting`` table."""
        return np.stack([t.predict(Xq) for t in self.trees_], axis=1)

    def vote(self, votes: np.ndarray) -> np.ndarray:
        """Combine per-tree labels (the ``multitree_voting`` semantics)."""
        C = self.n_classes_
        w = (
            np.ones(len(self.trees_))
            if self.tree_weights is None
            else np.asarray(self.tree_weights, dtype=np.float64)
        )
        onehot = np.eye(C)[votes]                      # [n, trees, C]
        scores = np.tensordot(onehot, w, axes=([1], [0]))  # [n, C]
        # Ties break toward the smaller class id (argmax convention) — the
        # voting table must enumerate the same convention.
        return np.argmax(scores, axis=1).astype(np.int64)

    def predict(self, Xq: np.ndarray) -> np.ndarray:
        return self.vote(self.tree_votes(Xq))

    def feature_importances_(self) -> np.ndarray:
        imps = np.stack([t.feature_importances_() for t in self.trees_])
        return imps.mean(axis=0)

    @property
    def n_layers(self) -> int:
        return max(t.n_layers for t in self.trees_)

"""Linear SVM (one-vs-one / one-vs-rest) trained on quantized features.

ACORN's SVM data plane (paper §4.3) holds *precomputed products* ``w_hi * x_i``
in ``svm_mul`` exact-match tables, sums them with the native signed adder and
keeps only the sign bit of each hyperplane.  To make the trained model and the
data-plane model the same object, we train on the quantizer's *bin centers*
(floats in [0,1)) and expose:

  * ``decision_values``  — float hyperplane scores (the "server/CPU" model),
  * ``decision_signs``   — sign bits as the switch computes them,
  * ``predict``          — majority vote over hyperplane signs (paper §C.2:
    "extracts the signed bit for each hyperplane ... majority voting").

Training is full-batch L2-regularized hinge subgradient descent with a
decaying step — deterministic, no sklearn.
"""
from __future__ import annotations

import itertools

import numpy as np

__all__ = ["LinearSVM"]


def _fit_binary(X, y_pm, C, epochs, lr):
    """Full-batch hinge subgradient descent with tail Polyak averaging."""
    n, F = X.shape
    w = np.zeros(F)
    b = 0.0
    Cn = C / n
    w_avg = np.zeros(F)
    b_avg = 0.0
    n_avg = 0
    tail = epochs // 2
    for e in range(epochs):
        margins = y_pm * (X @ w + b)
        viol = margins < 1.0
        # subgradient of 0.5||w||^2 + Cn * sum hinge
        gw = w - Cn * (y_pm[viol, None] * X[viol]).sum(axis=0)
        gb = -Cn * y_pm[viol].sum()
        step = lr / (1.0 + 0.02 * e)
        w -= step * gw
        b -= step * gb
        if e >= tail:
            w_avg += w
            b_avg += b
            n_avg += 1
    return w_avg / max(n_avg, 1), b_avg / max(n_avg, 1)


class LinearSVM:
    """Multi-class linear SVM with voting-compatible decision structure."""

    def __init__(
        self,
        C: float = 100.0,
        *,
        multi_class: str = "ovo",
        levels: int = 256,
        epochs: int = 800,
        lr: float = 0.1,
        random_state: int = 0,
    ) -> None:
        if multi_class not in ("ovo", "ovr"):
            raise ValueError("multi_class must be 'ovo' or 'ovr'")
        self.C = float(C)
        self.multi_class = multi_class
        self.levels = int(levels)
        self.epochs = int(epochs)
        self.lr = float(lr)
        self.random_state = random_state
        self.W_: np.ndarray | None = None      # [H, F]
        self.b_: np.ndarray | None = None      # [H]
        self.pairs_: list[tuple[int, int]] = []  # ovo: hyperplane h separates (i, j)
        self.n_classes_: int | None = None
        self.n_features_: int | None = None

    # ----------------------------------------------------------------- util
    def _unit(self, Xq: np.ndarray) -> np.ndarray:
        """Quantized ints → bin centers in [0, 1) (matches Quantizer)."""
        return (np.asarray(Xq, dtype=np.float64) + 0.5) / self.levels

    # ------------------------------------------------------------------ fit
    def fit(self, Xq: np.ndarray, y: np.ndarray) -> "LinearSVM":
        X = self._unit(Xq)
        y = np.asarray(y, dtype=np.int64)
        C_ = int(y.max()) + 1
        self.n_classes_ = C_
        self.n_features_ = X.shape[1]
        Ws, bs, pairs = [], [], []
        if self.multi_class == "ovo":
            for i, j in itertools.combinations(range(C_), 2):
                m = (y == i) | (y == j)
                y_pm = np.where(y[m] == i, 1.0, -1.0)
                w, b = _fit_binary(X[m], y_pm, self.C, self.epochs, self.lr)
                Ws.append(w)
                bs.append(b)
                pairs.append((i, j))
        else:  # ovr
            if C_ == 2:
                y_pm = np.where(y == 1, 1.0, -1.0)
                w, b = _fit_binary(X, y_pm, self.C, self.epochs, self.lr)
                Ws, bs, pairs = [w], [b], [(1, 0)]
            else:
                for i in range(C_):
                    y_pm = np.where(y == i, 1.0, -1.0)
                    w, b = _fit_binary(X, y_pm, self.C, self.epochs, self.lr)
                    Ws.append(w)
                    bs.append(b)
                    pairs.append((i, -1))
        self.W_ = np.stack(Ws)
        self.b_ = np.asarray(bs)
        self.pairs_ = pairs
        return self

    @property
    def n_hyperplanes(self) -> int:
        return 0 if self.W_ is None else self.W_.shape[0]

    # -------------------------------------------------------------- predict
    def decision_values(self, Xq: np.ndarray) -> np.ndarray:
        """Float hyperplane scores [n, H] (the server-side model)."""
        if self.W_ is None:
            raise RuntimeError("fit() first")
        return self._unit(Xq) @ self.W_.T + self.b_

    def decision_signs(self, Xq: np.ndarray) -> np.ndarray:
        """Sign bits [n, H]: 1 where score >= 0 (switch keeps only this)."""
        return (self.decision_values(Xq) >= 0).astype(np.int64)

    def votes_from_signs(self, signs: np.ndarray) -> np.ndarray:
        """Majority vote over hyperplane sign bits → labels.

        This is the exact semantics of ACORN's ``svm_predict`` table, so the
        table generator enumerates this function.
        """
        n = signs.shape[0]
        C_ = self.n_classes_
        scores = np.zeros((n, C_))
        for h, (i, j) in enumerate(self.pairs_):
            pos = signs[:, h] == 1
            if j >= 0:  # ovo
                scores[pos, i] += 1
                scores[~pos, j] += 1
            else:  # ovr: sign only votes for class i
                scores[pos, i] += 1
        if self.multi_class == "ovr" and C_ == 2:
            return signs[:, 0].astype(np.int64)
        return np.argmax(scores, axis=1).astype(np.int64)

    def predict(self, Xq: np.ndarray) -> np.ndarray:
        return self.votes_from_signs(self.decision_signs(Xq))

"""Classification metrics used throughout the paper's tables (no sklearn).

The paper reports accuracy, macro-F1 and Cohen's kappa; kappa is used to
measure agreement between the in-network prediction and the server-side
(float) model (Tables 4/5).
"""
from __future__ import annotations

import numpy as np

__all__ = ["accuracy", "macro_f1", "cohen_kappa", "confusion_matrix"]


def confusion_matrix(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int | None = None) -> np.ndarray:
    y_true = np.asarray(y_true, dtype=np.int64)
    y_pred = np.asarray(y_pred, dtype=np.int64)
    if y_true.shape != y_pred.shape:
        raise ValueError("shape mismatch")
    C = n_classes or int(max(y_true.max(initial=0), y_pred.max(initial=0))) + 1
    cm = np.bincount(y_true * C + y_pred, minlength=C * C).reshape(C, C)
    return cm


def accuracy(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    return float(np.mean(y_true == y_pred)) if y_true.size else 0.0


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int | None = None) -> float:
    cm = confusion_matrix(y_true, y_pred, n_classes)
    tp = np.diag(cm).astype(np.float64)
    fp = cm.sum(axis=0) - tp
    fn = cm.sum(axis=1) - tp
    denom = 2 * tp + fp + fn
    f1 = np.where(denom > 0, 2 * tp / np.maximum(denom, 1e-300), 0.0)
    # Match sklearn: classes absent from both y_true and y_pred contribute 0.
    return float(f1.mean()) if f1.size else 0.0


def cohen_kappa(a: np.ndarray, b: np.ndarray, n_classes: int | None = None) -> float:
    """Cohen's kappa between two raters (paper metric K, [20])."""
    cm = confusion_matrix(a, b, n_classes).astype(np.float64)
    n = cm.sum()
    if n == 0:
        return 0.0
    po = np.trace(cm) / n
    pe = float((cm.sum(axis=1) / n) @ (cm.sum(axis=0) / n))
    if pe == 1.0:
        return 1.0
    return float((po - pe) / (1.0 - pe))

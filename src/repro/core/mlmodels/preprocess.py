"""Preprocessing used by ACORN's translator (paper §C.2).

The paper min-max scales every dataset into [0, 1) and the data plane operates
on fixed-point integers.  ``Quantizer`` folds both: fit on training data, then
map raw features to ``precision_bits``-wide unsigned integers.  All downstream
components (tree training, SVM product LUTs, TCAM range expansion) operate on
these integers, so the "model the switch runs" and "the model we score" are the
same object — this is what keeps Cohen's kappa ≈ 1 in the paper's Tables 4/5.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass
class Quantizer:
    """Min-max scale to [0, 1) then quantize to ``precision_bits`` fixed point."""

    precision_bits: int = 8

    lo_: np.ndarray | None = None
    hi_: np.ndarray | None = None

    @property
    def levels(self) -> int:
        return 1 << self.precision_bits

    def fit(self, X: np.ndarray) -> "Quantizer":
        X = np.asarray(X, dtype=np.float64)
        self.lo_ = X.min(axis=0)
        self.hi_ = X.max(axis=0)
        # Guard constant columns (paper drops them, e.g. num_outbound_cmds).
        span = self.hi_ - self.lo_
        self.hi_ = np.where(span == 0, self.lo_ + 1.0, self.hi_)
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        if self.lo_ is None:
            raise RuntimeError("Quantizer.fit must run before transform")
        X = np.asarray(X, dtype=np.float64)
        unit = (X - self.lo_) / (self.hi_ - self.lo_)
        unit = np.clip(unit, 0.0, np.nextafter(1.0, 0.0))
        q = np.floor(unit * self.levels).astype(np.int64)
        return np.clip(q, 0, self.levels - 1)

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)

    def inverse_center(self, q: np.ndarray) -> np.ndarray:
        """Bin centers in original feature units (used by the SVM LUT builder)."""
        if self.lo_ is None:
            raise RuntimeError("Quantizer.fit must run before inverse_center")
        unit = (np.asarray(q, dtype=np.float64) + 0.5) / self.levels
        return unit * (self.hi_ - self.lo_) + self.lo_


def rfe_select(
    X: np.ndarray,
    y: np.ndarray,
    n_features: int,
    importance_fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
    *,
    step_frac: float = 0.5,
) -> np.ndarray:
    """Recursive feature elimination (paper §7.2 uses RFE [31]).

    Repeatedly fits via ``importance_fn`` (returns one non-negative importance
    per column) and drops the weakest ``step_frac`` of remaining columns until
    ``n_features`` survive.  Returns the selected column indices, sorted.
    """
    keep = np.arange(X.shape[1])
    while keep.size > n_features:
        imp = np.asarray(importance_fn(X[:, keep], y), dtype=np.float64)
        if imp.shape != (keep.size,):
            raise ValueError("importance_fn must return one value per column")
        n_drop = min(
            max(1, int(np.ceil(keep.size * step_frac)) - n_features // 2),
            keep.size - n_features,
        )
        order = np.argsort(imp, kind="stable")  # weakest first
        keep = np.delete(keep, order[:n_drop])
    return np.sort(keep)

"""Network latency / overhead simulator (paper §7.4, §7.6; Figs. 6, 7, 10).

The paper measures request serving time on a 2x Tofino2 testbed.  We model the
same decomposition (J_L = execution + propagation + transmission, §5.2) with
documented constants, and measure the *server-side inference time* for real —
wall-clocking our own numpy models per single request, which is what the
paper's server baseline does with sklearn.

Constants (documented; testbed-calibrated to the paper's reported ranges):
  l_e   = 1 µs    per-switch pipeline execution (Tofino-class)
  l_p   = 2 µs    per-hop propagation+serialization overhead in-DC
  rate  = 10 Gb/s link rate (paper's tcpreplay setup)
  host_stack = 25 µs per host network-stack traversal [1, 15, 61]
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import packets
from repro.core.planner import DeploymentPlan, LatencyModel

__all__ = [
    "ServerModel",
    "acorn_serving_time",
    "server_serving_time",
    "measure_inference_time",
    "simulate_serving",
    "serving_availability",
    "forwarding_overhead",
]


@dataclasses.dataclass(frozen=True)
class ServerModel:
    """Server-based baseline: client -> ToR -> ... -> server NIC -> stack -> model."""

    hops: int = 6                 # paper §7.4: two racks through two ToRs
    host_stack_s: float = 25e-6   # per host-stack traversal
    latency: LatencyModel = LatencyModel()


def acorn_serving_time(plan: DeploymentPlan) -> float:
    """J_L of the chosen plan (s) — in-network serving time per request."""
    return float(plan.breakdown["J_L"])


def server_serving_time(
    model_predict_s: float,
    request_bytes: int,
    *,
    server: ServerModel = ServerModel(),
) -> float:
    """Round-trip through the network to a server plus inference time."""
    lat = server.latency
    per_hop = lat.l_p + lat.t_bytes(request_bytes)
    travel = server.hops * per_hop + server.hops * (lat.l_p + lat.t_bytes(packets.response_bytes()))
    return travel + 2 * server.host_stack_s + model_predict_s


def measure_inference_time(model, Xq: np.ndarray, *, n_requests: int = 200) -> float:
    """Wall-clock per-request (batch of 1) prediction latency of a CPU model —
    the real quantity behind the paper's Fig. 7 'prediction latency'."""
    n = min(n_requests, Xq.shape[0])
    model.predict(Xq[:1])  # warm
    t0 = time.perf_counter()
    for i in range(n):
        model.predict(Xq[i : i + 1])
    return (time.perf_counter() - t0) / n


def simulate_serving(
    base_s: float,
    *,
    n: int = 1000,
    jitter_frac: float = 0.04,
    seed: int = 0,
    arrival_rate_rps: float | None = None,
    downtime_windows: tuple[tuple[float, float], ...] = (),
    return_arrivals: bool = False,
) -> np.ndarray:
    """Per-request samples around a mean (switch pipelines are near-
    deterministic: the paper reports 'consistent intervals, very few
    outliers' — we model small gaussian jitter + rare 10x outliers).

    A deployment is not static (planner ``replan`` under device failure):
    ``downtime_windows`` are ``(t0, t1)`` control-plane outages — detect ->
    replan -> drain -> reinstall — on the arrival clock.  A request arriving
    inside a window is held until the window closes (the drain/reinstall
    barrier) and pays the remainder on top of its serving time.  Arrivals
    are Poisson at ``arrival_rate_rps`` (defaults to uniform spacing over
    ``n * base_s * 100`` when windows are given but no rate is).  With
    ``return_arrivals`` the arrival times come back alongside the samples.
    """
    rng = np.random.default_rng(seed)
    s = base_s * (1.0 + jitter_frac * rng.standard_normal(n))
    outliers = rng.random(n) < 0.002
    s[outliers] *= 10.0
    s = np.maximum(s, base_s * 0.5)
    if not downtime_windows and arrival_rate_rps is None:
        return s                     # static plan: exact pre-fault behavior
    if arrival_rate_rps is not None:
        t_arr = np.cumsum(rng.exponential(1.0 / arrival_rate_rps, n))
    else:
        t_arr = np.linspace(0.0, n * base_s * 100.0, n)
    for t0, t1 in downtime_windows:
        held = (t_arr >= t0) & (t_arr < t1)
        s = np.where(held, s + (t1 - t_arr), s)
    if return_arrivals:
        return s, t_arr
    return s


def serving_availability(latency_s: np.ndarray, slo_s: float) -> float:
    """Fraction of requests served within the SLO — the availability metric
    ``benchmarks/fleet_serve.py`` records per fault schedule."""
    lat = np.asarray(latency_s, float)
    if lat.size == 0:
        return 1.0
    return float((lat <= slo_s).mean())


def forwarding_overhead(
    payload_bytes: int = 8000,          # jumbo frames (paper Fig. 10 setup)
    acorn_header_bytes: int = 70,
    *,
    rate_bps: float = 10e9,
    base_latency_s: float = 1.0e-6,
    stages_used: int = 20,
    total_stages: int = 20,
) -> dict:
    """Static goodput/latency overhead of running ACORN on the forwarding
    path (paper Fig. 10): goodput shrinks by the header share, latency grows
    with the fraction of pipeline stages doing ML work."""
    goodput_frac = payload_bytes / (payload_bytes + acorn_header_bytes)
    latency_overhead = 0.03 * (stages_used / total_stages)  # <=3% (paper: 2.7-3.3%)
    return {
        "goodput_gbps": rate_bps * goodput_frac / 1e9,
        "goodput_frac": goodput_frac,
        "latency_s": base_latency_s * (1 + latency_overhead),
        "latency_overhead_frac": latency_overhead,
    }

"""ACORN packet header (paper Appendix A) as a struct-of-arrays pytree.

Basic header: Packet ID | Type | MID | VID | RSLT | RID.
Data part: raw input features (size set by the max supported feature count —
an operator knob).  Intermediate part: per-tree status codes / SVM partial
sums that must travel between devices (paper §4).  When classification
finishes, the data + intermediate parts are dropped (``strip_payload``) to
shrink response packets — the planner's overhead objective J_O models exactly
this request/response size asymmetry.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PacketType", "PacketBatch", "header_bytes", "request_bytes", "response_bytes"]


class PacketType:
    FORWARD = 0   # ordinary traffic: data plane only forwards
    REQUEST = 1   # inference request (carries features)
    RESPONSE = 2  # inference response (carries RSLT only)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketBatch:
    """A batch of ACORN packets (one pipeline's PHV state, vectorized)."""

    packet_id: jax.Array   # uint32 [B]
    ptype: jax.Array       # int32 [B]
    mid: jax.Array         # int32 [B]  model type id (0=DT, 1=RF, 2=SVM)
    vid: jax.Array         # int32 [B]  model version
    rslt: jax.Array        # int32 [B]  prediction result (-1 = not yet)
    rid: jax.Array         # int32 [B]  routing code (next hop)
    features: jax.Array    # int32 [B, F]
    codes: jax.Array       # uint32 [B, T]  per-tree status codes
    svm_acc: jax.Array     # int32 [B, H]   partial hyperplane sums

    @property
    def batch(self) -> int:
        return self.packet_id.shape[0]

    @classmethod
    def make_request(
        cls,
        features: np.ndarray,
        *,
        mid: int | np.ndarray = 0,
        vid: int | np.ndarray = 0,
        max_features: int | None = None,
        n_trees: int = 1,
        n_hyperplanes: int = 1,
        max_versions: int | None = None,
    ) -> "PacketBatch":
        """Build a REQUEST batch.  ``mid`` and ``vid`` may each be a scalar
        or a per-packet array — together the model-zoo dispatch key; when the
        caller knows the target plane's version capacity, pass
        ``max_versions`` to validate VIDs at the install/classify boundary
        instead of shipping packets that can only ever yield ``rslt == -1``.

        Leaves are **host numpy**: requests model packets arriving from the
        wire, and the jitted classify device-puts them exactly once.  Host
        and device inputs of the same shape mint *separate* jit traces, so
        keeping every serving surface (sync classify, coalesced async
        dispatch) host-side on entry preserves the one-trace-per-bucket
        admission property across all of them."""
        features = np.asarray(features, dtype=np.int32)
        B, F = features.shape
        Fmax = max_features or F
        if F > Fmax:
            raise ValueError(f"{F} features > plane max {Fmax}")
        mids = np.broadcast_to(np.asarray(mid, np.int32), (B,))
        vids = np.broadcast_to(np.asarray(vid, np.int32), (B,))
        if max_versions is not None and vids.size and (
            vids.min() < 0 or vids.max() >= max_versions
        ):
            raise ValueError(
                f"vid range [{vids.min()}, {vids.max()}] outside the plane's "
                f"{max_versions} model-zoo versions"
            )
        feats = np.zeros((B, Fmax), dtype=np.int32)
        feats[:, :F] = features
        return cls(
            packet_id=np.arange(B, dtype=np.uint32),
            ptype=np.full((B,), PacketType.REQUEST, np.int32),
            mid=np.ascontiguousarray(mids),
            vid=np.ascontiguousarray(vids),
            rslt=np.full((B,), -1, np.int32),
            rid=np.zeros((B,), np.int32),
            features=feats,
            codes=np.zeros((B, n_trees), np.uint32),
            svm_acc=np.zeros((B, n_hyperplanes), np.int32),
        )

    def strip_payload(self) -> "PacketBatch":
        """Drop data + intermediates after classification (response packet)."""
        B = self.batch
        return dataclasses.replace(
            self,
            ptype=jnp.full((B,), PacketType.RESPONSE, jnp.int32),
            features=jnp.zeros((B, 0), jnp.int32),
            codes=jnp.zeros((B, 0), jnp.uint32),
            svm_acc=jnp.zeros((B, 0), jnp.int32),
        )


# --------------------------------------------------------------------------
# Wire-size model (bytes) — drives the planner's J_O and netsim.
# --------------------------------------------------------------------------
BASIC_HEADER_BYTES = 12  # packet_id(4) type(1) mid(1) vid(1) rslt(4) rid(1)
ETH_IP_BYTES = 34        # enclosing L2/L3 headers


def header_bytes(n_features: int, feat_bytes: int = 1, n_trees: int = 0,
                 code_bytes: int = 4, n_hyperplanes: int = 0, acc_bytes: int = 4) -> int:
    """ACORN header size with data + intermediate parts."""
    return (
        BASIC_HEADER_BYTES
        + n_features * feat_bytes
        + n_trees * code_bytes
        + n_hyperplanes * acc_bytes
    )


def request_bytes(n_features: int, feat_bytes: int = 1, n_trees: int = 0,
                  n_hyperplanes: int = 0) -> int:
    return ETH_IP_BYTES + header_bytes(n_features, feat_bytes, n_trees, 4, n_hyperplanes, 4)


def response_bytes() -> int:
    """After the last stage the data/intermediate parts are dropped."""
    return ETH_IP_BYTES + BASIC_HEADER_BYTES

"""The ACORN data plane engine: compile once, reprogram at runtime (paper §6).

A physical switch compiles the *template* P4 program once; afterwards every
model (re)deployment only rewrites match-action entries.  The TPU-native
equivalent: ``SwitchEngine`` jits one fixed-shape classification step whose
table entries are **inputs** (a ``PackedProgram`` pytree), so installing or
swapping a model is an array update — zero retrace (asserted by tests via
``cache_size() == 1``).

Like the paper's Fig. 5 data plane, one engine hosts *both* pipelines
simultaneously — a tree pipeline (fused single-launch dt_layer walk →
dt_predict → multitree_voting; ``mode="layerwise[-*]"`` selects the
pre-fusion per-layer kernel scan) and an SVM pipeline (svm_mul partials →
native adds → svm_predict) — and each packet selects its result by MID.
Non-request packets pass through untouched (forwarding is unaffected):
their rslt *and* their codes/svm_acc intermediates come out bit-identical.

Model zoo (the VID axis, paper Appendix A): every table array carries a
leading version axis ``V = profile.max_versions``, so one engine hosts ``V``
tree-pipeline programs and ``V`` SVM programs *simultaneously*, and each
packet selects its tables by ``(MID, VID)`` at classify time.
``install_program(..., vid=k)`` writes one version slot and preserves the
rest; ``evict_program`` empties a slot.  Install, swap, and evict are all
array updates against the same compiled trace.  A packet addressing an empty
or out-of-range version slot gets ``rslt == -1`` (no match) — it never reads
another version's tables.

Install-time program compilation (the exec image): the paper's control plane
"updates the entries in predefined tables" (§6.2) and the hot path stays pure
match-action.  Mirroring that boundary, program state splits into **source
tables** (what ``install_program`` writes — the swappable flow-table state)
and a derived, device-resident **``ExecImage``** — the kernel-ready operands
(flattened one-hot ``fsel``, no-match-padded entry blocks, chunked SVM LUTs,
Pallas-dtype predict tables) that classify binds straight into each
``pallas_call``.  The image is recomputed once per install/evict/swap, and
only for the written version slot; classify does **zero** per-call operand
prep (pinned by the exec-image jaxpr test).  ``docs/ARCHITECTURE.md`` pins
the full contract.

Distribution hooks: a ``PackedProgram`` can be *partial* — only the tables of
the program stages assigned to this device are installed; status codes and
SVM partial sums travel in the ``PacketBatch`` intermediates, so a packet
finishes classification after visiting every assigned device in path order
(see ``distributed_plane.py``).  Partial programs carry their own (partial)
exec image, built from exactly the entries this device owns.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packets import PacketBatch, PacketType
from repro.core.translator import MID_SVM, TableProgram
from repro.kernels import ops, tiling

__all__ = [
    "PlaneProfile",
    "PackedProgram",
    "ExecImage",
    "SwitchEngine",
    "build_exec_image",
    "empty_program",
    "install_program",
    "evict_program",
]

_SENTINEL = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class PlaneProfile:
    """Fixed template shapes — the operator's compile-time knobs (paper §3.2:
    "the size of the data part is decided by the maximum number of supported
    features, which can be configured by the network operator")."""

    max_features: int = 60       # paper: up to 60 features
    feature_width: int = 8       # quantization bits
    max_trees: int = 8
    max_layers: int = 32         # paper: tree depth up to 32
    max_entries_per_layer: int = 128   # 2 * nodes per layer
    max_leaves: int = 256        # dt_predict entries per tree
    max_classes: int = 32
    max_hyperplanes: int = 12    # svm_predict direct table = 2^H entries
    levels: int = 256
    # Model-zoo slots per pipeline (the VID range).  An operator knob like the
    # rest: table memory and the Pallas version-grid both scale with V, so the
    # default is a single-slot plane and zoos opt in explicitly.
    max_versions: int = 1

    def __post_init__(self):
        if self.max_hyperplanes > 16:
            raise ValueError("svm_predict direct table capped at 2^16 entries")
        if self.max_layers > 32:
            raise ValueError("status code is 32-bit (paper: 16-32 bit bitstring)")
        if self.max_versions < 1:
            raise ValueError("need at least one model-zoo version slot")
        if self.feature_width > 15:
            raise ValueError(
                "feature values are int16 in the quantized fused-classify "
                "operand layout: feature_width must be <= 15")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ExecImage:
    """Derived, device-resident kernel operands — the installed *executable*.

    Everything here is a pure function of the ``PackedProgram`` source tables
    (``build_exec_image``), precomputed at install/evict/swap time so
    ``_classify_impl`` binds operands straight into each ``pallas_call`` with
    zero per-call prep.  Field groups are the kernels' ``*Operands`` tuples
    (see ``kernels/tiling.py`` for shapes, dtypes, and the no-match padding
    convention):

    * ``walk``   — fused tree walk: flattened one-hot ``fsel``
      ``[V, T, L*E_pad, F_pad]`` + no-match-padded entry blocks
      ``[V, L, T, E_pad]``.
    * ``svm``    — chunked f32 LUT ``[V, n_chunks, chunk_f*levels, H_pad]``.
      Its bias block is **zeros**: the plane adds ``svm_bias`` *outside* the
      kernel so distributed partial sums compose (bias once, on the owning
      device).
    * ``forest`` — dt_predict validity/weights in Pallas block dtypes
      (``pred_codes``/``pred_labels`` bind as-is from the source tables).
    * ``fused``  — the whole-classify megakernel's quantized operand layout
      (int16 feature ids / range bounds, int8 leaf labels, bit-packed
      set_bit / valid / pred_valid words, chunked f32 LUT) — what the
      default single-launch classify binds; the three groups above serve
      the ``unfused`` / ``layerwise`` fallback modes.  Its bias block is
      zeros for the same distributed-compose reason as ``svm``'s.

    Residency trade-off: the image lives on the *program*, not the engine,
    so one ``PackedProgram`` serves any engine mode — at the cost of holding
    the image (≈ ``image_mib`` in ``benchmarks/zoo_swap.py``, linear in V)
    even under a ``mode="ref"`` or ``use_image=False`` engine that never
    dereferences it.  On the TPU target the image IS the working set; if
    ref-only deployments ever matter, carry ``image=None`` and let the next
    install heal it.
    """

    walk: tiling.TreeWalkOperands
    svm: tiling.SvmOperands
    forest: tiling.ForestOperands
    fused: tiling.ClassifyFusedOperands


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedProgram:
    """Entry arrays for one engine — the runtime-swappable 'flow table' state.

    All table arrays carry a leading version axis V (the model zoo); a
    packet's VID selects its slot at classify time.  Tree layouts use a
    layer axis [V, L, T, E]; since the PR-2 fusion the engine walks all
    layers inside **one** kernel launch (``kernels/tree_walk.py``), the
    per-layer kernel scan surviving only as the ``layerwise`` fallback mode.

    ``image`` is the derived exec image (kernel-ready operands) kept in sync
    by ``install_program``/``evict_program`` — the *source tables* here are
    the control plane's write interface, the image is what classify reads.
    """

    # tree pipeline
    dt_cv: jax.Array       # uint32 [V, L, T, E]
    dt_cm: jax.Array       # uint32 [V, L, T, E]
    dt_fid: jax.Array      # int32 [V, L, T, E]
    dt_flo: jax.Array      # int32 [V, L, T, E]
    dt_fhi: jax.Array      # int32 [V, L, T, E]
    dt_bit: jax.Array      # uint32 [V, L, T, E]
    dt_valid: jax.Array    # bool [V, L, T, E]
    layer_shift: jax.Array  # int32 [L] status-code bit per scan step (shared)
    pred_codes: jax.Array  # uint32 [V, T, P] sorted per (v, t)
    pred_labels: jax.Array  # int32 [V, T, P]
    pred_valid: jax.Array  # bool [V, T, P]
    pred_enable: jax.Array  # bool [V] — this device owns v's dt_predict/voting
    vote_weights: jax.Array  # float32 [V, T]
    # svm pipeline
    svm_lut: jax.Array     # int32 [V, H, F, levels]
    svm_bias: jax.Array    # int32 [V, H]
    svm_hvalid: jax.Array  # bool [V, H] — which hyperplanes each version defines
    svm_pred_table: jax.Array  # int32 [V, 2^H]
    svm_pred_enable: jax.Array  # bool [V]
    # derived exec image — kernel-ready operands, rebuilt per slot write
    image: ExecImage | None = None

    @property
    def n_versions(self) -> int:
        return self.pred_enable.shape[0]


def _fused_quantize(profile: PlaneProfile) -> bool:
    """Whether the quantized fused-operand widths are lossless for this
    profile: int8 labels need <= 127 classes, int16 feature values /
    range bounds need feature_width <= 15 (enforced in the profile) and
    levels <= 32768.  Profiles outside that envelope fall back to the f32
    width of the same layout — still one launch, same bits."""
    return profile.max_classes <= 127 and profile.levels <= 32768


def build_exec_image(packed: PackedProgram, profile: PlaneProfile) -> ExecImage:
    """Full (all-slot) source-tables -> exec-image compile.

    ``install_program``/``evict_program`` use the per-slot incremental path
    instead; this is the from-scratch build (``empty_program``, recovery of a
    legacy ``image=None`` program, and the image-consistency tests).
    """
    f_pad = tiling.lane_pad(profile.max_features)
    walk = tiling.prep_tree_walk(
        packed.dt_cv, packed.dt_cm, packed.dt_fid, packed.dt_flo,
        packed.dt_fhi, packed.dt_bit, packed.dt_valid, f_pad)
    # Zero bias by design: _classify_impl adds svm_bias outside the kernel so
    # distributed partial sums compose (see ExecImage docstring).
    svm = tiling.prep_svm_lookup(packed.svm_lut,
                                 jnp.zeros_like(packed.svm_bias))
    forest = tiling.prep_forest_vote(packed.pred_valid, packed.vote_weights)
    fused = tiling.prep_classify_fused(
        packed.dt_cv, packed.dt_cm, packed.dt_fid, packed.dt_flo,
        packed.dt_fhi, packed.dt_bit, packed.dt_valid, packed.pred_codes,
        packed.pred_labels, packed.pred_valid, packed.vote_weights,
        packed.svm_lut, jnp.zeros_like(packed.svm_bias),
        quantize=_fused_quantize(profile))
    return ExecImage(walk=walk, svm=svm, forest=forest, fused=fused)


def _prep_fused_slot(packed: PackedProgram, vid: int,
                     profile: PlaneProfile) -> tiling.ClassifyFusedOperands:
    """V=1 fused-operand slice for one slot's *current* source tables.

    The fused group spans both pipelines, so a tree install must fold in the
    slot's resident svm tables (and vice versa) — this reads whichever side
    the caller just wrote from the updated program and the other side from
    what was already installed.
    """
    s = slice(vid, vid + 1)
    return tiling.prep_classify_fused(
        packed.dt_cv[s], packed.dt_cm[s], packed.dt_fid[s], packed.dt_flo[s],
        packed.dt_fhi[s], packed.dt_bit[s], packed.dt_valid[s],
        packed.pred_codes[s], packed.pred_labels[s], packed.pred_valid[s],
        packed.vote_weights[s], packed.svm_lut[s],
        jnp.zeros_like(packed.svm_bias[s]),
        quantize=_fused_quantize(profile))


def _set_image_slot(image_group, slot_group, vid: int):
    """Write one version slot of an operand group (V=1 prep) into the full
    image group — the incremental install/evict image update."""
    return jax.tree.map(lambda full, s: full.at[vid].set(s[0]),
                        image_group, slot_group)


def empty_program(profile: PlaneProfile) -> PackedProgram:
    V = profile.max_versions
    L, T, E = profile.max_layers, profile.max_trees, profile.max_entries_per_layer
    P, H, F = profile.max_leaves, profile.max_hyperplanes, profile.max_features
    packed = PackedProgram(
        dt_cv=jnp.zeros((V, L, T, E), jnp.uint32),
        dt_cm=jnp.full((V, L, T, E), _SENTINEL, jnp.uint32),
        dt_fid=jnp.zeros((V, L, T, E), jnp.int32),
        dt_flo=jnp.ones((V, L, T, E), jnp.int32),
        dt_fhi=jnp.zeros((V, L, T, E), jnp.int32),
        dt_bit=jnp.zeros((V, L, T, E), jnp.uint32),
        dt_valid=jnp.zeros((V, L, T, E), bool),
        layer_shift=jnp.arange(L, dtype=jnp.int32),
        pred_codes=jnp.full((V, T, P), _SENTINEL, jnp.uint32),
        pred_labels=jnp.zeros((V, T, P), jnp.int32),
        pred_valid=jnp.zeros((V, T, P), bool),
        pred_enable=jnp.zeros((V,), bool),
        vote_weights=jnp.zeros((V, T), jnp.float32),
        svm_lut=jnp.zeros((V, H, F, profile.levels), jnp.int32),
        svm_bias=jnp.zeros((V, H), jnp.int32),
        svm_hvalid=jnp.zeros((V, H), bool),
        svm_pred_table=jnp.zeros((V, 2**H), jnp.int32),
        svm_pred_enable=jnp.zeros((V,), bool),
    )
    return dataclasses.replace(packed, image=build_exec_image(packed, profile))


def _check_vid(vid: int, profile: PlaneProfile) -> int:
    if not 0 <= vid < profile.max_versions:
        raise ValueError(
            f"vid {vid} out of range: profile hosts {profile.max_versions} "
            f"model-zoo versions (0..{profile.max_versions - 1})"
        )
    return vid


def install_program(
    packed: PackedProgram,
    program: TableProgram,
    profile: PlaneProfile,
    *,
    stages: set[int] | None = None,
    vid: int | None = None,
) -> PackedProgram:
    """Write a TableProgram's entries into one model-zoo version slot (the
    control plane's 'update the entries in predefined tables', paper §6.2).

    ``vid`` selects the slot (default: the program's own ``vid``); every other
    slot — and the *other* pipeline's state in ``packed`` — is preserved, so
    V tree models and V SVMs can coexist (paper Fig. 5 + Appendix A VID).
    ``stages`` restricts installation to a subset of program stages (the
    planner's per-device assignment); ``None`` installs everything.
    """
    vid = _check_vid(program.vid if vid is None else vid, profile)
    specs = program.stages()
    if stages is None:
        stages = set(range(len(specs)))
    own = [specs[i] for i in sorted(stages)]

    if program.kind in ("dt", "rf"):
        L, T, E = profile.max_layers, profile.max_trees, profile.max_entries_per_layer
        P = profile.max_leaves
        if program.n_trees > T:
            raise ValueError(f"{program.n_trees} trees > profile max {T}")
        cv = np.zeros((L, T, E), np.uint32)
        cm = np.full((L, T, E), _SENTINEL, np.uint32)
        fid = np.zeros((L, T, E), np.int32)
        flo = np.ones((L, T, E), np.int32)
        fhi = np.zeros((L, T, E), np.int32)
        bit = np.zeros((L, T, E), np.uint32)
        valid = np.zeros((L, T, E), bool)
        owned_pairs = {
            (tab.tree, tab.layer) for s in own for tab in s.tables if tab.kind == "dt_layer"
        }
        for t, layers in enumerate(program.dt_layers):
            for lt in layers:
                if (t, lt.layer) not in owned_pairs:
                    continue
                n = lt.n_entries
                if lt.layer >= L:
                    raise ValueError(f"layer {lt.layer} > profile max {L}")
                if n > E:
                    raise ValueError(f"{n} entries at layer {lt.layer} > profile max {E}")
                cv[lt.layer, t, :n] = lt.code_value
                cm[lt.layer, t, :n] = lt.code_mask
                fid[lt.layer, t, :n] = lt.fid
                flo[lt.layer, t, :n] = lt.f_lo
                fhi[lt.layer, t, :n] = lt.f_hi
                bit[lt.layer, t, :n] = lt.set_bit
                valid[lt.layer, t, :n] = True
        own_predict = any(tab.kind == "dt_predict" for s in own for tab in s.tables)
        pc = np.full((T, P), _SENTINEL, np.uint32)
        pl_ = np.zeros((T, P), np.int32)
        pv = np.zeros((T, P), bool)
        w = np.zeros((T,), np.float32)
        if own_predict:
            for p in program.dt_predicts:
                n = p.n_entries
                if n > P:
                    raise ValueError(f"{n} leaves > profile max {P}")
                pc[p.tree, :n] = p.codes
                pl_[p.tree, :n] = p.labels
                pv[p.tree, :n] = True
            if program.voting is not None:
                w[: program.n_trees] = program.voting.weights
            else:
                w[0] = 1.0
        new = dataclasses.replace(
            packed,
            dt_cv=packed.dt_cv.at[vid].set(jnp.asarray(cv)),
            dt_cm=packed.dt_cm.at[vid].set(jnp.asarray(cm)),
            dt_fid=packed.dt_fid.at[vid].set(jnp.asarray(fid)),
            dt_flo=packed.dt_flo.at[vid].set(jnp.asarray(flo)),
            dt_fhi=packed.dt_fhi.at[vid].set(jnp.asarray(fhi)),
            dt_bit=packed.dt_bit.at[vid].set(jnp.asarray(bit)),
            dt_valid=packed.dt_valid.at[vid].set(jnp.asarray(valid)),
            pred_codes=packed.pred_codes.at[vid].set(jnp.asarray(pc)),
            pred_labels=packed.pred_labels.at[vid].set(jnp.asarray(pl_)),
            pred_valid=packed.pred_valid.at[vid].set(jnp.asarray(pv)),
            pred_enable=packed.pred_enable.at[vid].set(own_predict),
            vote_weights=packed.vote_weights.at[vid].set(jnp.asarray(w)),
        )
        if packed.image is None:  # legacy program: recover with a full build
            return dataclasses.replace(
                new, image=build_exec_image(new, profile))
        # Install-time compile of the written slot only: prep the new entries
        # as a V=1 image slice and splice it into the resident image.
        f_pad = tiling.lane_pad(profile.max_features)
        walk_slot = tiling.prep_tree_walk(
            cv[None], cm[None], fid[None], flo[None], fhi[None], bit[None],
            valid[None], f_pad)
        forest_slot = tiling.prep_forest_vote(pv[None], w[None])
        image = dataclasses.replace(
            packed.image,
            walk=_set_image_slot(packed.image.walk, walk_slot, vid),
            forest=_set_image_slot(packed.image.forest, forest_slot, vid),
            fused=_set_image_slot(packed.image.fused,
                                  _prep_fused_slot(new, vid, profile), vid),
        )
        return dataclasses.replace(new, image=image)

    if program.kind == "svm":
        H, F, Lev = profile.max_hyperplanes, profile.max_features, profile.levels
        if program.n_hyperplanes > H:
            raise ValueError(f"{program.n_hyperplanes} hyperplanes > profile max {H}")
        if program.n_features > F:
            raise ValueError(f"{program.n_features} features > profile max {F}")
        lut = np.zeros((H, F, Lev), np.int32)
        # Ownership by stage (matches TableProgram.stages()/svm_stage_muls()).
        stage_muls = program.svm_stage_muls()
        owned_flat = set()
        for si in sorted(stages):
            if si < len(stage_muls):
                owned_flat.update(stage_muls[si])
        for k in owned_flat:
            m = program.svm_muls[k]
            lut[m.hyperplane, m.feature, : m.n_entries] = m.lut
        own_pred = any(tab.kind == "svm_predict" for s in own for tab in s.tables)
        bias = np.zeros((H,), np.int32)
        tbl = np.zeros((2**H,), np.int32)
        if own_pred:
            bias[: program.n_hyperplanes] = program.svm_bias
            sp = program.svm_predict
            if sp.table is None:
                raise ValueError("svm_predict table too large for direct materialization")
            tbl[: sp.table.shape[0]] = sp.table
        hvalid = np.zeros((H,), bool)
        hvalid[: program.n_hyperplanes] = True
        new = dataclasses.replace(
            packed,
            svm_lut=packed.svm_lut.at[vid].set(jnp.asarray(lut)),
            svm_bias=packed.svm_bias.at[vid].set(jnp.asarray(bias)),
            svm_hvalid=packed.svm_hvalid.at[vid].set(jnp.asarray(hvalid)),
            svm_pred_table=packed.svm_pred_table.at[vid].set(jnp.asarray(tbl)),
            svm_pred_enable=packed.svm_pred_enable.at[vid].set(own_pred),
        )
        if packed.image is None:  # legacy program: recover with a full build
            return dataclasses.replace(
                new, image=build_exec_image(new, profile))
        svm_slot = tiling.prep_svm_lookup(
            lut[None], np.zeros((1, H), np.int32))  # zero bias by design
        image = dataclasses.replace(
            packed.image,
            svm=_set_image_slot(packed.image.svm, svm_slot, vid),
            fused=_set_image_slot(packed.image.fused,
                                  _prep_fused_slot(new, vid, profile), vid),
        )
        return dataclasses.replace(new, image=image)

    raise ValueError(f"unknown program kind {program.kind}")


@functools.lru_cache(maxsize=8)
def _blank_slot_program(profile: PlaneProfile) -> PackedProgram:
    """One-slot blank (V=1) program *and* its image, memoized per profile: the
    empty fills live only in empty_program, and eviction splices these
    constant slices instead of re-running the (image-sized) blank build per
    call."""
    return empty_program(dataclasses.replace(profile, max_versions=1))


def evict_program(
    packed: PackedProgram,
    profile: PlaneProfile,
    *,
    vid: int,
    kind: str = "all",
) -> PackedProgram:
    """Empty one model-zoo version slot (``kind``: "tree" | "svm" | "all").

    Packets addressing an evicted slot get ``rslt == -1`` — same as a slot
    that was never installed.  Eviction is an array update, zero retrace.
    """
    vid = _check_vid(vid, profile)
    if kind not in ("tree", "svm", "all"):
        raise ValueError(f"unknown evict kind {kind!r}")
    blank = _blank_slot_program(profile)
    upd = {}
    tree_fields = ("dt_cv", "dt_cm", "dt_fid", "dt_flo", "dt_fhi", "dt_bit",
                   "dt_valid", "pred_codes", "pred_labels", "pred_valid",
                   "pred_enable", "vote_weights")
    svm_fields = ("svm_lut", "svm_bias", "svm_hvalid", "svm_pred_table",
                  "svm_pred_enable")
    fields = (tree_fields if kind == "tree"
              else svm_fields if kind == "svm"
              else tree_fields + svm_fields)
    for f in fields:
        upd[f] = getattr(packed, f).at[vid].set(getattr(blank, f)[0])
    new = dataclasses.replace(packed, **upd)
    if packed.image is None:  # legacy program: recover with a full build
        return dataclasses.replace(new, image=build_exec_image(new, profile))
    # Evicted slots get the blank slot's image slice (all-invalid operands).
    img = {}
    if kind in ("tree", "all"):
        img["walk"] = _set_image_slot(packed.image.walk, blank.image.walk, vid)
        img["forest"] = _set_image_slot(packed.image.forest,
                                        blank.image.forest, vid)
    if kind in ("svm", "all"):
        img["svm"] = _set_image_slot(packed.image.svm, blank.image.svm, vid)
    # The fused group spans both pipelines: rebuild its slot from the slot's
    # post-evict source tables (for kind="all" this equals the blank slice).
    img["fused"] = _set_image_slot(
        packed.image.fused, _prep_fused_slot(new, vid, profile), vid)
    return dataclasses.replace(
        new, image=dataclasses.replace(packed.image, **img))


# --------------------------------------------------------------------------
# The jitted classification step
# --------------------------------------------------------------------------
def _classify_impl(packed: PackedProgram, pb: PacketBatch, *, n_classes: int,
                   mode: str | None, use_image: bool = True) -> PacketBatch:
    feats = pb.features
    V = packed.n_versions
    # Classify-boundary VID validation: out-of-range packets are processed
    # against slot 0's tables (shape-stable) but their result is forced to -1.
    vid_ok = (pb.vid >= 0) & (pb.vid < V)
    vid = jnp.where(vid_ok, pb.vid, 0)
    # Bind the install-time exec image: the kernel launch reads precomputed
    # operands, zero per-call prep.  use_image=False forces the per-call prep
    # path (the pre-image behavior, kept for the install-vs-classify split
    # benchmark); the ref oracle and the fallback modes rebuild from source
    # tables, so unused operands drop out of the trace either way.
    img = packed.image if use_image else None

    # ---- both pipelines in ONE launch: walk -> vote codes stay VMEM-resident
    # and feed the svm LUT contraction in the same grid program.
    # mode="unfused[-*]" restores the pre-fusion three-launch classify;
    # mode="layerwise[-*]" additionally scans per-layer walk kernels.
    # Zero bias into the kernel: svm_bias is added below, outside, so
    # distributed partial sums compose (bias once, on the owning device).
    codes, tree_label, partial = ops.classify_fused_v(
        pb.codes, feats, vid, packed.dt_cv, packed.dt_cm, packed.dt_fid,
        packed.dt_flo, packed.dt_fhi, packed.dt_bit, packed.dt_valid,
        packed.layer_shift, packed.pred_codes, packed.pred_labels,
        packed.pred_valid, packed.vote_weights, packed.svm_lut,
        jnp.zeros_like(packed.svm_bias), n_classes, mode=mode,
        prep=img.fused if img else None,
        unfused_prep=(img.walk, img.forest, img.svm) if img else None)
    tree_result = jnp.where(packed.pred_enable[vid], tree_label, -1)

    # ---- svm predict: native adds on the kernel's LUT partials ----
    acc = pb.svm_acc + partial
    sums = acc + packed.svm_bias[vid]
    signs = ((sums >= 0) & packed.svm_hvalid[vid]).astype(jnp.int32)
    sign_code = (signs << jnp.arange(signs.shape[1])[None, :]).sum(axis=1)
    svm_label = packed.svm_pred_table[vid, sign_code]
    svm_result = jnp.where(packed.svm_pred_enable[vid], svm_label, -1)

    # ---- result select + forwarding passthrough ----
    # Non-REQUEST packets come out bit-identical: their codes / svm_acc
    # intermediates and rslt are never overwritten (classification must not
    # disturb forwarded traffic, paper §6.1).
    is_req = pb.ptype == PacketType.REQUEST
    codes = jnp.where(is_req[:, None], codes, pb.codes)
    acc = jnp.where(is_req[:, None], acc, pb.svm_acc)
    result = jnp.where(pb.mid == MID_SVM, svm_result, tree_result)
    result = jnp.where(vid_ok, result, -1)
    rslt = jnp.where(is_req & (result >= 0), result, pb.rslt)
    return dataclasses.replace(pb, codes=codes, svm_acc=acc, rslt=rslt)


class SwitchEngine:
    """One programmable data plane: jit-compiled once per (profile, batch shape).

    Hosts a model zoo: ``profile.max_versions`` tree programs and as many
    SVMs, resident simultaneously, dispatched per packet by (MID, VID).
    """

    def __init__(self, profile: PlaneProfile, *, mode: str | None = None,
                 use_image: bool = True) -> None:
        """``mode`` picks the kernel path: ``None`` auto-selects (pallas on
        TPU, ref elsewhere); ``"ref"`` / ``"interpret"`` / ``"pallas"`` force
        one and run classify as a single fused walk→vote→svm launch; an
        ``"unfused[-<kernel mode>]"`` prefix restores the pre-fusion
        three-launch classify, and ``"layerwise[-<kernel mode>]"``
        additionally swaps the fused tree walk for the per-layer kernel scan
        (L + 2 launches instead of 1).

        ``use_image=False`` disables exec-image binding, so every classify
        reruns the operand prep the image precomputes — the pre-image
        behavior, kept so ``benchmarks/zoo_swap.py`` can report the
        install-vs-classify cost split."""
        self.profile = profile
        self.mode = mode
        self.use_image = use_image
        self._fn = jax.jit(
            functools.partial(
                _classify_impl, n_classes=profile.max_classes, mode=mode,
                use_image=use_image,
            )
        )

    def classify(self, packed: PackedProgram, batch: PacketBatch) -> PacketBatch:
        return self._fn(packed, batch)

    def cache_size(self) -> int:
        """Number of distinct traces — must stay 1 across model swaps."""
        return self._fn._cache_size()

    def empty(self) -> PackedProgram:
        return empty_program(self.profile)

    def install(self, packed: PackedProgram, program: TableProgram,
                stages: set[int] | None = None, *,
                vid: int | None = None) -> PackedProgram:
        return install_program(packed, program, self.profile, stages=stages,
                               vid=vid)

    def evict(self, packed: PackedProgram, *, vid: int,
              kind: str = "all") -> PackedProgram:
        return evict_program(packed, self.profile, vid=vid, kind=kind)

"""ACORN's deployment plan optimizer (paper §5 + Appendix B).

Multi-objective placement of program stages onto programmable devices along a
path:  J = w_L*J_latency + w_D*J_devices + w_O*J_overhead.

Two solvers, cross-validated in tests:

* ``milp``  — the paper's formulation (scipy ``milp``/HiGHS, same as the
  paper's implementation §7.1) with decision variables x_{ijk} (program stage
  i → slot j of device k), y_k (device used), c_k (last stage on k), per-path.
* ``dp``    — beyond-paper exact dynamic program over (stage, path position):
  for homogeneous per-device slots the placement problem is a monotone
  sequence-partition problem, solvable in O(T_s^2 · |P|) — provably the same
  optimum, ~100x faster (benchmarked in benchmarks/fig8_planner.py).

The paper's *parallel decomposition* is reproduced: the outer loop enumerates
candidate paths (Yen k-shortest) and solves each path's subproblem
independently; "for random forests and SVMs with multiple hyperplanes, we run
the optimizer multiple times, each time for one tree or one hyperplane"
(App. B) — ``plan_program`` plans unit-by-unit with capacity carry-over, and
enforces the SVM colocation integrity constraint (all ``svm_mul`` tables of a
hyperplane on one device).

Faithfulness notes (deviations documented in DESIGN.md §2):
* App. B writes ``sum_i y_i = 1`` and ``sum_j x_{ijk} = y_k ∀i,k`` — taken
  literally these force one device hosting every stage; we implement the
  evidently intended guarantee constraints (x ≤ y, y = OR_i x).
* The stage-dependency family is encoded compactly as a strictly increasing
  rank ``pos(k)*D_s + j`` over consecutive stages — equivalent to the paper's
  prefix constraints for totally ordered stages (ours are).

Fault handling (beyond paper §9): ``replan`` re-solves with failed devices
excluded — the runtime swap path for a dead switch.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from repro.core import packets
from repro.core.topology import Network
from repro.core.translator import StageSpec, TableProgram

__all__ = [
    "DeviceModel",
    "LatencyModel",
    "PathProblem",
    "Plan",
    "DeploymentPlan",
    "solve_path",
    "plan_program",
    "plan_zoo",
    "replan",
    "replan_zoo",
]


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Per-device resource profile (paper §2.1: O(10)MB memory, <2 dozen stages)."""

    n_stages: int = 20
    tcam_per_stage: int = 4096
    sram_per_stage: int = 16384
    max_tables_per_stage: int = 16  # Tofino: 16 logical tables per stage
    programmable: bool = True


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    l_e: float = 1.0e-6          # per-switch pipeline execution (s)
    l_p: float = 2.0e-6          # per-hop propagation (s)
    rate_bps: float = 10e9       # link rate for transmission delay

    def t_bytes(self, nbytes: int) -> float:
        return nbytes * 8.0 / self.rate_bps


def _stage_fits(stage: StageSpec, dev: DeviceModel) -> bool:
    return (
        stage.tcam_entries <= dev.tcam_per_stage
        and stage.sram_entries <= dev.sram_per_stage
        and len(stage.tables) <= dev.max_tables_per_stage
    )


@dataclasses.dataclass
class PathProblem:
    """One path's placement subproblem."""

    stages: list[StageSpec]
    path: list[str]                       # src host ... dst host
    devices: dict[str, DeviceModel]
    free_slots: dict[str, int]            # remaining stage slots per device
    weights: tuple[float, float, float] = (1 / 3, 1 / 3, 1 / 3)
    latency: LatencyModel = LatencyModel()
    request_bytes: int = 128
    response_bytes: int = packets.response_bytes()
    colocate: dict[int, int] | None = None  # stage idx -> group id
    min_position: int = 0  # cross-unit dependency: earliest allowed path position


@dataclasses.dataclass
class Plan:
    path: list[str]
    assignment: dict[int, str]            # program-stage index -> device
    objective: float
    breakdown: dict
    solver: str
    solve_time: float

    def device_stages(self) -> dict[str, set[int]]:
        out: dict[str, set[int]] = {}
        for i, d in self.assignment.items():
            out.setdefault(d, set()).add(i)
        return out


def _objective_terms(prob: PathProblem, assignment: dict[int, str]) -> tuple[float, dict]:
    w_L, w_D, w_O = prob.weights
    lat = prob.latency
    hops = len(prob.path) - 1
    used = sorted(set(assignment.values()), key=prob.path.index)
    n_used = len(used)
    last_dev = assignment[max(assignment)]
    q = prob.path.index(last_dev)          # edges traversed with request size
    t_rq = lat.t_bytes(prob.request_bytes)
    t_rs = lat.t_bytes(prob.response_bytes)
    J_exe = lat.l_e * n_used
    J_prop = lat.l_p * hops
    J_trs = t_rq * q + t_rs * (hops - q)
    J_L = J_exe + J_prop + J_trs
    J_D = float(n_used)
    J_O = prob.request_bytes * q + prob.response_bytes * (hops - q)
    J = w_L * J_L + w_D * J_D + w_O * J_O
    return J, {
        "J": J, "J_L": J_L, "J_D": J_D, "J_O": J_O,
        "J_exe": J_exe, "J_prop": J_prop, "J_trsmt": J_trs,
        "hops": hops, "last_pos": q, "devices_used": used,
    }


# --------------------------------------------------------------------------
# MILP solver (the paper's)
# --------------------------------------------------------------------------
def _solve_milp(prob: PathProblem) -> Plan | None:
    t0 = time.perf_counter()
    stages = prob.stages
    T_s = len(stages)
    devs = [
        d for d in prob.path
        if d in prob.devices and prob.devices[d].programmable
        and prob.free_slots.get(d, 0) > 0
        and prob.path.index(d) >= prob.min_position
    ]
    if not devs:
        return None
    pos = {d: prob.path.index(d) for d in devs}
    slots = {d: prob.free_slots[d] for d in devs}
    Dmax = max(slots.values())
    K = len(devs)

    # variable layout: x[i, j, k] then y[k] then c[k] then g[grp, k]
    def xi(i, j, k):
        return (i * Dmax + j) * K + k

    nx = T_s * Dmax * K
    ny = K
    groups = sorted(set((prob.colocate or {}).values()))
    gidx = {g: gi for gi, g in enumerate(groups)}
    ng = len(groups) * K
    n_var = nx + ny + K + ng
    yk = lambda k: nx + k
    ck = lambda k: nx + ny + k
    gk = lambda g, k: nx + ny + K + gidx[g] * K + k

    w_L, w_D, w_O = prob.weights
    lat = prob.latency
    hops = len(prob.path) - 1
    t_rq = lat.t_bytes(prob.request_bytes)
    t_rs = lat.t_bytes(prob.response_bytes)
    c_obj = np.zeros(n_var)
    for k, d in enumerate(devs):
        c_obj[yk(k)] = w_L * lat.l_e + w_D
        c_obj[ck(k)] = (
            w_L * (t_rq * pos[d] + t_rs * (hops - pos[d]))
            + w_O * (prob.request_bytes * pos[d] + prob.response_bytes * (hops - pos[d]))
        )

    rows, cols, vals, lbs, ubs = [], [], [], [], []
    r = 0

    def add_row(entries, lb, ub):
        nonlocal r
        for c_, v in entries:
            rows.append(r)
            cols.append(c_)
            vals.append(v)
        lbs.append(lb)
        ubs.append(ub)
        r += 1

    fits = {
        (i, k): _stage_fits(stages[i], prob.devices[d])
        for i in range(T_s)
        for k, d in enumerate(devs)
    }
    # 1. each program stage placed exactly once (on a feasible slot)
    for i in range(T_s):
        ent = [
            (xi(i, j, k), 1.0)
            for k, d in enumerate(devs)
            for j in range(slots[d])
            if fits[(i, k)]
        ]
        if not ent:
            return None  # stage fits nowhere on this path
        add_row(ent, 1, 1)
    # 1b. infeasible placements forced to 0
    for i in range(T_s):
        for k, d in enumerate(devs):
            for j in range(Dmax):
                if j >= slots[d] or not fits[(i, k)]:
                    add_row([(xi(i, j, k), 1.0)], 0, 0)
    # 2. one program stage per device slot
    for k, d in enumerate(devs):
        for j in range(slots[d]):
            add_row([(xi(i, j, k), 1.0) for i in range(T_s)], 0, 1)
    # 3. guarantee: x <= y
    for i in range(T_s):
        for k in range(K):
            for j in range(slots[devs[k]]):
                add_row([(xi(i, j, k), 1.0), (yk(k), -1.0)], -1, 0)
    # 4. dependency: strictly increasing (position, slot) rank
    rank = {
        (j, k): float(pos[devs[k]] * (Dmax + 1) + j)
        for k in range(K)
        for j in range(Dmax)
    }
    for i in range(T_s - 1):
        ent = [(xi(i + 1, j, k), rank[(j, k)]) for k in range(K) for j in range(Dmax)]
        ent += [(xi(i, j, k), -rank[(j, k)]) for k in range(K) for j in range(Dmax)]
        add_row(ent, 1, np.inf)
    # 5. last-stage indicator: c_k = sum_j x[T_s-1, j, k]
    for k in range(K):
        ent = [(xi(T_s - 1, j, k), 1.0) for j in range(Dmax)] + [(ck(k), -1.0)]
        add_row(ent, 0, 0)
    # 6. colocation groups (SVM integrity constraint)
    if prob.colocate:
        for i, g in prob.colocate.items():
            for k in range(K):
                ent = [(xi(i, j, k), 1.0) for j in range(Dmax)] + [(gk(g, k), -1.0)]
                add_row(ent, 0, 0)
        for g in groups:
            add_row([(gk(g, k), 1.0) for k in range(K)], 1, 1)

    A = sp.csr_matrix((vals, (rows, cols)), shape=(r, n_var))
    res = milp(
        c=c_obj,
        constraints=LinearConstraint(A, np.asarray(lbs), np.asarray(ubs)),
        integrality=np.ones(n_var),
        bounds=Bounds(0, 1),
    )
    if not res.success:
        return None
    x = np.round(res.x).astype(int)
    assignment: dict[int, str] = {}
    for i in range(T_s):
        for k, d in enumerate(devs):
            for j in range(slots[d]):
                if x[xi(i, j, k)]:
                    assignment[i] = d
    obj, breakdown = _objective_terms(prob, assignment)
    return Plan(prob.path, assignment, obj, breakdown, "milp", time.perf_counter() - t0)


# --------------------------------------------------------------------------
# DP solver (beyond-paper exact, homogeneous slots)
# --------------------------------------------------------------------------
def _solve_dp(prob: PathProblem) -> Plan | None:
    t0 = time.perf_counter()
    stages = prob.stages
    T_s = len(stages)
    devs = [
        d for d in prob.path
        if d in prob.devices and prob.devices[d].programmable
        and prob.free_slots.get(d, 0) > 0
        and prob.path.index(d) >= prob.min_position
    ]
    if not devs:
        return None
    P = len(devs)
    w_L, w_D, w_O = prob.weights
    lat = prob.latency
    dev_cost = w_L * lat.l_e + w_D

    fits = np.array(
        [[_stage_fits(stages[i], prob.devices[d]) for d in devs] for i in range(T_s)]
    )
    cap = np.array([prob.free_slots[d] for d in devs])

    # Colocation: a group's stages must land on one device. Because groups are
    # contiguous runs of stages in our programs, it suffices to forbid cutting
    # inside a group.
    coloc = prob.colocate or {}
    same_group_as_prev = np.zeros(T_s, bool)
    for i in range(1, T_s):
        same_group_as_prev[i] = (
            i in coloc and (i - 1) in coloc and coloc[i] == coloc[i - 1]
        )

    INF = float("inf")
    # f[i][p]: min cost placing stages [0, i) with stage i-1 on device p.
    f = np.full((T_s + 1, P), INF)
    back = np.full((T_s + 1, P), -1, dtype=np.int64)  # run start stage
    backp = np.full((T_s + 1, P), -1, dtype=np.int64)  # previous device index

    for p in range(P):
        # first run [0, r) on device p
        for r in range(1, min(cap[p], T_s) + 1):
            if not fits[:r, p].all():
                break
            if r < T_s and same_group_as_prev[r]:
                continue
            if f[r, p] > dev_cost:
                f[r, p] = dev_cost
                back[r, p] = 0
                backp[r, p] = -1
    for i in range(1, T_s):
        for p in range(P):
            if f[i, p] == INF:
                continue
            for p2 in range(p + 1, P):
                for r in range(1, min(cap[p2], T_s - i) + 1):
                    if not fits[i : i + r, p2].all():
                        break
                    if i + r < T_s and same_group_as_prev[i + r]:
                        continue
                    if same_group_as_prev[i]:
                        continue  # can't cut inside a group
                    cost = f[i, p] + dev_cost
                    if cost < f[i + r, p2]:
                        f[i + r, p2] = cost
                        back[i + r, p2] = i
                        backp[i + r, p2] = p

    hops = len(prob.path) - 1
    t_rq = lat.t_bytes(prob.request_bytes)
    t_rs = lat.t_bytes(prob.response_bytes)
    best, best_p = INF, -1
    for p in range(P):
        if f[T_s, p] == INF:
            continue
        q = prob.path.index(devs[p])
        tail = (
            w_L * (lat.l_p * hops + t_rq * q + t_rs * (hops - q))
            + w_O * (prob.request_bytes * q + prob.response_bytes * (hops - q))
        )
        tot = f[T_s, p] + tail
        if tot < best:
            best, best_p = tot, p
    if best_p < 0:
        return None
    # reconstruct
    assignment: dict[int, str] = {}
    i, p = T_s, best_p
    while i > 0:
        start = int(back[i, p])
        for s in range(start, i):
            assignment[s] = devs[p]
        i, p = start, int(backp[i, p])
    obj, breakdown = _objective_terms(prob, assignment)
    return Plan(prob.path, assignment, obj, breakdown, "dp", time.perf_counter() - t0)


def _left_pack(prob: PathProblem, plan: Plan) -> Plan:
    """Canonicalize an optimal assignment: re-pack stages greedily onto the
    *same* device set in path order.  The combined objective depends only on
    (devices used, last-stage position), so this is objective-preserving —
    and it makes DP and MILP tie-break identically while leaving maximal free
    slots on downstream devices for later planner units.
    """
    used = sorted(set(plan.assignment.values()), key=prob.path.index)
    coloc = prob.colocate or {}
    stages = prob.stages
    new: dict[int, str] = {}
    di = 0
    cap = prob.free_slots.get(used[0], 0)
    i = 0
    while i < len(stages):
        # atomic block: a colocation group moves as one
        j = i + 1
        while j < len(stages) and j in coloc and (j - 1) in coloc \
                and coloc[j] == coloc[j - 1]:
            j += 1
        blk = list(range(i, j))
        placed = False
        while di < len(used):
            d = used[di]
            ok = (cap >= len(blk)
                  and all(_stage_fits(stages[b], prob.devices[d]) for b in blk))
            if ok:
                for b in blk:
                    new[b] = d
                cap -= len(blk)
                placed = True
                break
            di += 1
            cap = prob.free_slots.get(used[di], 0) if di < len(used) else 0
        if not placed:
            return plan  # cannot left-pack (shouldn't happen); keep original
        i = j
    # every used device must still host >= 1 stage, else the solver missed a
    # cheaper plan — keep the original in that (theoretical) case
    if set(new.values()) != set(used):
        return plan
    obj, breakdown = _objective_terms(prob, new)
    if obj > plan.objective + 1e-9:
        return plan
    return Plan(plan.path, new, obj, breakdown, plan.solver, plan.solve_time)


def solve_path(prob: PathProblem, solver: str = "dp") -> Plan | None:
    if solver == "milp":
        plan = _solve_milp(prob)
    elif solver == "dp":
        plan = _solve_dp(prob)
    else:
        raise ValueError(f"unknown solver {solver}")
    return _left_pack(prob, plan) if plan is not None else None


# --------------------------------------------------------------------------
# Whole-program planning (per-tree / per-hyperplane decomposition + paths)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class DeploymentPlan:
    path: list[str]
    assignment: dict[int, str]            # global stage idx -> device
    objective: float
    breakdown: dict
    solver: str
    solve_time: float
    unit_plans: list[Plan] = dataclasses.field(default_factory=list)

    def device_stages(self) -> dict[str, set[int]]:
        out: dict[str, set[int]] = {}
        for i, d in self.assignment.items():
            out.setdefault(d, set()).add(i)
        return out


def _program_units(program: TableProgram) -> list[tuple[list[int], dict[int, int] | None]]:
    """Split a program into planner units (paper App. B): per tree-block for
    forests, per hyperplane for SVMs; predict/voting stages form the final
    unit.  Returns [(global stage indices, colocate map per unit)]."""
    specs = program.stages()
    units: list[tuple[list[int], dict[int, int] | None]] = []
    if program.kind in ("dt", "rf"):
        blocks: dict[int, list[int]] = {}
        final: list[int] = []
        for s in specs:
            kinds = {t.kind for t in s.tables}
            if kinds <= {"dt_layer"}:
                blk = min(t.tree for t in s.tables) // program.trees_per_block
                blocks.setdefault(blk, []).append(s.index)
            else:
                final.append(s.index)
        for blk in sorted(blocks):
            units.append((blocks[blk], None))
        units.append((final, None))
    else:  # svm
        by_h: dict[int, list[int]] = {}
        final = []
        for s in specs:
            hs = s.hyperplanes
            if hs:
                by_h.setdefault(hs[0], []).append(s.index)
            else:
                final.append(s.index)
        for h in sorted(by_h):
            colocate = {i: h for i in range(len(by_h[h]))}  # unit-local indices
            units.append((by_h[h], colocate))
        units.append((final, None))
    return [u for u in units if u[0]]


def plan_program(
    program: TableProgram,
    network: Network,
    src: str,
    dst: str,
    *,
    devices: dict[str, DeviceModel] | None = None,
    default_device: DeviceModel = DeviceModel(),
    weights: tuple[float, float, float] = (1 / 3, 1 / 3, 1 / 3),
    latency: LatencyModel = LatencyModel(),
    solver: str = "dp",
    n_candidate_paths: int = 4,
    exclude: set[str] | None = None,
    reserved_slots: dict[str, int] | None = None,
    candidate_paths: list[list[str]] | None = None,
) -> DeploymentPlan:
    """Full ACORN planning: candidate paths × per-unit placement.

    ``reserved_slots`` carries capacity already consumed by previously planned
    programs (the model-zoo per-version assignment: versions planned earlier
    shrink the slots available to later ones, pushing them onto other devices
    of the path).  ``candidate_paths`` overrides path enumeration — used by
    ``plan_zoo`` to pin every version to one wire path.
    """
    t0 = time.perf_counter()
    specs = program.stages()
    devices = devices or {}
    exclude = exclude or set()
    reserved_slots = reserved_slots or {}
    req_bytes = packets.request_bytes(
        program.n_features,
        n_trees=program.n_trees,
        n_hyperplanes=program.n_hyperplanes,
    )
    paths = candidate_paths
    if paths is None:
        if exclude & {src, dst}:
            raise RuntimeError(f"endpoint failed: {sorted(exclude & {src, dst})}")
        # Enumerate on the surviving topology: the full network's k-shortest
        # list can have every candidate crossing the dead device even when an
        # alternate route exists one rank further down.
        search = network.without(exclude) if exclude else network
        paths = search.k_shortest_paths(src, dst, n_candidate_paths)
        if not paths and exclude:
            raise RuntimeError(
                f"no surviving path {src} -> {dst} with failed "
                f"device(s) {sorted(exclude)}"
            )
    if not paths:
        raise ValueError(f"no path {src} -> {dst}")
    units = _program_units(program)
    best: DeploymentPlan | None = None
    for path in paths:
        if any(d in exclude for d in path):
            continue
        devmap = {
            d: devices.get(d, default_device)
            for d in path
            if network.kind.get(d) == "switch" and network.programmable.get(d, False)
        }
        free = {
            d: max(0, devmap[d].n_stages - reserved_slots.get(d, 0))
            for d in devmap
        }
        assignment: dict[int, str] = {}
        unit_plans: list[Plan] = []
        ok = True
        for ui, (stage_ids, colocate) in enumerate(units):
            # The final unit (predict/voting) depends on every other unit:
            # it may not land upstream of any already-placed stage.
            min_pos = 0
            if ui == len(units) - 1 and assignment:
                min_pos = max(path.index(d) for d in assignment.values())
            sub = [specs[i] for i in stage_ids]
            prob = PathProblem(
                stages=sub,
                path=path,
                devices=devmap,
                free_slots=dict(free),
                weights=weights,
                latency=latency,
                request_bytes=req_bytes,
                colocate=colocate,
                min_position=min_pos,
            )
            p = solve_path(prob, solver)
            if p is None:
                ok = False
                break
            unit_plans.append(p)
            for local_i, dev in p.assignment.items():
                assignment[stage_ids[local_i]] = dev
                free[dev] -= 1
        if not ok:
            continue
        # combined objective over the union deployment
        comb = PathProblem(
            stages=specs, path=path, devices=devmap,
            free_slots={d: devmap[d].n_stages for d in devmap},
            weights=weights, latency=latency, request_bytes=req_bytes,
        )
        obj, breakdown = _objective_terms(comb, assignment)
        cand = DeploymentPlan(
            path, assignment, obj, breakdown, solver,
            time.perf_counter() - t0, unit_plans,
        )
        if best is None or cand.objective < best.objective:
            best = cand
    if best is None:
        raise RuntimeError(
            "no feasible deployment (model too large for path resources — "
            "paper's answer: add devices or features via RFE)"
        )
    best.solve_time = time.perf_counter() - t0
    return best


def plan_zoo(
    programs: list[TableProgram],
    network: Network,
    src: str,
    dst: str,
    **kw,
) -> list[DeploymentPlan]:
    """Per-version stage assignment for a model zoo (paper App. B extended
    along the VID axis): plan each version's program in order with capacity
    carry-over, so versions planned later are pushed onto devices of the path
    that still have free slots — different versions of a model can live on
    different devices, all serving the same wire path simultaneously.

    The first version picks the path; later versions are pinned to it so the
    merged deployment has one consistent hop order
    (see ``distributed_plane.build_zoo_device_programs``).
    """
    reserved: dict[str, int] = {}
    plans: list[DeploymentPlan] = []
    pinned: list[list[str]] | None = None
    for program in programs:
        plan = plan_program(
            program, network, src, dst,
            reserved_slots=dict(reserved),
            candidate_paths=pinned,
            **kw,
        )
        pinned = [plan.path]
        for dev in plan.assignment.values():
            reserved[dev] = reserved.get(dev, 0) + 1
        plans.append(plan)
    return plans


def replan(
    program: TableProgram,
    network: Network,
    src: str,
    dst: str,
    failed: set[str],
    **kw,
) -> DeploymentPlan:
    """Failure-aware replanning (beyond paper §9): exclude dead devices."""
    return plan_program(program, network, src, dst, exclude=failed, **kw)


def replan_zoo(
    programs: list[TableProgram],
    network: Network,
    src: str,
    dst: str,
    failed: set[str],
    **kw,
) -> list[DeploymentPlan]:
    """Zoo-wide failure-aware replanning — the control loop's replan step.

    Re-runs ``plan_zoo`` on the surviving topology, so the per-version
    capacity carry-over and the single-pinned-path invariant both hold on
    the post-fault deployment exactly as they did on the original one."""
    return plan_zoo(programs, network, src, dst, exclude=set(failed), **kw)

"""ACORN's five pre-defined match-action table types (paper §6, Table 1).

| table            | match   | keys                                  | action                |
|------------------|---------|---------------------------------------|-----------------------|
| dt_layer         | ternary | (feature value, prev status code)     | set decision bit      |
| dt_predict       | exact   | final status code                     | per-tree label        |
| multitree_voting | exact   | all per-tree labels                   | final label           |
| svm_mul          | exact   | feature value                         | precomputed product   |
| svm_predict      | exact   | hyperplane sign code                  | final label           |

Semantics notes (these make the layer representation *collision-free*, which
the paper asserts but does not prove):

* The status code accumulates one bit per layer (bit ``d`` = branch taken at
  depth ``d``), initialized to 0 and frozen once a leaf is reached.  Leaf
  paths form a prefix-free set (a leaf has no descendants), therefore
  (a) a frozen code can never match any deeper ``dt_layer`` entry — early
  leaves fall through with **no explicit entries**, exactly the paper's
  "passes through the remaining tables without triggering any actions"; and
  (b) zero-padded leaf codes are pairwise distinct, so ``dt_predict`` can use
  plain *exact* matching (paper Table 1) without ambiguity.

* Each internal node costs 2 logical entries: a high-priority ``x[f] <= t``
  range entry (branch bit 0) and a low-priority feature-wildcard catch-all
  (branch bit 1) — the paper's "entry priority is used to reduce the number
  of table entries" (Fig. 3).  Physical TCAM cost expands the range into
  ``<= width`` prefixes (``range_to_prefixes``); the catch-all costs 1.

Tables are plain numpy structs here; ``plane.py`` packs them into fixed-shape
JAX arrays (entries are *inputs* to the jitted engine — that is the runtime
programmability mechanism).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "range_to_prefixes",
    "tcam_entries_for_le_range",
    "DtLayerTable",
    "DtPredictTable",
    "VotingTable",
    "SvmMulTable",
    "SvmPredictTable",
]


# --------------------------------------------------------------------------
# TCAM range -> prefix expansion
# --------------------------------------------------------------------------
def range_to_prefixes(lo: int, hi: int, width: int) -> list[tuple[int, int]]:
    """Expand integer range [lo, hi] into ternary (value, mask) prefixes.

    Standard TCAM range expansion: worst case ``2*width - 2`` prefixes for an
    arbitrary range, ``<= width`` for a ``[0, t]`` range.  ``mask`` has 1-bits
    where the entry cares; match is ``(x & mask) == value``.
    """
    if lo > hi:
        return []
    full = (1 << width) - 1
    if lo < 0 or hi > full:
        raise ValueError(f"range [{lo},{hi}] out of [0,{full}]")
    out: list[tuple[int, int]] = []

    def rec(lo: int, hi: int, value: int, mask_bits: int) -> None:
        """Cover [lo,hi] within the aligned block (value, mask_bits top bits set)."""
        blk_lo = value
        blk_hi = value | (full >> mask_bits if mask_bits < width else 0)
        if lo <= blk_lo and blk_hi <= hi:
            mask = (full << (width - mask_bits)) & full if mask_bits else 0
            out.append((value, mask))
            return
        if blk_hi < lo or blk_lo > hi or mask_bits == width:
            return
        half = (blk_hi - blk_lo + 1) // 2
        rec(lo, hi, value, mask_bits + 1)               # left half (next bit 0)
        rec(lo, hi, value | half, mask_bits + 1)        # right half (next bit 1)

    rec(lo, hi, 0, 0)
    return out


def tcam_entries_for_le_range(t: int, width: int) -> int:
    """Physical TCAM entries to express ``x <= t`` on a ``width``-bit field."""
    return len(range_to_prefixes(0, t, width))


# --------------------------------------------------------------------------
# Table structs
# --------------------------------------------------------------------------
@dataclasses.dataclass
class DtLayerTable:
    """One tree layer's ternary table (paper Fig. 3).

    Logical entries (arrays of equal length E):
      match  = ((code & code_mask) == code_value)
               and (f_lo <= features[fid] <= f_hi)
      action = set status-code bit ``layer`` to ``set_bit``
    Highest ``priority`` wins; rows are kept sorted priority-descending so
    "first match" == "highest priority" in the engine and the kernel.
    """

    layer: int
    tree: int
    code_value: np.ndarray  # uint32 [E]
    code_mask: np.ndarray   # uint32 [E]
    fid: np.ndarray         # int32 [E]
    f_lo: np.ndarray        # int32 [E]
    f_hi: np.ndarray        # int32 [E]
    priority: np.ndarray    # int32 [E]
    set_bit: np.ndarray     # uint8 [E]
    feature_width: int = 8  # quantization bits (for TCAM expansion counting)

    def __post_init__(self) -> None:
        order = np.argsort(-self.priority, kind="stable")
        for f in ("code_value", "code_mask", "fid", "f_lo", "f_hi", "priority", "set_bit"):
            setattr(self, f, np.asarray(getattr(self, f))[order])

    @property
    def n_entries(self) -> int:
        return int(self.code_value.shape[0])

    @property
    def n_tcam_entries(self) -> int:
        """Physical TCAM entries after range->prefix expansion."""
        total = 0
        full = (1 << self.feature_width) - 1
        for lo, hi in zip(self.f_lo, self.f_hi):
            if lo == 0 and hi == full:
                total += 1  # wildcard catch-all
            else:
                total += len(range_to_prefixes(int(lo), int(hi), self.feature_width))
        return total

    def lookup(self, codes: np.ndarray, features: np.ndarray) -> np.ndarray:
        """Numpy oracle for one layer (B packets). Returns updated codes."""
        codes = codes.astype(np.uint32)
        code_ok = (codes[:, None] & self.code_mask[None, :]) == self.code_value[None, :]
        f = features[:, self.fid.astype(np.int64)]  # [B, E]
        f_ok = (f >= self.f_lo[None, :]) & (f <= self.f_hi[None, :])
        ok = code_ok & f_ok
        hit = ok.any(axis=1)
        first = np.argmax(ok, axis=1)  # rows sorted by priority desc
        bit = self.set_bit[first].astype(np.uint32)
        new = codes | (bit << np.uint32(self.layer))
        return np.where(hit, new, codes).astype(np.uint32)


@dataclasses.dataclass
class DtPredictTable:
    """Exact match: zero-padded leaf path code -> per-tree label."""

    tree: int
    codes: np.ndarray   # uint32 [E], unique
    labels: np.ndarray  # int32 [E]

    def __post_init__(self) -> None:
        order = np.argsort(self.codes, kind="stable")
        self.codes = np.asarray(self.codes, dtype=np.uint32)[order]
        self.labels = np.asarray(self.labels, dtype=np.int32)[order]
        if np.unique(self.codes).size != self.codes.size:
            raise ValueError("dt_predict codes must be unique (prefix-free property violated)")

    @property
    def n_entries(self) -> int:
        return int(self.codes.shape[0])

    def lookup(self, codes: np.ndarray) -> np.ndarray:
        pos = np.searchsorted(self.codes, codes.astype(np.uint32))
        pos = np.clip(pos, 0, self.codes.size - 1)
        found = self.codes[pos] == codes
        return np.where(found, self.labels[pos], -1).astype(np.int32)


@dataclasses.dataclass
class VotingTable:
    """Exact match on the tuple of per-tree labels -> final label.

    Realized as a *direct-indexed* SRAM table over the perfect hash
    ``sum_t label_t * C**t`` when ``C**T`` fits ``max_materialized`` (this is
    exactly what an exact-match SRAM table does); larger models fall back to
    computed weighted voting with identical semantics (``weights`` are still
    runtime-swappable inputs).
    """

    n_trees: int
    n_classes: int
    weights: np.ndarray                 # float64 [T]
    table: np.ndarray | None = None     # int32 [C**T] or None (computed fallback)
    max_materialized: int = 1 << 20

    @classmethod
    def build(cls, n_trees: int, n_classes: int, weights: np.ndarray | None = None,
              max_materialized: int = 1 << 20) -> "VotingTable":
        w = np.ones(n_trees) if weights is None else np.asarray(weights, np.float64)
        table = None
        if n_classes**n_trees <= max_materialized:
            combos = np.indices((n_classes,) * n_trees).reshape(n_trees, -1).T  # [C^T, T]
            onehot = np.eye(n_classes)[combos]          # [C^T, T, C]
            scores = np.tensordot(onehot, w, axes=([1], [0]))
            table = np.argmax(scores, axis=1).astype(np.int32)
        return cls(n_trees, n_classes, w, table, max_materialized)

    @property
    def n_entries(self) -> int:
        return int(self.n_classes**self.n_trees) if self.table is not None else 0

    def lookup(self, votes: np.ndarray) -> np.ndarray:
        """votes [B, T] -> final labels [B]."""
        if self.table is not None:
            idx = np.zeros(votes.shape[0], dtype=np.int64)
            for t in range(self.n_trees):
                idx += votes[:, t].astype(np.int64) * (self.n_classes**t)
            return self.table[idx]
        onehot = np.eye(self.n_classes)[votes]
        scores = np.tensordot(onehot, self.weights, axes=([1], [0]))
        return np.argmax(scores, axis=1).astype(np.int32)


@dataclasses.dataclass
class SvmMulTable:
    """One (hyperplane, feature) multiplication LUT (paper §4.3).

    ``lut[v] = round(w[h, f] * center(v) * 2**frac_bits)`` — the precomputed
    quantized product for feature value ``v``.  Exact-match SRAM; the engine
    direct-indexes it.
    """

    hyperplane: int
    feature: int
    lut: np.ndarray  # int32 [levels]

    @property
    def n_entries(self) -> int:
        return int(self.lut.shape[0])

    def lookup(self, values: np.ndarray) -> np.ndarray:
        return self.lut[values.astype(np.int64)]


@dataclasses.dataclass
class SvmPredictTable:
    """Exact match: H-bit hyperplane sign code -> label.

    Direct-indexed over the sign-code integer when ``2**H`` fits; fallback is
    computed pairwise voting (identical semantics, pairs are inputs).
    """

    n_hyperplanes: int
    n_classes: int
    pairs: np.ndarray                  # int32 [H, 2]; (i, j) ovo or (i, -1) ovr
    table: np.ndarray | None = None    # int32 [2**H]
    max_materialized: int = 1 << 16

    @classmethod
    def build(cls, pairs: np.ndarray, n_classes: int, vote_fn,
              max_materialized: int = 1 << 16) -> "SvmPredictTable":
        """``vote_fn(signs [N, H]) -> labels [N]`` (LinearSVM.votes_from_signs)."""
        pairs = np.asarray(pairs, dtype=np.int32)
        H = pairs.shape[0]
        table = None
        if 2**H <= max_materialized:
            codes = np.arange(2**H, dtype=np.int64)
            signs = ((codes[:, None] >> np.arange(H)[None, :]) & 1).astype(np.int64)
            table = vote_fn(signs).astype(np.int32)
        return cls(H, n_classes, pairs, table, max_materialized)

    @property
    def n_entries(self) -> int:
        return int(2**self.n_hyperplanes) if self.table is not None else 0

    def lookup(self, signs: np.ndarray) -> np.ndarray:
        code = (signs.astype(np.int64) << np.arange(self.n_hyperplanes)[None, :]).sum(axis=1)
        if self.table is not None:
            return self.table[code]
        # computed fallback: pairwise votes
        n = signs.shape[0]
        scores = np.zeros((n, self.n_classes))
        for h in range(self.n_hyperplanes):
            i, j = self.pairs[h]
            pos = signs[:, h] == 1
            if j >= 0:
                scores[pos, i] += 1
                scores[~pos, j] += 1
            else:
                scores[pos, i] += 1
        return np.argmax(scores, axis=1).astype(np.int32)

"""Datacenter topologies for the deployment planner (paper §7.5, Table 6).

Fat-Tree [4], DCell [30], BCube [29], Jellyfish [53] — the four families the
paper evaluates the optimizer on.  Each builder returns a ``Network``: nodes
(hosts + switches, with per-switch programmability flags), adjacency, and
path utilities (BFS shortest path + a Yen-style k-shortest-paths for the
planner's candidate path set P).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

__all__ = ["Network", "fat_tree", "dcell", "bcube", "jellyfish"]


@dataclasses.dataclass
class Network:
    name: str
    nodes: list[str]
    kind: dict[str, str]              # node -> "host" | "switch"
    adj: dict[str, list[str]]
    programmable: dict[str, bool]

    @property
    def n_switches(self) -> int:
        return sum(1 for n in self.nodes if self.kind[n] == "switch")

    @property
    def n_hosts(self) -> int:
        return sum(1 for n in self.nodes if self.kind[n] == "host")

    def hosts(self) -> list[str]:
        return [n for n in self.nodes if self.kind[n] == "host"]

    def switches(self) -> list[str]:
        return [n for n in self.nodes if self.kind[n] == "switch"]

    def without(self, failed: set[str]) -> "Network":
        """The surviving topology after ``failed`` nodes die (the control
        plane's replan view).  Path search on the subgraph reports
        unreachable endpoints honestly — ``shortest_path`` returns ``None``
        and ``k_shortest_paths`` returns ``[]`` — instead of routing through
        dead hardware."""
        failed = set(failed)
        unknown = failed - set(self.kind)
        if unknown:
            raise ValueError(f"unknown node(s): {sorted(unknown)}")
        nodes = [n for n in self.nodes if n not in failed]
        return Network(
            self.name,
            nodes,
            {n: self.kind[n] for n in nodes},
            {n: [v for v in self.adj[n] if v not in failed] for n in nodes},
            {n: self.programmable[n] for n in nodes},
        )

    # ---------------------------------------------------------------- paths
    def shortest_path(self, src: str, dst: str) -> list[str] | None:
        prev: dict[str, str] = {src: src}
        q = [src]
        while q:
            nxt = []
            for u in q:
                if u == dst:
                    path = [u]
                    while path[-1] != src:
                        path.append(prev[path[-1]])
                    return path[::-1]
                for v in self.adj[u]:
                    if v not in prev:
                        prev[v] = u
                        nxt.append(v)
            q = nxt
        return None

    def k_shortest_paths(self, src: str, dst: str, k: int = 4) -> list[list[str]]:
        """Yen's algorithm (hop metric). Returns up to k loop-free paths,
        shortest first — the planner's candidate set P."""
        first = self.shortest_path(src, dst)
        if first is None:
            return []
        paths = [first]
        candidates: list[tuple[int, int, list[str]]] = []
        tiebreak = itertools.count()
        while len(paths) < k:
            prev_path = paths[-1]
            for i in range(len(prev_path) - 1):
                spur, root = prev_path[i], prev_path[: i + 1]
                removed: set[tuple[str, str]] = set()
                for p in paths:
                    if p[: i + 1] == root and len(p) > i + 1:
                        removed.add((p[i], p[i + 1]))
                banned_nodes = set(root[:-1])
                tail = self._sp_avoid(spur, dst, removed, banned_nodes)
                if tail is not None:
                    cand = root[:-1] + tail
                    if cand not in paths and all(c[2] != cand for c in candidates):
                        heapq.heappush(candidates, (len(cand), next(tiebreak), cand))
            if not candidates:
                break
            _, _, best = heapq.heappop(candidates)
            paths.append(best)
        return paths

    def _sp_avoid(self, src, dst, removed_edges, banned_nodes):
        prev = {src: src}
        q = [src]
        while q:
            nxt = []
            for u in q:
                if u == dst:
                    path = [u]
                    while path[-1] != src:
                        path.append(prev[path[-1]])
                    return path[::-1]
                for v in self.adj[u]:
                    if v in banned_nodes or v in prev or (u, v) in removed_edges:
                        continue
                    prev[v] = u
                    nxt.append(v)
            q = nxt
        return None


def _mk(name: str) -> tuple[list, dict, dict, dict]:
    return [], {}, {}, {}


def _add(nodes, kind, adj, prog, node, nkind, programmable=True):
    if node not in kind:
        nodes.append(node)
        kind[node] = nkind
        adj[node] = []
        prog[node] = programmable and nkind == "switch"


def _link(adj, a, b):
    if b not in adj[a]:
        adj[a].append(b)
        adj[b].append(a)


# --------------------------------------------------------------------------
def fat_tree(k: int, *, hosts_per_edge: int = 1) -> Network:
    """K-ary fat-tree: k pods, k^2/4 core, k/2 agg + k/2 edge per pod."""
    if k % 2:
        raise ValueError("fat-tree k must be even")
    nodes, kind, adj, prog = _mk("fat-tree")
    half = k // 2
    cores = [f"core{i}" for i in range(half * half)]
    for c in cores:
        _add(nodes, kind, adj, prog, c, "switch")
    for p in range(k):
        aggs = [f"agg{p}_{i}" for i in range(half)]
        edges = [f"edge{p}_{i}" for i in range(half)]
        for a in aggs:
            _add(nodes, kind, adj, prog, a, "switch")
        for e in edges:
            _add(nodes, kind, adj, prog, e, "switch")
        for a in aggs:
            for e in edges:
                _link(adj, a, e)
        for i, a in enumerate(aggs):
            for j in range(half):
                _link(adj, a, cores[i * half + j])
        for ei, e in enumerate(edges):
            for h in range(hosts_per_edge):
                hn = f"h{p}_{ei}_{h}"
                _add(nodes, kind, adj, prog, hn, "host")
                _link(adj, e, hn)
    return Network("fat-tree", nodes, kind, adj, prog)


def dcell(n: int, k: int) -> Network:
    """DCell_k with n servers per DCell_0 (recursive, Guo et al. 2008)."""
    nodes, kind, adj, prog = _mk("dcell")

    def t(level):  # servers in a DCell_level
        cnt = n
        for _ in range(level):
            cnt = cnt * (cnt + 1)
        return cnt

    def build(prefix: tuple, level: int) -> list[str]:
        if level == 0:
            sw = "sw" + "_".join(map(str, prefix))
            _add(nodes, kind, adj, prog, sw, "switch")
            servers = []
            for i in range(n):
                s = "s" + "_".join(map(str, prefix + (i,)))
                _add(nodes, kind, adj, prog, s, "host")
                _link(adj, sw, s)
                servers.append(s)
            return servers
        g = t(level - 1) + 1           # number of sub-cells
        subs = [build(prefix + (i,), level - 1) for i in range(g)]
        # Full mesh between sub-cells: connect server j of cell i to server i
        # of cell j+1 (standard DCell wiring).
        for i in range(g):
            for j in range(i + 1, g):
                a = subs[i][j - 1 if j > i else j]
                b = subs[j][i]
                _link(adj, a, b)
        return [s for sub in subs for s in sub]

    build((), k)
    return Network("dcell", nodes, kind, adj, prog)


def bcube(n: int, k: int) -> Network:
    """BCube_k with n-port switches: n^(k+1) servers, (k+1)*n^k switches."""
    nodes, kind, adj, prog = _mk("bcube")
    n_servers = n ** (k + 1)
    servers = []
    for i in range(n_servers):
        digits = []
        x = i
        for _ in range(k + 1):
            digits.append(x % n)
            x //= n
        s = "s" + "_".join(map(str, digits[::-1]))
        _add(nodes, kind, adj, prog, s, "host")
        servers.append((s, digits[::-1]))
    for level in range(k + 1):
        for sw_idx in range(n**k):
            sw = f"sw{level}_{sw_idx}"
            _add(nodes, kind, adj, prog, sw, "switch")
    for s, digits in servers:
        for level in range(k + 1):
            rest = [d for i, d in enumerate(digits) if i != (k - level)]
            sw_idx = 0
            for d in rest:
                sw_idx = sw_idx * n + d
            _link(adj, s, f"sw{level}_{sw_idx}")
    return Network("bcube", nodes, kind, adj, prog)


def jellyfish(n: int, d: int, *, hosts: int = 8, seed: int = 0) -> Network:
    """Random d-regular graph over n switches (Singla et al., NSDI'12)."""
    rng = np.random.default_rng(seed)
    nodes, kind, adj, prog = _mk("jellyfish")
    sws = [f"sw{i}" for i in range(n)]
    for s in sws:
        _add(nodes, kind, adj, prog, s, "switch")
    # Pairing-model regular graph with patching.
    stubs = [i for i in range(n) for _ in range(d)]
    for attempt in range(200):
        rng.shuffle(stubs)
        pairs = [(stubs[2 * i], stubs[2 * i + 1]) for i in range(len(stubs) // 2)]
        ok = all(a != b for a, b in pairs)
        edge_set = {tuple(sorted(p)) for p in pairs}
        if ok and len(edge_set) == len(pairs):
            for a, b in pairs:
                _link(adj, sws[a], sws[b])
            break
    else:  # fallback: ring + chords
        for i in range(n):
            _link(adj, sws[i], sws[(i + 1) % n])
            for c in range(2, d):
                _link(adj, sws[i], sws[(i + 1 + c * (n // d)) % n])
    for h in range(hosts):
        hn = f"h{h}"
        _add(nodes, kind, adj, prog, hn, "host")
        _link(adj, hn, sws[int(rng.integers(0, n))])
    return Network("jellyfish", nodes, kind, adj, prog)

"""ACORN's code translator (paper §3.2, §4): trained model → TableProgram.

A ``TableProgram`` is the network-level object: the ordered stages of
match-action tables the deployment planner places onto devices, plus the
table structs the plane engine packs into runtime-swappable entry arrays.

Stage layout follows the paper's data plane design (Fig. 5):

* decision tree  — one ``dt_layer`` table per layer (stage), then a
  ``dt_predict`` stage;
* random forest  — trees are processed **two per block** ("at each stage, two
  DT_layer tables are grouped into a block"): trees (0,1) occupy stages
  0..D-1, trees (2,3) stages D..2D-1, ...; then one stage holding all
  ``dt_predict`` tables and one ``multitree_voting`` stage;
* SVM            — ``svm_mul`` tables grouped ``muls_per_stage`` per stage
  ("multiple multiplication tables can be placed in the same pipeline
  stage"), then the ``svm_predict`` stage; the native-adder hyperplane sums
  cost ALU, not entries.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mlmodels.cart import DecisionTree
from repro.core.mlmodels.forest import RandomForest
from repro.core.mlmodels.linsvm import LinearSVM
from repro.core.tables import (
    DtLayerTable,
    DtPredictTable,
    SvmMulTable,
    SvmPredictTable,
    VotingTable,
)

__all__ = [
    "TableSpec",
    "StageSpec",
    "TableProgram",
    "translate",
    "translate_decision_tree",
    "translate_random_forest",
    "translate_svm",
]

MID_DT, MID_RF, MID_SVM = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """Resource footprint of one table — the planner's t_{i,j}."""

    kind: str            # dt_layer | dt_predict | multitree_voting | svm_mul | svm_predict
    logical_entries: int
    tcam_entries: int    # physical TCAM after range->prefix expansion
    sram_entries: int
    tree: int = -1       # owning tree (dt) — for reporting
    layer: int = -1
    hyperplane: int = -1  # owning hyperplane (svm) — colocation constraint


@dataclasses.dataclass(frozen=True)
class StageSpec:
    index: int
    tables: tuple[TableSpec, ...]

    @property
    def tcam_entries(self) -> int:
        return sum(t.tcam_entries for t in self.tables)

    @property
    def sram_entries(self) -> int:
        return sum(t.sram_entries for t in self.tables)

    @property
    def hyperplanes(self) -> tuple[int, ...]:
        return tuple(sorted({t.hyperplane for t in self.tables if t.hyperplane >= 0}))


@dataclasses.dataclass
class TableProgram:
    kind: str  # "dt" | "rf" | "svm"
    mid: int
    vid: int   # model-zoo version slot this program targets (Appendix A VID)
    n_features: int
    n_classes: int
    feature_width: int
    levels: int
    # tree-family payload
    dt_layers: list[list[DtLayerTable]] = dataclasses.field(default_factory=list)  # [tree][layer]
    dt_predicts: list[DtPredictTable] = dataclasses.field(default_factory=list)
    voting: VotingTable | None = None
    # svm payload
    svm_muls: list[SvmMulTable] = dataclasses.field(default_factory=list)
    svm_predict: SvmPredictTable | None = None
    svm_bias: np.ndarray | None = None  # int32 [H] fixed-point
    frac_bits: int = 12
    muls_per_stage: int = 8
    trees_per_block: int = 2

    def __post_init__(self):
        if self.vid < 0:
            raise ValueError(
                f"vid {self.vid} invalid: the ACORN VID header field is "
                "unsigned (version slots are 0-indexed)"
            )

    # ------------------------------------------------------------ structure
    @property
    def n_trees(self) -> int:
        return len(self.dt_layers)

    @property
    def n_hyperplanes(self) -> int:
        return 0 if self.svm_predict is None else self.svm_predict.n_hyperplanes

    @property
    def tree_depths(self) -> list[int]:
        return [len(layers) for layers in self.dt_layers]

    def stages(self) -> list[StageSpec]:
        """Planner input: ordered program stages with per-table footprints."""
        out: list[StageSpec] = []
        if self.kind in ("dt", "rf"):
            tpb = self.trees_per_block
            for blk_start in range(0, self.n_trees, tpb):
                block = list(range(blk_start, min(blk_start + tpb, self.n_trees)))
                depth = max(len(self.dt_layers[t]) for t in block)
                for layer in range(depth):
                    tabs = []
                    for t in block:
                        if layer < len(self.dt_layers[t]):
                            lt = self.dt_layers[t][layer]
                            tabs.append(
                                TableSpec(
                                    "dt_layer",
                                    lt.n_entries,
                                    lt.n_tcam_entries,
                                    0,
                                    tree=t,
                                    layer=layer,
                                )
                            )
                    out.append(StageSpec(len(out), tuple(tabs)))
            pred_tabs = tuple(
                TableSpec("dt_predict", p.n_entries, 0, p.n_entries, tree=p.tree)
                for p in self.dt_predicts
            )
            out.append(StageSpec(len(out), pred_tabs))
            if self.voting is not None and self.n_trees > 1:
                out.append(
                    StageSpec(
                        len(out),
                        (
                            TableSpec(
                                "multitree_voting",
                                self.voting.n_entries,
                                0,
                                self.voting.n_entries,
                            ),
                        ),
                    )
                )
        elif self.kind == "svm":
            for muls in self.svm_stage_muls():
                tabs = tuple(
                    TableSpec(
                        "svm_mul",
                        self.svm_muls[k].n_entries,
                        0,
                        self.svm_muls[k].n_entries,
                        hyperplane=self.svm_muls[k].hyperplane,
                    )
                    for k in muls
                )
                out.append(StageSpec(len(out), tabs))
            sp = self.svm_predict
            out.append(
                StageSpec(
                    len(out),
                    (TableSpec("svm_predict", sp.n_entries, 0, sp.n_entries),),
                )
            )
        return out

    def svm_stage_muls(self) -> list[list[int]]:
        """Mul-table indices per stage. Stages never straddle hyperplanes, so
        the colocation integrity constraint (paper §5.3) maps to whole stages."""
        by_h: dict[int, list[int]] = {}
        for k, m in enumerate(self.svm_muls):
            by_h.setdefault(m.hyperplane, []).append(k)
        stages: list[list[int]] = []
        mps = self.muls_per_stage
        for h in sorted(by_h):
            ms = by_h[h]
            for i in range(0, len(ms), mps):
                stages.append(ms[i : i + mps])
        return stages

    @property
    def n_stages(self) -> int:
        return len(self.stages())

    def total_tcam_entries(self) -> int:
        return sum(s.tcam_entries for s in self.stages())

    def total_sram_entries(self) -> int:
        return sum(s.sram_entries for s in self.stages())


# --------------------------------------------------------------------------
# Translators
# --------------------------------------------------------------------------
def _tree_layer_tables(dt: DecisionTree, tree_idx: int, feature_width: int) -> list[DtLayerTable]:
    t = dt.tree_
    layers: list[DtLayerTable] = []
    full = (1 << feature_width) - 1
    for depth, nodes in t.internal_by_depth():
        cv, cm, fid, flo, fhi, prio, bit = [], [], [], [], [], [], []
        mask = np.uint32((1 << depth) - 1)
        for n in nodes:
            p = np.uint32(int(t.path[n]) & int(mask))
            f, thr = int(t.feature[n]), int(t.threshold[n])
            # high-priority `x[f] <= thr` -> branch 0 (left)
            cv.append(p); cm.append(mask); fid.append(f)
            flo.append(0); fhi.append(thr); prio.append(1); bit.append(0)
            # low-priority catch-all -> branch 1 (right); priority trick, Fig. 3
            cv.append(p); cm.append(mask); fid.append(f)
            flo.append(0); fhi.append(full); prio.append(0); bit.append(1)
        layers.append(
            DtLayerTable(
                layer=depth,
                tree=tree_idx,
                code_value=np.asarray(cv, np.uint32),
                code_mask=np.asarray(cm, np.uint32),
                fid=np.asarray(fid, np.int32),
                f_lo=np.asarray(flo, np.int32),
                f_hi=np.asarray(fhi, np.int32),
                priority=np.asarray(prio, np.int32),
                set_bit=np.asarray(bit, np.uint8),
                feature_width=feature_width,
            )
        )
    # Contiguous layers 0..D-1 (internal_by_depth only yields non-empty ones,
    # which for a tree are exactly 0..max_internal_depth).
    return layers


def _tree_predict_table(dt: DecisionTree, tree_idx: int) -> DtPredictTable:
    t = dt.tree_
    leaves = t.leaves()
    return DtPredictTable(
        tree=tree_idx,
        codes=t.path[leaves].astype(np.uint32),
        labels=t.label[leaves].astype(np.int32),
    )


def translate_decision_tree(
    dt: DecisionTree, *, vid: int = 0, feature_width: int = 8
) -> TableProgram:
    if dt.tree_ is None:
        raise ValueError("fit the tree first")
    if dt.tree_.max_depth > 32:
        raise ValueError("status code is 32-bit: depth must be <= 32 (paper limit)")
    return TableProgram(
        kind="dt",
        mid=MID_DT,
        vid=vid,
        n_features=dt.n_features_,
        n_classes=dt.n_classes_,
        feature_width=feature_width,
        levels=dt.levels,
        dt_layers=[_tree_layer_tables(dt, 0, feature_width)],
        dt_predicts=[_tree_predict_table(dt, 0)],
        voting=None,
    )


def translate_random_forest(
    rf: RandomForest, *, vid: int = 0, feature_width: int = 8, trees_per_block: int = 2
) -> TableProgram:
    if not rf.trees_:
        raise ValueError("fit the forest first")
    return TableProgram(
        kind="rf",
        mid=MID_RF,
        vid=vid,
        n_features=rf.n_features_,
        n_classes=rf.n_classes_,
        feature_width=feature_width,
        levels=rf.levels,
        dt_layers=[_tree_layer_tables(t, i, feature_width) for i, t in enumerate(rf.trees_)],
        dt_predicts=[_tree_predict_table(t, i) for i, t in enumerate(rf.trees_)],
        voting=VotingTable.build(
            len(rf.trees_),
            rf.n_classes_,
            None if rf.tree_weights is None else np.asarray(rf.tree_weights),
        ),
        trees_per_block=trees_per_block,
    )


def translate_svm(
    svm: LinearSVM, *, vid: int = 0, feature_width: int = 8, frac_bits: int = 12,
    muls_per_stage: int = 8,
) -> TableProgram:
    if svm.W_ is None:
        raise ValueError("fit the SVM first")
    H, F = svm.W_.shape
    levels = svm.levels
    S = float(1 << frac_bits)
    centers = (np.arange(levels) + 0.5) / levels
    muls = []
    for h in range(H):
        for f in range(F):
            lut = np.round(svm.W_[h, f] * centers * S).astype(np.int32)
            muls.append(SvmMulTable(hyperplane=h, feature=f, lut=lut))
    bias = np.round(svm.b_ * S).astype(np.int32)
    pairs = np.asarray(svm.pairs_, dtype=np.int32)
    pred = SvmPredictTable.build(pairs, svm.n_classes_, svm.votes_from_signs)
    return TableProgram(
        kind="svm",
        mid=MID_SVM,
        vid=vid,
        n_features=F,
        n_classes=svm.n_classes_,
        feature_width=feature_width,
        levels=levels,
        svm_muls=muls,
        svm_predict=pred,
        svm_bias=bias,
        frac_bits=frac_bits,
        muls_per_stage=muls_per_stage,
    )


def translate(model, **kw) -> TableProgram:
    """Single entry point (the paper's API: submit a trained Python model)."""
    if isinstance(model, DecisionTree):
        return translate_decision_tree(model, **kw)
    if isinstance(model, RandomForest):
        return translate_random_forest(model, **kw)
    if isinstance(model, LinearSVM):
        return translate_svm(model, **kw)
    raise TypeError(f"unsupported model type {type(model).__name__} (paper supports DT/RF/SVM)")

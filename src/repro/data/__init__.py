"""Dataset substrate.

* ``synth`` — seeded synthetic stand-ins for the paper's 10 datasets
  (offline container; shapes per paper Table 9).
* ``tokens`` — deterministic, resumable synthetic LM token pipeline used by
  the training loop (cursor-addressable: restart never replays or skips).
"""
from repro.data.synth import DATASETS, load_dataset, make_classification
from repro.data.tokens import TokenPipeline

__all__ = ["DATASETS", "load_dataset", "make_classification", "TokenPipeline"]

"""Seeded synthetic stand-ins for the paper's datasets (Table 9).

The container is offline, so NSL-KDD / UNSW-IoT / CICIDS-17 / ... are
regenerated as gaussian-cluster classification problems with the *same
(n_train, n_test, n_features, n_classes)* and a per-dataset class-imbalance
profile.  System-level results (table entry counts, pipeline stages, planner
time, latency/overhead) depend only on these shapes and on model structure, so
they reproduce faithfully; absolute accuracies are proxies (EXPERIMENTS.md
flags this next to every accuracy table).

``make_classification`` is our own: informative dims get per-class means on a
seeded hypercube, redundant dims are random linear combinations of informative
ones, the rest is noise — close in spirit to sklearn's generator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["make_classification", "DATASETS", "DatasetSpec", "load_dataset"]


def make_classification(
    n_samples: int,
    n_features: int,
    n_classes: int,
    *,
    n_informative: int | None = None,
    n_redundant: int | None = None,
    class_sep: float = 1.6,
    imbalance: float = 0.0,
    label_noise: float = 0.02,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian-cluster classification data.

    ``imbalance`` in [0, 1): 0 = balanced; larger values skew class priors
    geometrically (class k gets prior ∝ (1-imbalance)^k) — used to mimic IDS
    datasets with rare attack classes (paper §7.3 "datasets with multiple
    small classes").
    """
    rng = np.random.default_rng(seed)
    if n_informative is None:
        n_informative = max(2, min(n_features, int(np.ceil(np.log2(max(n_classes, 2)) + 3))))
    n_informative = min(n_informative, n_features)
    if n_redundant is None:
        n_redundant = min(n_features - n_informative, n_informative)

    # Class priors.
    pri = (1.0 - imbalance) ** np.arange(n_classes)
    pri = pri / pri.sum()
    y = rng.choice(n_classes, size=n_samples, p=pri)

    # Per-class means: 2 clusters per class for non-linearly-separable structure.
    n_clusters = 2
    means = rng.uniform(-1, 1, size=(n_classes, n_clusters, n_informative))
    means *= class_sep / np.maximum(np.linalg.norm(means, axis=-1, keepdims=True), 1e-9) * np.sqrt(n_informative)
    cluster = rng.integers(0, n_clusters, size=n_samples)
    Xi = means[y, cluster] + rng.normal(size=(n_samples, n_informative))

    blocks = [Xi]
    if n_redundant > 0:
        A = rng.normal(size=(n_informative, n_redundant))
        blocks.append(Xi @ A + 0.1 * rng.normal(size=(n_samples, n_redundant)))
    n_noise = n_features - n_informative - n_redundant
    if n_noise > 0:
        blocks.append(rng.normal(size=(n_samples, n_noise)))
    X = np.concatenate(blocks, axis=1)
    # Column shuffle so informative dims aren't a prefix.
    X = X[:, rng.permutation(n_features)]
    # Label noise.
    flip = rng.random(n_samples) < label_noise
    y[flip] = rng.choice(n_classes, size=int(flip.sum()), p=pri)
    return X, y.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_train: int
    n_test: int
    n_features: int
    n_classes: int
    imbalance: float = 0.0
    class_sep: float = 1.6
    seed: int = 0


# Paper Table 9 shapes, verbatim.
DATASETS: dict[str, DatasetSpec] = {
    s.name: s
    for s in [
        DatasetSpec("nsl-kdd", 125_948, 22_544, 119, 2, imbalance=0.15, seed=101),
        DatasetSpec("unsw-iot", 626_463, 143_141, 30, 25, imbalance=0.12, class_sep=1.9, seed=102),
        DatasetSpec("cicids-17", 102_996, 34_333, 78, 2, imbalance=0.3, seed=103),
        DatasetSpec("unsw-nb15", 175_341, 75_641, 166, 2, imbalance=0.2, seed=104),
        DatasetSpec("iscxvpn16", 2_357, 590, 23, 2, seed=105),
        DatasetSpec("vcaml", 10_011, 3_371, 14, 2, imbalance=0.4, seed=106),
        DatasetSpec("iris", 120, 30, 4, 3, class_sep=2.6, seed=107),
        DatasetSpec("digits", 1_437, 360, 64, 10, class_sep=2.0, seed=108),
        DatasetSpec("mnist", 20_000, 10_000, 784, 10, class_sep=2.0, seed=109),
        DatasetSpec("satdap", 3_539, 885, 36, 3, imbalance=0.2, seed=110),
    ]
}


def load_dataset(
    name: str,
    *,
    scale: float = 1.0,
    max_train: int | None = None,
    max_test: int | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Return (X_train, y_train, X_test, y_test) floats + int labels.

    ``scale`` shrinks sample counts (1 CPU core in this container); feature
    and class counts — which drive every system-level result — are never
    scaled.
    """
    spec = DATASETS[name.lower()]
    n_tr = int(spec.n_train * scale)
    n_te = int(spec.n_test * scale)
    if max_train is not None:
        n_tr = min(n_tr, max_train)
    if max_test is not None:
        n_te = min(n_te, max_test)
    n_tr = max(n_tr, 8 * spec.n_classes)
    n_te = max(n_te, 2 * spec.n_classes)
    X, y = make_classification(
        n_tr + n_te,
        spec.n_features,
        spec.n_classes,
        imbalance=spec.imbalance,
        class_sep=spec.class_sep,
        seed=spec.seed,
    )
    return X[:n_tr], y[:n_tr], X[n_tr:], y[n_tr:]

"""Deterministic, resumable synthetic LM token pipeline.

Production framing: at 1000+ nodes the data pipeline must be (a) sharded by
process with no cross-host coordination, (b) exactly resumable from a scalar
cursor carried in the checkpoint, (c) cheap enough to never be the straggler.
A counter-based generator gives all three: batch ``i`` is a pure function of
``(seed, cursor + i)``, so restart = set cursor; elastic re-sharding = rewrite
the (shard, num_shards) tuple, the global stream is unchanged.

Tokens are drawn from a Zipf-ish power-law over the vocab with a deterministic
per-position mixing hash — enough structure that cross-entropy decreases when
a model trains on it (examples/train_lm.py), while staying dependency-free.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenPipeline"]


def _philox(seed: int, counters: np.ndarray) -> np.ndarray:
    """Tiny counter-based RNG (splitmix64 round) → uint64 per counter."""
    x = (counters.astype(np.uint64) + np.uint64(seed * 0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x &= np.uint64(0xFFFFFFFFFFFFFFFF)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x &= np.uint64(0xFFFFFFFFFFFFFFFF)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass
class TokenPipeline:
    """Sharded, cursor-addressable synthetic token stream."""

    vocab_size: int
    seq_len: int
    global_batch: int
    shard: int = 0
    num_shards: int = 1
    seed: int = 0
    cursor: int = 0  # global step counter; checkpointed

    def __post_init__(self) -> None:
        if self.global_batch % self.num_shards != 0:
            raise ValueError("global_batch must divide evenly across shards")
        self.local_batch = self.global_batch // self.num_shards

    # ---------------------------------------------------------------- state
    def state_dict(self) -> dict:
        return {"cursor": int(self.cursor), "seed": int(self.seed)}

    def load_state_dict(self, state: dict) -> None:
        self.cursor = int(state["cursor"])
        self.seed = int(state["seed"])

    def reshard(self, shard: int, num_shards: int) -> "TokenPipeline":
        """Elastic re-sharding: same global stream under a new topology."""
        return dataclasses.replace(self, shard=shard, num_shards=num_shards, cursor=self.cursor)

    # ---------------------------------------------------------------- batch
    def next_batch(self) -> dict[str, np.ndarray]:
        batch = self.batch_at(self.cursor)
        self.cursor += 1
        return batch

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step, shard): tokens + next-token labels."""
        B, T, V = self.local_batch, self.seq_len, self.vocab_size
        row0 = step * self.global_batch + self.shard * B
        rows = row0 + np.arange(B, dtype=np.int64)
        pos = np.arange(T + 1, dtype=np.int64)
        counters = rows[:, None] * np.int64(1_000_003) + pos[None, :]
        u = _philox(self.seed, counters).astype(np.float64) / float(2**64)
        # Power-law marginal: rank ~ u^alpha * V, alpha > 1 skews to low ids.
        ranks = np.minimum((u**2.2 * V).astype(np.int64), V - 1)
        # Sequence structure: mix in the previous token so bigram stats are
        # learnable (pure-iid streams give a constant-loss floor immediately).
        mixed = (ranks[:, 1:] + (ranks[:, :-1] // 7)) % V
        toks = np.concatenate([ranks[:, :1], mixed], axis=1)
        return {
            "tokens": toks[:, :T].astype(np.int32),
            "labels": toks[:, 1 : T + 1].astype(np.int32),
        }

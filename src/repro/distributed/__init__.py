from repro.distributed.sharding import (
    batch_spec,
    dp_axes,
    opt_specs,
    param_specs,
    state_specs,
)

__all__ = ["param_specs", "opt_specs", "state_specs", "batch_spec", "dp_axes"]

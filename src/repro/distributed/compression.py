"""Int8 gradient compression with error feedback for the cross-pod axis.

At 1000+ nodes the pod axis is DCN (≈25 GB/s/chip) — 4x slower than ICI —
and carries exactly one collective: the gradient all-reduce.  Quantizing the
pod-axis reduction to int8 cuts that wire traffic 2x vs bf16 / 4x vs f32;
*error feedback* (Seide et al. 2014; Karimireddy et al. 2019) accumulates
the quantization residual locally and re-injects it next step, which keeps
SGD/Adam convergence (momentum sees an unbiased long-run gradient).

``compressed_psum(x, axis)`` is shard_map-compatible: per-chunk max-abs
scales (chunk=256) travel in f32 alongside the int8 payload — total wire
≈ 1.016 bytes/element.

Usage (train loop, cross-pod axis only):
    g_pod, ef = compress_decompress(g_local, ef)       # local error feedback
    g = lax.pmean(g_pod, "pod")                         # wire carries int8-fidelity values
Tests: tests/test_compression.py (bounded error, EF bias decay, convergence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress",
           "init_error_feedback"]

_CHUNK = 256


def _pad_flat(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _CHUNK
    return jnp.pad(flat, (0, pad)), flat.shape[0]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [...]-> (q int8 [n_pad], scale f32 [n_pad/CHUNK]) with per-chunk
    max-abs scaling."""
    flat, _ = _pad_flat(x)
    chunks = flat.reshape(-1, _CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1) / 127.0
    q = jnp.round(chunks / jnp.maximum(scale, 1e-30)[:, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, n: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def init_error_feedback(tree):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def compress_decompress(grads, error_feedback):
    """Per-leaf: q(g + ef) with the residual carried to the next step.

    Returns (int8-fidelity grads, new error feedback).  Apply *before* the
    cross-pod psum; the compressed values are what the slow link carries.
    """

    def one(g, ef):
        g32 = g.astype(jnp.float32) + ef
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s, g.shape, g.size)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))

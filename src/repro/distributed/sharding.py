"""Sharding rules: param-tree paths -> PartitionSpec over ("pod","data","model").

The layout is FSDP x TP (+ EP for MoE):

* matmul weights shard their *input-feature* axis over ``data`` (ZeRO-3
  weight sharding — all-gathered per layer inside the scan) and their
  *output-feature* axis over ``model`` (Megatron tensor parallel); row-
  parallel weights ("wo", "wd", "cv", "w_out") are transposed in the rule.
* MoE expert stacks shard the expert axis over ``model`` when it divides
  evenly (expert parallelism: qwen3 128e/16); otherwise fall back to plain
  FSDP x TP on the (D, F) axes (grok 8e on a 16-way model axis).
* 1-D / small tensors (norms, biases, per-channel gates) replicate.
* ``pod`` is a pure data-parallel axis: batch shards over ("pod","data"),
  parameters are replicated across pods (cross-pod gradient all-reduce is
  the only pod-axis collective — DESIGN.md §5).

Rules are *name-driven* with shape-divisibility guards, so every arch in the
pool maps without per-arch tables, and a failed guard degrades to
replication instead of a lowering error.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig

__all__ = ["param_specs", "opt_specs", "state_specs", "batch_spec", "dp_axes"]

# weight name -> which logical axis gets "model": "col" shards the last axis,
# "row" shards the second-to-last.
_COL = {"wq", "wk", "wv", "wg", "wu", "xq", "xk", "xv", "ck", "cr",
        "w_gate", "w_in", "wr", "wa", "wi", "w_lora_a"}
_ROW = {"wo", "wd", "xo", "cv", "w_out", "w_lora_b"}


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _divisible(n: int, mesh_shape: dict, axis: str) -> bool:
    return axis in mesh_shape and n % mesh_shape[axis] == 0


def _spec_for(path: tuple[str, ...], shape: tuple[int, ...], mesh_shape: dict,
              cfg: ArchConfig) -> P:
    name = path[-1]
    nd = len(shape)
    md, dt = mesh_shape.get("model", 1), mesh_shape.get("data", 1)

    if name == "embed":  # [V, D] — vocab over model (Megatron embedding)
        if _divisible(shape[0], mesh_shape, "model"):
            return P("model", None)
        return P(None, "model") if _divisible(shape[1], mesh_shape, "model") else P()
    if name == "head":   # [D, V]
        if _divisible(shape[1], mesh_shape, "model") and _divisible(shape[0], mesh_shape, "data"):
            return P("data", "model")
        return P(None, "model") if _divisible(shape[1], mesh_shape, "model") else P()
    if name == "enc_pos":
        return P()

    # MoE expert stacks: [L, E, D, F] / [L, E, F, D]
    if name in ("wg", "wu", "wd") and nd == 4:
        L, E = shape[0], shape[1]
        if _divisible(E, mesh_shape, "model"):
            # expert parallelism + FSDP on the wider matrix axis
            wide = 2 if shape[2] >= shape[3] else 3
            spec = [None, "model", None, None]
            if _divisible(shape[wide], mesh_shape, "data"):
                spec[wide] = "data"
            return P(*spec)
        # fallback: FSDP x TP on (D, F)
        col = name in ("wg", "wu")
        d_ax, f_ax = (2, 3) if col else (3, 2)
        spec = [None, None, None, None]
        if _divisible(shape[d_ax], mesh_shape, "data"):
            spec[d_ax] = "data"
        if _divisible(shape[f_ax], mesh_shape, "model"):
            spec[f_ax] = "model"
        return P(*spec)
    if name == "router":  # [L, D, E]
        return P(None, "data", None) if _divisible(shape[1], mesh_shape, "data") else P()

    if name in _COL and nd >= 2:
        spec = [None] * nd
        model_ok = _divisible(shape[-1], mesh_shape, "model")
        if name in ("wk", "wv", "xk", "xv"):
            # KV projections: sharding the flat (Hkv*hd) axis more ways than
            # there are KV heads splits head_dim — GSPMD then replicates the
            # attention logits (observed 20 GB/layer traffic).  Only shard
            # when whole heads land on each shard.
            model_ok = model_ok and cfg.n_kv % max(md, 1) == 0
        if model_ok:
            spec[-1] = "model"
        if _divisible(shape[-2], mesh_shape, "data"):
            spec[-2] = "data"
        return P(*spec)
    if name in _ROW and nd >= 2:
        spec = [None] * nd
        if _divisible(shape[-2], mesh_shape, "model"):
            spec[-2] = "model"
        if _divisible(shape[-1], mesh_shape, "data"):
            spec[-1] = "data"
        return P(*spec)
    return P()  # norms, gates, biases, conv taps: replicated


def param_specs(cfg: ArchConfig, mesh) -> dict:
    """PartitionSpec tree matching ``init_params_shape(cfg)``."""
    from repro.models.transformer import init_params_shape

    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    shapes = init_params_shape(cfg)

    def walk(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return _spec_for(names, leaf.shape, mesh_shape, cfg)

    return jax.tree_util.tree_map_with_path(walk, shapes)


def opt_specs(pspecs) -> dict:
    """Optimizer moments shard exactly like their parameters."""
    return {"m": pspecs, "v": pspecs, "step": P()}


def batch_spec(multi_pod: bool, *, n_micro: bool = False) -> P:
    dp = dp_axes(multi_pod)
    return P(None, dp, None) if n_micro else P(dp, None)


def state_specs(cfg: ArchConfig, mesh, multi_pod: bool, *, batch: int = 8,
                cache_len: int = 16, split_kv: bool = True) -> dict:
    """Decode-state sharding: batch over dp axes, heads over model when even.

    Divisibility guards are evaluated on the *real* (batch, cache_len), so a
    batch-1 long-context cell degrades to replication instead of erroring.

    ``split_kv`` (beyond-paper, §Perf): when the KV-head count does not
    divide the model axis, shard the cache *sequence* dimension over
    ``model`` instead — FlashDecoding-style split-KV: every model shard
    scans 1/16th of the cache and the softmax is combined with small
    collectives, instead of every shard reading the whole cache.
    """
    from repro.models.transformer import init_decode_state

    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = dp_axes(multi_pod)
    dp_total = 1
    for a in dp:
        dp_total *= mesh_shape.get(a, 1)

    def spec(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        # leading axis is the layer stack; batch is axis 1
        s = [None] * nd
        if nd >= 2 and shape[1] % dp_total == 0 and shape[1] > 1:
            s[1] = dp
        # KV caches [L, B, T, Hkv, hd]: shard heads over model if divisible
        md = mesh_shape.get("model", 1)
        if nd == 5 and shape[3] % md == 0 and shape[3] > 1:
            s[3] = "model"
        elif nd == 5 and split_kv and shape[2] % md == 0 and shape[2] > md:
            s[2] = "model"  # split-KV: shard the cache sequence dim
        # RWKV state [L, B, H, K, K]
        if nd == 5 and path and "S" in str(path[-1]) and shape[2] % mesh_shape.get("model", 1) == 0:
            s[2] = "model"
            s[3] = None
        return P(*s)

    shapes = jax.eval_shape(lambda: init_decode_state(cfg, batch, cache_len))
    return jax.tree_util.tree_map_with_path(spec, shapes)

"""Machine-readable VMEM memory plans for every Pallas kernel.

This is the single source of truth behind the "Kernel memory plans" table in
``docs/ARCHITECTURE.md``: one :class:`KernelBudget` per kernel module, pinning
the **reference-config** per-grid-step VMEM footprint that the prose table
quotes.  Three consumers read it:

* ``repro.analysis.lint`` rule **PL003** re-derives each kernel's footprint
  straight from the ``BlockSpec``/``scratch_shapes`` AST under ``bindings``
  and fails the lint if the recomputed bytes drift more than ``tolerance``
  from ``pinned_bytes`` (someone grew a block without re-budgeting) or
  exceed ``budget_bytes`` (16 MiB/core, the TPU VMEM ceiling);
* ``tools/check_doc_refs.py`` cross-checks the doc table's kernel names
  against ``BUDGETS`` keys, so the prose and the manifest cannot diverge
  silently;
* tests recompute the KiB numbers quoted in the doc from this manifest.

**This module must stay importable without jax** — the lint CLI and the doc
checker both run in environments where importing jax (or anything that
initializes a TPU runtime) is off the table.  Plain stdlib only.

``bindings`` give the reference values for every free variable appearing in
the kernel's ``BlockSpec`` shape tuples (the doc's parenthetical "block_b=256,
L=32, ..." config).  ``intermediates`` are VMEM-resident arrays *created
inside the kernel body* — invisible to BlockSpec accounting but real VMEM
(e.g. ``tree_walk``'s ``fv_all = feats @ fsel.T`` product, which the doc's
6.2 MiB explicitly counts) — declared here as name -> bytes.
"""
from __future__ import annotations

import dataclasses

__all__ = ["KernelBudget", "BUDGETS", "VMEM_BYTES"]

# Per-core VMEM ceiling (TPU v4/v5 class): 16 MiB.
VMEM_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class KernelBudget:
    """Reference-config VMEM plan for one kernel module."""

    kernel: str
    # Reference value for every free variable in the BlockSpec shape tuples.
    bindings: dict
    # In-kernel VMEM-resident arrays (name -> bytes) that BlockSpec
    # accounting cannot see.
    intermediates: dict
    # Recomputed per-grid-step footprint at the reference config:
    # sum(prod(block shape) * itemsize over in/out specs) + scratch bytes
    # + sum(intermediates).  PL003 must reproduce this within `tolerance`.
    pinned_bytes: int
    # Operand element size when every block shares one width (f32/i32/u32).
    itemsize: int = 4
    # Kernel module stem this entry budgets (defaults to the manifest key).
    # Lets one module carry several entries — e.g. ``classify_fused`` pins
    # both the quantized and the f32 operand widths of the same launch.
    module: str = ""
    # Per-BlockSpec element sizes, in pallas_call source order (in_specs
    # first, then out_specs).  Empty means uniform ``itemsize``.  PL003
    # refuses to guess: a length mismatch with the parsed spec list fails
    # the lint rather than silently misbudgeting.
    spec_itemsizes: tuple = ()
    budget_bytes: int = VMEM_BYTES
    tolerance: float = 0.01
    note: str = ""


BUDGETS = {
    "tree_walk": KernelBudget(
        kernel="tree_walk",
        bindings={"block_b": 256, "F_pad": 128, "L": 32, "E_pad": 128},
        intermediates={
            # fv_all = feats @ fsel.T stays resident across the whole walk:
            # [block_b, L * E_pad] f32 = 256 * 32 * 128 * 4.
            "fv_all": 256 * 32 * 128 * 4,
        },
        pinned_bytes=6_524_032,
        note="feats 128 KiB + fsel 2 MiB + fv_all 4 MiB + entry blocks; "
             "block_b auto-halves when L*E_pad would overflow",
    ),
    "tcam_match": KernelBudget(
        kernel="tcam_match",
        bindings={"block_b": 256, "F_pad": 128, "E_pad": 128},
        intermediates={
            # fv = feats @ fsel.T: [block_b, E_pad] f32 = 256 * 128 * 4.
            "fv": 256 * 128 * 4,
        },
        pinned_bytes=333_828,
        note="feats 128 KiB + f_sel 64 KiB + fv 128 KiB + entry rows; "
             "independent of V (one version's block per step)",
    ),
    "forest_vote": KernelBudget(
        kernel="forest_vote",
        bindings={"block_b": 256, "T": 8, "P": 1024},
        intermediates={},
        pinned_bytes=116_768,
        note="leaf tables [T, P] fully resident (T<=8, P<=1024 -> 32 KiB "
             "per table); independent of V",
    ),
    "svm_lookup": KernelBudget(
        kernel="svm_lookup",
        bindings={"block_b": 128, "chunk_f": 8, "L": 256, "H_pad": 8},
        intermediates={},
        pinned_bytes=74_272,
        note="one (version, chunk) LUT slice [chunk_f*L, H_pad] = 64 KiB "
             "streamed per step; L is the quantization level count",
    ),
    "classify_fused": KernelBudget(
        kernel="classify_fused",
        module="classify_fused",
        bindings={"block_b": 256, "T": 8, "L": 32, "E_pad": 128, "WP": 4,
                  "F_pad": 128, "P": 256, "PW": 8, "n_chunks": 8,
                  "chunk_f": 8, "levels": 256, "H_pad": 16},
        # in_specs order: codes, vid, feats(i16), fid(i16), cv, cm, flo(i16),
        # fhi(i16), bitpk, validpk, shift, pred_codes, plab(i8), pvalidpk,
        # weights, lut, bias; out: codes, label, svm.
        spec_itemsizes=(4, 4, 2, 2, 4, 4, 2, 2, 4, 4, 4, 4, 1, 4, 4, 4, 4,
                        4, 4, 4),
        intermediates={
            # svm one-hot [block_b, chunk_f*levels] f32, live per chunk.
            "svm_onehot": 256 * 8 * 256 * 4,
            # vote select jnp.where(eq, plab, 0): [block_b, T, P] i32.
            "vote_select": 256 * 8 * 256 * 4,
            # walk selector [E_pad, F_pad] f32 + fv [block_b, E_pad] f32.
            "walk_select": 128 * 128 * 4 + 256 * 128 * 4,
        },
        pinned_bytes=6_017_504,
        note="quantized widths (i16 feats/fid/range bounds, i8 labels, "
             "bit-packed masks): the whole classify in one launch at ~6.0 "
             "MiB/step, independent of V — V=8 zoos fit the same plan",
    ),
    "classify_fused_f32": KernelBudget(
        kernel="classify_fused",
        module="classify_fused",
        bindings={"block_b": 256, "T": 8, "L": 32, "E_pad": 128, "WP": 4,
                  "F_pad": 128, "P": 256, "PW": 8, "n_chunks": 8,
                  "chunk_f": 8, "levels": 256, "H_pad": 16},
        intermediates={
            "svm_onehot": 256 * 8 * 256 * 4,
            "vote_select": 256 * 8 * 256 * 4,
            "walk_select": 128 * 128 * 4 + 256 * 128 * 4,
        },
        pinned_bytes=6_285_792,
        note="full-width counterfactual of the same launch (quantize=False: "
             "i32 feats/fid/labels, f32 range bounds) — the +268 KiB the "
             "quantized layouts buy back per grid step",
    ),
    "decode_attn": KernelBudget(
        kernel="decode_attn",
        bindings={"Hq": 32, "D": 128, "block_s": 512, "Hkv": 8},
        intermediates={},
        pinned_bytes=4_243_716,
        note="k/v chunks dominate (2 x 2 MiB at f32 accounting; bf16 "
             "operands halve them) + f32 online-softmax scratch",
    ),
}

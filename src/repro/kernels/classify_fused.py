"""Pallas TPU kernel: the whole-classify megakernel (walk -> vote -> svm).

Pre-fusion, one classify issued three launches — ``tree_walk`` produced the
per-packet status codes, which round-tripped through HBM into
``forest_vote``'s compare-reduce and (independently) ``svm_lookup`` streamed
the feature tile a second time.  This kernel runs all three stages inside
**one** grid program, so classify drops from 3 ``pallas_call``s to 1:

  1. *walk* — the multi-layer ternary walk of ``tree_walk.py``, per tree: a
     ``fori_loop`` over L layer-indexed table slices with the same masked
     code equality + range compare + exclusive-cumsum priority encode.  The
     per-(layer, tree) one-hot feature selector is rebuilt in VMEM from the
     int16 ``fid`` table (an iota compare + MXU matmul), which deletes the
     precomputed f32 ``[V, T, L*E_pad, F_pad]`` ``fsel`` stream entirely —
     the largest operand of the unfused path.
  2. *vote* — the resulting ``[Bb, T]`` codes never leave VMEM; they feed the
     exact compare-reduce + weighted one-hot voting of ``forest_vote.py``
     (identical accumulation shapes and order, so no new float divergence).
  3. *svm* — the feature tile, already VMEM-resident from the walk, drives
     the chunked one-hot LUT contraction of ``svm_lookup.py`` as a static
     chunk loop; per-chunk f32 partials stay integer-exact (< 2**24) and are
     rounded once by the wrapper.

Quantized operand layouts (``tiling.prep_classify_fused``): feature ids and
range bounds stream as int16, leaf labels as int8, and the three {0,1}
tables (``set_bit``/``valid``/``pred_valid``) as bit-packed uint32 words
unpacked per layer in VMEM — all lossless, upcast in-kernel, so quantized
and f32 layouts decode bit-identical classifications (pinned by the
round-trip property tests).

Model-zoo dispatch follows the established version-grid pattern: grid
(batch blocks, versions), outputs initialized at v == 0 (codes pass through
unchanged, label/svm zero) and merged per step for packets whose ``vid``
matches.

Per-step VMEM at the reference config (block_b=256, L=32, T=8, E_pad=128,
F_pad=128, P=256, levels=256, H_pad=16): quantized operands ~1.6 MiB +
in-kernel transients (svm one-hot 2 MiB, vote compare 2 MiB, walk selector
~0.2 MiB) ~ 6.0 MiB — under the 16 MiB ceiling and independent of V, so
V=8 zoos fit the same plan (see ``kernels/budgets.py``: ``classify_fused``
vs the f32-width counterfactual ``classify_fused_f32``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import (
    LANES,
    SVM_CHUNK_F,
    SVM_SUBLANES,
    ClassifyFusedOperands,
    pad_to,
    prep_classify_fused,
)

__all__ = ["classify_fused_pallas_v"]


def _unpack_bits(words, n_words: int, out_len: int):
    """uint32 words [..., W] -> {0,1} uint32 [..., out_len] (little-endian
    within each word, matching ``tiling.bitpack_last``)."""
    lead = words.shape[:-1]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, lead + (n_words, 32), words.ndim)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(lead + (n_words * 32,))[..., :out_len]


def _kernel(codes_ref, vid_ref, feats_ref, fid_ref, cv_ref, cm_ref, flo_ref,
            fhi_ref, bitpk_ref, validpk_ref, shift_ref, pc_ref, plab_ref,
            pvpk_ref, w_ref, lut_ref, bias_ref,
            out_codes_ref, out_label_ref, out_svm_ref, *,
            n_layers: int, n_trees: int, e_pad: int, f_pad: int,
            n_leaves: int, n_classes: int, n_chunks: int, chunk_f: int,
            levels: int):
    v = pl.program_id(1)
    codes0 = codes_ref[...]                     # [Bb, T] uint32

    @pl.when(v == 0)
    def _init():
        out_codes_ref[...] = codes0
        out_label_ref[...] = jnp.zeros_like(out_label_ref)
        out_svm_ref[...] = jnp.zeros_like(out_svm_ref)

    feats = feats_ref[...]                      # [Bb, F_pad] i16|i32
    feats_f = feats.astype(jnp.float32)
    wp = e_pad // 32

    # ---- stage 1: multi-layer walk, all T trees, codes stay in VMEM ----
    def walk_tree(t):
        def layer(l, codes):                    # codes [Bb, 1] uint32
            # One-hot feature selector rebuilt from the int16 fid row: the
            # MXU indirection of tree_walk without its precomputed f32 fsel.
            fid_l = fid_ref[0, l, t].astype(jnp.int32)      # [E_pad]
            onehot = (
                fid_l[:, None]
                == jax.lax.broadcasted_iota(jnp.int32, (e_pad, f_pad), 1)
            ).astype(jnp.float32)
            fv = jnp.dot(feats_f, onehot.T,
                         preferred_element_type=jnp.float32)  # [Bb, E_pad]
            cv = cv_ref[0, l, t][None, :]
            cm = cm_ref[0, l, t][None, :]
            flo = flo_ref[0, l, t][None, :].astype(jnp.float32)
            fhi = fhi_ref[0, l, t][None, :].astype(jnp.float32)
            bit = _unpack_bits(bitpk_ref[0, l, t], wp, e_pad)[None, :]
            valid = _unpack_bits(validpk_ref[0, l, t], wp, e_pad)[None, :]
            code_ok = (codes & cm) == cv        # [Bb, E_pad]
            ok = code_ok & (fv >= flo) & (fv <= fhi) & (valid != 0)
            first = ok & (jnp.cumsum(ok.astype(jnp.int32), axis=1) == 1)
            b = jnp.sum(jnp.where(first, bit, 0), axis=1, keepdims=True)
            hit = ok.any(axis=1, keepdims=True)
            shift = shift_ref[0, l].astype(jnp.uint32)
            new = codes | (b.astype(jnp.uint32) << shift)
            return jnp.where(hit, new, codes)

        return jax.lax.fori_loop(0, n_layers, layer, codes0[:, t:t + 1])

    codes = jnp.concatenate([walk_tree(t) for t in range(n_trees)], axis=1)

    # ---- stage 2: forest vote (forest_vote.py compare-reduce, verbatim) ----
    pc = pc_ref[0]                              # [T, P] uint32 (this version)
    plab = plab_ref[0].astype(jnp.int32)        # [T, P]
    pvalid = _unpack_bits(pvpk_ref[0], pvpk_ref.shape[-1], n_leaves
                          ).astype(jnp.int32)   # [T, P]
    eq = (codes[:, :, None] == pc[None]) & (pvalid[None] != 0)   # [Bb, T, P]
    per_tree = jnp.sum(jnp.where(eq, plab[None], 0), axis=2)     # [Bb, T]
    w = w_ref[0]                                # [1, T] f32
    classes = jax.lax.iota(jnp.int32, n_classes)
    onehot = (per_tree[:, :, None] == classes[None, None, :]).astype(jnp.float32)
    scores = jnp.sum(onehot * w[0][None, :, None], axis=1)       # [Bb, C]
    best = jnp.max(scores, axis=1, keepdims=True)
    is_best = scores >= best
    first_best = is_best & (jnp.cumsum(is_best.astype(jnp.int32), axis=1) == 1)
    label = jnp.sum(
        jnp.where(first_best, classes[None, :], 0), axis=1, keepdims=True
    ).astype(jnp.int32)

    # ---- stage 3: svm LUT contraction (svm_lookup.py chunk loop, bias
    # first then chunks ascending — the int-exact accumulation order) ----
    feats_i = feats.astype(jnp.int32)
    acc = jnp.zeros(out_svm_ref.shape, jnp.float32) \
        + bias_ref[0].astype(jnp.float32)
    for c in range(n_chunks):
        fc = feats_i[:, c * chunk_f:(c + 1) * chunk_f]   # [Bb, chunk_f]
        onehot_s = (
            fc[:, :, None] == jax.lax.iota(jnp.int32, levels)[None, None, :]
        ).astype(jnp.float32)                   # [Bb, chunk_f, levels]
        Bb, Fc, L = onehot_s.shape
        acc = acc + jnp.dot(
            onehot_s.reshape(Bb, Fc * L), lut_ref[0, c],
            preferred_element_type=jnp.float32)          # [Bb, H_pad]

    # ---- version merge ----
    mine = vid_ref[...] == v                    # [Bb, 1]
    out_codes_ref[...] = jnp.where(mine, codes, out_codes_ref[...])
    out_label_ref[...] = jnp.where(mine, label, out_label_ref[...])
    out_svm_ref[...] = jnp.where(mine, acc, out_svm_ref[...])


@functools.partial(jax.jit, static_argnames=("n_classes", "quantize",
                                             "block_b", "interpret"))
def classify_fused_pallas_v(
    codes: jax.Array,        # uint32 [B, T]
    features: jax.Array,     # int32 [B, F]
    vid: jax.Array,          # int32 [B] model version per packet, in [0, V)
    code_value: jax.Array,   # uint32 [V, L, T, E]
    code_mask: jax.Array,
    fid: jax.Array,          # int32 [V, L, T, E]
    f_lo: jax.Array,
    f_hi: jax.Array,
    set_bit: jax.Array,      # uint32 [V, L, T, E], {0, 1}
    valid: jax.Array,        # bool [V, L, T, E]
    layer_shift: jax.Array,  # int32 [L] status-code bit per layer
    pred_codes: jax.Array,   # uint32 [V, T, P]
    pred_labels: jax.Array,  # int32 [V, T, P]
    pred_valid: jax.Array,   # bool [V, T, P]
    weights: jax.Array,      # float32 [V, T]
    lut: jax.Array,          # int32 [V, H, F, levels]
    bias: jax.Array,         # int32 [V, H]
    n_classes: int,
    *,
    prep: ClassifyFusedOperands | None = None,
    quantize: bool = True,
    block_b: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One launch for the whole classify: returns (codes [B, T] uint32,
    vote label [B] int32, svm sums [B, H] int32)."""
    B, T = codes.shape
    V, L, _, _ = code_value.shape
    _, H, F_svm, levels = lut.shape
    P = pred_codes.shape[2]
    if prep is None:
        # Per-call fallback (standalone/test path): the same prep the plane
        # runs once per install and binds via ``prep=``.
        prep = prep_classify_fused(
            code_value, code_mask, fid, f_lo, f_hi, set_bit, valid,
            pred_codes, pred_labels, pred_valid, weights, lut, bias,
            quantize=quantize)
    E_pad = prep.cv.shape[3]
    WP = prep.bitpk.shape[3]
    PW = prep.pvalidpk.shape[2]
    H_pad = prep.bias.shape[1]
    chunk_f = SVM_CHUNK_F
    n_chunks = -(-F_svm // chunk_f)
    # Source-derived shape validation: a prep built for a different profile
    # cannot slip through (same stance as tree_walk / svm_lookup).
    if prep.cv.shape != (V, L, T, E_pad) or \
            prep.lut.shape != (V, n_chunks, chunk_f * levels, H_pad) or \
            H_pad != -(-H // SVM_SUBLANES) * SVM_SUBLANES or \
            prep.pred_codes.shape != (V, T, P):
        raise ValueError(
            f"prepped operand shapes {prep.cv.shape}/{prep.lut.shape}/"
            f"{prep.pred_codes.shape} do not match this launch — the exec "
            "image was built for a different profile")

    feat_dtype = jnp.int16 if prep.fid.dtype == jnp.int16 else jnp.int32
    # -1 fill: svm chunk columns beyond F match no quantization level (zero
    # contribution); walk entries never select a padded column (fid < F).
    feats = pad_to(features.astype(feat_dtype), 1, LANES, fill=-1)
    F_pad = feats.shape[1]
    if n_chunks * chunk_f > F_pad:
        raise ValueError(
            f"svm chunk span {n_chunks * chunk_f} exceeds the lane-padded "
            f"feature width {F_pad}")

    # Largest in-kernel transients scale with block_b: the svm one-hot
    # [block_b, chunk_f*levels] and the vote compare [block_b, T, P]; halve
    # the batch tile before either would crowd VMEM.
    while block_b > 8 and \
            block_b * max(chunk_f * levels, T * P, 4 * E_pad) * 4 \
            > 4 * 1024 * 1024:
        block_b //= 2

    codes_p = pad_to(codes, 0, block_b)
    feats_p = pad_to(feats, 0, block_b)
    vid_p = pad_to(vid.astype(jnp.int32).reshape(-1, 1), 0, block_b, fill=-1)
    B_pad = codes_p.shape[0]

    out_codes, out_label, out_svm = pl.pallas_call(
        functools.partial(
            _kernel, n_layers=L, n_trees=T, e_pad=E_pad, f_pad=F_pad,
            n_leaves=P, n_classes=n_classes, n_chunks=n_chunks,
            chunk_f=chunk_f, levels=levels),
        grid=(B_pad // block_b, V),
        in_specs=[
            pl.BlockSpec((block_b, T), lambda i, v: (i, 0)),       # codes
            pl.BlockSpec((block_b, 1), lambda i, v: (i, 0)),       # vid
            pl.BlockSpec((block_b, F_pad), lambda i, v: (i, 0)),   # feats
            pl.BlockSpec((1, L, T, E_pad), lambda i, v: (v, 0, 0, 0)),  # fid
            pl.BlockSpec((1, L, T, E_pad), lambda i, v: (v, 0, 0, 0)),  # cv
            pl.BlockSpec((1, L, T, E_pad), lambda i, v: (v, 0, 0, 0)),  # cm
            pl.BlockSpec((1, L, T, E_pad), lambda i, v: (v, 0, 0, 0)),  # flo
            pl.BlockSpec((1, L, T, E_pad), lambda i, v: (v, 0, 0, 0)),  # fhi
            pl.BlockSpec((1, L, T, WP), lambda i, v: (v, 0, 0, 0)),  # bitpk
            pl.BlockSpec((1, L, T, WP), lambda i, v: (v, 0, 0, 0)),  # validpk
            pl.BlockSpec((1, L), lambda i, v: (0, 0)),             # shift
            pl.BlockSpec((1, T, P), lambda i, v: (v, 0, 0)),       # pred_codes
            pl.BlockSpec((1, T, P), lambda i, v: (v, 0, 0)),       # plab
            pl.BlockSpec((1, T, PW), lambda i, v: (v, 0, 0)),      # pvalidpk
            pl.BlockSpec((1, 1, T), lambda i, v: (v, 0, 0)),       # weights
            pl.BlockSpec((1, n_chunks, chunk_f * levels, H_pad),
                         lambda i, v: (v, 0, 0, 0)),               # lut
            pl.BlockSpec((1, H_pad), lambda i, v: (v, 0)),         # bias
        ],
        out_specs=[
            pl.BlockSpec((block_b, T), lambda i, v: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, v: (i, 0)),
            pl.BlockSpec((block_b, H_pad), lambda i, v: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, T), codes.dtype),
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, H_pad), jnp.float32),
        ],
        interpret=interpret,
    )(codes_p, vid_p, feats_p, prep.fid, prep.cv, prep.cm, prep.flo,
      prep.fhi, prep.bitpk, prep.validpk,
      layer_shift.reshape(1, L).astype(jnp.int32), prep.pred_codes,
      prep.plab, prep.pvalidpk, prep.weights, prep.lut, prep.bias)
    return (out_codes[:B], out_label[:B, 0],
            jnp.round(out_svm[:B, :H]).astype(jnp.int32))

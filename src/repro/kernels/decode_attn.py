"""Pallas TPU kernel: GQA decode attention (flash-decoding style).

Beyond-paper serving hot-spot: one new token's query against a long KV cache.
Online-softmax over sequence chunks — running (max, denominator, accumulator)
live in VMEM scratch and persist across the sequential S-chunk grid axis, so
the cache is streamed HBM→VMEM exactly once per decode step.

Grid: (batch, S chunks).  Per-step VMEM: q [Hq, D] + k/v chunk [Sc, Hkv*D]
(Sc=512, Hkv=8, D=128 → 2 × 512 KiB bf16) + f32 accumulators.

The q@k contraction is grouped for GQA: q is reshaped [Hkv, G, D] and each KV
head's chunk multiplies its G query rows on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attn_pallas"]

_NEG_INF = -1e30


def _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_s: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [Hq, D]
    k = k_ref[0].astype(jnp.float32)            # [Sc, Hkv, D]
    v = v_ref[0].astype(jnp.float32)
    Hq, D = q.shape
    Sc, Hkv, _ = k.shape
    G = Hq // Hkv

    qg = q.reshape(Hkv, G, D)
    # [Hkv, G, Sc] logits, grouped GQA contraction on the MXU.
    logits = jax.lax.dot_general(
        qg, k.transpose(1, 2, 0),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * scale
    kv_len = kvlen_ref[0, 0]
    spos = c * block_s + jax.lax.iota(jnp.int32, Sc)
    mask = (spos < kv_len)[None, None, :]
    logits = jnp.where(mask, logits, _NEG_INF)
    logits = logits.reshape(Hq, Sc)

    m_prev = m_ref[...]                          # [Hq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                  # [Hq, Sc]
    p = jnp.where(mask.reshape(1, Sc) | jnp.zeros((Hq, 1), bool), p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    pg = p.reshape(Hkv, G, Sc)
    pv = jax.lax.dot_general(
        pg, v.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(Hq, D)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(c == n_chunks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attn_pallas(
    q: jax.Array,       # [B, Hq, D]
    k: jax.Array,       # [B, S, Hkv, D]
    v: jax.Array,
    kv_len: jax.Array,  # int32 [B]
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    scale = D ** -0.5
    pad_s = (-S) % block_s
    k_p = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    S_pad = k_p.shape[1]
    n_chunks = S_pad // block_s

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_s=block_s, n_chunks=n_chunks),
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),                    # kv_len
            pl.BlockSpec((1, Hq, D), lambda b, c: (b, 0, 0)),             # q
            pl.BlockSpec((1, block_s, Hkv, D), lambda b, c: (b, c, 0, 0)),  # k
            pl.BlockSpec((1, block_s, Hkv, D), lambda b, c: (b, c, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, c: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),   # running max
            pltpu.VMEM((Hq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((Hq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(kv_len.reshape(B, 1).astype(jnp.int32), q, k_p, v_p)
    return out

"""Pallas TPU kernel: fused ``dt_predict`` + ``multitree_voting``.

The switch's exact-match SRAM lookup (status code -> leaf label) is
re-expressed as a compare-reduce — a content-addressable match, which is what
the SRAM hash table emulates anyway:

    eq[b,t,p]   = (pred_codes[t,p] == codes[b,t]) & valid[t,p]
    label[b,t]  = sum_p eq * pred_labels          (at most one p matches)

followed by weighted one-hot voting and an argmax with smaller-class-id tie
break (matches ``RandomForest.vote``).  Everything is VPU elementwise +
reductions over VMEM-resident blocks; no gathers.

Model-zoo dispatch: leaf tables carry a leading version axis ``[V, T, P]``
and the grid gains an innermost version dimension.  Each step's table block
is selected by the step's vid scalar (``pl.program_id(1)``) — one version's
``[T, P]`` tables VMEM-resident at a time — and the outputs of packets whose
``vid`` matches are merged into the revisited output block.

Grid: (batch blocks, versions).  Per-step entry tables [T, P] stay fully
VMEM-resident (T<=8, P<=1024 → 32 KiB) independent of V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import ForestOperands, prep_forest_vote

__all__ = ["forest_predict_vote_pallas", "forest_predict_vote_pallas_v"]


def _kernel(codes_ref, vid_ref, pc_ref, plab_ref, pvalid_ref, w_ref,
            out_label_ref, out_per_tree_ref, *, n_classes: int):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        out_label_ref[...] = jnp.zeros_like(out_label_ref)
        out_per_tree_ref[...] = jnp.zeros_like(out_per_tree_ref)

    codes = codes_ref[...]                       # [Bb, T] uint32
    pc = pc_ref[0]                               # [T, P] uint32 (this version)
    plab = plab_ref[0]                           # [T, P] int32
    pvalid = pvalid_ref[0]                       # [T, P] int32
    eq = (codes[:, :, None] == pc[None]) & (pvalid[None] != 0)   # [Bb, T, P]
    per_tree = jnp.sum(jnp.where(eq, plab[None], 0), axis=2)     # [Bb, T]
    w = w_ref[0]                                 # [1, T] f32
    classes = jax.lax.iota(jnp.int32, n_classes)
    onehot = (per_tree[:, :, None] == classes[None, None, :]).astype(jnp.float32)
    scores = jnp.sum(onehot * w[0][None, :, None], axis=1)       # [Bb, C]
    # argmax with ties to the smaller class id
    best = jnp.max(scores, axis=1, keepdims=True)
    is_best = scores >= best
    first_best = is_best & (jnp.cumsum(is_best.astype(jnp.int32), axis=1) == 1)
    label = jnp.sum(
        jnp.where(first_best, classes[None, :], 0), axis=1, keepdims=True
    ).astype(jnp.int32)
    mine = vid_ref[...] == v                     # [Bb, 1]
    out_label_ref[...] = jnp.where(mine, label, out_label_ref[...])
    out_per_tree_ref[...] = jnp.where(mine, per_tree.astype(jnp.int32),
                                      out_per_tree_ref[...])


@functools.partial(jax.jit, static_argnames=("n_classes", "block_b", "interpret"))
def forest_predict_vote_pallas_v(
    codes: jax.Array,        # uint32 [B, T]
    vid: jax.Array,          # int32 [B] model version per packet, in [0, V)
    pred_codes: jax.Array,   # uint32 [V, T, P]
    pred_labels: jax.Array,  # int32 [V, T, P]
    pred_valid: jax.Array,   # bool [V, T, P]
    weights: jax.Array,      # float32 [V, T]
    n_classes: int,
    *,
    prep: ForestOperands | None = None,
    block_b: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, T = codes.shape
    V, _, P = pred_codes.shape
    if prep is None:
        # Per-call fallback: same dtype/layout pass the plane runs once per
        # install and binds via ``prep=`` (tiling.prep_forest_vote).
        prep = prep_forest_vote(pred_valid, weights)
    pv_i32, w_r = prep
    pad_b = (-B) % block_b
    codes_p = jnp.pad(codes, ((0, pad_b), (0, 0)))
    vid_p = jnp.pad(vid.astype(jnp.int32).reshape(-1, 1), ((0, pad_b), (0, 0)),
                    constant_values=-1)
    B_pad = codes_p.shape[0]

    label, per_tree = pl.pallas_call(
        functools.partial(_kernel, n_classes=n_classes),
        grid=(B_pad // block_b, V),
        in_specs=[
            pl.BlockSpec((block_b, T), lambda i, v: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, v: (i, 0)),
            pl.BlockSpec((1, T, P), lambda i, v: (v, 0, 0)),
            pl.BlockSpec((1, T, P), lambda i, v: (v, 0, 0)),
            pl.BlockSpec((1, T, P), lambda i, v: (v, 0, 0)),
            pl.BlockSpec((1, 1, T), lambda i, v: (v, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, v: (i, 0)),
            pl.BlockSpec((block_b, T), lambda i, v: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, T), jnp.int32),
        ],
        interpret=interpret,
    )(codes_p, vid_p, pred_codes, pred_labels, pv_i32, w_r)
    return label[:B, 0], per_tree[:B]


def forest_predict_vote_pallas(
    codes: jax.Array,        # uint32 [B, T]
    pred_codes: jax.Array,   # uint32 [T, P]
    pred_labels: jax.Array,  # int32 [T, P]
    pred_valid: jax.Array,   # bool [T, P]
    weights: jax.Array,      # float32 [T]
    n_classes: int,
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Single-version API: V=1 slice of the zoo kernel, every packet on vid 0."""
    vid = jnp.zeros((codes.shape[0],), jnp.int32)
    return forest_predict_vote_pallas_v(
        codes, vid, pred_codes[None], pred_labels[None], pred_valid[None],
        weights.reshape(1, -1), n_classes, block_b=block_b, interpret=interpret)

"""Pallas TPU kernel: fused ``dt_predict`` + ``multitree_voting``.

The switch's exact-match SRAM lookup (status code -> leaf label) is
re-expressed as a compare-reduce — a content-addressable match, which is what
the SRAM hash table emulates anyway:

    eq[b,t,p]   = (pred_codes[t,p] == codes[b,t]) & valid[t,p]
    label[b,t]  = sum_p eq * pred_labels          (at most one p matches)

followed by weighted one-hot voting and an argmax with smaller-class-id tie
break (matches ``RandomForest.vote``).  Everything is VPU elementwise +
reductions over VMEM-resident blocks; no gathers.

Grid: (batch blocks,).  Entry tables [T, P] are fully VMEM-resident
(T<=8, P<=1024 → 32 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["forest_predict_vote_pallas"]


def _kernel(codes_ref, pc_ref, plab_ref, pvalid_ref, w_ref, out_label_ref,
            out_per_tree_ref, *, n_classes: int):
    codes = codes_ref[...]                       # [Bb, T] uint32
    pc = pc_ref[...]                             # [T, P] uint32
    plab = plab_ref[...]                         # [T, P] int32
    pvalid = pvalid_ref[...]                     # [T, P] int32
    eq = (codes[:, :, None] == pc[None]) & (pvalid[None] != 0)   # [Bb, T, P]
    per_tree = jnp.sum(jnp.where(eq, plab[None], 0), axis=2)     # [Bb, T]
    out_per_tree_ref[...] = per_tree.astype(jnp.int32)
    w = w_ref[...]                               # [1, T] f32
    classes = jax.lax.iota(jnp.int32, n_classes)
    onehot = (per_tree[:, :, None] == classes[None, None, :]).astype(jnp.float32)
    scores = jnp.sum(onehot * w[0][None, :, None], axis=1)       # [Bb, C]
    # argmax with ties to the smaller class id
    best = jnp.max(scores, axis=1, keepdims=True)
    is_best = scores >= best
    first_best = is_best & (jnp.cumsum(is_best.astype(jnp.int32), axis=1) == 1)
    out_label_ref[...] = jnp.sum(
        jnp.where(first_best, classes[None, :], 0), axis=1, keepdims=True
    ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_classes", "block_b", "interpret"))
def forest_predict_vote_pallas(
    codes: jax.Array,        # uint32 [B, T]
    pred_codes: jax.Array,   # uint32 [T, P]
    pred_labels: jax.Array,  # int32 [T, P]
    pred_valid: jax.Array,   # bool [T, P]
    weights: jax.Array,      # float32 [T]
    n_classes: int,
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, T = codes.shape
    P = pred_codes.shape[1]
    pad_b = (-B) % block_b
    codes_p = jnp.pad(codes, ((0, pad_b), (0, 0)))
    B_pad = codes_p.shape[0]

    label, per_tree = pl.pallas_call(
        functools.partial(_kernel, n_classes=n_classes),
        grid=(B_pad // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, T), lambda i: (i, 0)),
            pl.BlockSpec((T, P), lambda i: (0, 0)),
            pl.BlockSpec((T, P), lambda i: (0, 0)),
            pl.BlockSpec((T, P), lambda i: (0, 0)),
            pl.BlockSpec((1, T), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, T), jnp.int32),
        ],
        interpret=interpret,
    )(codes_p, pred_codes, pred_labels, pred_valid.astype(jnp.int32),
      weights.reshape(1, -1).astype(jnp.float32))
    return label[:B, 0], per_tree[:B]

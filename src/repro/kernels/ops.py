"""Public kernel API: jit'd wrappers that dispatch Pallas vs the jnp oracle.

On TPU the Pallas path compiles natively; on CPU (this container) the default
is the XLA-compiled ``ref`` oracle, with ``mode="interpret"`` available to
execute the actual Pallas kernel bodies in the interpreter (the kernel-sweep
tests do exactly that and ``assert_allclose`` against ``ref``).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attn_pallas
from repro.kernels.forest_vote import (
    forest_predict_vote_pallas,
    forest_predict_vote_pallas_v,
)
from repro.kernels.svm_lookup import svm_lookup_pallas, svm_lookup_pallas_v
from repro.kernels.tcam_match import tcam_match_pallas, tcam_match_pallas_v

__all__ = [
    "tcam_match", "svm_lookup", "forest_predict_vote", "decode_attn",
    "tcam_match_v", "svm_lookup_v", "forest_predict_vote_v",
]


def _resolve(mode: str | None) -> str:
    if mode is not None:
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def tcam_match(codes, features, code_value, code_mask, fid, f_lo, f_hi,
               set_bit, valid, shift, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.tcam_match(codes, features, code_value, code_mask, fid,
                              f_lo, f_hi, set_bit, valid, shift)
    return tcam_match_pallas(codes, features, code_value, code_mask, fid,
                             f_lo, f_hi, set_bit, valid, shift,
                             interpret=(m == "interpret"))


def svm_lookup(features, lut, bias, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.svm_lookup(features, lut, bias)
    return svm_lookup_pallas(features, lut, bias, interpret=(m == "interpret"))


def forest_predict_vote(codes, pred_codes, pred_labels, pred_valid, weights,
                        n_classes, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.forest_predict_vote(codes, pred_codes, pred_labels,
                                       pred_valid, weights, n_classes)
    return forest_predict_vote_pallas(codes, pred_codes, pred_labels,
                                      pred_valid, weights, n_classes,
                                      interpret=(m == "interpret"))


def tcam_match_v(codes, features, vid, code_value, code_mask, fid, f_lo, f_hi,
                 set_bit, valid, shift, *, mode: str | None = None):
    """Version-indexed tcam_match: tables are [V, T, E], packet b uses vid[b]."""
    m = _resolve(mode)
    if m == "ref":
        return ref.tcam_match_v(codes, features, vid, code_value, code_mask,
                                fid, f_lo, f_hi, set_bit, valid, shift)
    return tcam_match_pallas_v(codes, features, vid, code_value, code_mask,
                               fid, f_lo, f_hi, set_bit, valid, shift,
                               interpret=(m == "interpret"))


def svm_lookup_v(features, vid, lut, bias, *, mode: str | None = None):
    """Version-indexed svm_lookup: lut is [V, H, F, L], packet b uses vid[b]."""
    m = _resolve(mode)
    if m == "ref":
        return ref.svm_lookup_v(features, vid, lut, bias)
    return svm_lookup_pallas_v(features, vid, lut, bias,
                               interpret=(m == "interpret"))


def forest_predict_vote_v(codes, vid, pred_codes, pred_labels, pred_valid,
                          weights, n_classes, *, mode: str | None = None):
    """Version-indexed dt_predict + voting: tables are [V, T, P]."""
    m = _resolve(mode)
    if m == "ref":
        return ref.forest_predict_vote_v(codes, vid, pred_codes, pred_labels,
                                         pred_valid, weights, n_classes)
    return forest_predict_vote_pallas_v(codes, vid, pred_codes, pred_labels,
                                        pred_valid, weights, n_classes,
                                        interpret=(m == "interpret"))


def decode_attn(q, k, v, kv_len, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.decode_attn(q, k, v, kv_len)
    return decode_attn_pallas(q, k, v, kv_len, interpret=(m == "interpret"))

"""Public kernel API: jit'd wrappers that dispatch Pallas vs the jnp oracle.

On TPU the Pallas path compiles natively; on CPU (this container) the default
is the XLA-compiled ``ref`` oracle, with ``mode="interpret"`` available to
execute the actual Pallas kernel bodies in the interpreter (the kernel-sweep
tests do exactly that and ``assert_allclose`` against ``ref``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.classify_fused import classify_fused_pallas_v
from repro.kernels.decode_attn import decode_attn_pallas
from repro.kernels.forest_vote import (
    forest_predict_vote_pallas,
    forest_predict_vote_pallas_v,
)
from repro.kernels.svm_lookup import svm_lookup_pallas, svm_lookup_pallas_v
from repro.kernels.tcam_match import tcam_match_pallas, tcam_match_pallas_v
from repro.kernels.tree_walk import tree_walk_pallas_v

__all__ = [
    "tcam_match", "svm_lookup", "forest_predict_vote", "decode_attn",
    "tcam_match_v", "svm_lookup_v", "forest_predict_vote_v", "tree_walk_v",
    "classify_fused_v",
    "base_mode", "count_pallas_launches", "count_operand_prep_ops",
]


def _resolve(mode: str | None) -> str:
    if mode is not None:
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def base_mode(mode: str | None) -> str | None:
    """Strip a ``layerwise``/``unfused`` prefix down to the underlying kernel
    mode.

    ``"layerwise"`` selects the scan-of-``tcam_match_v`` tree-walk fallback
    and ``"unfused"`` the pre-megakernel three-launch classify; an optional
    suffix pins the per-stage kernel mode (``"layerwise-ref"``,
    ``"unfused-interpret"``, ...).  Kernels beneath the prefixed path only
    understand the base mode, so dispatchers route them through this.
    """
    if mode is None:
        return mode
    for prefix in ("layerwise", "unfused"):
        if mode.startswith(prefix):
            return mode[len(prefix):].lstrip("-") or None
    return mode


def _sum_jaxpr_eqns(fn, args, kwargs, visit) -> int:
    """Trace ``fn`` and sum counts over its equations, walking nested
    sub-jaxprs (pjit, scan bodies, ...).  ``visit(eqn, mult)`` returns
    ``(count, descend)``; ``mult`` is the iteration multiplier accumulated
    from enclosing ``scan``s.  Both jaxpr counters below share this traversal
    so a fix to it (e.g. a new higher-order primitive) cannot silently reach
    only one of them."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)

    def walk(jaxpr, mult) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            count, descend = visit(eqn, mult)
            n += count
            if not descend:
                continue
            sub_mult = mult * (eqn.params.get("length", 1)
                               if eqn.primitive.name == "scan" else 1)
            for p in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                    p, is_leaf=lambda x: hasattr(x, "jaxpr") or hasattr(x, "eqns")
                ):
                    if hasattr(sub, "jaxpr"):
                        sub = sub.jaxpr
                    if hasattr(sub, "eqns"):
                        n += walk(sub, sub_mult)
        return n

    return walk(closed.jaxpr, 1)


def count_pallas_launches(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` launches one invocation of ``fn`` issues.

    Traces ``fn`` and walks the jaxpr; a kernel under ``lax.scan`` counts
    once per iteration (a scanned kernel *launches* every step — exactly the
    per-layer overhead the fused tree walk removes).  Benchmarks and the
    single-launch acceptance test both use this.
    """
    def visit(eqn, mult):
        if eqn.primitive.name == "pallas_call":
            return mult, False   # nothing beneath launches separately
        return 0, True

    return _sum_jaxpr_eqns(fn, args, kwargs, visit)


def count_operand_prep_ops(fn, *args, **kwargs) -> int:
    """Number of table-shaped (ndim >= 3) intermediate ops one invocation of
    ``fn`` computes *outside* of ``pallas_call`` kernel bodies.

    Per-packet arrays are at most 2-D (``[B, T]`` codes, ``[B, F]`` features),
    so any >= 3-D equation in the traced jaxpr is operand prep — one-hot
    ``fsel`` construction, no-match entry padding, LUT re-layout.  With the
    install-time ``ExecImage`` bound, classify must trace to **zero** such
    equations: every table operand flows from the jaxpr inputs straight into
    the kernel launches.  The exec-image acceptance test pins this.

    A prep op inside a ``lax.scan`` body reruns every iteration, so it
    multiplies through the accumulated scan length — the same convention as
    ``count_pallas_launches`` (both counters share ``_sum_jaxpr_eqns``, and
    the fused-path unit test pins the multiplied counts).
    """
    def visit(eqn, mult):
        if eqn.primitive.name == "pallas_call":
            return 0, False   # in-kernel math is not per-call HBM-side prep
        return mult * int(any(getattr(v.aval, "ndim", 0) >= 3
                              for v in eqn.outvars)), True

    return _sum_jaxpr_eqns(fn, args, kwargs, visit)


def tcam_match(codes, features, code_value, code_mask, fid, f_lo, f_hi,
               set_bit, valid, shift, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.tcam_match(codes, features, code_value, code_mask, fid,
                              f_lo, f_hi, set_bit, valid, shift)
    return tcam_match_pallas(codes, features, code_value, code_mask, fid,
                             f_lo, f_hi, set_bit, valid, shift,
                             interpret=(m == "interpret"))


def svm_lookup(features, lut, bias, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.svm_lookup(features, lut, bias)
    return svm_lookup_pallas(features, lut, bias, interpret=(m == "interpret"))


def forest_predict_vote(codes, pred_codes, pred_labels, pred_valid, weights,
                        n_classes, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.forest_predict_vote(codes, pred_codes, pred_labels,
                                       pred_valid, weights, n_classes)
    return forest_predict_vote_pallas(codes, pred_codes, pred_labels,
                                      pred_valid, weights, n_classes,
                                      interpret=(m == "interpret"))


def tcam_match_v(codes, features, vid, code_value, code_mask, fid, f_lo, f_hi,
                 set_bit, valid, shift, *, mode: str | None = None, prep=None):
    """Version-indexed tcam_match: tables are [V, T, E], packet b uses vid[b].

    ``prep`` binds install-time operands (``tiling.prep_tcam_match``); the
    ref oracle rebuilds from the source tables and ignores it.
    """
    m = _resolve(mode)
    if m == "ref":
        return ref.tcam_match_v(codes, features, vid, code_value, code_mask,
                                fid, f_lo, f_hi, set_bit, valid, shift)
    return tcam_match_pallas_v(codes, features, vid, code_value, code_mask,
                               fid, f_lo, f_hi, set_bit, valid, shift,
                               prep=prep, interpret=(m == "interpret"))


def tree_walk_v(codes, features, vid, code_value, code_mask, fid, f_lo, f_hi,
                set_bit, valid, layer_shift, *, mode: str | None = None,
                prep=None):
    """Fused multi-layer tree walk: tables are [V, L, T, E], packet b walks
    all L layers of version ``vid[b]`` in one kernel launch.

    ``prep`` binds install-time operands (``tiling.prep_tree_walk``, the
    plane's ``ExecImage``) so the launch does zero per-call operand prep.
    The ref oracle and the layerwise fallback work from the source tables
    and ignore ``prep``.

    ``mode="layerwise[-<kernel mode>]"`` selects the pre-fusion fallback — a
    ``lax.scan`` of ``tcam_match_v`` over the layer axis (L launches) — for
    deployments where per-layer staging still matters (e.g. partial
    per-layer device placements that want layer-granular kernels).
    """
    m = _resolve(mode)
    if m.startswith("layerwise"):
        sub = base_mode(m)

        def step(c, x):
            cv, cm, fd, lo, hi, bit, vld, shift = x
            return tcam_match_v(c, features, vid, cv, cm, fd, lo, hi, bit,
                                vld, shift, mode=sub), None

        per_layer = lambda a: jnp.moveaxis(a, 1, 0)
        xs = (per_layer(code_value), per_layer(code_mask), per_layer(fid),
              per_layer(f_lo), per_layer(f_hi), per_layer(set_bit),
              per_layer(valid), layer_shift)
        out, _ = jax.lax.scan(step, codes, xs)
        return out
    if m == "ref":
        return ref.tree_walk_v(codes, features, vid, code_value, code_mask,
                               fid, f_lo, f_hi, set_bit, valid, layer_shift)
    return tree_walk_pallas_v(codes, features, vid, code_value, code_mask,
                              fid, f_lo, f_hi, set_bit, valid, layer_shift,
                              prep=prep, interpret=(m == "interpret"))


def svm_lookup_v(features, vid, lut, bias, *, mode: str | None = None,
                 prep=None):
    """Version-indexed svm_lookup: lut is [V, H, F, L], packet b uses vid[b].

    ``prep`` binds the install-time chunked LUT layout
    (``tiling.prep_svm_lookup``); the ref oracle ignores it.
    """
    m = _resolve(mode)
    if m == "ref":
        return ref.svm_lookup_v(features, vid, lut, bias)
    return svm_lookup_pallas_v(features, vid, lut, bias, prep=prep,
                               interpret=(m == "interpret"))


def forest_predict_vote_v(codes, vid, pred_codes, pred_labels, pred_valid,
                          weights, n_classes, *, mode: str | None = None,
                          prep=None):
    """Version-indexed dt_predict + voting: tables are [V, T, P].

    ``prep`` binds the install-time validity/weight layouts
    (``tiling.prep_forest_vote``); the ref oracle ignores it.
    """
    m = _resolve(mode)
    if m == "ref":
        return ref.forest_predict_vote_v(codes, vid, pred_codes, pred_labels,
                                         pred_valid, weights, n_classes)
    return forest_predict_vote_pallas_v(codes, vid, pred_codes, pred_labels,
                                        pred_valid, weights, n_classes,
                                        prep=prep,
                                        interpret=(m == "interpret"))


def classify_fused_v(codes, features, vid, code_value, code_mask, fid, f_lo,
                     f_hi, set_bit, valid, layer_shift, pred_codes,
                     pred_labels, pred_valid, weights, lut, bias, n_classes,
                     *, mode: str | None = None, prep=None,
                     unfused_prep=None):
    """Whole-classify megakernel: walk -> vote -> svm in **one**
    ``pallas_call`` (``kernels/classify_fused.py``), returning (final codes
    [B, T], vote label [B], svm sums [B, H]).

    ``prep`` binds the install-time quantized operand layout
    (``tiling.prep_classify_fused``, the plane's ``ExecImage.fused``); the
    ref oracle and the fallback paths ignore it.

    ``mode="unfused[-<kernel mode>]"`` selects the pre-fusion three-launch
    classify — the individual stage dispatchers above, binding
    ``unfused_prep`` = (walk, forest, svm) operand groups when given — and
    ``mode="layerwise[-<kernel mode>]"`` additionally swaps the fused walk
    for the per-layer kernel scan (L + 2 launches).
    """
    m = _resolve(mode)
    if m == "ref":
        return ref.classify_fused_v(
            codes, features, vid, code_value, code_mask, fid, f_lo, f_hi,
            set_bit, valid, layer_shift, pred_codes, pred_labels, pred_valid,
            weights, lut, bias, n_classes)
    if m.startswith(("layerwise", "unfused")):
        sub = base_mode(m)
        walk_prep, forest_prep, svm_prep = unfused_prep or (None, None, None)
        codes_out = tree_walk_v(
            codes, features, vid, code_value, code_mask, fid, f_lo, f_hi,
            set_bit, valid, layer_shift,
            mode=m if m.startswith("layerwise") else sub, prep=walk_prep)
        label, _per_tree = forest_predict_vote_v(
            codes_out, vid, pred_codes, pred_labels, pred_valid, weights,
            n_classes, mode=sub, prep=forest_prep)
        sums = svm_lookup_v(features, vid, lut, bias, mode=sub, prep=svm_prep)
        return codes_out, label, sums
    return classify_fused_pallas_v(
        codes, features, vid, code_value, code_mask, fid, f_lo, f_hi,
        set_bit, valid, layer_shift, pred_codes, pred_labels, pred_valid,
        weights, lut, bias, n_classes, prep=prep,
        interpret=(m == "interpret"))


def decode_attn(q, k, v, kv_len, *, mode: str | None = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.decode_attn(q, k, v, kv_len)
    return decode_attn_pallas(q, k, v, kv_len, interpret=(m == "interpret"))

"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the semantic ground truth; kernels in this package must
``assert_allclose`` against these over shape/dtype sweeps (tests/test_kernels*).
They are also the engine's CPU execution path — the dry-run and the paper
benchmarks run these through XLA, while the Pallas versions target TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "tcam_match", "svm_lookup", "forest_predict_vote", "decode_attn",
    "tcam_match_v", "svm_lookup_v", "forest_predict_vote_v", "tree_walk_v",
    "classify_fused_v",
]


def tcam_match(
    codes: jax.Array,      # uint32 [B, T]
    features: jax.Array,   # int32 [B, F]
    code_value: jax.Array,  # uint32 [T, E]
    code_mask: jax.Array,   # uint32 [T, E]
    fid: jax.Array,         # int32 [T, E]
    f_lo: jax.Array,        # int32 [T, E]
    f_hi: jax.Array,        # int32 [T, E]
    set_bit: jax.Array,     # uint32 [T, E]
    valid: jax.Array,       # bool [T, E]
    shift: jax.Array,       # int32 scalar — which status-code bit this layer writes
) -> jax.Array:
    """One ``dt_layer`` ternary lookup for B packets × T trees.

    Entries are pre-sorted priority-descending, so "first matching entry" ==
    "highest-priority match" (the TCAM contract).  No match => code unchanged
    (that is how early leaves fall through, paper §4.1).
    """
    f = features[:, fid]                                   # [B, T, E]
    code_ok = (codes[:, :, None] & code_mask[None]) == code_value[None]
    ok = code_ok & (f >= f_lo[None]) & (f <= f_hi[None]) & valid[None]
    hit = ok.any(axis=-1)
    first = jnp.argmax(ok, axis=-1)                        # [B, T]
    bit = jnp.take_along_axis(
        jnp.broadcast_to(set_bit[None], ok.shape), first[..., None], axis=-1
    )[..., 0].astype(jnp.uint32)
    new = codes | (bit << shift.astype(jnp.uint32))
    return jnp.where(hit, new, codes)


def tcam_match_v(
    codes: jax.Array,      # uint32 [B, T]
    features: jax.Array,   # int32 [B, F]
    vid: jax.Array,        # int32 [B] model version per packet, in [0, V)
    code_value: jax.Array,  # uint32 [V, T, E]
    code_mask: jax.Array,   # uint32 [V, T, E]
    fid: jax.Array,         # int32 [V, T, E]
    f_lo: jax.Array,        # int32 [V, T, E]
    f_hi: jax.Array,        # int32 [V, T, E]
    set_bit: jax.Array,     # uint32 [V, T, E]
    valid: jax.Array,       # bool [V, T, E]
    shift: jax.Array,       # int32 scalar
) -> jax.Array:
    """Version-indexed ``tcam_match``: packet b matches against the entry
    tables of version ``vid[b]`` (the model-zoo per-packet dispatch).

    Same asymptotic cost as the single-version oracle — the per-packet table
    gather produces the [B, T, E] working set the V=1 path broadcasts anyway.
    """
    cv = code_value[vid]                                   # [B, T, E]
    cm = code_mask[vid]
    fidv = fid[vid]                                        # [B, T, E]
    f = jax.vmap(lambda ft, ix: ft[ix])(features, fidv)    # [B, T, E]
    code_ok = (codes[:, :, None] & cm) == cv
    ok = code_ok & (f >= f_lo[vid]) & (f <= f_hi[vid]) & valid[vid]
    hit = ok.any(axis=-1)
    first = jnp.argmax(ok, axis=-1)                        # [B, T]
    bit = jnp.take_along_axis(set_bit[vid], first[..., None], axis=-1)[
        ..., 0
    ].astype(jnp.uint32)
    new = codes | (bit << shift.astype(jnp.uint32))
    return jnp.where(hit, new, codes)


def tree_walk_v(
    codes: jax.Array,      # uint32 [B, T]
    features: jax.Array,   # int32 [B, F]
    vid: jax.Array,        # int32 [B] model version per packet, in [0, V)
    code_value: jax.Array,  # uint32 [V, L, T, E]
    code_mask: jax.Array,   # uint32 [V, L, T, E]
    fid: jax.Array,         # int32 [V, L, T, E]
    f_lo: jax.Array,        # int32 [V, L, T, E]
    f_hi: jax.Array,        # int32 [V, L, T, E]
    set_bit: jax.Array,     # uint32 [V, L, T, E]
    valid: jax.Array,       # bool [V, L, T, E]
    layer_shift: jax.Array,  # int32 [L] status-code bit per layer
) -> jax.Array:
    """Fused multi-layer tree walk: apply all L ``dt_layer`` ternary lookups
    in sequence (layer l writes status-code bit ``layer_shift[l]``).

    Semantic ground truth for the single-launch walk kernel — by construction
    identical to scanning ``tcam_match_v`` over the layer axis, which is the
    layerwise fallback path in ``ops.tree_walk_v``.
    """
    per_layer = lambda a: jnp.moveaxis(a, 1, 0)  # [V, L, ...] -> [L, V, ...]
    xs = (per_layer(code_value), per_layer(code_mask), per_layer(fid),
          per_layer(f_lo), per_layer(f_hi), per_layer(set_bit),
          per_layer(valid), layer_shift)

    def step(c, x):
        cv, cm, fd, lo, hi, bit, vld, shift = x
        return tcam_match_v(c, features, vid, cv, cm, fd, lo, hi, bit, vld,
                            shift), None

    out, _ = jax.lax.scan(step, codes, xs)
    return out


def svm_lookup(
    features: jax.Array,  # int32 [B, F]
    lut: jax.Array,       # int32 [H, F, L]  precomputed products
    bias: jax.Array,      # int32 [H]
) -> jax.Array:
    """``svm_mul`` exact-match lookups + native-adder hyperplane sums.

    Returns int32 sums [B, H]; the sign bit of each is the hyperplane code
    (paper §4.3: "extracts the highest bits as the code for the hyperplanes").
    """
    B, F = features.shape
    # lut[h, f, features[b, f]] summed over f
    per_f = jnp.take_along_axis(
        lut.transpose(1, 2, 0)[None],                  # [1, F, L, H]
        features[:, :, None, None].astype(jnp.int32),  # [B, F, 1, 1]
        axis=2,
    )[:, :, 0, :]                                      # [B, F, H]
    return per_f.sum(axis=1).astype(jnp.int32) + bias[None, :]


def svm_lookup_v(
    features: jax.Array,  # int32 [B, F]
    vid: jax.Array,       # int32 [B] model version per packet, in [0, V)
    lut: jax.Array,       # int32 [V, H, F, L]
    bias: jax.Array,      # int32 [V, H]
) -> jax.Array:
    """Version-indexed ``svm_lookup``: packet b sums the product LUTs of
    version ``vid[b]``."""
    H = lut.shape[1]
    F = lut.shape[2]

    def one(feat, v):
        idx = jnp.broadcast_to(feat[None, :, None], (H, F, 1)).astype(jnp.int32)
        per_f = jnp.take_along_axis(lut[v], idx, axis=2)[:, :, 0]   # [H, F]
        return per_f.sum(axis=1).astype(jnp.int32)

    return jax.vmap(one)(features, vid) + bias[vid]


def forest_predict_vote(
    codes: jax.Array,        # uint32 [B, T] final status codes
    pred_codes: jax.Array,   # uint32 [T, P] sorted ascending (pad: 0xFFFFFFFF)
    pred_labels: jax.Array,  # int32 [T, P]
    pred_valid: jax.Array,   # bool [T, P]
    weights: jax.Array,      # float32 [T] voting weights (0 disables a tree)
    n_classes: int,
) -> tuple[jax.Array, jax.Array]:
    """``dt_predict`` (exact match via binary search) + ``multitree_voting``.

    Returns (final_label int32 [B], per_tree_labels int32 [B, T]).
    Argmax ties break to the smaller class id (matches RandomForest.vote).
    """
    def one_tree(c, pc, pl, pv):
        pos = jnp.clip(jnp.searchsorted(pc, c), 0, pc.shape[0] - 1)
        found = (pc[pos] == c) & pv[pos]
        return jnp.where(found, pl[pos], 0)

    per_tree = jax.vmap(one_tree, in_axes=(1, 0, 0, 0), out_axes=1)(
        codes, pred_codes, pred_labels, pred_valid
    )  # [B, T]
    onehot = (per_tree[:, :, None] == jnp.arange(n_classes)[None, None, :])
    scores = (onehot * weights[None, :, None]).sum(axis=1)  # [B, C]
    return jnp.argmax(scores, axis=-1).astype(jnp.int32), per_tree.astype(jnp.int32)


def forest_predict_vote_v(
    codes: jax.Array,        # uint32 [B, T]
    vid: jax.Array,          # int32 [B] model version per packet, in [0, V)
    pred_codes: jax.Array,   # uint32 [V, T, P] sorted ascending per (v, t)
    pred_labels: jax.Array,  # int32 [V, T, P]
    pred_valid: jax.Array,   # bool [V, T, P]
    weights: jax.Array,      # float32 [V, T]
    n_classes: int,
) -> tuple[jax.Array, jax.Array]:
    """Version-indexed ``dt_predict`` + ``multitree_voting``: packet b uses
    the leaf tables and voting weights of version ``vid[b]``."""

    def one_packet(c, v):
        def one_tree(ct, pct, plt, pvt):
            pos = jnp.clip(jnp.searchsorted(pct, ct), 0, pct.shape[0] - 1)
            found = (pct[pos] == ct) & pvt[pos]
            return jnp.where(found, plt[pos], 0)

        return jax.vmap(one_tree)(c, pred_codes[v], pred_labels[v], pred_valid[v])

    per_tree = jax.vmap(one_packet)(codes, vid)            # [B, T]
    w = weights[vid]                                       # [B, T]
    onehot = per_tree[:, :, None] == jnp.arange(n_classes)[None, None, :]
    scores = (onehot * w[:, :, None]).sum(axis=1)          # [B, C]
    return jnp.argmax(scores, axis=-1).astype(jnp.int32), per_tree.astype(jnp.int32)


def classify_fused_v(
    codes: jax.Array,        # uint32 [B, T]
    features: jax.Array,     # int32 [B, F]
    vid: jax.Array,          # int32 [B] model version per packet, in [0, V)
    code_value: jax.Array,   # uint32 [V, L, T, E]
    code_mask: jax.Array,
    fid: jax.Array,          # int32 [V, L, T, E]
    f_lo: jax.Array,
    f_hi: jax.Array,
    set_bit: jax.Array,      # uint32 [V, L, T, E]
    valid: jax.Array,        # bool [V, L, T, E]
    layer_shift: jax.Array,  # int32 [L]
    pred_codes: jax.Array,   # uint32 [V, T, P]
    pred_labels: jax.Array,  # int32 [V, T, P]
    pred_valid: jax.Array,   # bool [V, T, P]
    weights: jax.Array,      # float32 [V, T]
    lut: jax.Array,          # int32 [V, H, F, levels]
    bias: jax.Array,         # int32 [V, H]
    n_classes: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Whole-classify oracle: tree walk -> forest vote, plus the svm LUT
    sums, composed from the three stage oracles above.

    Semantic ground truth for the single-launch megakernel
    (``kernels/classify_fused.py``) — by construction identical to issuing
    the three stages as separate launches, which is the ``unfused`` fallback
    path in ``ops.classify_fused_v``.  Returns (final codes [B, T], vote
    label [B], svm sums [B, H]).
    """
    codes_out = tree_walk_v(codes, features, vid, code_value, code_mask, fid,
                            f_lo, f_hi, set_bit, valid, layer_shift)
    label, _per_tree = forest_predict_vote_v(
        codes_out, vid, pred_codes, pred_labels, pred_valid, weights,
        n_classes)
    sums = svm_lookup_v(features, vid, lut, bias)
    return codes_out, label, sums


def decode_attn(
    q: jax.Array,        # [B, Hq, D]      single-step query
    k: jax.Array,        # [B, S, Hkv, D]  KV cache
    v: jax.Array,        # [B, S, Hkv, D]
    kv_len: jax.Array,   # int32 [B]       valid cache length per sequence
    scale: float | None = None,
) -> jax.Array:
    """GQA decode attention (one new token against the cache), masked softmax."""
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    mask = (jnp.arange(S)[None, :] < kv_len[:, None])[:, None, None, :]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)

"""Pallas TPU kernel: ``svm_mul`` LUT lookup + hyperplane accumulation.

The switch's per-(hyperplane, feature) exact-match product tables become a
one-hot contraction: ``sums[b,h] = sum_{f,v} onehot(feats)[b,f,v] * lut[h,f,v]``
— an MXU matmul over the flattened (feature, level) axis, chunked over
features so the one-hot block stays in VMEM.

Exactness: each per-chunk partial sum is a sum of ``chunk_f`` products, each
``|p| < 2**(frac_bits + 7)``; with the default chunk_f=8 and frac_bits<=16 the
f32 partial is integer-exact (< 2**24); partials are then accumulated in f32
across chunks by the sequential grid dimensions and rounded once at the end —
across-chunk totals stay well under 2**31 and each chunk total under 2**24,
so the final int32 equals the reference integer sum.

Model-zoo dispatch: LUTs carry a leading version axis ``[V, H, F, L]`` and
the grid gains a version dimension (between batch and chunk).  Each step
streams one (version, chunk) LUT slice into VMEM — selected by the step's vid
scalar ``pl.program_id(1)`` — and accumulates masked partials only into the
packets whose ``vid`` matches; version masks are disjoint, so the revisited
accumulator ends up holding exactly one version's sum per packet.

Grid: (batch blocks, versions, feature chunks) — versions and chunks are the
sequential reduction axes; the output block is revisited and accumulated.

The chunked f32 LUT layout ``[V, n_chunks, chunk_f*levels, H_pad]`` only
changes at install/swap; the plane precomputes it once per slot write
(``tiling.prep_svm_lookup``, held in the ``ExecImage``) and binds it via
``prep=``.  Without ``prep=`` the wrapper reruns the same layout pass per
call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import SVM_SUBLANES, SvmOperands, prep_svm_lookup

__all__ = ["svm_lookup_pallas", "svm_lookup_pallas_v"]


def _kernel(feats_ref, vid_ref, lut_ref, bias_ref, out_ref, *, levels: int):
    v = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when((v == 0) & (c == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mine = (vid_ref[...] == v).astype(jnp.float32)   # [Bb, 1]

    @pl.when(c == 0)
    def _bias():
        out_ref[...] += mine * bias_ref[0].astype(jnp.float32)

    feats = feats_ref[...]                      # [Bb, Fc] int32
    lut = lut_ref[0, 0]                         # [Fc*L, H] f32 (this v, chunk)
    onehot = (
        feats[:, :, None] == jax.lax.iota(jnp.int32, levels)[None, None, :]
    ).astype(jnp.float32)                       # [Bb, Fc, L]
    Bb, Fc, L = onehot.shape
    partial = jnp.dot(
        onehot.reshape(Bb, Fc * L), lut, preferred_element_type=jnp.float32
    )                                           # [Bb, H]
    out_ref[...] += mine * partial


@functools.partial(jax.jit, static_argnames=("block_b", "chunk_f", "interpret"))
def svm_lookup_pallas_v(
    features: jax.Array,  # int32 [B, F]
    vid: jax.Array,       # int32 [B] model version per packet, in [0, V)
    lut: jax.Array,       # int32 [V, H, F, L]
    bias: jax.Array,      # int32 [V, H]
    *,
    prep: SvmOperands | None = None,
    block_b: int = 128,
    chunk_f: int = 8,
    interpret: bool = False,
) -> jax.Array:
    B, F = features.shape
    V, H, _, L = lut.shape

    if prep is None:
        # Per-call fallback: same prep the plane runs once per install and
        # binds via ``prep=`` (tiling.prep_svm_lookup).
        prep = prep_svm_lookup(lut, bias, chunk_f=chunk_f)
    lut_r, bias_p = prep
    # Expected layout derived from the *source* shapes, so a prep built for a
    # different feature/hyperplane width cannot slip through.
    n_chunks = -(-F // chunk_f)
    H_pad = -(-H // SVM_SUBLANES) * SVM_SUBLANES
    if lut_r.shape != (V, n_chunks, chunk_f * L, H_pad) or \
            bias_p.shape != (V, H_pad):
        raise ValueError(
            f"prepped lut/bias shapes {lut_r.shape}/{bias_p.shape} do not "
            f"match this launch (expected "
            f"{(V, n_chunks, chunk_f * L, H_pad)}/{(V, H_pad)})")
    pad_b = (-B) % block_b
    pad_f = n_chunks * chunk_f - F
    # padded feature columns match no level => contribute 0
    feats = jnp.pad(features, ((0, pad_b), (0, pad_f)), constant_values=-1)
    vid_p = jnp.pad(vid.astype(jnp.int32).reshape(-1, 1), ((0, pad_b), (0, 0)),
                    constant_values=-1)
    B_pad, F_pad = feats.shape

    out = pl.pallas_call(
        functools.partial(_kernel, levels=L),
        grid=(B_pad // block_b, V, n_chunks),
        in_specs=[
            pl.BlockSpec((block_b, chunk_f), lambda i, v, c: (i, c)),
            pl.BlockSpec((block_b, 1), lambda i, v, c: (i, 0)),
            pl.BlockSpec((1, 1, chunk_f * L, H_pad), lambda i, v, c: (v, c, 0, 0)),
            pl.BlockSpec((1, H_pad), lambda i, v, c: (v, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, H_pad), lambda i, v, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B_pad, H_pad), jnp.float32),
        interpret=interpret,
    )(feats, vid_p, lut_r, bias_p)
    return jnp.round(out[:B, :H]).astype(jnp.int32)


def svm_lookup_pallas(
    features: jax.Array,  # int32 [B, F]
    lut: jax.Array,       # int32 [H, F, L]
    bias: jax.Array,      # int32 [H]
    *,
    block_b: int = 128,
    chunk_f: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Single-version API: V=1 slice of the zoo kernel, every packet on vid 0."""
    vid = jnp.zeros((features.shape[0],), jnp.int32)
    return svm_lookup_pallas_v(
        features, vid, lut[None], bias[None],
        block_b=block_b, chunk_f=chunk_f, interpret=interpret)

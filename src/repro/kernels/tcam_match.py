"""Pallas TPU kernel: ternary (TCAM) match for ``dt_layer`` tables.

Hardware adaptation (DESIGN.md §2): a Tofino TCAM matches (code, feature)
ternary keys in one cycle; the TPU has no CAM, so we re-express the lookup as

  1. *feature select* — the per-entry "which feature does this entry test"
     indirection becomes a one-hot **MXU matmul**: ``fv = feats @ f_sel^T``
     with ``f_sel[e, :] = onehot(fid[e])``.  No dynamic gather in-kernel.
  2. *ternary compare* — masked equality on the status code plus a range
     compare on ``fv``, all VPU elementwise ops on VMEM-resident entries.
  3. *priority encode* — entries are pre-sorted priority-descending; the
     first match is isolated with an exclusive-cumsum trick
     (``ok & (cumsum(ok) == 1)``), avoiding argmax+gather.

Model-zoo dispatch: entry tables carry a leading version axis ``[V, T, E]``
and the grid gains an innermost version dimension.  Each grid step indexes
its table block by the step's vid scalar (``pl.program_id(2)``) — so only one
version's entries are VMEM-resident at a time — and merges results for the
packets whose ``vid`` matches that version (masked select on the revisited
output block).  Packets with no hit, or whose version differs, keep their
incoming status code.

Grid: (batch blocks, trees, versions).  Block shapes are MXU-aligned: the
batch tile is ``block_b`` (multiple of 8, lane-dim padded feature count F_pad
and entry count E_pad are multiples of 128).

VMEM budget per step (block_b=256, F_pad=128, E_pad=128):
  feats 256*128*4 = 128 KiB, f_sel 128*128*4 = 64 KiB, fv 256*128*4 = 128 KiB,
  entry arrays 6*128*4 ≈ 3 KiB  → well under 16 MiB, independent of V.

Operand prep (one-hot ``f_sel``, no-match-padded entry blocks) only changes
at install/swap; callers that launch this kernel repeatedly should run
``tiling.prep_tcam_match`` once and bind the result via ``prep=`` — without
it, the wrapper reruns the same prep every call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import TcamOperands, pad_to, prep_tcam_match

__all__ = ["tcam_match_pallas", "tcam_match_pallas_v"]


def _kernel(codes_ref, vid_ref, feats_ref, fsel_ref, cv_ref, cm_ref, flo_ref,
            fhi_ref, bit_ref, valid_ref, shift_ref, out_ref):
    v = pl.program_id(2)
    codes = codes_ref[...]                      # [Bb, 1] uint32

    @pl.when(v == 0)
    def _passthrough():
        out_ref[...] = codes

    feats = feats_ref[...]                      # [Bb, F_pad] f32
    fsel = fsel_ref[0, 0]                       # [E_pad, F_pad] f32 (this v, tree)
    # MXU: select the tested feature value for every entry.
    fv = jnp.dot(feats, fsel.T, preferred_element_type=jnp.float32)  # [Bb, E]
    cv = cv_ref[0, 0][None, :]                  # [1, E] uint32
    cm = cm_ref[0, 0][None, :]
    flo = flo_ref[0, 0][None, :]                # [1, E] f32
    fhi = fhi_ref[0, 0][None, :]
    valid = valid_ref[0, 0][None, :]
    code_ok = (codes & cm) == cv                # [Bb, E]
    ok = code_ok & (fv >= flo) & (fv <= fhi) & (valid != 0)
    # Priority encode: first (== highest-priority) match only.
    first = ok & (jnp.cumsum(ok.astype(jnp.int32), axis=1) == 1)
    bit = jnp.sum(jnp.where(first, bit_ref[0, 0][None, :], 0), axis=1,
                  keepdims=True)
    hit = ok.any(axis=1, keepdims=True)
    shift = shift_ref[0, 0].astype(jnp.uint32)
    new = codes | (bit.astype(jnp.uint32) << shift)
    mine = vid_ref[...] == v                    # [Bb, 1]
    out_ref[...] = jnp.where(mine & hit, new, out_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def tcam_match_pallas_v(
    codes: jax.Array,      # uint32 [B, T]
    features: jax.Array,   # int32 [B, F]
    vid: jax.Array,        # int32 [B] model version per packet, in [0, V)
    code_value: jax.Array,  # uint32 [V, T, E]
    code_mask: jax.Array,
    fid: jax.Array,         # int32 [V, T, E]
    f_lo: jax.Array,
    f_hi: jax.Array,
    set_bit: jax.Array,     # uint32 [V, T, E]
    valid: jax.Array,       # bool [V, T, E]
    shift: jax.Array,       # int32 scalar
    *,
    prep: TcamOperands | None = None,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, T = codes.shape
    V, _, _ = code_value.shape

    feats = pad_to(features.astype(jnp.float32), 1, 128)
    F_pad = feats.shape[1]
    if prep is None:
        # Per-call fallback: same prep a caller can run once at install time
        # and bind via ``prep=`` (tiling.prep_tcam_match).
        prep = prep_tcam_match(code_value, code_mask, fid, f_lo, f_hi,
                               set_bit, valid, F_pad)
    fsel, cv, cm, flo, fhi, bit, vld = prep
    E_pad = cv.shape[2]
    if fsel.shape != (V, T, E_pad, F_pad):
        raise ValueError(
            f"prepped fsel shape {fsel.shape} does not match this launch "
            f"(expected {(V, T, E_pad, F_pad)})")

    codes_p = pad_to(codes, 0, block_b)
    feats_p = pad_to(feats, 0, block_b)
    vid_p = pad_to(vid.astype(jnp.int32).reshape(-1, 1), 0, block_b, fill=-1)
    B_pad = codes_p.shape[0]
    grid = (B_pad // block_b, T, V)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda i, t, v: (i, t)),       # codes
            pl.BlockSpec((block_b, 1), lambda i, t, v: (i, 0)),       # vid
            pl.BlockSpec((block_b, F_pad), lambda i, t, v: (i, 0)),   # feats
            pl.BlockSpec((1, 1, E_pad, F_pad), lambda i, t, v: (v, t, 0, 0)),
            pl.BlockSpec((1, 1, E_pad), lambda i, t, v: (v, t, 0)),   # cv
            pl.BlockSpec((1, 1, E_pad), lambda i, t, v: (v, t, 0)),   # cm
            pl.BlockSpec((1, 1, E_pad), lambda i, t, v: (v, t, 0)),   # flo
            pl.BlockSpec((1, 1, E_pad), lambda i, t, v: (v, t, 0)),   # fhi
            pl.BlockSpec((1, 1, E_pad), lambda i, t, v: (v, t, 0)),   # bit
            pl.BlockSpec((1, 1, E_pad), lambda i, t, v: (v, t, 0)),   # valid
            pl.BlockSpec((1, 1), lambda i, t, v: (0, 0)),             # shift
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, t, v: (i, t)),
        out_shape=jax.ShapeDtypeStruct((B_pad, T), codes.dtype),
        interpret=interpret,
    )(codes_p, vid_p, feats_p, fsel, cv, cm, flo, fhi, bit, vld,
      shift.reshape(1, 1).astype(jnp.int32))
    return out[:B]


def tcam_match_pallas(
    codes: jax.Array,      # uint32 [B, T]
    features: jax.Array,   # int32 [B, F]
    code_value: jax.Array,  # uint32 [T, E]
    code_mask: jax.Array,
    fid: jax.Array,         # int32 [T, E]
    f_lo: jax.Array,
    f_hi: jax.Array,
    set_bit: jax.Array,     # uint32 [T, E]
    valid: jax.Array,       # bool [T, E]
    shift: jax.Array,       # int32 scalar
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Single-version API: V=1 slice of the zoo kernel, every packet on vid 0."""
    vid = jnp.zeros((codes.shape[0],), jnp.int32)
    return tcam_match_pallas_v(
        codes, features, vid, code_value[None], code_mask[None], fid[None],
        f_lo[None], f_hi[None], set_bit[None], valid[None], shift,
        block_b=block_b, interpret=interpret)

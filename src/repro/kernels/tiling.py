"""Shared MXU/VPU tiling helpers for the entry-table kernels.

``tcam_match`` (per-layer) and ``tree_walk`` (fused multi-layer) pad their
entry tables with one no-match convention; it lives here once so a change to
the padding contract cannot silently diverge between the kernels:

  * padded entries mask **all** code bits against value 0,
  * and carry an empty feature range [1, 0],

so a padded entry can never match any packet.  The one-hot feature-select
matrix likewise zeroes invalid entries' rows (they select no feature).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["pad_to", "pad_entry_tables", "feature_select_matrix"]

LANES = 128


def pad_to(x: jax.Array, axis: int, mult: int, fill=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def pad_entry_tables(axis: int, code_value, code_mask, f_lo, f_hi, set_bit,
                     valid):
    """Pad the entry axis to a 128-lane multiple with the no-match fills;
    range tables are cast to f32 (the in-kernel compare dtype) and ``valid``
    to int32 (Pallas block dtype)."""
    pad_e = lambda a, fill=0: pad_to(a, axis, LANES, fill)
    return (pad_e(code_value),
            pad_e(code_mask, fill=np.uint32(0xFFFFFFFF)),  # mask all, value 0
            pad_e(f_lo.astype(jnp.float32), fill=1.0),
            pad_e(f_hi.astype(jnp.float32), fill=0.0),     # empty range
            pad_e(set_bit.astype(jnp.uint32)),
            pad_e(valid.astype(jnp.int32)))


def feature_select_matrix(fid: jax.Array, valid: jax.Array,
                          f_pad: int) -> jax.Array:
    """One-hot feature selector for the MXU ``feats @ fsel^T`` indirection,
    entry axis (``fid``'s last) padded to 128 lanes; invalid entries select
    nothing (all-zero row)."""
    fsel = jax.nn.one_hot(fid, f_pad, dtype=jnp.float32) * valid[..., None]
    return pad_to(fsel, fid.ndim - 1, LANES)

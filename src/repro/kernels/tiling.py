"""Shared MXU/VPU tiling helpers + the install-time operand-prep entry points.

``tcam_match`` (per-layer) and ``tree_walk`` (fused multi-layer) pad their
entry tables with one no-match convention; it lives here once so a change to
the padding contract cannot silently diverge between the kernels:

  * padded entries mask **all** code bits against value 0,
  * and carry an empty feature range [1, 0],

so a padded entry can never match any packet.  The one-hot feature-select
matrix likewise zeroes invalid entries' rows (they select no feature).

The ``prep_*`` functions are the **single install-time entry point** for
turning source tables into the kernel-ready operands a ``pallas_call`` binds
directly (the plane's ``ExecImage``, see ``docs/ARCHITECTURE.md``).  Each
kernel wrapper accepts the matching ``*Operands`` tuple via ``prep=`` and,
when it is absent, falls back to calling the same ``prep_*`` function per
call — so the prepped and unprepped paths cannot diverge semantically.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LANES", "pad_to", "lane_pad", "bitpack_last", "pad_entry_tables",
    "feature_select_matrix",
    "TreeWalkOperands", "TcamOperands", "SvmOperands", "ForestOperands",
    "ClassifyFusedOperands",
    "prep_tree_walk", "prep_tcam_match", "prep_svm_lookup", "prep_forest_vote",
    "prep_classify_fused",
]

LANES = 128
SVM_CHUNK_F = 8     # feature chunk per svm_lookup grid step
SVM_SUBLANES = 8    # hyperplane-axis padding multiple


def pad_to(x: jax.Array, axis: int, mult: int, fill=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def lane_pad(n: int) -> int:
    """Smallest multiple of the 128-lane dimension >= n."""
    return ((n + LANES - 1) // LANES) * LANES


def bitpack_last(x: jax.Array) -> jax.Array:
    """Pack a 0/1 array into uint32 words along its last axis (length must be
    a multiple of 32): word ``w`` bit ``j`` holds ``x[..., 32*w + j]``.

    Inputs are collapsed through ``!= 0`` first, so this is lossless exactly
    for {0, 1}-valued tables — which ``set_bit`` / ``valid`` / ``pred_valid``
    are by the translator contract (each dt_layer writes one status bit).
    """
    *lead, n = x.shape
    if n % 32:
        raise ValueError(f"bitpack_last needs a 32-multiple last axis, got {n}")
    bits = (x != 0).astype(jnp.uint32).reshape(*lead, n // 32, 32)
    return (bits << jnp.arange(32, dtype=jnp.uint32)).sum(
        axis=-1, dtype=jnp.uint32)


def pad_entry_tables(axis: int, code_value, code_mask, f_lo, f_hi, set_bit,
                     valid):
    """Pad the entry axis to a 128-lane multiple with the no-match fills;
    range tables are cast to f32 (the in-kernel compare dtype) and ``valid``
    to int32 (Pallas block dtype)."""
    pad_e = lambda a, fill=0: pad_to(a, axis, LANES, fill)
    return (pad_e(code_value),
            pad_e(code_mask, fill=np.uint32(0xFFFFFFFF)),  # mask all, value 0
            pad_e(f_lo.astype(jnp.float32), fill=1.0),
            pad_e(f_hi.astype(jnp.float32), fill=0.0),     # empty range
            pad_e(set_bit.astype(jnp.uint32)),
            pad_e(valid.astype(jnp.int32)))


def feature_select_matrix(fid: jax.Array, valid: jax.Array,
                          f_pad: int) -> jax.Array:
    """One-hot feature selector for the MXU ``feats @ fsel^T`` indirection,
    entry axis (``fid``'s last) padded to 128 lanes; invalid entries select
    nothing (all-zero row)."""
    fsel = jax.nn.one_hot(fid, f_pad, dtype=jnp.float32) * valid[..., None]
    return pad_to(fsel, fid.ndim - 1, LANES)


# --------------------------------------------------------------------------
# Install-time operand prep (the ExecImage building blocks)
# --------------------------------------------------------------------------
class TreeWalkOperands(NamedTuple):
    """Kernel-ready operands for the fused ``tree_walk_pallas_v`` launch."""

    fsel: jax.Array    # f32  [V, T, L*E_pad, F_pad] flattened one-hot selector
    cv: jax.Array      # u32  [V, L, T, E_pad]
    cm: jax.Array      # u32  [V, L, T, E_pad]  (pad: mask all vs value 0)
    flo: jax.Array     # f32  [V, L, T, E_pad]  (pad: 1.0 — empty range)
    fhi: jax.Array     # f32  [V, L, T, E_pad]  (pad: 0.0)
    bit: jax.Array     # u32  [V, L, T, E_pad]
    valid: jax.Array   # i32  [V, L, T, E_pad]


class TcamOperands(NamedTuple):
    """Kernel-ready operands for one per-layer ``tcam_match_pallas_v`` launch."""

    fsel: jax.Array    # f32  [V, T, E_pad, F_pad]
    cv: jax.Array      # u32  [V, T, E_pad]
    cm: jax.Array      # u32  [V, T, E_pad]
    flo: jax.Array     # f32  [V, T, E_pad]
    fhi: jax.Array     # f32  [V, T, E_pad]
    bit: jax.Array     # u32  [V, T, E_pad]
    valid: jax.Array   # i32  [V, T, E_pad]


class SvmOperands(NamedTuple):
    """Kernel-ready operands for ``svm_lookup_pallas_v``."""

    lut: jax.Array     # f32  [V, n_chunks, chunk_f*levels, H_pad]
    bias: jax.Array    # i32  [V, H_pad]


class ForestOperands(NamedTuple):
    """Kernel-ready operands for ``forest_predict_vote_pallas_v`` (the
    ``pred_codes``/``pred_labels`` tables bind as-is and need no prep)."""

    valid: jax.Array    # i32 [V, T, P]
    weights: jax.Array  # f32 [V, 1, T]


def prep_tree_walk(code_value, code_mask, fid, f_lo, f_hi, set_bit, valid,
                   f_pad: int) -> TreeWalkOperands:
    """Source ``[V, L, T, E]`` dt_layer tables -> fused-walk operands.

    ``f_pad`` is the lane-padded feature width the classify path will present
    (``lane_pad(max_features)``) — the fsel matmul operand must match it.
    """
    V, L, T, E = fid.shape
    fsel = feature_select_matrix(fid, valid, f_pad)   # [V, L, T, E_pad, F_pad]
    cv, cm, flo, fhi, bit, vld = pad_entry_tables(
        3, code_value, code_mask, f_lo, f_hi, set_bit, valid)
    e_pad = cv.shape[3]
    # [V, L, T, E_pad, F_pad] -> [V, T, L*E_pad, F_pad]: one matmul operand
    # covering every layer's entries.
    fsel = fsel.transpose(0, 2, 1, 3, 4).reshape(V, T, L * e_pad, f_pad)
    return TreeWalkOperands(fsel, cv, cm, flo, fhi, bit, vld)


def prep_tcam_match(code_value, code_mask, fid, f_lo, f_hi, set_bit, valid,
                    f_pad: int) -> TcamOperands:
    """Source ``[V, T, E]`` single-layer tables -> per-layer kernel operands."""
    fsel = feature_select_matrix(fid, valid, f_pad)   # [V, T, E_pad, F_pad]
    padded = pad_entry_tables(2, code_value, code_mask, f_lo, f_hi, set_bit,
                              valid)
    return TcamOperands(fsel, *padded)


def prep_svm_lookup(lut, bias, *, chunk_f: int = SVM_CHUNK_F) -> SvmOperands:
    """Source ``[V, H, F, levels]`` product LUTs -> chunked f32 MXU operand.

    Feature axis padded to ``chunk_f`` (padded columns match feature value
    -1, never a real level, so they contribute 0), hyperplane axis padded to
    the sublane multiple, then laid out ``[V, n_chunks, chunk_f*levels,
    H_pad]`` so each grid step streams one (version, chunk) slice.
    """
    V, H, F, levels = lut.shape
    lut_p = pad_to(pad_to(lut, 1, SVM_SUBLANES), 2, chunk_f)
    bias_p = pad_to(bias, 1, SVM_SUBLANES)
    h_pad = lut_p.shape[1]
    n_chunks = lut_p.shape[2] // chunk_f
    lut_r = (
        lut_p.transpose(0, 2, 3, 1)
        .reshape(V, n_chunks, chunk_f * levels, h_pad)
        .astype(jnp.float32)
    )
    return SvmOperands(lut_r, bias_p)


def prep_forest_vote(pred_valid, weights) -> ForestOperands:
    """Source ``[V, T, P]`` validity + ``[V, T]`` vote weights -> Pallas block
    dtypes/layouts (int32 validity, ``[V, 1, T]`` f32 weights)."""
    V, T = weights.shape
    return ForestOperands(pred_valid.astype(jnp.int32),
                          weights.reshape(V, 1, T).astype(jnp.float32))


class ClassifyFusedOperands(NamedTuple):
    """Kernel-ready operands for the whole-classify megakernel
    (``classify_fused_pallas_v``): walk -> vote -> svm in one launch.

    Quantized widths (``prep_classify_fused(..., quantize=True)``) shrink
    what the launch streams per grid step without changing a single output
    bit: feature ids and range bounds are int16 (lossless for
    ``feature_width <= 15``), leaf labels int8 (``n_classes <= 127``), and
    the three {0,1} tables (``set_bit``/``valid``/``pred_valid``) are
    bit-packed into uint32 words — 32 entries per lane.  The f32 width
    (``quantize=False``) keeps i32/f32 element types in the identical layout;
    both compile against the same kernel, which upcasts in VMEM.  SVM LUT
    *values* stay f32 in both widths: per-chunk partials must remain
    integer-exact (< 2**24, see ``svm_lookup.py``).

    Unlike ``TreeWalkOperands`` there is no precomputed one-hot ``fsel``
    matmul operand: the fused kernel rebuilds the per-(layer, tree) one-hot
    selector from ``fid`` in VMEM, so the dominant f32 ``[V, T, L*E_pad,
    F_pad]`` stream of the unfused path disappears entirely.
    """

    # tree walk, [V, L, T, E_pad] (WP = E_pad // 32)
    fid: jax.Array       # i16 (quantized) | i32
    cv: jax.Array        # u32
    cm: jax.Array        # u32  (pad: mask all vs value 0)
    flo: jax.Array       # i16 (quantized) | f32  (pad: 1 — empty range)
    fhi: jax.Array       # i16 (quantized) | f32  (pad: 0)
    bitpk: jax.Array     # u32 [V, L, T, WP] bit-packed set_bit
    validpk: jax.Array   # u32 [V, L, T, WP] bit-packed valid
    # forest vote, [V, T, P] (PW = ceil32(P) // 32)
    pred_codes: jax.Array  # u32
    plab: jax.Array        # i8 (quantized) | i32
    pvalidpk: jax.Array    # u32 [V, T, PW] bit-packed pred_valid
    weights: jax.Array     # f32 [V, 1, T]
    # svm
    lut: jax.Array       # f32 [V, n_chunks, chunk_f*levels, H_pad]
    bias: jax.Array      # i32 [V, H_pad]


def prep_classify_fused(code_value, code_mask, fid, f_lo, f_hi, set_bit,
                        valid, pred_codes, pred_labels, pred_valid, weights,
                        lut, bias, *, chunk_f: int = SVM_CHUNK_F,
                        quantize: bool = True) -> ClassifyFusedOperands:
    """Source tables of all three classify stages -> megakernel operands.

    Walk tables are ``[V, L, T, E]`` dt_layer state, predict tables
    ``[V, T, P]`` + ``[V, T]`` weights, svm ``[V, H, F, levels]`` + bias.
    ``quantize`` selects the narrow widths (see ``ClassifyFusedOperands``);
    it is a pure layout choice — both widths decode bit-identically.
    """
    V, L, T, E = fid.shape
    cv, cm, flo, fhi, bit, vld = pad_entry_tables(
        3, code_value, code_mask, f_lo, f_hi, set_bit, valid)
    # fid pad fill 0 is harmless: padded entries are masked out via the
    # bit-packed valid words before any match can use their selected feature.
    fid_p = pad_to(fid, 3, LANES)
    bitpk = bitpack_last(bit)
    validpk = bitpack_last(vld)
    if quantize:
        fid_p = fid_p.astype(jnp.int16)
        flo = flo.astype(jnp.int16)
        fhi = fhi.astype(jnp.int16)
        plab = pred_labels.astype(jnp.int8)
    else:
        fid_p = fid_p.astype(jnp.int32)
        plab = pred_labels.astype(jnp.int32)
    pvalidpk = bitpack_last(pad_to(pred_valid.astype(jnp.uint32), 2, 32))
    w_r = weights.reshape(V, 1, T).astype(jnp.float32)
    lut_r, bias_p = prep_svm_lookup(lut, bias, chunk_f=chunk_f)
    return ClassifyFusedOperands(
        fid=fid_p, cv=cv, cm=cm, flo=flo, fhi=fhi, bitpk=bitpk,
        validpk=validpk, pred_codes=pred_codes.astype(jnp.uint32), plab=plab,
        pvalidpk=pvalidpk, weights=w_r, lut=lut_r, bias=bias_p)

"""Pallas TPU kernel: fused multi-layer tree walk (``dt_layer`` × L in one
launch).

The layerwise path launches one ``tcam_match`` kernel per tree layer
(``lax.scan`` over L ``pallas_call``s), re-streaming the packet feature block
from HBM every layer — the per-stage partitioning overhead SpliDT
(arXiv:2509.00397) identifies for staged tree traversal.  This kernel
collapses the scan into **one** ``pallas_call`` that walks the layer axis
*inside* the kernel with a ``fori_loop`` over layer-indexed table blocks:

  1. *feature select, all layers at once* — the per-entry one-hot feature
     indirection for every layer is a single MXU matmul
     ``fv_all = feats @ fsel^T`` with ``fsel`` flattened to
     ``[L * E_pad, F_pad]``; the product stays VMEM-resident for the whole
     walk (one HBM read of the feature tile per classify, not per layer).
  2. *layer walk* — a ``fori_loop`` carries the status codes; step ``l``
     slices layer ``l``'s entries ``[E_pad]`` from the VMEM-resident table
     blocks ``[L, E_pad]`` and applies the same ternary compare + priority
     encode as ``tcam_match`` (masked code equality, range compare,
     exclusive-cumsum first-match).
  3. *version merge* — as in the layerwise kernel, the grid's innermost
     dimension sweeps versions; each step walks *all* L layers with version
     ``v``'s tables and merges the final codes for packets whose ``vid``
     matches (a no-hit walk leaves codes unchanged, preserving the TCAM
     fall-through contract per layer).

Operand prep is **install-time** work: the one-hot ``fsel`` matrix and the
no-match-padded entry blocks only change when a model is (un)installed, so
the plane precomputes them once per slot write (``tiling.prep_tree_walk``,
held in the engine's ``ExecImage``) and binds them via ``prep=``.  Without
``prep=`` this wrapper runs the same prep per call — the standalone/test
path — streaming O(V·L·E·F) extra HBM bytes per classify.

Grid: (batch blocks, trees, versions) — exactly **one** launch per classify,
vs ``L`` for the layerwise scan.  Per-step VMEM (block_b=256, L=32,
E_pad=128, F_pad=128): feats 128 KiB + fsel 2 MiB + fv_all 4 MiB + entry
blocks 6·16 KiB ≈ 6.2 MiB — under the 16 MiB budget; ``block_b`` is halved
automatically when L·E_pad would overflow it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import TreeWalkOperands, prep_tree_walk, pad_to

__all__ = ["tree_walk_pallas_v"]


def _kernel(codes_ref, vid_ref, feats_ref, fsel_ref, cv_ref, cm_ref, flo_ref,
            fhi_ref, bit_ref, valid_ref, shift_ref, out_ref, *, n_layers: int,
            e_pad: int):
    v = pl.program_id(2)
    codes0 = codes_ref[...]                     # [Bb, 1] uint32

    @pl.when(v == 0)
    def _passthrough():
        out_ref[...] = codes0

    feats = feats_ref[...]                      # [Bb, F_pad] f32
    fsel = fsel_ref[0, 0]                       # [L*E_pad, F_pad] f32
    # One MXU pass selects the tested feature value for every (layer, entry);
    # the [Bb, L*E_pad] product then stays resident across the whole walk.
    fv_all = jnp.dot(feats, fsel.T, preferred_element_type=jnp.float32)

    def layer(l, codes):
        off = pl.multiple_of(l * e_pad, e_pad)
        fv = jax.lax.dynamic_slice_in_dim(fv_all, off, e_pad, axis=1)
        cv = cv_ref[0, l, 0][None, :]           # [1, E_pad] uint32
        cm = cm_ref[0, l, 0][None, :]
        flo = flo_ref[0, l, 0][None, :]         # [1, E_pad] f32
        fhi = fhi_ref[0, l, 0][None, :]
        valid = valid_ref[0, l, 0][None, :]
        code_ok = (codes & cm) == cv            # [Bb, E_pad]
        ok = code_ok & (fv >= flo) & (fv <= fhi) & (valid != 0)
        # Priority encode: first (== highest-priority) match only.
        first = ok & (jnp.cumsum(ok.astype(jnp.int32), axis=1) == 1)
        bit = jnp.sum(jnp.where(first, bit_ref[0, l, 0][None, :], 0), axis=1,
                      keepdims=True)
        hit = ok.any(axis=1, keepdims=True)
        shift = shift_ref[0, l].astype(jnp.uint32)
        new = codes | (bit.astype(jnp.uint32) << shift)
        return jnp.where(hit, new, codes)

    codes = jax.lax.fori_loop(0, n_layers, layer, codes0)
    mine = vid_ref[...] == v                    # [Bb, 1]
    out_ref[...] = jnp.where(mine, codes, out_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def tree_walk_pallas_v(
    codes: jax.Array,      # uint32 [B, T]
    features: jax.Array,   # int32 [B, F]
    vid: jax.Array,        # int32 [B] model version per packet, in [0, V)
    code_value: jax.Array,  # uint32 [V, L, T, E]
    code_mask: jax.Array,
    fid: jax.Array,         # int32 [V, L, T, E]
    f_lo: jax.Array,
    f_hi: jax.Array,
    set_bit: jax.Array,     # uint32 [V, L, T, E]
    valid: jax.Array,       # bool [V, L, T, E]
    layer_shift: jax.Array,  # int32 [L] status-code bit per layer
    *,
    prep: TreeWalkOperands | None = None,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, T = codes.shape
    V, L, _, _ = code_value.shape

    feats = pad_to(features.astype(jnp.float32), 1, 128)
    F_pad = feats.shape[1]
    if prep is None:
        # Per-call fallback (standalone/test path): the same prep the plane
        # runs once per install and binds via ``prep=`` (tiling.prep_tree_walk).
        prep = prep_tree_walk(code_value, code_mask, fid, f_lo, f_hi, set_bit,
                              valid, F_pad)
    fsel, cv, cm, flo, fhi, bit, vld = prep
    E_pad = cv.shape[3]
    if fsel.shape != (V, T, L * E_pad, F_pad):
        raise ValueError(
            f"prepped fsel shape {fsel.shape} does not match this launch "
            f"(expected {(V, T, L * E_pad, F_pad)}) — the exec image was "
            "built for a different profile or feature width")

    # Keep the per-step fv_all product inside VMEM: the [block_b, L*E_pad]
    # tile is the largest resident array, so shrink the batch tile as the
    # fused layer axis grows.
    while block_b > 8 and block_b * L * E_pad * 4 > 4 * 1024 * 1024:
        block_b //= 2

    codes_p = pad_to(codes, 0, block_b)
    feats_p = pad_to(feats, 0, block_b)
    vid_p = pad_to(vid.astype(jnp.int32).reshape(-1, 1), 0, block_b, fill=-1)
    B_pad = codes_p.shape[0]
    grid = (B_pad // block_b, T, V)

    out = pl.pallas_call(
        functools.partial(_kernel, n_layers=L, e_pad=E_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda i, t, v: (i, t)),       # codes
            pl.BlockSpec((block_b, 1), lambda i, t, v: (i, 0)),       # vid
            pl.BlockSpec((block_b, F_pad), lambda i, t, v: (i, 0)),   # feats
            pl.BlockSpec((1, 1, L * E_pad, F_pad),
                         lambda i, t, v: (v, t, 0, 0)),               # fsel
            pl.BlockSpec((1, L, 1, E_pad), lambda i, t, v: (v, 0, t, 0)),  # cv
            pl.BlockSpec((1, L, 1, E_pad), lambda i, t, v: (v, 0, t, 0)),  # cm
            pl.BlockSpec((1, L, 1, E_pad), lambda i, t, v: (v, 0, t, 0)),  # flo
            pl.BlockSpec((1, L, 1, E_pad), lambda i, t, v: (v, 0, t, 0)),  # fhi
            pl.BlockSpec((1, L, 1, E_pad), lambda i, t, v: (v, 0, t, 0)),  # bit
            pl.BlockSpec((1, L, 1, E_pad), lambda i, t, v: (v, 0, t, 0)),  # valid
            pl.BlockSpec((1, L), lambda i, t, v: (0, 0)),             # shift
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, t, v: (i, t)),
        out_shape=jax.ShapeDtypeStruct((B_pad, T), codes.dtype),
        interpret=interpret,
    )(codes_p, vid_p, feats_p, fsel, cv, cm, flo, fhi, bit, vld,
      layer_shift.reshape(1, L).astype(jnp.int32))
    return out[:B]

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
``jax.jit(step, in_shardings=..., out_shardings=...).lower(**specs).compile()``
must succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh for
every assigned architecture x input shape; ``memory_analysis()`` proves fit,
``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--set tokens_per_device=4096]

Results land in benchmarks/results/dryrun/<arch>__<shape>__<pods>pod.json.
"""
# The 512 placeholder devices MUST be configured before jax (or anything that
# imports jax) is imported — jax locks the device count on first init.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.hlocost import parse_hlo_cost  # noqa: E402
from repro.analysis.roofline import (  # noqa: E402
    HW,
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)
from repro.configs import ARCH_IDS, SHAPES, applicable, get_config  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    batch_spec,
    dp_axes,
    opt_specs,
    param_specs,
    state_specs,
)
from repro.launch.mesh import make_production_mesh, mesh_chip_count  # noqa: E402
from repro.models.common import ArchConfig  # noqa: E402
from repro.models.transformer import init_decode_state, init_params_shape  # noqa: E402
from repro.optim.adamw import AdamWConfig, adamw_init  # noqa: E402
from repro.serving.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.step import make_train_step, microbatch_plan  # noqa: E402

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(__file__)))),
    "benchmarks", "results", "dryrun",
)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ArchConfig, shape_name: str, *, n_micro: int = 1,
                global_batch: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    sp = SHAPES[shape_name]
    S, B = sp.seq_len, global_batch or sp.global_batch
    if sp.kind == "train":
        B_mb = B // n_micro
        batch = {"tokens": _i32(n_micro, B_mb, S), "labels": _i32(n_micro, B_mb, S)}
        if cfg.family == "encdec":
            batch["enc_inputs"] = jax.ShapeDtypeStruct(
                (n_micro, B_mb, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        return batch
    if sp.kind == "prefill":
        batch = {"tokens": _i32(B, S)}
        if cfg.family == "encdec":
            batch["enc_inputs"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        return batch
    # decode: one token against a cache of S
    return {"tokens": _i32(B, 1), "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def _analytic_param_bytes_per_device(shapes, specs, mesh) -> float:
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf_bytes(sd, spec):
        n = 1
        for d in sd.shape:
            n *= d
        shards = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shards *= mesh_shape.get(a, 1)
        return n * sd.dtype.itemsize / shards

    leaves = jax.tree.leaves(
        jax.tree.map(leaf_bytes, shapes, specs,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)))
    return float(sum(leaves))


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool,
                  overrides: dict | None = None, cfg: ArchConfig | None = None,
                  unroll: bool = False):
    """Returns (lowered, meta) for one cell.  ``cfg``/``unroll`` support the
    scan-correction probes (unrolled reduced-layer variants)."""
    overrides = overrides or {}
    cfg = cfg or get_config(arch)
    cfg_kw = {}
    if "moe_impl" in overrides:
        cfg_kw["moe_impl"] = str(overrides["moe_impl"])
    if "attn_k_chunk" in overrides:
        cfg_kw["attn_k_chunk"] = int(overrides["attn_k_chunk"])
    if "capacity_factor" in overrides:
        cfg_kw["capacity_factor"] = float(overrides["capacity_factor"])
    if "attn_mxu_native" in overrides:
        cfg_kw["attn_mxu_native"] = bool(int(overrides["attn_mxu_native"]))
    if cfg_kw:
        cfg = cfg.scaled(**cfg_kw)
    sp = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = 1
    for a in dp_axes(multi_pod):
        dp_total *= mesh_shape.get(a, 1)

    pspecs = param_specs(cfg, mesh)
    pshapes = init_params_shape(cfg)
    pshard = _ns(mesh, pspecs)
    meta = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "chips": chips, "kind": sp.kind}

    if sp.kind == "train":
        tpd = int(overrides.get("tokens_per_device", 8192 if cfg.d_model <= 4096 else 4096))
        n_micro = int(overrides.get(
            "n_micro", microbatch_plan(cfg, sp.seq_len, sp.global_batch, dp_total,
                                       tokens_per_device=tpd)))
        state_dtype = overrides.get(
            "state_dtype", "bfloat16" if cfg.param_count() > 150e9 else "float32")
        opt_cfg = AdamWConfig(state_dtype=state_dtype)
        oshapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), pshapes)
        ospecs = opt_specs(pspecs)
        oshard = _ns(mesh, ospecs)
        q_chunk = int(overrides.get("q_chunk", 0))
        step = make_train_step(cfg, opt_cfg, n_micro=n_micro, q_chunk=q_chunk,
                               remat=bool(overrides.get("remat", True)),
                               has_enc=cfg.family == "encdec", unroll=unroll,
                               grad_specs=pspecs)
        bshapes = input_specs(cfg, shape_name, n_micro=n_micro,
                              global_batch=overrides.get("probe_global_batch"))
        bspec = batch_spec(multi_pod, n_micro=True)
        bshard = {k: NamedSharding(mesh, bspec) for k in bshapes}
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        meta.update(n_micro=n_micro, state_dtype=state_dtype,
                    tokens_per_device=tpd, q_chunk=q_chunk)
        with mesh:
            lowered = jitted.lower(pshapes, oshapes, bshapes)
        opt_bytes = _analytic_param_bytes_per_device(oshapes["m"], pspecs, mesh) * 2
        meta["analytic_bytes_per_device"] = (
            _analytic_param_bytes_per_device(pshapes, pspecs, mesh) * 2  # p + grads
            + opt_bytes)
        return lowered, meta

    if sp.kind == "prefill":
        q_chunk = int(overrides.get("q_chunk", 1024))
        prefill = make_prefill_step(cfg, q_chunk=q_chunk, unroll=unroll)
        bshapes = input_specs(cfg, shape_name)
        dp = dp_axes(multi_pod)
        tshard = NamedSharding(mesh, P(dp, None))
        in_sh = (pshard, tshard)
        args = (pshapes, bshapes["tokens"])
        if cfg.family == "encdec":
            in_sh = (pshard, tshard, NamedSharding(mesh, P(dp, None, None)))
            args = args + (bshapes["enc_inputs"],)
        vocab_ok = cfg.vocab % mesh_shape.get("model", 1) == 0
        out_spec = P(dp, None, "model" if vocab_ok else None)
        jitted = jax.jit(prefill, in_shardings=in_sh,
                         out_shardings=NamedSharding(mesh, out_spec))
        meta.update(q_chunk=q_chunk)
        with mesh:
            lowered = jitted.lower(*args)
        meta["analytic_bytes_per_device"] = _analytic_param_bytes_per_device(
            pshapes, pspecs, mesh)
        return lowered, meta

    # decode
    step = make_decode_step(cfg, unroll=unroll)
    sshapes = jax.eval_shape(
        lambda: init_decode_state(cfg, sp.global_batch, sp.seq_len))
    sspecs = state_specs(cfg, mesh, multi_pod, batch=sp.global_batch,
                         cache_len=sp.seq_len,
                         split_kv=bool(int(overrides.get("split_kv", 1))))
    sshard = _ns(mesh, sspecs)
    bshapes = input_specs(cfg, shape_name)
    dp = dp_axes(multi_pod)
    dp_ok = sp.global_batch % dp_total == 0 and sp.global_batch > 1
    tshard = NamedSharding(mesh, P(dp, None) if dp_ok else P(None, None))
    jitted = jax.jit(
        step,
        in_shardings=(pshard, sshard, tshard, NamedSharding(mesh, P())),
        out_shardings=(None, sshard),
        donate_argnums=(1,),
    )
    with mesh:
        lowered = jitted.lower(pshapes, sshapes, bshapes["tokens"], bshapes["pos"])
    meta["analytic_bytes_per_device"] = (
        _analytic_param_bytes_per_device(pshapes, pspecs, mesh)
        + _analytic_param_bytes_per_device(sshapes, sspecs, mesh))
    return lowered, meta


def _probe_cfg(cfg: ArchConfig, units: int) -> ArchConfig:
    """Reduced-layer same-width config for the scan-correction probes."""
    if cfg.family == "hybrid":
        return cfg.scaled(n_layers=3 * units)
    if cfg.family == "encdec":
        return cfg.scaled(n_layers=units, n_enc_layers=units)
    return cfg.scaled(n_layers=units)


def _scan_units(cfg: ArchConfig) -> float:
    if cfg.family == "hybrid":
        return cfg.n_layers / 3.0   # 26 layers ~ 8.67 superblock units
    return float(cfg.n_layers)


def _probe_costs(arch, shape_name, multi_pod, overrides, cfg, n_micro_real):
    """Lower UNROLLED reduced-layer variants; XLA then counts every op, so a
    linear model T(u, m) = f_opt + m*(f_fix + u*f_layer) reconstructs the
    true full-model cost (design note: 'cost_analysis FLOPs for while-loops
    are scaled by trip count where XLA does not')."""
    sp = SHAPES[shape_name]
    B_mb = sp.global_batch // max(n_micro_real, 1)

    def one(units, n_micro):
        ov = dict(overrides or {})
        if sp.kind == "prefill":
            # The q-chunk scan is a while loop the probe would count once;
            # probe unchunked instead (same total attention flops/traffic).
            ov["q_chunk"] = 0
        if sp.kind == "train":
            # Probe at the *real per-microbatch* global batch so the unrolled
            # micro-scan body matches the real cell's body exactly.
            ov["n_micro"] = n_micro
            ov["probe_global_batch"] = B_mb * n_micro
        lowered, _ = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                   overrides=ov, cfg=_probe_cfg(cfg, units),
                                   unroll=True)
        comp = lowered.compile()
        txt = comp.as_text()
        cost = parse_hlo_cost(txt)          # exact on unrolled modules
        coll = collective_bytes_from_hlo(txt)
        return (cost["matmul_flops"], cost["traffic_bytes"], float(coll["total"]))

    U = _scan_units(cfg)
    # Probe at u in {2, 3}: the u=1 module triggers anomalous GSPMD layout
    # choices (observed: higher flops/traffic than u=2), while u=2 -> 3 is
    # linear and matches the analytic per-layer estimate.
    if sp.kind == "train":
        t21 = one(2, 1)
        t31 = one(3, 1)
        t22 = one(2, 2)
        out = {}
        for i, key in enumerate(("flops", "bytes", "collective")):
            f_lay = max(t31[i] - t21[i], 0.0)
            f_fix = max(t22[i] - t21[i] - 2.0 * f_lay, 0.0)
            f_opt = max(t21[i] - f_fix - 2.0 * f_lay, 0.0)
            out[key] = f_opt + n_micro_real * (f_fix + U * f_lay)
        return out
    t2 = one(2, 1)
    t3 = one(3, 1)
    out = {}
    for i, key in enumerate(("flops", "bytes", "collective")):
        f_lay = max(t3[i] - t2[i], 0.0)
        f_fix = max(t2[i] - 2.0 * f_lay, 0.0)
        out[key] = f_fix + U * f_lay
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overrides: dict | None = None, out_dir: str = RESULTS_DIR,
             hw: HW = HW(), tag: str = "", probes: bool = True) -> dict:
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    pods = 2 if multi_pod else 1
    rec: dict = {"arch": arch, "shape": shape_name, "pods": pods}
    if not ok:
        rec.update(status="skip", reason=why)
    else:
        try:
            t0 = time.perf_counter()
            lowered, meta = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                          overrides=overrides)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0
            hlo_text = compiled.as_text()
            raw_cost = parse_hlo_cost(hlo_text)  # scan bodies counted once
            flops = raw_cost["matmul_flops"]
            bytes_acc = raw_cost["traffic_bytes"]
            try:
                ma = compiled.memory_analysis()
                mem = {
                    "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                    "output_bytes": getattr(ma, "output_size_in_bytes", None),
                    "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                    "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
                }
            except Exception as e:  # CPU backend may not support it
                mem = {"error": str(e)}
            coll = collective_bytes_from_hlo(hlo_text)
            sp = SHAPES[shape_name]
            chips = meta["chips"]
            # Scan bodies are costed once by XLA (verified); reconstruct the
            # true per-device cost from unrolled reduced-layer probes.
            # Roofline table is single-pod only, so probes run there only.
            corrected = None
            if probes and not multi_pod:
                corrected = _probe_costs(arch, shape_name, multi_pod,
                                         overrides, cfg,
                                         meta.get("n_micro", 1))
            c_flops = corrected["flops"] if corrected else flops
            c_bytes = corrected["bytes"] if corrected else bytes_acc
            c_coll = corrected["collective"] if corrected else coll["total"]
            rl = roofline_terms(
                hlo_flops=c_flops, hlo_bytes=c_bytes,
                collective_wire_bytes=c_coll, chips=chips, hw=hw)
            mf = model_flops(cfg, sp.seq_len, sp.global_batch, sp.kind)
            rec.update(
                status="ok", meta=meta, t_lower_s=round(t_lower, 2),
                t_compile_s=round(t_compile, 2),
                hlo_flops_raw=flops, hlo_bytes_raw=bytes_acc,
                hlo_flops_per_device=c_flops, hlo_bytes_per_device=c_bytes,
                collectives=coll, collective_wire_bytes=c_coll,
                memory=mem, roofline=rl,
                model_flops_total=mf,
                useful_flops_ratio=(mf / (c_flops * chips)) if c_flops else None,
                overrides=overrides or {},
            )
        except Exception as e:
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-2000:])
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{pods}pod{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="perf override key=value (tokens_per_device, q_chunk, "
                         "n_micro, state_dtype, remat)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = v if not v.replace(".", "").lstrip("-").isdigit() else (
            float(v) if "." in v else int(v))

    cells = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    elif args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    else:
        ap.error("--all or (--arch and --shape)")
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for arch, shape in cells:
        for mp in meshes:
            t0 = time.perf_counter()
            rec = run_cell(arch, shape, multi_pod=mp, overrides=overrides,
                           out_dir=args.out, tag=args.tag)
            status = rec["status"]
            extra = rec.get("reason", rec.get("error", ""))
            dom = rec.get("roofline", {}).get("dominant", "")
            print(f"[{time.strftime('%H:%M:%S')}] {arch:24s} {shape:12s} "
                  f"{'2pod' if mp else '1pod'} -> {status:5s} {dom:10s} "
                  f"({time.perf_counter()-t0:.1f}s) {extra[:90]}", flush=True)


if __name__ == "__main__":
    main()

"""Production mesh builders (functions, not module constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_chip_count"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (TPU v5e); the multi-pod mesh adds a leading pure-DP
    'pod' axis of 2 (DCN-connected pods).

    The single-pod mesh explicitly takes the first 256 of the (512 emulated)
    devices so both meshes can be built in one dry-run process.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n

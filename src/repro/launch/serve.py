"""Serving launcher: batched fixed-shape decode with weight hot-swap.

The serving engine follows the ACORN discipline: compile once per
(arch, batch, cache_len); model/tenant swaps are weight-array updates with
zero retrace (asserted at runtime).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
      --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--swaps", type=int, default=2,
                    help="simulated tenant/model-version swaps")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_config
    from repro.models import decode_step, init_decode_state, init_params
    from repro.models.transformer import encode_kv
    from repro.serving.serve import greedy_decode

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    B, P = args.batch, args.prompt_len
    cache = P + args.gen
    step = jax.jit(lambda p, s, t, pos: decode_step(p, s, t, pos, cfg))

    for tenant in range(args.swaps):
        params = init_params(cfg, jax.random.key(tenant))
        prompts = jax.random.randint(jax.random.key(100 + tenant), (B, P), 0,
                                     cfg.vocab)
        state = init_decode_state(cfg, B, cache)
        if cfg.family == "encdec":
            enc = jnp.zeros((B, cfg.enc_seq, cfg.d_model), cfg.jdtype)
            state["ek"], state["ev"] = encode_kv(params, enc, cfg)
        t0 = time.perf_counter()
        logits = None
        for t in range(P):
            logits, state = step(params, state, prompts[:, t:t + 1], jnp.int32(t))
        first = jnp.argmax(logits[:, -1], -1)[:, None].astype(prompts.dtype)
        toks = greedy_decode(params, state, first, jnp.int32(P), cfg, args.gen)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        print(f"tenant {tenant}: {B}x({P} prefill + {args.gen} decode) in "
              f"{dt*1e3:.0f} ms ({B*args.gen/dt:.0f} tok/s) "
              f"traces={step._cache_size()}")
    assert step._cache_size() == 1, "weight swap must not retrace"
    print(f"served {args.swaps} tenants through ONE compiled decode step")


if __name__ == "__main__":
    main()

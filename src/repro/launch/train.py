"""Production train launcher: mesh + sharded train step + fault tolerance.

On real hardware this binds jax.distributed, builds the production mesh,
and runs the pjit'd step with async checkpoints, cursor-exact data resume,
straggler timing, and optional cross-pod int8 gradient compression.  In this
container it runs the same code path on the CPU device count available
(smoke scale) — the full-scale path is exercised by dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --steps 50 --smoke [--ckpt-dir /tmp/ck] [--resume]

Production XLA flags (latency-hiding scheduler / collective overlap) are in
PRODUCTION_XLA_FLAGS — plumbed to the real launcher environment.
"""
from __future__ import annotations

import argparse
import os
import time

PRODUCTION_XLA_FLAGS = " ".join([
    "--xla_tpu_enable_latency_hiding_scheduler=true",
    "--xla_tpu_megacore_fusion_allow_ags=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices (CPU container)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--straggler-warn-ms", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_config
    from repro.data import TokenPipeline
    from repro.models import init_params
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.checkpoint import Checkpointer
    from repro.train.step import make_train_step

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ocfg = AdamWConfig(lr=3e-3 if args.smoke else 3e-4, warmup_steps=10,
                       total_steps=max(args.steps, 100),
                       state_dtype="bfloat16" if cfg.param_count() > 150e9
                       else "float32")
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params, ocfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab, seq_len=args.seq,
                         global_batch=args.global_batch,
                         shard=jax.process_index(),
                         num_shards=jax.process_count())
    step_fn = jax.jit(make_train_step(cfg, ocfg, n_micro=args.n_micro,
                                      has_enc=cfg.family == "encdec"))
    ck = Checkpointer(args.ckpt_dir or f"/tmp/acorn_{args.arch}_ck", keep=3)
    start = 0
    if args.resume:
        try:
            start, params, opt, extra = ck.restore(params, opt)
            pipe.load_state_dict(extra["data"])
            print(f"resumed from step {start}")
        except FileNotFoundError:
            print("no checkpoint found; cold start")

    def batch():
        b = pipe.next_batch()
        out = {
            "tokens": jnp.asarray(b["tokens"]).reshape(args.n_micro, -1, args.seq),
            "labels": jnp.asarray(b["labels"]).reshape(args.n_micro, -1, args.seq),
        }
        if cfg.family == "encdec":
            B_mb = out["tokens"].shape[1]
            out["enc_inputs"] = jnp.zeros(
                (args.n_micro, B_mb, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        return out

    times = []
    for s in range(start + 1, args.steps + 1):
        t0 = time.perf_counter()
        params, opt, m = step_fn(params, opt, batch())
        m["loss"].block_until_ready()
        dt = time.perf_counter() - t0
        times.append(dt)
        # straggler hook: flag steps slower than the trailing median
        if args.straggler_warn_ms and len(times) > 5:
            med = sorted(times[-20:])[len(times[-20:]) // 2]
            if dt > med + args.straggler_warn_ms / 1e3:
                print(f"[straggler] step {s}: {dt*1e3:.0f} ms vs median "
                      f"{med*1e3:.0f} ms")
        if s % 10 == 0 or s == args.steps:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} {dt*1e3:.0f} ms")
        if s % args.ckpt_every == 0 or s == args.steps:
            ck.save(s, params, opt, extra={"data": pipe.state_dict()})
    ck.wait()
    print(f"done at step {args.steps}; checkpoints in {ck.dir}")


if __name__ == "__main__":
    main()

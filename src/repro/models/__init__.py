"""LM substrate: the 10 assigned architectures as composable JAX modules.

Pure-functional models: parameters are pytrees of arrays (or
ShapeDtypeStructs for the dry-run), layers are stacked on a leading axis and
executed with ``lax.scan`` so the HLO stays small at 94 layers, and every
entry point is a plain function — ``pjit``-able with the sharding rules in
``repro.distributed.sharding``.
"""
from repro.models.common import ArchConfig
from repro.models.transformer import (
    init_params,
    init_params_shape,
    forward,
    decode_step,
    init_decode_state,
)

__all__ = [
    "ArchConfig",
    "init_params",
    "init_params_shape",
    "forward",
    "decode_step",
    "init_decode_state",
]

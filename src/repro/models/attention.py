"""GQA attention: full-causal, sliding-window, q-chunked (long prefill),
and single-token decode against a KV cache.

All variants take q [B, S, Hq, D], k/v [B, T, Hkv, D] and fold the GQA group
into the head axis with a reshape (no materialized repeat).  The q-chunked
path bounds the logits working set to [B, Hq, chunk, T] — the memory lever
for 32k prefill (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gqa_attention", "decode_attention"]

_NEG = -1e30


def _logits_mask(S: int, T: int, offset: int, window: int) -> jax.Array:
    """Causal (+ optional sliding window) mask [S, T]; query i sits at
    absolute position offset+i, keys at 0..T-1."""
    qpos = offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def gqa_attention(
    q: jax.Array,            # [B, S, Hq, D]
    k: jax.Array,            # [B, T, Hkv, D]
    v: jax.Array,            # [B, T, Hkv, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    q_chunk: int = 0,        # 0 = single-shot; >0 = scan over query chunks
    k_chunk: int = 0,        # >0 = online-softmax over key chunks ("flash")
) -> jax.Array:
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, S, Hkv, G, D)

    def block(q_blk, offset):
        # q_blk [B, s, Hkv, G, D] -> out [B, s, Hkv, G, D]
        logits = jnp.einsum(
            "bshgd,bthd->bhgst", q_blk.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        s = q_blk.shape[1]
        if causal:
            m = _logits_mask(s, T, offset, window)
            logits = jnp.where(m[None, None, None], logits, _NEG)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))

    def block_online(q_blk, offset):
        """FlashAttention-style: scan over key chunks with running
        (max, denominator, accumulator) — the [s, T] logits never exist as
        one tensor, which is exactly what the fused TPU kernel guarantees
        (HBM traffic drops from O(S*T) to O(S*D))."""
        s = q_blk.shape[1]
        nk = T // k_chunk
        qf = q_blk.astype(jnp.float32)
        kc = k.reshape(B, nk, k_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(B, nk, k_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

        def kstep(carry, xs):
            m, l, acc = carry
            k_b, v_b, j = xs
            logits = jnp.einsum(
                "bshgd,bthd->bhgst", qf, k_b.astype(jnp.float32)) * scale
            if causal:
                qpos = offset + jnp.arange(s)[:, None]
                kpos = j * k_chunk + jnp.arange(k_chunk)[None, :]
                msk = kpos <= qpos
                if window > 0:
                    msk &= kpos > qpos - window
                logits = jnp.where(msk[None, None, None], logits, _NEG)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgst,bthd->bshgd", p, v_b.astype(jnp.float32))
            acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, s), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, s), jnp.float32)
        a0 = jnp.zeros((B, s, Hkv, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kstep, (m0, l0, a0),
                                      (kc, vc, jnp.arange(nk)))
        return acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]

    blk = block_online if (k_chunk and T % k_chunk == 0) else block
    if q_chunk and S > q_chunk and S % q_chunk == 0:
        n = S // q_chunk
        qc = qg.reshape(B, n, q_chunk, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)

        def step(_, xs):
            q_blk, i = xs
            return None, blk(q_blk, q_offset + i * q_chunk)

        _, out = jax.lax.scan(step, None, (qc, jnp.arange(n)))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hq, D)
    else:
        out = blk(qg, q_offset).reshape(B, S, Hq, D)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,        # [B, 1, Hq, D]
    k_cache: jax.Array,  # [B, T, Hkv, D]
    v_cache: jax.Array,
    kv_len: jax.Array,   # int32 [B] — valid entries (new token already written)
    *,
    window: int = 0,
    mxu_native: bool = False,
) -> jax.Array:
    """One-token GQA decode. With ``window>0`` the cache is a ring buffer of
    size ``window`` and every slot is valid once warm.

    ``mxu_native``: feed the matmuls bf16 operands with f32 accumulation
    (what the MXU does natively) instead of materializing f32 copies of the
    whole cache — §Perf decode lever, numerics validated in tests.
    """
    B, _, Hq, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    if mxu_native:
        logits = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                            preferred_element_type=jnp.float32) * scale
    else:
        logits = jnp.einsum(
            "bhgd,bthd->bhgt", qg.astype(jnp.float32),
            k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(T)[None, :] < kv_len[:, None]
    logits = jnp.where(mask[:, None, None], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    if mxu_native:
        out = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhgt,bthd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)

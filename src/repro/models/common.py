"""Shared pieces: arch config, norms, RoPE, initializers."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ArchConfig", "rms_norm", "rope", "apply_rope", "dense_init", "DTYPES"]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture = one instance of this config (src/repro/configs/)."""

    name: str
    family: str                 # dense | moe | hybrid | rwkv | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    rope_theta: float = 1_000_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- hybrid (RecurrentGemma): layer i is attention iff i % 3 == 2 ---
    window: int = 0             # local-attention window (0 = full causal)
    lru_dim: int = 0            # RG-LRU recurrence width
    conv_width: int = 4
    # --- enc-dec (whisper): frontend is a STUB; encoder sees frame embeds ---
    n_enc_layers: int = 0
    enc_seq: int = 0
    # --- compute / perf levers (EXPERIMENTS.md §Perf) ---
    moe_impl: str = "onehot"     # "sort" = sort-based dispatch (beyond-paper)
    attn_k_chunk: int = 0        # >0 = online-softmax (flash) attention
    attn_mxu_native: bool = False  # bf16 matmul inputs + f32 accumulation
    dtype: str = "bfloat16"
    # long_500k applicability: sub-quadratic families only (DESIGN.md)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return DTYPES[self.dtype]

    def scaled(self, **kw) -> "ArchConfig":
        """Reduced config of the same family (per-arch smoke tests)."""
        return dataclasses.replace(self, **kw)

    # -------- parameter count (MODEL_FLOPS = 6*N*D in the roofline) --------
    def param_count(self) -> int:
        D, V = self.d_model, self.vocab
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv
        n = V * D  # embedding (tied head also counted once below if untied)
        n += V * D  # lm head (untied)
        per_layer_attn = D * (Hq * hd) + 2 * D * (Hkv * hd) + (Hq * hd) * D
        if self.family == "dense" or self.family == "encdec":
            per_layer = per_layer_attn + 3 * D * self.d_ff + 2 * D
            n += self.n_layers * per_layer
            if self.family == "encdec":
                # encoder layers + decoder cross-attention
                n += self.n_enc_layers * (per_layer_attn + 3 * D * self.d_ff + 2 * D)
                n += self.n_layers * per_layer_attn  # cross-attn blocks
        elif self.family == "moe":
            per_layer = per_layer_attn + 3 * D * self.moe_d_ff * self.n_experts
            per_layer += D * self.n_experts + 2 * D  # router + norms
            n += self.n_layers * per_layer
        elif self.family == "hybrid":
            n_attn = self.n_layers // 3
            n_rec = self.n_layers - n_attn
            rec_layer = 2 * D * self.lru_dim + self.lru_dim * D  # in gate(x2) + out
            rec_layer += self.conv_width * self.lru_dim + 2 * self.lru_dim * self.lru_dim  # conv + gates
            n += n_attn * per_layer_attn + n_rec * rec_layer
            n += self.n_layers * (3 * D * self.d_ff + 2 * D)
        elif self.family == "rwkv":
            tm = 5 * D * D + 2 * D * (D // 16)  # wr,wk,wv,wg,wo + decay lora
            cm = D * D + 2 * D * self.d_ff      # cr + ck + cv
            n += self.n_layers * (tm + cm + 2 * D)
        return int(n)

    def active_param_count(self) -> int:
        """MoE: experts replaced by top_k-worth of FFN compute."""
        if self.family != "moe":
            return self.param_count()
        D = self.d_model
        dense_like = self.param_count()
        dense_like -= self.n_layers * 3 * D * self.moe_d_ff * self.n_experts
        dense_like += self.n_layers * 3 * D * self.moe_d_ff * self.top_k
        return int(dense_like)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [..., S] -> (sin, cos) [..., S, head_dim/2]."""
    freqs = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * freqs[None, :]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., S, H, D]; sin/cos [..., S, D/2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)

"""Mixture-of-Experts FFN with capacity-buffer dispatch (GShard/Switch style).

Top-k routing + one-hot dispatch/combine einsums: XLA-SPMD-friendly (static
shapes, experts shardable over the ``model`` axis = expert parallelism).
Tokens over capacity are dropped (standard capacity-factor semantics); the
router adds the usual load-balancing auxiliary loss.

Used by grok-1 (8e top-2, d_ff 32768) and qwen3-moe (128e top-8, d_ff 1536).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn", "router_dispatch"]


def router_dispatch(logits: jax.Array, top_k: int, capacity: int):
    """logits [T, E] -> (dispatch [T, E, C] bool-ish, combine [T, E, C] f32, aux).

    Position-in-expert via cumsum over (token, k) arrival order; tokens whose
    slot >= capacity are dropped.
    """
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # [T, k, E]
    # arrival order: k-slot-major within token, tokens in order
    flat = onehot.reshape(T * top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(T, top_k, E)
    pos = (pos_in_expert * onehot).sum(-1)                     # [T, k]
    keep = pos < capacity
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    disp_k = onehot[..., None] * pos_oh[:, :, None, :] * keep[..., None, None]
    dispatch = disp_k.sum(axis=1)                              # [T, E, C]
    combine = (disp_k * gate_vals[..., None, None]).sum(axis=1)
    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    f = onehot.sum(axis=(0, 1)) / (T * top_k)                  # fraction routed
    p = probs.mean(axis=0)
    aux = E * jnp.sum(f * p)
    return dispatch, combine, aux


def moe_ffn(x: jax.Array, params: dict, *, top_k: int, capacity_factor: float,
            impl: str = "onehot"):
    """x [B, S, D]; params: router [D, E], wg/wu [E, D, F], wd [E, F, D].

    impl="onehot": GShard-style dense dispatch/combine einsums — simple and
    SPMD-safe, but the [T, E, C] contractions cost O(T*E*C*D) extra FLOPs.
    impl="sort":   beyond-paper sort-based dispatch (argsort by expert +
    scatter into per-expert buffers + gather-combine) — expert matmuls only;
    verified equal to onehot in tests/test_moe_impl.py.
    """
    if impl == "sort":
        return _moe_ffn_sort(x, params, top_k=top_k,
                             capacity_factor=capacity_factor)
    if impl == "sort_sharded":
        return _moe_ffn_sort(x, params, top_k=top_k,
                             capacity_factor=capacity_factor,
                             shard_buffers=True)
    B, S, D = x.shape
    E = params["router"].shape[1]
    T = B * S
    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    capacity = max(int(T * top_k / E * capacity_factor), 1)
    dispatch, combine, aux = router_dispatch(logits, top_k, capacity)

    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), xt)  # [E, C, D]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xe, params["wu"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, params["wd"])              # [E, C, D]
    y = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), ye)
    return y.reshape(B, S, D), aux


def _moe_ffn_sort(x: jax.Array, params: dict, *, top_k: int,
                  capacity_factor: float, shard_buffers: bool = False):
    """Sort-based dispatch: same capacity/drop semantics as onehot, but the
    routing is argsort + scatter/gather — O(Tk log Tk + Tk*D) data movement
    instead of O(T*E*C*D) dispatch matmuls.

    ``shard_buffers``: constrain the scatter/gather buffers' feature axis
    over the ``model`` mesh axis — without it GSPMD replicates the [E*C, D]
    buffers on every device (observed: the memory term of the qwen3 cell is
    ~75% replicated-buffer traffic).  Requires a mesh context (dry-run /
    production path)."""
    B, S, D = x.shape
    E = params["router"].shape[1]
    T = B * S
    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)            # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    C = max(int(T * top_k / E * capacity_factor), 1)

    N = T * top_k
    flat_e = expert_idx.reshape(N)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    flat_g = gate_vals.reshape(N)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(N, dtype=jnp.int32) - start[se].astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)  # drop row

    def cons(a, spec):
        if not shard_buffers:
            return a
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(a, P(*spec))

    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(
        xt[st], mode="drop")
    buf = cons(buf, (None, "model"))
    xe = buf[: E * C].reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xe, params["wu"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["wd"]).reshape(E * C, D)
    ye = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], axis=0)
    ye = cons(ye, (None, "model"))
    contrib = ye[slot] * sg[:, None].astype(ye.dtype)              # [N, D]
    y = jnp.zeros((T, D), x.dtype).at[st].add(contrib.astype(x.dtype))

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    f = onehot.sum(axis=(0, 1)) / N
    aux = E * jnp.sum(f * probs.mean(axis=0))
    return y.reshape(B, S, D), aux

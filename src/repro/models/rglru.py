"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

TPU-native adaptation: the gated *diagonal* linear recurrence

    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t),
    a_t = exp(-c · softplus(Λ) · sigmoid(W_a x_t))

is associative, so training runs ``jax.lax.associative_scan`` (log-depth,
fully parallel — no CUDA linear-scan kernel needed) and decoding is an O(1)
state update.  The block wraps the recurrence Griffin-style:
linear-in → short temporal conv → RG-LRU → (⊙ GeLU gate branch) → linear-out.

State = (h [B, R], conv tail [B, W-1, R]) — constant-size, which is what
makes ``long_500k`` runnable for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rglru_scan", "rglru_step", "recurrent_block", "recurrent_block_step"]

_C = 8.0


def _gates(x, params):
    """x [..., R] -> (log_a [..., R], gated input [..., R])."""
    a_gate = jax.nn.sigmoid(x @ params["wa"] + params["ba"])
    i_gate = jax.nn.sigmoid(x @ params["wi"] + params["bi"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * a_gate          # <= 0
    gx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i_gate * x)
    return log_a, gx


def rglru_scan(x: jax.Array, params: dict, h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [B, S, R], h0 [B, R] -> (h_seq [B, S, R], h_last [B, R])."""
    xf = x.astype(jnp.float32)
    log_a, gx = _gates(xf, params)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    la, b = jax.lax.associative_scan(combine, (log_a, gx), axis=1)
    h = jnp.exp(la) * h0[:, None, :] + b
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(x: jax.Array, params: dict, h: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One decode step: x [B, R], h [B, R] -> (out, new h)."""
    xf = x.astype(jnp.float32)
    log_a, gx = _gates(xf, params)
    h_new = jnp.exp(log_a) * h + gx
    return h_new.astype(x.dtype), h_new


def _conv_scan(x: jax.Array, w: jax.Array, tail: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv width W. x [B, S, R], tail [B, W-1, R]."""
    W = w.shape[0]
    xx = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xx[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return out, xx[:, -(W - 1):, :]


def recurrent_block(x: jax.Array, params: dict, state: dict | None):
    """Griffin recurrent block over a sequence. x [B, S, D]."""
    B, S, _ = x.shape
    R = params["w_in"].shape[1]
    gate = jax.nn.gelu(x @ params["w_gate"])
    u = x @ params["w_in"]
    tail = state["conv"] if state else jnp.zeros((B, params["conv_w"].shape[0] - 1, R), x.dtype)
    h0 = state["h"] if state else jnp.zeros((B, R), jnp.float32)
    u, new_tail = _conv_scan(u, params["conv_w"], tail)
    h_seq, h_last = rglru_scan(u, params["lru"], h0)
    y = (h_seq.astype(x.dtype) * gate) @ params["w_out"]
    return y.astype(x.dtype), {"h": h_last, "conv": new_tail.astype(x.dtype)}


def recurrent_block_step(x: jax.Array, params: dict, state: dict):
    """One-token decode. x [B, 1, D]."""
    xt = x[:, 0, :]
    gate = jax.nn.gelu(xt @ params["w_gate"])
    u = xt @ params["w_in"]
    tail = state["conv"]                                  # [B, W-1, R]
    W = params["conv_w"].shape[0]
    window = jnp.concatenate([tail, u[:, None, :].astype(tail.dtype)], axis=1)
    u_conv = sum(window[:, i, :] * params["conv_w"][i] for i in range(W))
    out, h_new = rglru_step(u_conv, params["lru"], state["h"])
    y = (out.astype(x.dtype) * gate) @ params["w_out"]
    return y[:, None, :].astype(x.dtype), {"h": h_new, "conv": window[:, 1:, :]}

"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay.

TPU-native adaptation (DESIGN.md §2): the CUDA wkv6 kernel is a sequential
scan; here training uses the *chunkwise-parallel* form — within a chunk the
decay products become cumulative log-sums and the token-token interaction is
a masked einsum on the MXU; across chunks a [K, V] state is carried by a
``lax.scan``.  Decode is the O(1) recurrence.

Per head (K = V = head size):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = (r_t · S_{t-1}) + (r_t · (u ⊙ k_t)) v_t
with w_t = exp(-exp(wbase + lora(x_t))) ∈ (0,1) per channel (data-dependent),
u the per-channel "bonus" for the current token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["time_mix", "time_mix_step", "channel_mix", "channel_mix_step"]


def _token_shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """x_{t-1} stream: shift right; slot 0 takes ``last`` (decode carry)."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return prev.at[:, :1].set(first.astype(x.dtype))


def _mix_inputs(x, xprev, params):
    """RWKV6 token-shift mixing for each projection stream."""
    out = {}
    for name in ("r", "k", "v", "g", "w"):
        mu = params[f"mu_{name}"]
        out[name] = x + (xprev - x) * mu
    return out


def _decay(xw, params):
    """Data-dependent per-channel log-decay (<= 0), via a low-rank mlp."""
    lora = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    return -jnp.exp(params["w_base"].astype(jnp.float32) + lora.astype(jnp.float32))


def _project(x, xprev, params, n_heads):
    m = _mix_inputs(x, xprev, params)
    B, S, D = x.shape
    K = D // n_heads
    r = (m["r"] @ params["wr"]).reshape(B, S, n_heads, K)
    k = (m["k"] @ params["wk"]).reshape(B, S, n_heads, K)
    v = (m["v"] @ params["wv"]).reshape(B, S, n_heads, K)
    g = jax.nn.silu(m["g"] @ params["wg"])
    logw = _decay(m["w"], params).reshape(B, S, n_heads, K)
    return r, k, v, g, logw


def time_mix(
    x: jax.Array,          # [B, S, D]
    params: dict,
    state: dict | None,    # {"S": [B, H, K, K] f32, "last": [B, D]}
    *,
    n_heads: int,
    chunk: int = 64,
) -> tuple[jax.Array, dict]:
    B, S, D = x.shape
    K = D // n_heads
    last = state["last"] if state else None
    S0 = state["S"] if state else jnp.zeros((B, n_heads, K, K), jnp.float32)
    xprev = _token_shift(x, last)
    r, k, v, g, logw = _project(x, xprev, params, n_heads)
    u = params["u"].reshape(n_heads, K)

    pad = (-S) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        r, k, v, logw = z(r), z(k), z(v), z(logw)
    T = r.shape[1]
    n_chunks = T // chunk
    # [n_chunks, B, H, C, K]
    resh = lambda a: a.reshape(B, n_chunks, chunk, n_heads, K).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(logw)

    def chunk_step(Sst, xs):
        rr, kk, vv, ww = xs                        # [B, H, C, K]
        rr = rr.astype(jnp.float32)
        kk = kk.astype(jnp.float32)
        vv = vv.astype(jnp.float32)
        cum = jnp.cumsum(ww, axis=2)               # inclusive log-decay products
        # inter-chunk: r_t decayed by prod_{<t} w = exp(cum - w_t); exponent
        # <= 0, so underflow-safe.
        rdec = rr * jnp.exp(cum - ww)
        o = jnp.einsum("bhck,bhkv->bhcv", rdec, Sst)
        # intra-chunk (s < t): sum_k r_t k_s exp(cum_{t-1} - cum_s).  Keep the
        # exponent *joint* in (t, s, k) — factorizing it into exp(cum_t)*
        # exp(-cum_s) overflows f32 once the chunk accumulates ~90 nats of
        # decay; the joint form is <= 0 for s < t, hence exact.
        dec = jnp.exp(
            jnp.minimum((cum - ww)[:, :, :, None, :] - cum[:, :, None, :, :], 0.0)
        )                                          # [B, H, C, C, K]
        att = (rr[:, :, :, None, :] * dec * kk[:, :, None, :, :]).sum(-1)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
        att = jnp.where(mask[None, None], att, 0.0)
        o = o + jnp.einsum("bhcs,bhsv->bhcv", att, vv)
        # current-token bonus
        bonus = jnp.einsum("bhck,bhck->bhc", rr, u[None, :, None, :] * kk)
        o = o + bonus[..., None] * vv
        # state to next chunk: S' = diag(prod w) S + sum_s (k_s prod_{>s} w) v_s^T
        total = cum[:, :, -1:, :]                  # [B, H, 1, K]
        kdec = kk * jnp.exp(total - cum)
        Snew = jnp.exp(total[:, :, 0, :])[..., None] * Sst + jnp.einsum(
            "bhsk,bhsv->bhkv", kdec, vv
        )
        return Snew, o

    S_last, o = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, T, n_heads, K)[:, :S]
    o = _group_norm(o, params).reshape(B, S, D)
    y = (o * g) @ params["wo"]
    return y.astype(x.dtype), {"S": S_last, "last": x[:, -1, :].astype(jnp.float32)}


def _group_norm(o, params):
    """Per-head layer norm (RWKV's ln_x)."""
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    return (o - mu) * jax.lax.rsqrt(var + 64e-5) * params["ln_x_w"] + params["ln_x_b"]


def time_mix_step(x: jax.Array, params: dict, state: dict, *, n_heads: int):
    """One-token decode. x [B, 1, D]."""
    B, _, D = x.shape
    K = D // n_heads
    xprev = state["last"][:, None, :]
    r, k, v, g, logw = _project(x, xprev.astype(x.dtype), params, n_heads)
    rr = r[:, 0].astype(jnp.float32)               # [B, H, K]
    kk = k[:, 0].astype(jnp.float32)
    vv = v[:, 0].astype(jnp.float32)
    ww = jnp.exp(logw[:, 0])                       # decay in (0,1)
    u = params["u"].reshape(n_heads, K)
    Sst = state["S"]
    o = jnp.einsum("bhk,bhkv->bhv", rr, Sst)
    o = o + jnp.einsum("bhk,bhk->bh", rr, u[None] * kk)[..., None] * vv
    Snew = ww[..., None] * Sst + jnp.einsum("bhk,bhv->bhkv", kk, vv)
    o = _group_norm(o[:, None].reshape(B, 1, n_heads, K), params).reshape(B, 1, D)
    y = (o * g) @ params["wo"]
    return y.astype(x.dtype), {"S": Snew, "last": x[:, -1, :].astype(jnp.float32)}


def channel_mix(x: jax.Array, params: dict, state: dict | None):
    """RWKV FFN: r-gated squared-relu. x [B, S, D]."""
    last = state["last_c"] if state else None
    xprev = _token_shift(x, last)
    xr = x + (xprev - x) * params["mu_cr"]
    xk = x + (xprev - x) * params["mu_ck"]
    r = jax.nn.sigmoid(xr @ params["cr"])
    kk = jnp.square(jax.nn.relu(xk @ params["ck"]))
    return (r * (kk @ params["cv"])).astype(x.dtype), {"last_c": x[:, -1, :].astype(jnp.float32)}


def channel_mix_step(x: jax.Array, params: dict, state: dict):
    xprev = state["last_c"][:, None, :].astype(x.dtype)
    xr = x + (xprev - x) * params["mu_cr"]
    xk = x + (xprev - x) * params["mu_ck"]
    r = jax.nn.sigmoid(xr @ params["cr"])
    kk = jnp.square(jax.nn.relu(xk @ params["ck"]))
    return (r * (kk @ params["cv"])).astype(x.dtype), {"last_c": x[:, -1, :].astype(jnp.float32)}

"""Decoder-only / enc-dec LM over the block families, scan-over-layers.

One ``forward``/``decode_step`` pair covers all 10 assigned architectures via
``ArchConfig.family``:

  dense   — GQA + SwiGLU (internlm2*, starcoder2, granite, chameleon backbone)
  moe     — GQA + capacity-dispatch MoE (grok-1, qwen3-moe)
  hybrid  — RecurrentGemma: (RG-LRU, RG-LRU, local-attn) superblocks
  rwkv    — RWKV-6 time-mix + channel-mix
  encdec  — whisper backbone: encoder over stub frame embeddings + decoder
            with self+cross attention

Layers are stacked on a leading axis and executed with ``lax.scan`` (small
HLO at 94 layers, scan-carry remat point per layer).  Parameters are plain
pytrees; ``init_params_shape`` gives the allocation-free ShapeDtypeStruct
tree for the multi-pod dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import rwkv as rwkv_mod
from repro.models.attention import decode_attention, gqa_attention
from repro.models.common import ArchConfig, apply_rope, dense_init, rms_norm, rope
from repro.models.moe import moe_ffn
from repro.models.rglru import recurrent_block, recurrent_block_step

__all__ = [
    "init_params",
    "init_params_shape",
    "forward",
    "decode_step",
    "init_decode_state",
]


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def _attn_params(key, cfg: ArchConfig, L: int, dt):
    D, hd, Hq, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (L, D, Hq * hd), dt),
        "wk": dense_init(ks[1], (L, D, Hkv * hd), dt),
        "wv": dense_init(ks[2], (L, D, Hkv * hd), dt),
        "wo": dense_init(ks[3], (L, Hq * hd, D), dt),
    }


def _mlp_params(key, D: int, F: int, L: int, dt):
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], (L, D, F), dt),
        "wu": dense_init(ks[1], (L, D, F), dt),
        "wd": dense_init(ks[2], (L, F, D), dt),
    }


def _rec_params(key, cfg: ArchConfig, L: int, dt):
    D, R, W = cfg.d_model, cfg.lru_dim, cfg.conv_width
    ks = jax.random.split(key, 8)
    return {
        "w_gate": dense_init(ks[0], (L, D, R), dt),
        "w_in": dense_init(ks[1], (L, D, R), dt),
        "w_out": dense_init(ks[2], (L, R, D), dt),
        "conv_w": dense_init(ks[3], (L, W, R), jnp.float32, scale=0.3),
        "lru": {
            "wa": dense_init(ks[4], (L, R, R), jnp.float32),
            "ba": jnp.zeros((L, R), jnp.float32),
            "wi": dense_init(ks[5], (L, R, R), jnp.float32),
            "bi": jnp.zeros((L, R), jnp.float32),
            "lam": jnp.linspace(0.5, 4.0, R)[None, :].repeat(L, 0).astype(jnp.float32),
        },
    }


def _rwkv_params(key, cfg: ArchConfig, L: int, dt):
    D, F = cfg.d_model, cfg.d_ff
    H = cfg.n_heads if cfg.n_heads else D // 64
    K = D // H
    lora = max(D // 16, 32)
    ks = jax.random.split(key, 12)
    p = {
        "wr": dense_init(ks[0], (L, D, D), dt),
        "wk": dense_init(ks[1], (L, D, D), dt),
        "wv": dense_init(ks[2], (L, D, D), dt),
        "wg": dense_init(ks[3], (L, D, D), dt),
        "wo": dense_init(ks[4], (L, D, D), dt),
        "w_lora_a": dense_init(ks[5], (L, D, lora), dt),
        "w_lora_b": dense_init(ks[6], (L, lora, D), dt, scale=0.01),
        "w_base": jnp.full((L, D), 0.5, jnp.float32),
        "u": dense_init(ks[7], (L, D), jnp.float32, scale=0.5),
        "ln_x_w": jnp.ones((L, H, K), jnp.float32),
        "ln_x_b": jnp.zeros((L, H, K), jnp.float32),
        "cr": dense_init(ks[8], (L, D, D), dt),
        "ck": dense_init(ks[9], (L, D, F), dt),
        "cv": dense_init(ks[10], (L, F, D), dt),
    }
    for i, name in enumerate(("r", "k", "v", "g", "w", "cr", "ck")):
        p[f"mu_{name if len(name)==1 else name}"] = jnp.full((L, D), 0.5, jnp.float32)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = cfg.jdtype
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    keys = jax.random.split(key, 12)
    params: dict = {
        "embed": dense_init(keys[0], (V, D), dt, scale=0.02),
        "head": dense_init(keys[1], (D, V), dt),
        "ln_f": jnp.zeros((D,), jnp.float32),
    }
    if cfg.family in ("dense",):
        params["layers"] = {
            "ln1": jnp.zeros((L, D), jnp.float32),
            "ln2": jnp.zeros((L, D), jnp.float32),
            **_attn_params(keys[2], cfg, L, dt),
            **_mlp_params(keys[3], D, cfg.d_ff, L, dt),
        }
    elif cfg.family == "moe":
        E, F = cfg.n_experts, cfg.moe_d_ff
        ks = jax.random.split(keys[3], 4)
        params["layers"] = {
            "ln1": jnp.zeros((L, D), jnp.float32),
            "ln2": jnp.zeros((L, D), jnp.float32),
            **_attn_params(keys[2], cfg, L, dt),
            "router": dense_init(ks[0], (L, D, E), jnp.float32),
            "wg": dense_init(ks[1], (L, E, D, F), dt),
            "wu": dense_init(ks[2], (L, E, D, F), dt),
            "wd": dense_init(ks[3], (L, E, F, D), dt),
        }
    elif cfg.family == "hybrid":
        n_super, n_tail = L // 3, L % 3
        params["super"] = {
            "ln_r1": jnp.zeros((n_super, D), jnp.float32),
            "ln_r2": jnp.zeros((n_super, D), jnp.float32),
            "ln_a": jnp.zeros((n_super, D), jnp.float32),
            "ln_m1": jnp.zeros((n_super, D), jnp.float32),
            "ln_m2": jnp.zeros((n_super, D), jnp.float32),
            "ln_m3": jnp.zeros((n_super, D), jnp.float32),
            "rec1": _rec_params(keys[2], cfg, n_super, dt),
            "rec2": _rec_params(keys[4], cfg, n_super, dt),
            **_attn_params(keys[5], cfg, n_super, dt),
            "mlp1": _mlp_params(keys[6], D, cfg.d_ff, n_super, dt),
            "mlp2": _mlp_params(keys[7], D, cfg.d_ff, n_super, dt),
            "mlp3": _mlp_params(keys[8], D, cfg.d_ff, n_super, dt),
        }
        if n_tail:
            params["tail"] = {
                "ln_r": jnp.zeros((n_tail, D), jnp.float32),
                "ln_m": jnp.zeros((n_tail, D), jnp.float32),
                "rec": _rec_params(keys[9], cfg, n_tail, dt),
                "mlp": _mlp_params(keys[10], D, cfg.d_ff, n_tail, dt),
            }
    elif cfg.family == "rwkv":
        params["layers"] = {
            "ln1": jnp.zeros((L, D), jnp.float32),
            "ln2": jnp.zeros((L, D), jnp.float32),
            **_rwkv_params(keys[2], cfg, L, dt),
        }
    elif cfg.family == "encdec":
        Le = cfg.n_enc_layers
        params["enc_pos"] = dense_init(keys[4], (cfg.enc_seq, D), dt, scale=0.02)
        params["enc_layers"] = {
            "ln1": jnp.zeros((Le, D), jnp.float32),
            "ln2": jnp.zeros((Le, D), jnp.float32),
            **_attn_params(keys[2], cfg, Le, dt),
            **_mlp_params(keys[3], D, cfg.d_ff, Le, dt),
        }
        params["ln_enc"] = jnp.zeros((D,), jnp.float32)
        xa = _attn_params(keys[5], cfg, L, dt)
        params["layers"] = {
            "ln1": jnp.zeros((L, D), jnp.float32),
            "ln_x": jnp.zeros((L, D), jnp.float32),
            "ln2": jnp.zeros((L, D), jnp.float32),
            **_attn_params(keys[6], cfg, L, dt),
            "xq": xa["wq"], "xk": xa["wk"], "xv": xa["wv"], "xo": xa["wo"],
            **_mlp_params(keys[7], D, cfg.d_ff, L, dt),
        }
    else:
        raise ValueError(cfg.family)
    return params


def init_params_shape(cfg: ArchConfig):
    """ShapeDtypeStruct tree — zero allocation (dry-run input)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# --------------------------------------------------------------------------
# Blocks (sequence forward)
# --------------------------------------------------------------------------
def _attn_block(x, lp, cfg: ArchConfig, sin, cos, *, window=0, q_chunk=0,
                causal=True, prefix=""):
    B, S, D = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    g = lambda n: lp[prefix + n] if prefix else lp[n]
    q = (x @ g("wq")).reshape(B, S, Hq, hd)
    k = (x @ g("wk")).reshape(B, S, Hkv, hd)
    v = (x @ g("wv")).reshape(B, S, Hkv, hd)
    if sin is not None:
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    o = gqa_attention(q, k, v, causal=causal, window=window, q_chunk=q_chunk,
                      k_chunk=cfg.attn_k_chunk)
    return o.reshape(B, S, Hq * hd) @ g("wo")


def _mlp_block(x, lp, prefix=""):
    g = lambda n: lp[prefix][n] if prefix else lp[n]
    return (jax.nn.silu(x @ g("wg")) * (x @ g("wu"))) @ g("wd")


def _dense_layer(x, lp, cfg, sin, cos, q_chunk):
    h = x + _attn_block(rms_norm(x, lp["ln1"]), lp, cfg, sin, cos, q_chunk=q_chunk)
    return h + _mlp_block(rms_norm(h, lp["ln2"]), lp)


def _moe_layer(carry, lp, cfg, sin, cos, q_chunk):
    x, aux = carry
    h = x + _attn_block(rms_norm(x, lp["ln1"]), lp, cfg, sin, cos, q_chunk=q_chunk)
    y, a = moe_ffn(
        rms_norm(h, lp["ln2"]),
        {"router": lp["router"], "wg": lp["wg"], "wu": lp["wu"], "wd": lp["wd"]},
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        impl=cfg.moe_impl,
    )
    return (h + y, aux + a)


def _hybrid_super(x, lp, cfg, sin, cos, q_chunk):
    y, _ = recurrent_block(rms_norm(x, lp["ln_r1"]), lp["rec1"], None)
    x = x + y
    x = x + _mlp_block(rms_norm(x, lp["ln_m1"]), lp, "mlp1")
    y, _ = recurrent_block(rms_norm(x, lp["ln_r2"]), lp["rec2"], None)
    x = x + y
    x = x + _mlp_block(rms_norm(x, lp["ln_m2"]), lp, "mlp2")
    x = x + _attn_block(rms_norm(x, lp["ln_a"]), lp, cfg, sin, cos,
                        window=cfg.window, q_chunk=q_chunk)
    x = x + _mlp_block(rms_norm(x, lp["ln_m3"]), lp, "mlp3")
    return x


def _rwkv_layer(x, lp, cfg):
    H = cfg.n_heads if cfg.n_heads else cfg.d_model // 64
    y, _ = rwkv_mod.time_mix(rms_norm(x, lp["ln1"]), lp, None, n_heads=H)
    x = x + y
    y, _ = rwkv_mod.channel_mix(rms_norm(x, lp["ln2"]), lp, None)
    return x + y


# --------------------------------------------------------------------------
# Forward (training / prefill)
# --------------------------------------------------------------------------
def _scan(fn, x, stack, remat: bool, unroll: bool = False):
    f = jax.checkpoint(fn) if remat else fn

    def body(carry, lp):
        return f(carry, lp), None

    out, _ = jax.lax.scan(body, x, stack, unroll=True if unroll else 1)
    return out


def forward(
    params: dict,
    tokens: jax.Array,          # int32 [B, S]
    cfg: ArchConfig,
    *,
    enc_inputs: jax.Array | None = None,   # [B, enc_seq, D] (encdec stub frontend)
    q_chunk: int = 0,
    remat: bool = True,
    unroll: bool = False,
) -> jax.Array:
    B, S = tokens.shape
    x = params["embed"][tokens]
    pos = jnp.arange(S)
    sin, cos = rope(pos, cfg.hd, cfg.rope_theta)
    sin, cos = sin[None], cos[None]

    if cfg.family == "dense":
        x = _scan(lambda h, lp: _dense_layer(h, lp, cfg, sin, cos, q_chunk),
                  x, params["layers"], remat, unroll)
    elif cfg.family == "moe":
        x, _aux = _scan(
            lambda c, lp: _moe_layer(c, lp, cfg, sin, cos, q_chunk),
            (x, jnp.zeros((), jnp.float32)), params["layers"], remat, unroll)
    elif cfg.family == "hybrid":
        x = _scan(lambda h, lp: _hybrid_super(h, lp, cfg, sin, cos, q_chunk),
                  x, params["super"], remat, unroll)
        if "tail" in params:
            def tail_layer(h, lp):
                y, _ = recurrent_block(rms_norm(h, lp["ln_r"]), lp["rec"], None)
                h = h + y
                return h + _mlp_block(rms_norm(h, lp["ln_m"]), lp, "mlp")
            x = _scan(tail_layer, x, params["tail"], remat, unroll)
    elif cfg.family == "rwkv":
        x = _scan(lambda h, lp: _rwkv_layer(h, lp, cfg), x, params["layers"], remat, unroll)
    elif cfg.family == "encdec":
        if enc_inputs is None:
            raise ValueError("encdec needs enc_inputs (frontend stub output)")
        e = _encode(params, enc_inputs, cfg, remat=remat, unroll=unroll)

        def dec_layer(h, lp):
            h = h + _attn_block(rms_norm(h, lp["ln1"]), lp, cfg, sin, cos, q_chunk=q_chunk)
            # cross attention
            Bq, Sq, D = h.shape
            hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
            q = (rms_norm(h, lp["ln_x"]) @ lp["xq"]).reshape(Bq, Sq, Hq, hd)
            k = (e @ lp["xk"]).reshape(Bq, -1, Hkv, hd)
            v = (e @ lp["xv"]).reshape(Bq, -1, Hkv, hd)
            o = gqa_attention(q, k, v, causal=False)
            h = h + o.reshape(Bq, Sq, Hq * hd) @ lp["xo"]
            return h + _mlp_block(rms_norm(h, lp["ln2"]), lp)

        x = _scan(dec_layer, x, params["layers"], remat, unroll)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["ln_f"])
    return x @ params["head"]


def _encode(params, enc_inputs, cfg: ArchConfig, *, remat: bool = True,
            unroll: bool = False):
    """Whisper encoder over stub frame embeddings (frontend is a STUB)."""
    e = enc_inputs + params["enc_pos"][None]

    def enc_layer(h, lp):
        h = h + _attn_block(rms_norm(h, lp["ln1"]), lp, cfg, None, None, causal=False)
        return h + _mlp_block(rms_norm(h, lp["ln2"]), lp)

    e = _scan(enc_layer, e, params["enc_layers"], remat, unroll)
    return rms_norm(e, params["ln_enc"])


def encode_kv(params, enc_inputs, cfg: ArchConfig):
    """Precompute per-decoder-layer cross-attention K/V (decode-time state)."""
    e = _encode(params, enc_inputs, cfg)
    B, Se, _ = e.shape
    hd, Hkv = cfg.hd, cfg.n_kv

    def per_layer(lp):
        return ((e @ lp["xk"]).reshape(B, Se, Hkv, hd),
                (e @ lp["xv"]).reshape(B, Se, Hkv, hd))

    ks, vs = jax.vmap(per_layer)(
        {"xk": params["layers"]["xk"], "xv": params["layers"]["xv"]})
    return ks, vs


# --------------------------------------------------------------------------
# Decode (one token against caches / recurrent state)
# --------------------------------------------------------------------------
def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    dt = cfg.jdtype
    hd, Hkv, D = cfg.hd, cfg.n_kv, cfg.d_model
    if cfg.family in ("dense", "moe"):
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, cache_len, Hkv, hd), dt),
            "v": jnp.zeros((L, batch, cache_len, Hkv, hd), dt),
        }
    if cfg.family == "hybrid":
        n_super, n_tail = cfg.n_layers // 3, cfg.n_layers % 3
        R, W = cfg.lru_dim, cfg.conv_width
        win = min(cfg.window, cache_len)
        st = {
            "super": {
                "h1": jnp.zeros((n_super, batch, R), jnp.float32),
                "c1": jnp.zeros((n_super, batch, W - 1, R), dt),
                "h2": jnp.zeros((n_super, batch, R), jnp.float32),
                "c2": jnp.zeros((n_super, batch, W - 1, R), dt),
                "k": jnp.zeros((n_super, batch, win, Hkv, hd), dt),
                "v": jnp.zeros((n_super, batch, win, Hkv, hd), dt),
            }
        }
        if n_tail:
            st["tail"] = {
                "h": jnp.zeros((n_tail, batch, R), jnp.float32),
                "c": jnp.zeros((n_tail, batch, W - 1, R), dt),
            }
        return st
    if cfg.family == "rwkv":
        H = cfg.n_heads if cfg.n_heads else D // 64
        K = D // H
        L = cfg.n_layers
        return {
            "S": jnp.zeros((L, batch, H, K, K), jnp.float32),
            "last": jnp.zeros((L, batch, D), jnp.float32),
            "last_c": jnp.zeros((L, batch, D), jnp.float32),
        }
    if cfg.family == "encdec":
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, cache_len, Hkv, hd), dt),
            "v": jnp.zeros((L, batch, cache_len, Hkv, hd), dt),
            "ek": jnp.zeros((L, batch, cfg.enc_seq, Hkv, hd), dt),
            "ev": jnp.zeros((L, batch, cfg.enc_seq, Hkv, hd), dt),
        }
    raise ValueError(cfg.family)


def _decode_attn_layer(x, lp, cache_k, cache_v, pos, cfg, sin, cos, *, ring=False,
                       window=0):
    B = x.shape[0]
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    q = (x @ lp["wq"]).reshape(B, 1, Hq, hd)
    k = (x @ lp["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ lp["wv"]).reshape(B, 1, Hkv, hd)
    if sin is not None:
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    T = cache_k.shape[1]
    slot = (pos % T) if ring else jnp.minimum(pos, T - 1)
    ck = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    kv_len = jnp.full((B,), jnp.minimum(pos + 1, T), jnp.int32)
    o = decode_attention(q, ck, cv, kv_len, mxu_native=cfg.attn_mxu_native)
    return (o.reshape(B, 1, Hq * hd) @ lp["wo"]), ck, cv


def decode_step(
    params: dict,
    state: dict,
    tokens: jax.Array,   # int32 [B, 1]
    pos: jax.Array,      # int32 scalar — current position
    cfg: ArchConfig,
    *,
    unroll: bool = False,
) -> tuple[jax.Array, dict]:
    B = tokens.shape[0]
    x = params["embed"][tokens]
    sin, cos = rope(pos[None], cfg.hd, cfg.rope_theta)
    sin, cos = sin[None], cos[None]

    if cfg.family in ("dense", "moe"):
        def body(h, xs):
            lp, ck, cv = xs
            a, ck, cv = _decode_attn_layer(
                rms_norm(h, lp["ln1"]), lp, ck, cv, pos, cfg, sin, cos)
            h = h + a
            if cfg.family == "dense":
                h = h + _mlp_block(rms_norm(h, lp["ln2"]), lp)
            else:
                y, _ = moe_ffn(
                    rms_norm(h, lp["ln2"]),
                    {"router": lp["router"], "wg": lp["wg"], "wu": lp["wu"], "wd": lp["wd"]},
                    top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                    impl=cfg.moe_impl)
                h = h + y
            return h, (ck, cv)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], state["k"], state["v"]), unroll=True if unroll else 1)
        state = {"k": ks, "v": vs}
    elif cfg.family == "hybrid":
        def sbody(h, xs):
            lp, st = xs
            y, s1 = recurrent_block_step(rms_norm(h, lp["ln_r1"]), lp["rec1"],
                                         {"h": st["h1"], "conv": st["c1"]})
            h = h + y
            h = h + _mlp_block(rms_norm(h, lp["ln_m1"]), lp, "mlp1")
            y, s2 = recurrent_block_step(rms_norm(h, lp["ln_r2"]), lp["rec2"],
                                         {"h": st["h2"], "conv": st["c2"]})
            h = h + y
            h = h + _mlp_block(rms_norm(h, lp["ln_m2"]), lp, "mlp2")
            a, ck, cv = _decode_attn_layer(
                rms_norm(h, lp["ln_a"]), lp, st["k"], st["v"], pos, cfg, sin, cos,
                ring=True)
            h = h + a
            h = h + _mlp_block(rms_norm(h, lp["ln_m3"]), lp, "mlp3")
            return h, {"h1": s1["h"], "c1": s1["conv"], "h2": s2["h"],
                       "c2": s2["conv"], "k": ck, "v": cv}

        tail_state = state.get("tail")
        x, new_super = jax.lax.scan(sbody, x, (params["super"], state["super"]), unroll=True if unroll else 1)
        state = {"super": new_super}
        if "tail" in params:
            def tbody(h, xs):
                lp, st = xs
                y, s = recurrent_block_step(rms_norm(h, lp["ln_r"]), lp["rec"],
                                            {"h": st["h"], "conv": st["c"]})
                h = h + y
                h = h + _mlp_block(rms_norm(h, lp["ln_m"]), lp, "mlp")
                return h, {"h": s["h"], "c": s["conv"]}

            x, new_tail = jax.lax.scan(tbody, x, (params["tail"], tail_state), unroll=True if unroll else 1)
            state["tail"] = new_tail
    elif cfg.family == "rwkv":
        H = cfg.n_heads if cfg.n_heads else cfg.d_model // 64

        def body(h, xs):
            lp, S_l, last_l, lastc_l = xs
            y, ts = rwkv_mod.time_mix_step(
                rms_norm(h, lp["ln1"]), lp,
                {"S": S_l, "last": last_l}, n_heads=H)
            h = h + y
            y, cs = rwkv_mod.channel_mix_step(
                rms_norm(h, lp["ln2"]), lp, {"last_c": lastc_l})
            h = h + y
            return h, (ts["S"], ts["last"], cs["last_c"])

        x, (Ss, lasts, lastcs) = jax.lax.scan(
            body, x, (params["layers"], state["S"], state["last"], state["last_c"]))
        state = {"S": Ss, "last": lasts, "last_c": lastcs}
    elif cfg.family == "encdec":
        def body(h, xs):
            lp, ck, cv, ek, ev = xs
            a, ck, cv = _decode_attn_layer(
                rms_norm(h, lp["ln1"]), lp, ck, cv, pos, cfg, sin, cos)
            h = h + a
            hd, Hq = cfg.hd, cfg.n_heads
            q = (rms_norm(h, lp["ln_x"]) @ lp["xq"]).reshape(B, 1, Hq, hd)
            kvl = jnp.full((B,), ek.shape[1], jnp.int32)
            o = decode_attention(q, ek, ev, kvl)
            h = h + o.reshape(B, 1, Hq * hd) @ lp["xo"]
            h = h + _mlp_block(rms_norm(h, lp["ln2"]), lp)
            return h, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            body, x,
            (params["layers"], state["k"], state["v"], state["ek"], state["ev"]))
        state = {"k": ks, "v": vs, "ek": state["ek"], "ev": state["ev"]}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["ln_f"])
    return x @ params["head"], state

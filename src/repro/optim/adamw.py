"""AdamW with configurable state dtype + global-norm clipping.

``state_dtype="bfloat16"`` halves optimizer memory — the lever that fits
grok-1-314b's states on 256 chips (DESIGN.md §5; 16 GB HBM budget in
EXPERIMENTS.md §Dry-run).  Moments are stored in the chosen dtype but the
update math runs in f32.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"     # "bfloat16" halves optimizer HBM
    warmup_steps: int = 100
    total_steps: int = 10_000


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}

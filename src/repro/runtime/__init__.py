"""The repro runtime layer: one front door to every classify substrate.

ACORN's pipeline model serves line-rate *aggregate* traffic arriving on many
ingress ports; scaling the reproduction therefore needs two independent axes:

* **path** (pipeline-parallel)  — program stages laid across "switch" devices,
  packets hopping via collective-permute (the wire);
* **ports** (data-parallel)     — the packet batch itself sharded across
  "port" devices that replicate the program, so throughput grows with port
  count at fixed latency.

``DataplaneRuntime`` (``facade.py``) is the facade: it owns *admission* —
ragged request batches are padded into power-of-two buckets of passthrough
packets (``admission.py``), so arbitrary traffic sizes hit at most O(log B)
compiled shapes per executor — and delegates execution to a pluggable
``Executor`` (``executors.py``):

* ``SingleSwitchExecutor``   — one ``SwitchEngine``, the jit-once plane;
* ``SequentialPathExecutor`` — partial programs applied in path order
  (functional reference for every distributed decomposition);
* ``PipelinedExecutor``      — shard_map ring over a ``("switch",)`` axis
  (GPipe-style), compiled pipelines memoized per ``n_micro``;
* ``ShardedExecutor``        — the 2D ``("switch", "port")`` mesh: pipeline
  along the path, data-parallel across ports.

``policies.py`` holds the pluggable ``BatchingPolicy`` strategies
(immediate / size-or-deadline / adaptive-bucket) the async serving front
(``repro.serving.async_server``) coalesces traffic through; the
``coalesce``/``split`` seam in ``admission.py`` lets them batch many
per-client submits into one admitted bucket — same shapes, same O(log B)
trace bound.

``control.py`` is the self-healing control plane: ``ControlLoop`` runs the
detect -> replan -> drain -> reinstall cycle over a fleet of devices (see
``repro.serving.fleet``), with ``DeviceFailure`` as the data-path failure
signal and ``ControlCounters`` surfaced through ``latency_stats()``.

This package is the **only** place in ``src/repro`` allowed to construct a
``shard_map`` classify loop (pinned by ``tests/test_runtime.py``).
"""
from repro.runtime.admission import (
    bucket_ladder,
    bucket_size,
    coalesce,
    pad_to_bucket,
    split,
    trim,
)
from repro.runtime.control import ControlCounters, ControlLoop, DeviceFailure
from repro.runtime.executors import (
    Executor,
    PipelinedExecutor,
    SequentialPathExecutor,
    ShardedExecutor,
    SingleSwitchExecutor,
)
from repro.runtime.facade import DataplaneRuntime
from repro.runtime.policies import (
    AdaptiveBucketPolicy,
    BatchingPolicy,
    ImmediatePolicy,
    SizeOrDeadlinePolicy,
    SloAutoscaler,
)

__all__ = [
    "DataplaneRuntime",
    "Executor",
    "SingleSwitchExecutor",
    "SequentialPathExecutor",
    "PipelinedExecutor",
    "ShardedExecutor",
    "BatchingPolicy",
    "ImmediatePolicy",
    "SizeOrDeadlinePolicy",
    "AdaptiveBucketPolicy",
    "SloAutoscaler",
    "ControlLoop",
    "ControlCounters",
    "DeviceFailure",
    "bucket_size",
    "bucket_ladder",
    "pad_to_bucket",
    "trim",
    "coalesce",
    "split",
]

"""Admission control: ragged traffic -> a small set of compiled batch shapes.

Every executor jit-compiles per batch shape, so letting arbitrary request
sizes through would compile once per distinct B — the compile-thrash analogue
of the pre-zoo per-model retrace.  Admission instead rounds each batch up to
a **power-of-two bucket** (in units of the executor's ``granularity``, the
divisibility its mesh layout needs) and fills the tail with zeroed packets.

A zero-filled packet has ``ptype == PacketType.FORWARD`` (= 0): the plane's
passthrough gate leaves its ``rslt``/``codes``/``svm_acc`` untouched (paper
§6.1 — classification never disturbs forwarded traffic), so padding is
semantically invisible and ``trim`` just slices it back off.  Net effect:
any sequence of batch sizes ≤ B costs at most ``O(log B)`` traces per
executor (pinned in ``tests/test_runtime.py``).

``coalesce``/``split`` are the multi-client seam on the same invariant: an
async serving front (``repro.serving.async_server``) concatenates several
per-client request batches into one flat batch, runs it through the same
bucketing, and splits the classified batch back per client.  Because
classification is per-packet, coalescing is semantically invisible too —
each client's slice is bit-identical to classifying its batch alone (pinned
in ``tests/test_conformance.py``).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packets import PacketBatch

__all__ = ["bucket_size", "bucket_ladder", "pad_to_bucket", "trim",
           "coalesce", "split"]


def bucket_size(batch: int, granularity: int = 1) -> int:
    """Smallest power-of-two multiple of ``granularity`` holding ``batch``.

    ``granularity`` is the executor's batch divisibility requirement
    (``n_micro * n_ports`` for mesh executors, 1 for single-switch), so the
    bucket always splits evenly into microbatches and port shards.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1 packet, got {batch}")
    if granularity < 1:
        raise ValueError(f"granularity must be >= 1, got {granularity}")
    units = -(-batch // granularity)          # ceil(batch / granularity)
    return granularity * (1 << max(units - 1, 0).bit_length())


def bucket_ladder(max_batch: int, granularity: int = 1) -> tuple[int, ...]:
    """Every admission bucket a batch of up to ``max_batch`` can land in:
    ``granularity * 2^k`` for ``k = 0 .. log2(bucket(max_batch))`` — the
    shapes a serving front pre-traces so no dispatch pays first-touch
    compile mid-stream (``DataplaneRuntime.warm``).  Length is the O(log B)
    trace bound itself."""
    top = bucket_size(max_batch, granularity)
    ladder = []
    b = granularity
    while b <= top:
        ladder.append(b)
        b *= 2
    return tuple(ladder)


def pad_to_bucket(pb: PacketBatch, bucket: int) -> PacketBatch:
    """Pad a request batch to ``bucket`` packets with passthrough tail.

    The tail is zero-filled: ``ptype = FORWARD`` (0), zero features and
    intermediates — packets the plane forwards untouched by construction.

    Host-resident leaves (numpy — what ``coalesce`` produces) pad with
    numpy: a ``jnp.concatenate`` outside jit XLA-compiles once per
    (batch, bucket) shape pair per leaf, which on a live serving front
    turns every new ragged size into a ~100x glue stall before the warmed
    classify trace even runs.  Device-resident leaves keep the jnp path so
    the sync pipeline never forces a device -> host round-trip.
    """
    B = pb.batch
    if bucket < B:
        raise ValueError(f"bucket {bucket} smaller than batch {B}")
    if bucket == B:
        return pb

    def pad(x):
        if isinstance(x, np.ndarray):
            return np.concatenate(
                [x, np.zeros((bucket - B,) + x.shape[1:], x.dtype)])
        # Device-resident leaf: jnp on purpose — numpy here would force a
        # device -> host round-trip mid-pipeline (docstring above).
        return jnp.concatenate(  # planelint: disable=PL002
            [jnp.asarray(x),     # planelint: disable=PL002
             jnp.zeros((bucket - B,) + x.shape[1:], x.dtype)])

    return jax.tree.map(pad, pb)


def trim(pb: PacketBatch, batch: int) -> PacketBatch:
    """Slice the admission padding back off (device-side, no transfer)."""
    if pb.batch == batch:
        return pb
    return jax.tree.map(lambda x: x[:batch], pb)


def coalesce(batches: Sequence[PacketBatch]) -> tuple[PacketBatch, tuple[int, ...]]:
    """Concatenate per-client request batches into one flat batch.

    Returns ``(flat, offsets)`` where ``offsets`` has ``len(batches) + 1``
    entries and client ``i``'s packets occupy ``flat[offsets[i]:offsets[i+1]]``
    — the demux map ``split`` (and the async server's future demux) slices
    by.  Empty member batches are legal and occupy an empty slice.
    """
    batches = list(batches)
    if not batches:
        raise ValueError("coalesce needs at least one batch")
    offsets = [0]
    for b in batches:
        offsets.append(offsets[-1] + b.batch)
    if len(batches) == 1:
        return batches[0], tuple(offsets)
    # Host-side numpy concatenation, deliberately: a jnp.concatenate over a
    # varying number of ragged operands XLA-compiles per (count, shapes)
    # signature — a serving front coalescing live traffic would recompile
    # constantly and pay ~100x the classify cost in glue.  The flat batch is
    # device_put once by the executor's jitted classify.
    flat = jax.tree.map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs]), *batches)
    return flat, tuple(offsets)


def split(pb: PacketBatch, offsets: Sequence[int]) -> list[PacketBatch]:
    """Invert ``coalesce``: slice the flat batch back per client (device-side)."""
    if not offsets or offsets[0] != 0 or offsets[-1] != pb.batch:
        raise ValueError(
            f"offsets {tuple(offsets)} do not tile a batch of {pb.batch}")
    return [jax.tree.map(lambda x: x[lo:hi], pb)
            for lo, hi in zip(offsets, offsets[1:])]

"""Admission control: ragged traffic -> a small set of compiled batch shapes.

Every executor jit-compiles per batch shape, so letting arbitrary request
sizes through would compile once per distinct B — the compile-thrash analogue
of the pre-zoo per-model retrace.  Admission instead rounds each batch up to
a **power-of-two bucket** (in units of the executor's ``granularity``, the
divisibility its mesh layout needs) and fills the tail with zeroed packets.

A zero-filled packet has ``ptype == PacketType.FORWARD`` (= 0): the plane's
passthrough gate leaves its ``rslt``/``codes``/``svm_acc`` untouched (paper
§6.1 — classification never disturbs forwarded traffic), so padding is
semantically invisible and ``trim`` just slices it back off.  Net effect:
any sequence of batch sizes ≤ B costs at most ``O(log B)`` traces per
executor (pinned in ``tests/test_runtime.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packets import PacketBatch

__all__ = ["bucket_size", "pad_to_bucket", "trim"]


def bucket_size(batch: int, granularity: int = 1) -> int:
    """Smallest power-of-two multiple of ``granularity`` holding ``batch``.

    ``granularity`` is the executor's batch divisibility requirement
    (``n_micro * n_ports`` for mesh executors, 1 for single-switch), so the
    bucket always splits evenly into microbatches and port shards.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1 packet, got {batch}")
    if granularity < 1:
        raise ValueError(f"granularity must be >= 1, got {granularity}")
    units = -(-batch // granularity)          # ceil(batch / granularity)
    return granularity * (1 << max(units - 1, 0).bit_length())


def pad_to_bucket(pb: PacketBatch, bucket: int) -> PacketBatch:
    """Pad a request batch to ``bucket`` packets with passthrough tail.

    The tail is zero-filled: ``ptype = FORWARD`` (0), zero features and
    intermediates — packets the plane forwards untouched by construction.
    """
    B = pb.batch
    if bucket < B:
        raise ValueError(f"bucket {bucket} smaller than batch {B}")
    if bucket == B:
        return pb
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [jnp.asarray(x),
             jnp.zeros((bucket - B,) + x.shape[1:], x.dtype)]),
        pb)


def trim(pb: PacketBatch, batch: int) -> PacketBatch:
    """Slice the admission padding back off (device-side, no transfer)."""
    if pb.batch == batch:
        return pb
    return jax.tree.map(lambda x: x[:batch], pb)

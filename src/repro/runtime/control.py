"""Self-healing control plane for whole-topology serving (beyond paper §9).

The paper's planner replans around dead devices offline (``planner.replan``);
this module makes that loop *live*.  ``ControlLoop`` sits next to an
``AsyncZooServer`` and runs the availability cycle against a fleet:

    detect -> replan -> drain -> reinstall

* **detect** — a heartbeat probe (and the data path itself, via
  ``DeviceFailure`` raised when a dispatch's wire path crosses a dead
  device) notices that a serving-path device is down;
* **replan** — the zoo is re-solved on the surviving topology with the
  per-version capacity carry-over intact (``planner.replan_zoo``); the
  solve runs on a worker thread so the event loop keeps accepting submits;
* **drain** — the server holds new dispatches and waits for the in-flight
  one to land, so no batch is ever classified half-old half-new;
* **reinstall** — the fleet retargets its executor to the new path and
  per-device ``ExecImage`` programs, then the server releases the hold.

Ordering is what makes the answers stay bit-identical: a request either
completes on the old deployment, or fails with ``DeviceFailure`` and is
retried after ``heal()`` — never a mix.  ``ControlCounters`` records the
cycle (failures/replans/drains/reinstalls, heal latency, downtime windows)
and is surfaced through ``AsyncZooServer.latency_stats()`` via
``add_stats_source`` — one stats path for data plane and control plane.

Layering: this module must not import ``repro.serving`` — the fleet and
server come in through the ``HealableFleet`` / ``DrainableServer``
protocols below (same inversion as the ``Executor`` seam).
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Protocol, runtime_checkable

__all__ = [
    "DeviceFailure",
    "ControlCounters",
    "ControlLoop",
    "HealableFleet",
    "DrainableServer",
]


class DeviceFailure(RuntimeError):
    """A wire path crosses a dead device — the data-path failure signal.

    Raised by the fleet executor instead of classifying through dead
    hardware; the serving layer catches it, runs ``ControlLoop.heal()``,
    and retries the request on the post-replan deployment."""

    def __init__(self, device: str, *, path: list[str] | None = None) -> None:
        self.device = device
        self.path = list(path) if path is not None else None
        msg = f"device {device!r} is down"
        if self.path is not None:
            msg += f" on serving path {self.path}"
        super().__init__(msg)


@dataclasses.dataclass
class ControlCounters:
    """Lifetime control-plane accounting, merged into ``latency_stats()``."""

    failures_detected: int = 0
    replans: int = 0
    drains: int = 0
    reinstalls: int = 0
    retries: int = 0
    heal_failures: int = 0          # replan infeasible: no surviving deployment
    interrupted_heals: int = 0      # server shut down mid-heal (drain refused
                                    # or the owned hold was broken by stop())
    last_heal_ms: float = 0.0
    total_downtime_s: float = 0.0
    # (t0, t1) heal windows on the serving clock (seconds since loop start)
    # — netsim.simulate_serving takes these as its downtime_windows.
    downtime_windows: list[tuple[float, float]] = \
        dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["downtime_windows"] = [tuple(w) for w in self.downtime_windows]
        return out


@runtime_checkable
class HealableFleet(Protocol):
    """What the control loop needs from a fleet (``serving/fleet.py``)."""

    def failed_on_path(self) -> set[str]:
        """Dead devices on the current serving wire path."""
        ...

    def replan_sync(self):
        """Re-solve the deployment on the surviving topology (blocking CPU
        work).  Returns ``(plans, devices, programs)``; raises
        ``RuntimeError`` when no feasible deployment survives."""
        ...

    def reinstall(self, plans, devices, programs) -> None:
        """Retarget the executor to the post-replan deployment."""
        ...


@runtime_checkable
class DrainableServer(Protocol):
    """What the control loop needs from the async server."""

    async def drain(self) -> None: ...
    def release(self) -> None: ...
    def add_stats_source(self, name: str, fn) -> None: ...


class ControlLoop:
    """Failure detection + heal cycle over one fleet/server pair.

    ``start()`` launches the heartbeat probe task; ``heal()`` runs one
    serialized detect->replan->drain->reinstall cycle (idempotent — a raced
    call that finds the path already healthy returns ``False``).  A replan
    with no surviving deployment raises ``RuntimeError`` out of ``heal()``;
    the probe task counts it and keeps probing, submitters see it on retry.
    """

    def __init__(self, fleet: HealableFleet, server: DrainableServer, *,
                 probe_interval_s: float = 0.02) -> None:
        self.fleet = fleet
        self.server = server
        self.probe_interval_s = float(probe_interval_s)
        self.counters = ControlCounters()
        self._lock: asyncio.Lock | None = None
        self._task: asyncio.Task | None = None
        self._t0 = 0.0
        server.add_stats_source("control", self.counters.as_dict)

    async def start(self) -> "ControlLoop":
        if self._task is not None:
            raise RuntimeError("control loop already started")
        loop = asyncio.get_running_loop()
        self._lock = asyncio.Lock()
        self._t0 = loop.time()
        self._task = loop.create_task(self._probe_loop(),
                                      name="fleet-control-probe")
        return self

    async def stop(self) -> None:
        if self._task is None:
            return
        self._task.cancel()
        try:
            await self._task
        except asyncio.CancelledError:
            pass
        self._task = None

    async def _probe_loop(self) -> None:
        """Heartbeat detection: poll serving-path device health.  An
        infeasible heal is counted, not fatal — the probe keeps running and
        the failure surfaces on the next submit's retry."""
        while True:
            await asyncio.sleep(self.probe_interval_s)
            if self.fleet.failed_on_path():
                try:
                    await self.heal()
                except RuntimeError:
                    pass        # counted in heal(); submitters surface it

    async def heal(self) -> bool:
        """One detect->replan->drain->reinstall cycle.

        Serialized on a lock so the probe task and concurrent retrying
        submitters collapse into a single replan.  Returns ``True`` if a
        reinstall happened, ``False`` if the path was already healthy."""
        loop = asyncio.get_running_loop()
        async with self._lock:
            failed = self.fleet.failed_on_path()
            if not failed:
                return False          # raced: an earlier heal already fixed it
            t_detect = loop.time()
            self.counters.failures_detected += len(failed)
            try:
                # the ILP/DP solve is blocking CPU work — run it off-loop so
                # the server keeps accepting submits mid-replan
                plans, devices, programs = await loop.run_in_executor(
                    None, self.fleet.replan_sync)
            except RuntimeError:
                self.counters.heal_failures += 1
                raise
            self.counters.replans += 1
            # drain BEFORE reinstall: the in-flight dispatch completes (or
            # fails with DeviceFailure and retries) on the old deployment —
            # no batch sees a half-swapped program set.  A server that is
            # shutting down refuses the drain barrier (RuntimeError): the
            # heal cannot proceed against a flushing server, so it is
            # counted as interrupted and surfaced, never applied half-way.
            try:
                await self.server.drain()
            except RuntimeError:
                self.counters.interrupted_heals += 1
                raise
            self.counters.drains += 1
            broken = None
            try:
                self.fleet.reinstall(plans, devices, programs)
            finally:
                try:
                    self.server.release()
                except RuntimeError as e:
                    # stop() broke our hold mid-reinstall: the server
                    # already flushed and shut down underneath the barrier.
                    # Capture rather than raise here so a reinstall
                    # exception (if any) is not masked by the finally.
                    broken = e
            if broken is not None:
                self.counters.interrupted_heals += 1
                raise RuntimeError(
                    "server stopped during heal: the drain barrier was "
                    "broken by stop() while the reinstall ran") from broken
            self.counters.reinstalls += 1
            t_done = loop.time()
            self.counters.last_heal_ms = (t_done - t_detect) * 1e3
            self.counters.total_downtime_s += t_done - t_detect
            self.counters.downtime_windows.append(
                (t_detect - self._t0, t_done - self._t0))
            return True

    def note_retry(self) -> None:
        """A submitter retried a request after ``DeviceFailure``."""
        self.counters.retries += 1

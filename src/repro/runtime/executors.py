"""Pluggable classify executors behind one ``Executor`` protocol.

Each executor turns the same jit-once classify step (``_classify_impl``)
into a different execution substrate; all four are bit-identical on the same
zoo and traffic (pinned in ``tests/test_runtime.py``):

* ``SingleSwitchExecutor``   — one ``SwitchEngine``: the paper's single
  programmable switch, compile-once per batch shape.
* ``SequentialPathExecutor`` — partial programs applied in path order on one
  device: the functional reference every distributed layout must match.
* ``PipelinedExecutor``      — the GPipe-style shard_map ring over a
  ``("switch",)`` mesh axis (microbatch m enters switch 0 at step m, hops via
  ``ppermute``, exits switch n-1 at step m+n-1).  Compiled pipelines are
  memoized **per n_micro** — revisiting a previous microbatch count reuses
  its pipeline instead of rebuilding (the old ``PipelinedPlane`` kept one
  ``_run`` slot and thrashed it).
* ``ShardedExecutor``        — the 2D ``("switch", "port")`` mesh:
  pipeline-parallel along the path axis *and* data-parallel across ports.
  ``PackedProgram``/``ExecImage`` leaves are sharded over "switch" and
  replicated over "port"; ``PacketBatch`` leaves are sharded over "port" —
  each port lane serves its slice of the aggregate traffic, so throughput
  scales with port count at fixed latency (``benchmarks/runtime_scale.py``).

This module is the only place in ``src/repro`` that may construct a
``shard_map`` classify loop (pinned by ``tests/test_runtime.py``).
"""
from __future__ import annotations

import functools
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.packets import PacketBatch
from repro.core.plane import (
    PackedProgram,
    PlaneProfile,
    SwitchEngine,
    _classify_impl,
)
from repro.core.translator import TableProgram

__all__ = [
    "Executor",
    "SingleSwitchExecutor",
    "SequentialPathExecutor",
    "PipelinedExecutor",
    "ShardedExecutor",
]


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` moved over jax versions: new jax exposes it at the
    top level (with ``check_vma``), jax<=0.4.x only under
    ``jax.experimental.shard_map`` (with ``check_rep``).  Support both."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp

    return sm_exp(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


@runtime_checkable
class Executor(Protocol):
    """What ``DataplaneRuntime`` needs from an execution substrate.

    ``granularity`` is the batch divisibility the executor's layout requires
    (admission rounds buckets up to a multiple of it); ``classify`` maps a
    flat ``[B]`` batch to the classified flat batch in the same packet order;
    ``swap`` reprograms the plane(s) with zero retrace; ``cache_size`` counts
    compiled traces (the compile-once/bucketing assertions).
    """

    @property
    def granularity(self) -> int: ...

    def classify(self, batch: PacketBatch) -> PacketBatch: ...

    def swap(self, device_programs: list[PackedProgram]) -> None: ...

    def cache_size(self) -> int: ...


class SingleSwitchExecutor:
    """One programmable switch — wraps the ``SwitchEngine`` jit cache.

    Also carries the control-plane write interface (``install``/``evict``)
    so a serving front can treat the executor as the owning plane.
    """

    granularity = 1

    def __init__(self, profile: PlaneProfile | None = None, *,
                 engine: SwitchEngine | None = None,
                 packed: PackedProgram | None = None,
                 mode: str | None = None, use_image: bool = True) -> None:
        if engine is None:
            if profile is None:
                raise ValueError("need a PlaneProfile or an existing engine")
            engine = SwitchEngine(profile, mode=mode, use_image=use_image)
        self.engine = engine
        self.packed = packed if packed is not None else engine.empty()

    @property
    def profile(self) -> PlaneProfile:
        return self.engine.profile

    def classify(self, batch: PacketBatch) -> PacketBatch:
        return self.engine.classify(self.packed, batch)

    def install(self, program: TableProgram, *, vid: int | None = None,
                stages: set[int] | None = None) -> "SingleSwitchExecutor":
        self.packed = self.engine.install(self.packed, program, stages,
                                          vid=vid)
        return self

    def evict(self, *, vid: int, kind: str = "all") -> "SingleSwitchExecutor":
        self.packed = self.engine.evict(self.packed, vid=vid, kind=kind)
        return self

    def swap(self, device_programs) -> None:
        if isinstance(device_programs, PackedProgram):
            device_programs = [device_programs]
        (packed,) = device_programs
        self.packed = packed

    def cache_size(self) -> int:
        return self.engine.cache_size()


def _chain(programs: tuple[PackedProgram, ...], batch: PacketBatch, *,
           n_classes: int, mode: str | None) -> PacketBatch:
    for packed in programs:
        batch = _classify_impl(packed, batch, n_classes=n_classes, mode=mode)
    return batch


class SequentialPathExecutor:
    """Apply each hop's partial program in path order on one device.

    The functional reference for every distributed decomposition: status
    codes and SVM partial sums ride the batch between "hops" exactly as they
    ride the wire.  ``jit=False`` keeps the eager op-by-op semantics (used by
    the deprecated ``run_sequential`` shim and semantics tests); the default
    jits the whole chain into one trace.
    """

    granularity = 1

    def __init__(self, device_programs: list[PackedProgram], *,
                 n_classes: int, mode: str | None = None,
                 jit: bool = True) -> None:
        self.programs = tuple(device_programs)
        if not self.programs:
            raise ValueError("need at least one device program")
        impl = functools.partial(_chain, n_classes=n_classes, mode=mode)
        self._jit = jit
        self._fn = jax.jit(impl) if jit else impl

    def classify(self, batch: PacketBatch) -> PacketBatch:
        return self._fn(self.programs, batch)

    def swap(self, device_programs: list[PackedProgram]) -> None:
        if len(device_programs) != len(self.programs):
            raise ValueError("device count changed — replan instead")
        self.programs = tuple(device_programs)

    def cache_size(self) -> int:
        return self._fn._cache_size() if self._jit else 0


class ShardedExecutor:
    """2D ``("switch", "port")`` mesh: pipeline the path, shard the traffic.

    Device layout (``n_switch * n_ports`` devices):

    * program state (``PackedProgram`` + its ``ExecImage``) is stacked on a
      leading switch axis, sharded ``P("switch")`` — replicated across the
      port axis (every port lane holds the full path's tables);
    * the packet batch ``[n_micro, B_mb, ...]`` is sharded ``P(None,
      "port")`` — each port lane carries ``B_mb / n_ports`` packets of every
      microbatch, the "many ingress ports" of a real switch;
    * inside the shard_map the ring pipeline runs along "switch" exactly as
      the 1D pipeline (``ppermute`` = the wire); the port axis needs no
      collective at all — port lanes are independent traffic.

    Compiled pipelines are memoized per ``n_micro``; batch-shape variation
    within one ``n_micro`` is handled by the jit cache (admission keeps that
    to O(log B) buckets).
    """

    def __init__(self, device_programs: list[PackedProgram], *,
                 n_classes: int, mode: str | None = None, n_ports: int = 1,
                 n_micro: int | None = None, devices=None) -> None:
        device_programs = list(device_programs)
        self.n_switch = len(device_programs)
        if self.n_switch < 1:
            raise ValueError("need at least one device program")
        self.n_ports = int(n_ports)
        if self.n_ports < 1:
            raise ValueError("need at least one port lane")
        self.n_micro = int(n_micro) if n_micro is not None else self.n_switch
        if self.n_micro < 1:
            raise ValueError("need at least one microbatch")
        need = self.n_switch * self.n_ports
        if devices is None:
            devices = jax.devices()[:need]
        if len(devices) < need:
            raise ValueError(
                f"need {need} devices ({self.n_switch} switches x "
                f"{self.n_ports} ports), have {len(devices)}")
        self.mesh = Mesh(
            np.asarray(devices[:need]).reshape(self.n_switch, self.n_ports),
            ("switch", "port"))
        self.n_classes = n_classes
        self.mode = mode
        self._runs: dict[int, object] = {}   # n_micro -> jitted pipeline
        self._put(device_programs)

    def _put(self, device_programs: list[PackedProgram]) -> None:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *device_programs)
        sharding = NamedSharding(self.mesh, P("switch"))
        self.packed = jax.tree.map(
            lambda x: jax.device_put(x, sharding), stacked)

    @property
    def granularity(self) -> int:
        # bucket must split into n_micro microbatches, each into n_ports shards
        return self.n_micro * self.n_ports

    def _build(self, n_micro: int):
        n_switch, n_classes, mode = self.n_switch, self.n_classes, self.mode
        n_steps = n_micro + n_switch - 1
        perm = [(i, (i + 1) % n_switch) for i in range(n_switch)]

        @functools.partial(
            _shard_map,
            mesh=self.mesh,
            in_specs=(P("switch"), P(None, "port")),
            out_specs=P(None, "switch", "port"),
        )
        def pipeline(packed_stack, micro):
            packed = jax.tree.map(lambda x: x[0], packed_stack)
            idx = jax.lax.axis_index("switch")

            def step(state, s):
                inj = jax.tree.map(
                    lambda x: jnp.take(x, jnp.minimum(s, n_micro - 1), axis=0),
                    micro)
                mb = jax.tree.map(
                    lambda a, b: jnp.where(idx == 0, a, b), inj, state)
                out = _classify_impl(packed, mb, n_classes=n_classes,
                                     mode=mode)
                nxt = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, "switch", perm), out)
                return nxt, out

            init = jax.tree.map(lambda x: jnp.zeros_like(x[0]), micro)
            _, outs = jax.lax.scan(step, init, jnp.arange(n_steps))
            # leading axis: steps; switch axis added on axis 1 by out_specs;
            # the port shards of each microbatch re-concatenate on axis 2.
            return jax.tree.map(lambda x: x[:, None], outs)

        return pipeline

    def _run_for(self, n_micro: int):
        fn = self._runs.get(n_micro)
        if fn is None:
            # jit at the memo-store site: one compiled pipeline per n_micro,
            # never rebuilt (PL005 retrace-hazard discipline).
            fn = self._runs[n_micro] = jax.jit(self._build(n_micro))
        return fn

    def run(self, microbatches: PacketBatch) -> PacketBatch:
        """Pipeline pre-split microbatches ``[n_micro, B_mb, ...]``; returns
        the classified packets as one flat ``[n_micro * B_mb]`` batch in the
        input packet order."""
        n_micro = int(microbatches.packet_id.shape[0])
        B_mb = int(microbatches.packet_id.shape[1])
        if B_mb % self.n_ports:
            raise ValueError(
                f"microbatch size {B_mb} not divisible by {self.n_ports} "
                "port lanes — admit through DataplaneRuntime")
        outs = self._run_for(n_micro)(self.packed, microbatches)
        # microbatch m exits the last switch at step m + n_switch - 1
        sel = jax.tree.map(
            lambda x: x[self.n_switch - 1:, self.n_switch - 1], outs)
        return jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), sel)

    def classify(self, batch: PacketBatch) -> PacketBatch:
        B = batch.batch
        if B % self.granularity:
            raise ValueError(
                f"batch {B} not a multiple of granularity "
                f"{self.granularity} — admit through DataplaneRuntime")
        n_micro = self.n_micro
        mbs = jax.tree.map(
            lambda x: x.reshape((n_micro, B // n_micro) + x.shape[1:]), batch)
        return self.run(mbs)

    def swap(self, device_programs: list[PackedProgram]) -> None:
        """Runtime reprogram: restack + reshard the new entry arrays (and
        their install-time exec images); every compiled pipeline is reused."""
        device_programs = list(device_programs)
        if len(device_programs) != self.n_switch:
            raise ValueError("device count changed — replan instead")
        self._put(device_programs)

    def cache_size(self) -> int:
        return sum(fn._cache_size() for fn in self._runs.values())


class PipelinedExecutor(ShardedExecutor):
    """The 1D pipeline: a ``ShardedExecutor`` with the port axis pinned to 1.

    Absorbs the old ``PipelinedPlane`` with its compile thrash fixed: the
    compiled pipeline for each ``n_micro`` lives in a memo table from
    ``__init__`` on, so alternating microbatch counts never rebuilds.
    """

    def __init__(self, device_programs: list[PackedProgram], *,
                 n_classes: int, mode: str | None = None,
                 n_micro: int | None = None, devices=None) -> None:
        super().__init__(device_programs, n_classes=n_classes, mode=mode,
                         n_ports=1, n_micro=n_micro, devices=devices)

"""``DataplaneRuntime`` — the facade every serving surface classifies through.

One object, two responsibilities:

* **admission** — pad each ragged request batch into its power-of-two bucket
  of passthrough packets (``admission.py``), run the executor on the bucket
  shape, slice the padding back off.  Arbitrary traffic sizes therefore cost
  at most O(log B) compiled traces per executor, and every caller — the
  ``ZooServer`` serving front, examples, benchmarks — shares the same
  bucketed shapes.
* **delegation** — execution goes to the pluggable ``Executor``
  (``executors.py``); swapping substrates (single switch → pipelined path →
  2D switch x port mesh) changes *which executor is plugged in*, never the
  caller.

Control-plane writes (``install``/``evict``) pass through to executors that
own a plane (``SingleSwitchExecutor``); mesh executors are constructed from
pre-built device programs and reprogrammed wholesale via ``swap``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np

from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile
from repro.runtime.admission import (
    bucket_ladder,
    bucket_size,
    coalesce,
    pad_to_bucket,
    split,
    trim,
)
from repro.runtime.executors import Executor, SingleSwitchExecutor

__all__ = ["DataplaneRuntime"]


class DataplaneRuntime:
    """Admission-controlled front over one pluggable executor."""

    def __init__(self, executor: Executor) -> None:
        self.executor = executor

    @classmethod
    def for_profile(cls, profile: PlaneProfile, *,
                    mode: str | None = None) -> "DataplaneRuntime":
        """Single-switch runtime over a fresh engine — the quickstart path."""
        return cls(SingleSwitchExecutor(profile, mode=mode))

    # ---------------------------------------------------------- admission
    def bucket(self, batch: int) -> int:
        """The padded shape a batch of ``batch`` packets executes at."""
        return bucket_size(batch, self.executor.granularity)

    def run(self, batch: PacketBatch) -> PacketBatch:
        """Classify a flat request batch of any size.

        Pads to the bucket shape (passthrough tail), executes, trims — the
        result stays on device (callers needing host values convert
        explicitly, e.g. ``np.asarray(out.rslt)``).  An empty batch (B = 0,
        the async front's empty submit) short-circuits: nothing to classify,
        nothing traced.
        """
        B = batch.batch
        if B == 0:
            return batch
        out = self.executor.classify(pad_to_bucket(batch, self.bucket(B)))
        return trim(out, B)

    def results(self, batch: PacketBatch) -> np.ndarray:
        """``run`` + the one host round-trip serving fronts usually want."""
        return np.asarray(self.run(batch).rslt)

    def run_host(self, batch: PacketBatch) -> PacketBatch:
        """``run`` variant that lands the result on host (numpy leaves).

        Same classification, different trim: the padded device result is
        transferred once and the admission tail sliced off in numpy.  A
        device-side trim (``run``) lazily compiles one slice kernel per
        (bucket, batch) shape pair per leaf — fine for a handful of batch
        shapes, but a live serving front sees a new ragged size on nearly
        every coalesced dispatch and would stall ~tens of ms of glue compile
        each time.  The async server always wants host values anyway, so it
        trims here for free.
        """
        B = batch.batch
        if B == 0:
            return batch
        # normalize leaves to host first so padding takes admission's numpy
        # branch unconditionally — a lone device-leaf request (the
        # single-batch coalesce fast path returns its input untouched)
        # must not fall back to the per-ragged-shape jnp glue
        batch = jax.tree.map(np.asarray, batch)
        out = self.executor.classify(pad_to_bucket(batch, self.bucket(B)))
        return jax.tree.map(lambda x: np.asarray(x)[:B], out)

    def warm(self, make_batch, max_batch: int) -> tuple[int, ...]:
        """Pre-trace every admission bucket up to ``bucket(max_batch)``.

        ``make_batch(b)`` must build a ``PacketBatch`` of exactly ``b``
        packets (serving fronts pass zero-filled FORWARD passthrough
        traffic — semantically invisible, same compiled shapes); each
        bucket is driven once through the ``run_host`` hot path, so the
        executable cache is warmed against exactly the shapes a batching
        policy can dispatch into.  Returns the warmed bucket ladder.
        Blocking compile work — serving fronts call this off-loop.
        """
        ladder = bucket_ladder(max_batch, self.executor.granularity)
        for b in ladder:
            self.run_host(make_batch(b))
        return ladder

    # ------------------------------------------------------------ coalesce
    # The multi-client seam batching policies dispatch through: several
    # per-client request batches run as ONE admitted batch (one bucket, one
    # executor call), then split back per client.  Policies thereby reuse
    # the power-of-two bucketing — and its O(log B) trace bound — instead of
    # inventing shapes of their own.
    @staticmethod
    def coalesce(batches: Sequence[PacketBatch]) -> tuple[PacketBatch, tuple[int, ...]]:
        """Concatenate per-client batches; returns (flat batch, demux offsets)."""
        return coalesce(batches)

    def run_coalesced(self, batches: Sequence[PacketBatch]) -> list[PacketBatch]:
        """Classify several per-client batches as one admitted batch.

        Equivalent to ``[self.run(b) for b in batches]`` packet-for-packet
        (classification is per-packet; pinned in ``tests/test_conformance.py``)
        but costs one executor dispatch for the whole group.
        """
        flat, offsets = coalesce(batches)
        return split(self.run(flat), offsets)

    # ------------------------------------------------------ control plane
    def install(self, program, *, vid: int | None = None,
                stages: set[int] | None = None) -> None:
        ex = self.executor
        if not hasattr(ex, "install"):
            raise NotImplementedError(
                f"{type(ex).__name__} is built from pre-installed device "
                "programs — reprogram it wholesale via swap()")
        ex.install(program, vid=vid, stages=stages)

    def evict(self, *, vid: int, kind: str = "all") -> None:
        ex = self.executor
        if not hasattr(ex, "evict"):
            raise NotImplementedError(
                f"{type(ex).__name__} is built from pre-installed device "
                "programs — reprogram it wholesale via swap()")
        ex.evict(vid=vid, kind=kind)

    def swap(self, device_programs) -> None:
        self.executor.swap(device_programs)

    def cache_size(self) -> int:
        """Compiled traces across the executor — with admission on, at most
        one per (n_micro, bucket) shape."""
        return self.executor.cache_size()

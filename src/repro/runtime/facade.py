"""``DataplaneRuntime`` — the facade every serving surface classifies through.

One object, two responsibilities:

* **admission** — pad each ragged request batch into its power-of-two bucket
  of passthrough packets (``admission.py``), run the executor on the bucket
  shape, slice the padding back off.  Arbitrary traffic sizes therefore cost
  at most O(log B) compiled traces per executor, and every caller — the
  ``ZooServer`` serving front, examples, benchmarks — shares the same
  bucketed shapes.
* **delegation** — execution goes to the pluggable ``Executor``
  (``executors.py``); swapping substrates (single switch → pipelined path →
  2D switch x port mesh) changes *which executor is plugged in*, never the
  caller.

Control-plane writes (``install``/``evict``) pass through to executors that
own a plane (``SingleSwitchExecutor``); mesh executors are constructed from
pre-built device programs and reprogrammed wholesale via ``swap``.
"""
from __future__ import annotations

import numpy as np

from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile
from repro.runtime.admission import bucket_size, pad_to_bucket, trim
from repro.runtime.executors import Executor, SingleSwitchExecutor

__all__ = ["DataplaneRuntime"]


class DataplaneRuntime:
    """Admission-controlled front over one pluggable executor."""

    def __init__(self, executor: Executor) -> None:
        self.executor = executor

    @classmethod
    def for_profile(cls, profile: PlaneProfile, *,
                    mode: str | None = None) -> "DataplaneRuntime":
        """Single-switch runtime over a fresh engine — the quickstart path."""
        return cls(SingleSwitchExecutor(profile, mode=mode))

    # ---------------------------------------------------------- admission
    def bucket(self, batch: int) -> int:
        """The padded shape a batch of ``batch`` packets executes at."""
        return bucket_size(batch, self.executor.granularity)

    def run(self, batch: PacketBatch) -> PacketBatch:
        """Classify a flat request batch of any size.

        Pads to the bucket shape (passthrough tail), executes, trims — the
        result stays on device (callers needing host values convert
        explicitly, e.g. ``np.asarray(out.rslt)``).
        """
        B = batch.batch
        out = self.executor.classify(pad_to_bucket(batch, self.bucket(B)))
        return trim(out, B)

    def results(self, batch: PacketBatch) -> np.ndarray:
        """``run`` + the one host round-trip serving fronts usually want."""
        return np.asarray(self.run(batch).rslt)

    # ------------------------------------------------------ control plane
    def install(self, program, *, vid: int | None = None,
                stages: set[int] | None = None) -> None:
        ex = self.executor
        if not hasattr(ex, "install"):
            raise NotImplementedError(
                f"{type(ex).__name__} is built from pre-installed device "
                "programs — reprogram it wholesale via swap()")
        ex.install(program, vid=vid, stages=stages)

    def evict(self, *, vid: int, kind: str = "all") -> None:
        ex = self.executor
        if not hasattr(ex, "evict"):
            raise NotImplementedError(
                f"{type(ex).__name__} is built from pre-installed device "
                "programs — reprogram it wholesale via swap()")
        ex.evict(vid=vid, kind=kind)

    def swap(self, device_programs) -> None:
        self.executor.swap(device_programs)

    def cache_size(self) -> int:
        """Compiled traces across the executor — with admission on, at most
        one per (n_micro, bucket) shape."""
        return self.executor.cache_size()

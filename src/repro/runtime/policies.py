"""Pluggable batching policies for the async serving front-end.

A serving front that accepts per-client ``submit()`` calls has to decide,
every time traffic is queued, *when* to cut a batch and *how much* of the
queue to take.  That decision is the whole latency/throughput trade-off of
online serving — so it is a policy object, not a hard-coded loop:

* ``ImmediatePolicy``        — cut a batch the instant anything is queued.
  Lowest queueing delay per request at low load; at high load every request
  pays one full dispatch (trace lookup + kernel launch + host demux), so the
  service rate caps out near ``1 / t_dispatch`` and the queue — and p99 —
  grow without bound.
* ``SizeOrDeadlinePolicy``   — classic size-or-timeout coalescing: flush
  when ``max_batch`` packets are queued *or* the oldest request has waited
  ``max_wait_us``.  Bounded added latency, amortized dispatch.
* ``AdaptiveBucketPolicy``   — widens its target batch to the next
  power-of-two **admission bucket** under sustained load and snaps back
  down when a deadline flush shows the load dropped.  Because targets are
  the same ``granularity * 2^k`` buckets admission pads to
  (``admission.bucket_size``), a widening target never mints new compiled
  shapes — the O(log B) trace bound is preserved by construction.

The protocol is synchronous and pure-by-inputs so policies are unit-testable
without an event loop; ``AsyncZooServer`` (``repro.serving.async_server``)
owns the clock and calls:

* ``wait_us(queued_packets, oldest_age_us)`` — ``<= 0`` means "cut a batch
  now"; a positive value is the longest the server may sleep waiting for
  more arrivals before asking again.
* ``drain(queued_packets)``  — how many packets the cut batch may take
  (whole requests are never split across batches).
* ``note_dispatch(packets, waited_us)`` — feedback after each dispatch;
  adaptive policies update their load estimate here.
"""
from __future__ import annotations

import collections
from typing import Protocol, runtime_checkable

import numpy as np

from repro.runtime.admission import bucket_size

__all__ = [
    "BatchingPolicy",
    "ImmediatePolicy",
    "SizeOrDeadlinePolicy",
    "AdaptiveBucketPolicy",
    "SloAutoscaler",
]


@runtime_checkable
class BatchingPolicy(Protocol):
    """What the async serving loop needs from a coalescing strategy."""

    def wait_us(self, queued_packets: int, oldest_age_us: float) -> float:
        """<= 0: dispatch now; > 0: wait at most this long for more traffic."""
        ...

    def drain(self, queued_packets: int) -> int:
        """Max packets the next batch may take (>= 1 request regardless)."""
        ...

    def note_dispatch(self, packets: int, waited_us: float) -> None:
        """Feedback after a dispatch of ``packets`` that waited ``waited_us``."""
        ...


class ImmediatePolicy:
    """No coalescing at all: one request per dispatch, immediately.

    ``drain`` returns 1 — the serving loop always takes at least one whole
    request, so each dispatch carries exactly the oldest queued request.
    This is the per-request baseline every batching policy is measured
    against; under overload its queue (and p99) grow without bound while
    coalescing policies amortize the dispatch cost away.
    """

    def wait_us(self, queued_packets: int, oldest_age_us: float) -> float:
        return 0.0

    def drain(self, queued_packets: int) -> int:
        return 1

    def note_dispatch(self, packets: int, waited_us: float) -> None:
        pass


class SizeOrDeadlinePolicy:
    """Flush at ``max_batch`` packets or when the oldest request has waited
    ``max_wait_us`` — whichever comes first."""

    def __init__(self, max_batch: int = 64, max_wait_us: float = 2_000.0) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_us < 0:
            raise ValueError(f"max_wait_us must be >= 0, got {max_wait_us}")
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)

    def wait_us(self, queued_packets: int, oldest_age_us: float) -> float:
        if queued_packets >= self.max_batch:
            return 0.0
        return self.max_wait_us - oldest_age_us

    def drain(self, queued_packets: int) -> int:
        return min(queued_packets, self.max_batch)

    def note_dispatch(self, packets: int, waited_us: float) -> None:
        pass


class AdaptiveBucketPolicy:
    """Size-or-deadline whose size target tracks offered load, snapped to
    admission buckets.

    An EWMA of per-dispatch batch size estimates demand; the flush target is
    that estimate rounded **up** to its power-of-two admission bucket
    (``bucket_size``, in units of the executor's ``granularity``), clamped
    to ``[min_batch, max_batch]``.  Sustained load therefore widens the
    admission bucket the server fills before cutting a batch — bigger
    batches, same compiled shapes.

    When load drops, the estimate must not bleed down one EWMA step per
    sparse request (each paying the full deadline meanwhile): a **deadline
    flush below target** — the batch waited out ``max_wait_us`` and still
    didn't fill — is direct evidence the demand estimate overshot, so
    ``note_dispatch`` snaps the estimate down to the observed arrivals.  At
    most one sparse dispatch after a burst pays the full deadline.
    """

    def __init__(self, *, min_batch: int = 1, max_batch: int = 256,
                 max_wait_us: float = 2_000.0, alpha: float = 0.3,
                 granularity: int = 1) -> None:
        if not (1 <= min_batch <= max_batch):
            raise ValueError(
                f"need 1 <= min_batch <= max_batch, got {min_batch}, {max_batch}")
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.min_batch = int(min_batch)
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)
        self.alpha = float(alpha)
        self.granularity = int(granularity)
        self._demand = float(min_batch)

    @property
    def target_batch(self) -> int:
        """Current flush target: the demand estimate's admission bucket,
        never above ``max_batch`` — ``drain`` can't cut more than
        ``max_batch``, so a larger target would wait out the deadline on
        every dispatch without ever being reachable."""
        demand = min(max(self._demand, self.min_batch), self.max_batch)
        return min(bucket_size(int(round(demand)), self.granularity),
                   self.max_batch)

    def wait_us(self, queued_packets: int, oldest_age_us: float) -> float:
        if queued_packets >= self.target_batch:
            return 0.0
        return self.max_wait_us - oldest_age_us

    def drain(self, queued_packets: int) -> int:
        return min(queued_packets, self.max_batch)

    def note_dispatch(self, packets: int, waited_us: float) -> None:
        if waited_us >= self.max_wait_us and packets < self.target_batch:
            # waited the whole deadline and the target bucket still didn't
            # fill: load dropped — snap to what a full window actually held
            self._demand = float(packets)
        else:
            self._demand = ((1 - self.alpha) * self._demand
                            + self.alpha * packets)


class SloAutoscaler:
    """p99-vs-SLO lane controller for the continuous serving engine.

    Decides when the ``("switch", "port")`` mesh should widen or narrow its
    port lanes: sustained p99 latency **above** ``slo_p99_ms`` (``patience``
    consecutive over-SLO observations on a full evidence window) widens to
    the next lane count in ``lanes``; sustained p99 **below**
    ``narrow_margin * slo_p99_ms`` narrows back, releasing devices.  A
    ``cooldown`` of observations after each change — and a cleared evidence
    window — keeps the controller from flapping on the transient while the
    freshly-swapped executor settles.

    Pure-by-inputs like the batching policies: ``observe`` takes one
    request latency and returns the new lane count when (and only when) a
    scale decision fires, else ``None``.  The engine owns the actual
    executor swap — quiesce, pre-warm the incoming lane's buckets, swap —
    so this class stays unit-testable without an event loop or a mesh.
    """

    def __init__(self, *, slo_p99_ms: float, lanes: tuple[int, ...] = (1, 2, 4),
                 window: int = 64, patience: int = 4,
                 narrow_margin: float = 0.5, cooldown: int = 32) -> None:
        if slo_p99_ms <= 0:
            raise ValueError(f"slo_p99_ms must be > 0, got {slo_p99_ms}")
        if len(lanes) < 1 or list(lanes) != sorted(set(lanes)):
            raise ValueError(
                f"lanes must be distinct and ascending, got {lanes}")
        if not (0.0 < narrow_margin < 1.0):
            raise ValueError(
                f"narrow_margin must be in (0, 1), got {narrow_margin}")
        if patience < 1 or window < 2 or cooldown < 0:
            raise ValueError("need patience >= 1, window >= 2, cooldown >= 0")
        self.slo_p99_ms = float(slo_p99_ms)
        self.lanes = tuple(int(l) for l in lanes)
        self.patience = int(patience)
        self.narrow_margin = float(narrow_margin)
        self.cooldown = int(cooldown)
        self.lane = self.lanes[0]
        self._lat = collections.deque(maxlen=int(window))
        self._hot = 0
        self._cold = 0
        self._since_change = self.cooldown   # first decision needs no wait

    @property
    def p99_ms(self) -> float:
        """Current-window p99 estimate (NaN until the window has evidence)."""
        if len(self._lat) < 2:
            return float("nan")
        return float(np.percentile(np.asarray(self._lat, float), 99))

    def observe(self, latency_ms: float) -> int | None:
        """Feed one completed request's end-to-end latency.  Returns the
        new lane count when a widen/narrow decision fires, else ``None``."""
        self._lat.append(float(latency_ms))
        self._since_change += 1
        if (len(self._lat) < self._lat.maxlen
                or self._since_change < self.cooldown):
            return None          # not enough post-change evidence yet
        p99 = self.p99_ms
        if p99 > self.slo_p99_ms:
            self._hot += 1
            self._cold = 0
        elif p99 < self.narrow_margin * self.slo_p99_ms:
            self._cold += 1
            self._hot = 0
        else:
            self._hot = self._cold = 0
        i = self.lanes.index(self.lane)
        if self._hot >= self.patience and i + 1 < len(self.lanes):
            return self._decide(self.lanes[i + 1])
        if self._cold >= self.patience and i > 0:
            return self._decide(self.lanes[i - 1])
        return None

    def _decide(self, lane: int) -> int:
        self.lane = lane
        self._hot = self._cold = 0
        self._since_change = 0
        self._lat.clear()        # old-lane latencies are not evidence now
        return lane

from repro.serving.async_server import AsyncResult, AsyncZooServer
from repro.serving.engine import ContinuousZooServer
from repro.serving.fleet import FleetExecutor, FleetRuntime
from repro.serving.loadgen import LoadReport, arrival_times, open_loop
from repro.serving.serve import ZooServer, make_decode_step, make_prefill_step

__all__ = ["AsyncResult", "AsyncZooServer", "ContinuousZooServer",
           "FleetExecutor", "FleetRuntime", "LoadReport", "ZooServer",
           "arrival_times", "make_decode_step", "make_prefill_step",
           "open_loop"]

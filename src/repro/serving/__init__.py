from repro.serving.serve import ZooServer, make_decode_step, make_prefill_step

__all__ = ["ZooServer", "make_decode_step", "make_prefill_step"]

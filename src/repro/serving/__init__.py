from repro.serving.async_server import AsyncResult, AsyncZooServer
from repro.serving.fleet import FleetExecutor, FleetRuntime
from repro.serving.serve import ZooServer, make_decode_step, make_prefill_step

__all__ = ["AsyncResult", "AsyncZooServer", "FleetExecutor", "FleetRuntime",
           "ZooServer", "make_decode_step", "make_prefill_step"]

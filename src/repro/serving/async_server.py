"""``AsyncZooServer`` — the live request-stream front over a model zoo.

The paper's serving story is end-to-end: models deploy once, then traffic
arrives *continuously* and is classified at line rate (§1, §6).  The batch
entry points (``ZooServer.classify``, the examples) model one tenant handing
the plane a ready-made batch; this module models the plane's actual ingress
side — many concurrent clients each submitting small ragged batches on an
asyncio event loop, a ``BatchingPolicy`` (``repro.runtime.policies``)
deciding when to cut a batch, and the runtime's coalesce seam
(``DataplaneRuntime.coalesce`` / ``run``) turning the cut into exactly one
admitted bucket dispatch.

Data path of one dispatch::

    submit(feats) --+                            +--> future.set_result
    submit(feats) --+-> queue -> policy decides -+--> future.set_result
    submit(feats) --+   (cut)    coalesce->run   +--> future.set_result
                                 demux rslt/codes/svm_acc by offsets

Invariants (pinned in ``tests/test_async_serving.py`` and the conformance
harness ``tests/test_conformance.py``):

* **bit-identity** — every request's ``rslt``/``codes``/``svm_acc`` equal a
  synchronous ``DataplaneRuntime`` classify of the same packets, whatever
  the policy coalesced them with;
* **whole requests** — a client's batch is never split across dispatches;
* **O(log B) traces** — dispatch sizes hit the executor only through
  admission bucketing, so a traffic storm mints no new compiled shapes;
* the blocking executor call runs in a worker thread
  (``loop.run_in_executor``), so the event loop keeps accepting submits
  while a batch classifies — that concurrency is where size-or-deadline
  coalescing beats per-request dispatch at high offered load
  (``benchmarks/serve_async.py``);
* **no future is left pending** — ``stop()`` flushes the queue through a
  final dispatch, and any straggler that slipped in around the final drain
  cut (or survived an externally-cancelled dispatch loop) is
  fail-or-flushed deterministically before ``stop()`` returns.

Hold ownership: ``drain()``/``hold()`` give the control plane an exclusive
dispatch barrier.  ``stop()`` on a held server must still flush (a dying
server cannot wait on a holder that may never come back), so it *breaks*
the hold — and the owner is told: its next ``release()`` raises
``RuntimeError`` instead of silently resuming a server that already
flushed through whatever half-installed state the holder was protecting.

``ContinuousZooServer`` (``repro.serving.engine``) extends this class with
a persistent slot-pool dispatch engine; the cut/complete helpers below
(``_next_cut`` / ``_finish_dispatch`` / ``_fail``) are the shared seam.

Latency accounting: each request carries ``t_submit`` / ``t_dispatch`` /
``t_done`` (event-loop monotonic clock); ``latency_stats()`` aggregates
p50/p99/p99.9 end-to-end latency, queue wait, and mean coalesced batch
size.  Empty submits (B = 0) resolve without a dispatch but are counted —
rates and percentiles cover every accepted request, not just the queued
ones.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses

import numpy as np

from repro.core.packets import PacketBatch
from repro.runtime import DataplaneRuntime, ImmediatePolicy
from repro.runtime.policies import BatchingPolicy
from repro.serving.serve import ZooServer

__all__ = ["AsyncResult", "AsyncZooServer"]


@dataclasses.dataclass
class AsyncResult:
    """One request's demuxed classification + its latency accounting."""

    rslt: np.ndarray      # int32 [B]
    codes: np.ndarray     # uint32 [B, T]
    svm_acc: np.ndarray   # int32 [B, H]
    t_submit: float       # event-loop clock (s)
    t_dispatch: float
    t_done: float

    @property
    def latency_s(self) -> float:
        """End-to-end: submit -> result available."""
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> float:
        """Coalescing delay the batching policy charged this request."""
        return self.t_dispatch - self.t_submit


class _Pending:
    __slots__ = ("pb", "future", "t_submit")

    def __init__(self, pb: PacketBatch, future: asyncio.Future,
                 t_submit: float) -> None:
        self.pb = pb
        self.future = future
        self.t_submit = t_submit


class AsyncZooServer:
    """Asyncio serving front over one ``ZooServer`` / ``DataplaneRuntime``.

    Construction does not start serving; use ``async with`` (or ``start()``
    / ``stop()``).  ``stop()`` drains: queued requests are flushed through a
    final dispatch before the loop exits, so no future is left pending.

    Control-plane writes (``install`` / ``evict``) pass through to the
    wrapped ``ZooServer`` — an install between dispatches is exactly the
    paper's runtime reprogrammability, now under live traffic.
    """

    def __init__(self, zoo: ZooServer, *,
                 policy: BatchingPolicy | None = None,
                 stats_window: int = 100_000) -> None:
        self.zoo = zoo
        self.policy = policy if policy is not None else ImmediatePolicy()
        self._queue: collections.deque[_Pending] = collections.deque()
        self._queued_packets = 0
        self._arrival: asyncio.Event | None = None
        self._hold_gate: asyncio.Event | None = None   # cleared = held
        self._idle: asyncio.Event | None = None        # set = no dispatch in flight
        self._inflight = 0
        self._task: asyncio.Task | None = None
        self._closing = False
        self._held = False            # a drain()/hold() owner is active
        self._hold_broken = False     # stop() force-released an owned hold
        self._stats_sources: dict[str, object] = {}
        # bounded: a long-lived front at line rate must not grow its
        # accounting without limit (stats_window = most recent requests /
        # dispatches retained; counters below keep lifetime totals)
        self._dispatch_log: collections.deque[tuple[int, int, float, float]] \
            = collections.deque(maxlen=stats_window)
        self._latencies: collections.deque[float] = \
            collections.deque(maxlen=stats_window)
        self._queue_waits: collections.deque[float] = \
            collections.deque(maxlen=stats_window)
        self._total_requests = 0
        self._total_dispatches = 0

    @property
    def runtime(self) -> DataplaneRuntime:
        return self.zoo.runtime

    # ----------------------------------------------------------- lifecycle
    async def start(self) -> "AsyncZooServer":
        if self._task is not None:
            raise RuntimeError("server already started")
        self._closing = False
        self._held = False
        self._hold_broken = False
        self._arrival = asyncio.Event()
        self._hold_gate = asyncio.Event()
        self._hold_gate.set()
        self._idle = asyncio.Event()
        self._idle.set()
        self._task = asyncio.get_running_loop().create_task(
            self._dispatch_loop(), name="async-zoo-dispatch")
        return self

    async def stop(self) -> None:
        """Flush queued requests, then stop the dispatch loop.

        An owned ``hold()``/``drain()`` barrier is *broken* so the final
        drain can flush; the owner's next ``release()`` raises.  Requests
        that raced past the final drain cut — or were stranded by an
        externally-cancelled dispatch loop — are fail-or-flushed before
        this returns: no future is ever left pending.
        """
        if self._task is None:
            return
        self._closing = True
        if self._held:
            # a control-plane drain still owns the barrier; break it and
            # remember — the owner's release() must raise, not silently
            # resume a server that flushed through its half-done reinstall
            self._held = False
            self._hold_broken = True
        self._hold_gate.set()
        self._arrival.set()
        task, self._task = self._task, None
        try:
            await task
        except asyncio.CancelledError:
            if not task.cancelled():
                raise           # stop() itself was cancelled
            # the dispatch loop was killed out from under us (external
            # cancel / loop teardown): its queue is flushed below
        await self._flush_stragglers()

    async def __aenter__(self) -> "AsyncZooServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -------------------------------------------------------- control plane
    def install(self, model_or_program, *, vid: int, tag: str = "") -> int:
        return self.zoo.install(model_or_program, vid=vid, tag=tag)

    def evict(self, *, vid: int, kind: str = "all") -> None:
        self.zoo.evict(vid=vid, kind=kind)

    # ------------------------------------------------------ quiesce seam
    # The control plane's drain/reinstall barrier (repro.runtime.control):
    # hold() pauses cutting new dispatches (submits keep queuing), drain()
    # additionally waits for every in-flight dispatch to land, release()
    # resumes.  Nothing is dropped — held requests dispatch after release.
    def hold(self) -> None:
        """Pause new dispatches; queued and new submits wait for release()."""
        if self._hold_gate is None:
            raise RuntimeError("AsyncZooServer is not serving")
        if self._closing:
            # a hold taken now would stall the final flush forever
            raise RuntimeError("AsyncZooServer is stopping — hold unavailable")
        self._held = True
        self._hold_gate.clear()

    def release(self) -> None:
        """Resume dispatching after a hold().  Raises if ``stop()`` broke
        the hold meanwhile — the barrier the caller thought it owned did
        not survive shutdown, and whatever it was protecting (a reinstall,
        a swap) may have raced the final flush."""
        if self._hold_gate is None:
            raise RuntimeError("AsyncZooServer is not serving")
        if self._hold_broken:
            self._hold_broken = False
            raise RuntimeError(
                "hold was broken by stop(): the server flushed and shut "
                "down while the control plane still owned the drain barrier")
        self._held = False
        self._hold_gate.set()

    async def drain(self) -> None:
        """Quiesce for a control-plane write: hold new dispatches and wait
        until every in-flight dispatch completes.  The caller owns the
        hold and must release() when its reinstall is done.  Raises
        ``RuntimeError`` on a stopping server — a drain barrier cannot be
        granted while the final flush is running."""
        if self._hold_gate is None:
            raise RuntimeError("AsyncZooServer is not serving")
        if self._closing or self._task is None or self._task.done():
            raise RuntimeError(
                "AsyncZooServer is stopping — drain unavailable")
        self.hold()
        await self._idle.wait()

    def add_stats_source(self, name: str, fn) -> None:
        """Register a named zero-arg stats provider whose dict is merged
        into ``latency_stats()`` under ``name`` — the control plane's
        failure/replan/drain counters ride this path."""
        if name in self._stats_sources:
            raise ValueError(f"stats source {name!r} already registered")
        self._stats_sources[name] = fn

    # -------------------------------------------------------------- submit
    async def submit(self, features, *, mid: int = 0, vid=0) -> AsyncResult:
        """Classify one client's ragged feature batch; resolves when the
        batching policy's dispatch completes."""
        return await self.submit_batch(
            self.zoo.make_request(features, mid=mid, vid=vid))

    async def submit_batch(self, pb: PacketBatch) -> AsyncResult:
        """Classify one pre-built ``PacketBatch`` (arbitrary ptype/vid mixes
        — the conformance harness's entry point)."""
        if self._task is None or self._task.done() or self._closing:
            # _task.done() covers a dispatch loop that died out from under
            # us (external cancel): enqueueing now would strand the future
            # until stop() — fail fast instead
            raise RuntimeError("AsyncZooServer is not serving — use "
                               "'async with AsyncZooServer(zoo) as srv'")
        loop = asyncio.get_running_loop()
        now = loop.time()
        if pb.batch == 0:
            # empty submit: nothing to classify, resolve immediately — but
            # it is still an accepted request; rates and percentiles must
            # not silently exclude it
            self._total_requests += 1
            self._latencies.append(0.0)
            self._queue_waits.append(0.0)
            return AsyncResult(
                rslt=np.empty((0,), np.int32),
                codes=np.asarray(pb.codes, np.uint32),
                svm_acc=np.asarray(pb.svm_acc, np.int32),
                t_submit=now, t_dispatch=now, t_done=now)
        pending = _Pending(pb, loop.create_future(), now)
        self._queue.append(pending)
        self._queued_packets += pb.batch
        self._arrival.set()
        return await pending.future

    # ------------------------------------------------------------ dispatch
    def _classify_flat(self, flat: PacketBatch):
        # run_host: one padded-result transfer, host-side trim — no
        # per-ragged-shape slice compiles on the serving hot path
        out = self.runtime.run_host(flat)
        return out.rslt, out.codes, out.svm_acc

    def _cut_batch(self) -> list[_Pending]:
        """Pop whole requests up to the policy's drain limit (>= 1 request)."""
        limit = max(int(self.policy.drain(self._queued_packets)), 1)
        reqs: list[_Pending] = []
        taken = 0
        while self._queue and (
                not reqs or taken + self._queue[0].pb.batch <= limit):
            p = self._queue.popleft()
            reqs.append(p)
            taken += p.pb.batch
        self._queued_packets -= taken
        return reqs

    @staticmethod
    def _fail(reqs: list[_Pending], exc: BaseException) -> None:
        for p in reqs:
            if not p.future.done():
                p.future.set_exception(exc)

    async def _next_cut(self, loop):
        """Policy wait phase + cut + coalesce: the front half of one
        dispatch.  Returns ``(reqs, flat, offsets)``, or ``None`` when the
        queue emptied under the wait.  A broken ``BatchingPolicy`` (it is a
        user-implementable protocol) or coalesce failure fails the affected
        futures loudly and returns ``None`` — the caller keeps serving.
        (CancelledError is a BaseException and still propagates.)"""
        reqs: list[_Pending] = []
        try:
            # hold for more traffic until the policy says cut (or the
            # server is draining on stop())
            while self._queue and not self._closing:
                age_us = (loop.time() - self._queue[0].t_submit) * 1e6
                w = self.policy.wait_us(self._queued_packets, age_us)
                if w <= 0:
                    break
                self._arrival.clear()
                try:
                    await asyncio.wait_for(self._arrival.wait(), w / 1e6)
                except (asyncio.TimeoutError, TimeoutError):
                    break   # deadline: cut what we have
            if not self._queue:
                return None
            reqs = self._cut_batch()
            flat, offsets = self.runtime.coalesce([p.pb for p in reqs])
        except Exception as e:
            if not reqs:        # failed before the cut: fail the queue
                reqs = list(self._queue)
                self._queue.clear()
                self._queued_packets = 0
            self._fail(reqs, e)
            return None
        return reqs, flat, offsets

    def _finish_dispatch(self, reqs: list[_Pending], offsets, batch_packets,
                         rslt, codes, acc, t_dispatch: float, t_done: float,
                         waited_us: float) -> None:
        """Back half of one dispatch: policy feedback, accounting, demux.
        A broken ``note_dispatch`` hook fails the batch's futures (the
        results are already computed, but the policy contract was violated
        — surface it) and leaves the server serving."""
        try:
            self.policy.note_dispatch(batch_packets, waited_us)
        except Exception as e:   # broken feedback hook: surface it
            self._fail(reqs, e)
            return
        self._dispatch_log.append(
            (batch_packets, len(reqs), waited_us, t_done - t_dispatch))
        self._total_dispatches += 1
        for p, lo, hi in zip(reqs, offsets, offsets[1:]):
            self._total_requests += 1
            self._latencies.append(t_done - p.t_submit)
            self._queue_waits.append(t_dispatch - p.t_submit)
            if not p.future.done():   # client may have been cancelled
                p.future.set_result(AsyncResult(
                    rslt=rslt[lo:hi], codes=codes[lo:hi],
                    svm_acc=acc[lo:hi], t_submit=p.t_submit,
                    t_dispatch=t_dispatch, t_done=t_done))

    async def _dispatch_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            if not self._queue:
                if self._closing:
                    return
                self._arrival.clear()
                await self._arrival.wait()
                continue
            if not self._hold_gate.is_set():
                # held by the control plane's drain/reinstall barrier;
                # stop() sets the gate, so a closing server still flushes
                await self._hold_gate.wait()
                continue
            cut = await self._next_cut(loop)
            if cut is None:
                continue
            reqs, flat, offsets = cut
            t_dispatch = loop.time()
            waited_us = (t_dispatch - reqs[0].t_submit) * 1e6
            self._inflight += 1
            self._idle.clear()
            try:
                rslt, codes, acc = await loop.run_in_executor(
                    None, self._classify_flat, flat)
            except Exception as e:  # executor died: fail this batch's futures
                self._fail(reqs, e)
                continue
            finally:
                self._inflight -= 1
                if self._inflight == 0:
                    self._idle.set()
            self._finish_dispatch(reqs, offsets, flat.batch, rslt, codes,
                                  acc, t_dispatch, loop.time(), waited_us)

    async def _flush_stragglers(self) -> None:
        """Deterministic fail-or-flush of requests still queued after the
        dispatch loop exited — the shutdown-race backstop.  Each round is
        classified through the same ``run_host`` path (flush), and any
        failure fails that round's futures (fail); either way every future
        resolves before ``stop()`` returns."""
        loop = asyncio.get_running_loop()
        while self._queue:
            reqs = list(self._queue)
            self._queue.clear()
            self._queued_packets = 0
            try:
                flat, offsets = self.runtime.coalesce([p.pb for p in reqs])
                t_dispatch = loop.time()
                waited_us = (t_dispatch - reqs[0].t_submit) * 1e6
                rslt, codes, acc = await loop.run_in_executor(
                    None, self._classify_flat, flat)
            except Exception as e:
                self._fail(reqs, e)
                continue
            self._finish_dispatch(reqs, offsets, flat.batch, rslt, codes,
                                  acc, t_dispatch, loop.time(), waited_us)

    # --------------------------------------------------------------- stats
    def latency_stats(self) -> dict:
        """Aggregate latency accounting: p50/p99/p99.9 end-to-end, queue
        wait, dispatch count, and mean coalesced batch size.  ``requests``
        / ``dispatches`` are lifetime totals; the distribution numbers
        cover the most recent ``stats_window`` of each.  Registered stats
        sources (``add_stats_source``) are merged in as nested dicts — the
        control plane's counters appear under ``"control"``, the
        continuous engine's under ``"engine"``."""
        lat = np.asarray(self._latencies, float)
        if lat.size == 0:
            out = {"requests": self._total_requests,
                   "dispatches": self._total_dispatches}
        else:
            waits = np.asarray(self._queue_waits, float)
            batches = np.asarray(
                [b for b, _, _, _ in self._dispatch_log], float)
            out = {
                "requests": self._total_requests,
                "dispatches": self._total_dispatches,
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3),
                "p999_ms": float(np.percentile(lat, 99.9) * 1e3),
                "mean_ms": float(lat.mean() * 1e3),
                "p50_wait_ms": float(np.percentile(waits, 50) * 1e3),
                "mean_batch_packets": float(batches.mean())
                if batches.size else 0.0,
            }
        for name, fn in self._stats_sources.items():
            out[name] = fn()
        return out

"""``ContinuousZooServer`` — persistent continuous-batching dispatch engine.

``AsyncZooServer`` (PR 5) dispatches one cut at a time: the loop cuts a
batch, awaits the executor call, demuxes, and only then looks at the queue
again — so while a result demuxes, arrivals sit queued and the executor
idles.  This engine makes serving *continuous*, the MLPerf-offline shape
the ROADMAP names:

* **slot pool** — a fixed pool of ``n_slots`` in-flight dispatch slots fed
  by a bounded ``asyncio.Queue``.  The cutter coroutine keeps cutting (the
  same ``BatchingPolicy`` wait/cut/coalesce seam as the base class) while
  slot workers run the blocking executor calls on a dedicated thread pool
  and demux — a new batch cuts while the previous result is still
  demuxing, and on a multi-core host ``n_slots`` dispatches overlap.
* **warmed-executable cache keyed by admission bucket** — before taking
  traffic the engine drives every ``granularity * 2^k`` bucket the policy
  can dispatch into through ``DataplaneRuntime.warm`` (zero-filled FORWARD
  passthrough batches — semantically invisible, identical compiled
  shapes), so no live dispatch ever pays first-touch compile.
* **SLO-driven lane autoscaling** — a ``SloAutoscaler``
  (``repro.runtime.policies``) watches request p99 against a target; when
  sustained load blows the SLO the engine widens the ``("switch", "port")``
  mesh to the next executor in ``lane_pool`` (and narrows back when load
  drops).  The swap is safe by sequencing: pre-warm the incoming lane's
  buckets off-loop, quiesce (wait for every in-flight slot), swap the
  runtime's executor, resume — no dispatch ever straddles two lane widths,
  so answers stay bit-identical through scale events (pinned in
  ``tests/test_engine.py``; every ``lane_pool`` executor must be
  programmed with the same zoo).

Everything the base class guarantees still holds — bit-identity, whole
requests, O(log B) traces, the hold/drain/release quiesce seam (``drain``
waits for *all* slots), deterministic fail-or-flush on ``stop()`` — and the
204-draw conformance harness runs this engine alongside the base server.
Shape glue stays numpy-side (planelint PL002) and nothing blocks inside
``async def`` (PL004): executor calls and warmup ride the slot thread pool.

Engine stats merge into ``latency_stats()`` under ``"engine"``: slot
count, current lanes, scale events, warmed buckets, and the peak number of
concurrently *executing* dispatches (the overlap the slot pool buys).
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses

import numpy as np

from repro.core.packets import PacketBatch
from repro.runtime import DataplaneRuntime
from repro.runtime.executors import Executor
from repro.runtime.policies import BatchingPolicy, SloAutoscaler
from repro.serving.async_server import AsyncZooServer, _Pending
from repro.serving.serve import ZooServer

__all__ = ["ContinuousZooServer"]


class _Work:
    """One cut batch in flight between the cutter and a slot worker."""

    __slots__ = ("reqs", "flat", "offsets")

    def __init__(self, reqs: list[_Pending], flat: PacketBatch,
                 offsets: tuple[int, ...]) -> None:
        self.reqs = reqs
        self.flat = flat
        self.offsets = offsets


class ContinuousZooServer(AsyncZooServer):
    """Continuous-batching front: cutter + slot pool over one runtime.

    ``warm_max_batch`` bounds the pre-traced bucket ladder; it defaults to
    the policy's ``max_batch`` when it has one (``SizeOrDeadlinePolicy`` /
    ``AdaptiveBucketPolicy``), else warming is skipped.  ``lane_pool`` maps
    lane count -> ``Executor`` (all programmed identically); with an
    ``autoscaler`` the engine starts on ``autoscaler.lane`` and swaps
    between them under quiesce.
    """

    def __init__(self, zoo: ZooServer, *,
                 policy: BatchingPolicy | None = None,
                 n_slots: int = 2,
                 warm: bool = True,
                 warm_max_batch: int | None = None,
                 lane_pool: dict[int, Executor] | None = None,
                 autoscaler: SloAutoscaler | None = None,
                 stats_window: int = 100_000) -> None:
        super().__init__(zoo, policy=policy, stats_window=stats_window)
        if n_slots < 1:
            raise ValueError(f"need n_slots >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        self.lane_pool = dict(lane_pool) if lane_pool else None
        self.autoscaler = autoscaler
        if autoscaler is not None:
            if not self.lane_pool:
                raise ValueError("an autoscaler needs a lane_pool to scale")
            missing = sorted(set(autoscaler.lanes) - set(self.lane_pool))
            if missing:
                raise ValueError(
                    f"autoscaler lanes {missing} missing from lane_pool")
        if warm_max_batch is None and warm:
            warm_max_batch = getattr(self.policy, "max_batch", None)
        self._warm_to = int(warm_max_batch) if warm and warm_max_batch else None
        self._warmed: dict[int, tuple[int, ...]] = {}   # id(executor) -> ladder
        self._slots_q: asyncio.Queue | None = None
        self._slot_tasks: list[asyncio.Task] = []
        self._pool: concurrent.futures.ThreadPoolExecutor | None = None
        self._pending_lanes: int | None = None
        self._lanes = autoscaler.lane if autoscaler is not None else \
            (min(self.lane_pool) if self.lane_pool else 1)
        self._executing = 0
        self._peak_executing = 0
        self._scale_ups = 0
        self._scale_downs = 0
        self.add_stats_source("engine", self._engine_stats)

    # ----------------------------------------------------------- lifecycle
    async def start(self) -> "ContinuousZooServer":
        await super().start()       # events + the cutter task (_dispatch_loop)
        loop = asyncio.get_running_loop()
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.n_slots, thread_name_prefix="dispatch-slot")
        # bounded: the cutter may run at most n_slots cuts ahead of the
        # slowest slot — backpressure instead of unbounded coalesced
        # batches piling up behind a stalled executor
        self._slots_q = asyncio.Queue(maxsize=self.n_slots)
        self._slot_tasks = [
            loop.create_task(self._slot_worker(), name=f"dispatch-slot-{i}")
            for i in range(self.n_slots)]
        if self.lane_pool is not None:
            self.runtime.executor = self.lane_pool[self._lanes]
        # warm the active executor's bucket ladder off-loop: first-touch
        # compile happens before the first live dispatch, not under it
        await loop.run_in_executor(
            self._pool, self._warm_one, self.runtime.executor)
        return self

    # -------------------------------------------------- warmed-bucket cache
    def _passthrough(self, b: int) -> PacketBatch:
        """A zero-filled FORWARD batch of ``b`` packets: the plane forwards
        it untouched (admission's padding invariant), so warming classifies
        nothing — it only mints the bucket's executable."""
        pb = self.zoo.make_request(
            np.zeros((b, self.zoo.profile.max_features), np.int32))
        return dataclasses.replace(pb, ptype=np.zeros((b,), np.int32))

    def _warm_one(self, executor: Executor) -> tuple[int, ...]:
        """Pre-trace ``executor``'s bucket ladder (blocking; pool thread).
        Keyed per executor so each lane in the pool warms exactly once."""
        if self._warm_to is None:
            return ()
        key = id(executor)
        if key not in self._warmed:
            # a throwaway facade over the target executor: jit caches live
            # in the executor itself, so warming through it warms the lane
            self._warmed[key] = DataplaneRuntime(executor).warm(
                self._passthrough, self._warm_to)
        return self._warmed[key]

    @property
    def warmed_buckets(self) -> tuple[int, ...]:
        """Bucket ladder warmed for the currently active executor."""
        return self._warmed.get(id(self.runtime.executor), ())

    # --------------------------------------------------------- autoscaling
    @property
    def lanes(self) -> int:
        """Current port-lane width (1 when no lane_pool is configured)."""
        return self._lanes

    async def _apply_scale(self, loop) -> None:
        lanes = self._pending_lanes
        self._pending_lanes = None
        if lanes is None or lanes == self._lanes:
            return
        incoming = self.lane_pool[lanes]
        # pre-warm the incoming lane first (off-loop, overlapping live
        # traffic), then quiesce: no dispatch may straddle the swap
        await loop.run_in_executor(self._pool, self._warm_one, incoming)
        await self._idle.wait()
        if lanes > self._lanes:
            self._scale_ups += 1
        else:
            self._scale_downs += 1
        self.runtime.executor = incoming
        self._lanes = lanes

    def _observe(self, t_done: float, reqs: list[_Pending]) -> None:
        if self.autoscaler is None:
            return
        decision = None
        for p in reqs:
            d = self.autoscaler.observe((t_done - p.t_submit) * 1e3)
            if d is not None:
                decision = d
        if decision is not None:
            self._pending_lanes = decision
            self._arrival.set()      # wake an idle cutter to apply it

    # ------------------------------------------------------------ dispatch
    async def _dispatch_loop(self) -> None:
        """The cutter: policy wait -> cut -> coalesce -> hand to a slot.
        Never blocks on the executor — that is the slot workers' job."""
        loop = asyncio.get_running_loop()
        while True:
            if self._pending_lanes is not None and self._hold_gate.is_set():
                await self._apply_scale(loop)
                continue
            if not self._queue:
                if self._closing:
                    break
                self._arrival.clear()
                await self._arrival.wait()
                continue
            if not self._hold_gate.is_set():
                # held by the control plane's drain/reinstall barrier;
                # stop() sets the gate, so a closing server still flushes
                await self._hold_gate.wait()
                continue
            cut = await self._next_cut(loop)
            if cut is None:
                continue
            reqs, flat, offsets = cut
            # in-flight from the moment it leaves the queue: drain() must
            # wait for slot-queued work too, or a reinstall could race a
            # batch that was cut but not yet picked up
            self._inflight += 1
            self._idle.clear()
            await self._slots_q.put(_Work(reqs, flat, offsets))
        # closing: stop the slot workers after the queued work lands
        for _ in self._slot_tasks:
            await self._slots_q.put(None)
        await asyncio.gather(*self._slot_tasks)
        self._slot_tasks = []
        self._pool.shutdown(wait=False)

    async def _slot_worker(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            work = await self._slots_q.get()
            if work is None:
                return
            reqs, flat = work.reqs, work.flat
            t_dispatch = loop.time()
            waited_us = (t_dispatch - reqs[0].t_submit) * 1e6
            self._executing += 1
            self._peak_executing = max(self._peak_executing, self._executing)
            try:
                rslt, codes, acc = await loop.run_in_executor(
                    self._pool, self._classify_flat, flat)
            except Exception as e:   # executor died: fail this batch only
                self._fail(reqs, e)
                continue
            finally:
                self._executing -= 1
                self._inflight -= 1
                if self._inflight == 0:
                    self._idle.set()
            t_done = loop.time()
            self._finish_dispatch(reqs, work.offsets, flat.batch, rslt,
                                  codes, acc, t_dispatch, t_done, waited_us)
            self._observe(t_done, reqs)

    # --------------------------------------------------------------- stats
    def _engine_stats(self) -> dict:
        return {
            "slots": self.n_slots,
            "lanes": self._lanes,
            "scale_ups": self._scale_ups,
            "scale_downs": self._scale_downs,
            "warmed_buckets": list(self.warmed_buckets),
            "peak_concurrent_dispatches": self._peak_executing,
        }

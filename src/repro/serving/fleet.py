"""Whole-topology fleet serving: the planner's network as a live data plane.

Everything below ``ZooServer`` so far drove *one* path of devices; this
module drives the full ``core/topology.py`` graph the ILP planner optimizes
over (paper §5, §7.5).  ``FleetRuntime`` plans a model zoo onto a topology
with ``planner.plan_zoo``, slices per-device partial zoos with
``distributed_plane.build_zoo_device_programs``, and serves requests
hop-by-hop along the plan's wire path — each hosting switch applying its own
``PackedProgram`` (tables + exec image), intermediates riding in the packet
between hops, exactly the paper's in-packet transport.

One compiled template serves the whole fleet: ``SwitchEngine.classify``
takes the program as an *argument*, so every switch in the topology shares
one jitted trace and differs only in its table entries — the reproduction's
analogue of flashing one P4 binary to every switch and differing only in
entries (§6).  ``FleetExecutor.cache_size()`` therefore stays O(1) however
many devices the plan uses (at a fixed batch shape: one trace, at most two
cached executables — the host-resident first hop vs device-resident later
hops — never one per device).

Failure story (the self-healing loop, ``repro.runtime.control``):
``kill()`` marks a device dead; a dispatch whose wire path crosses a dead
device raises ``DeviceFailure`` instead of classifying through it; the
``ControlLoop`` detects, replans the zoo on the surviving topology
(capacity carry-over intact), drains the async server, and ``reinstall``s
the new per-device programs — submits retried through ``submit_batch``
return answers bit-identical to the pre-fault oracle (pinned by the
fault-schedule lane of ``tests/test_conformance.py``).

``FleetExecutor`` implements the ``repro.runtime`` ``Executor`` protocol,
so the whole fleet sits behind the same ``DataplaneRuntime`` admission seam
(power-of-two buckets, O(log B) traces) and ``ZooServer``/``AsyncZooServer``
fronts as every other substrate — no new entry points.
"""
from __future__ import annotations

import contextlib

import numpy as np

from repro.core.distributed_plane import build_zoo_device_programs
from repro.core.netsim import acorn_serving_time, simulate_serving
from repro.core.packets import PacketBatch
from repro.core.plane import PackedProgram, PlaneProfile, SwitchEngine
from repro.core.planner import (
    DeploymentPlan,
    DeviceModel,
    plan_zoo,
    replan_zoo,
)
from repro.core.topology import Network
from repro.core.translator import TableProgram
from repro.runtime import SizeOrDeadlinePolicy
from repro.runtime.control import ControlLoop, DeviceFailure
from repro.runtime.policies import BatchingPolicy
from repro.serving.async_server import AsyncResult, AsyncZooServer
from repro.serving.serve import ZooServer

__all__ = ["FleetExecutor", "FleetRuntime"]


class FleetExecutor:
    """``Executor`` over a deployment plan's wire path.

    Holds the shared template ``SwitchEngine``, the hosting hops' partial
    zoos in path order, and a live ``down`` set shared with the owning
    ``FleetRuntime``.  ``classify`` walks the hosting hops in order — the
    same chain-of-partial-programs semantics as ``SequentialPathExecutor``
    — after checking every switch on the wire path (hosting or not) is
    alive; a dead one raises ``DeviceFailure`` for the control loop.
    """

    granularity = 1

    def __init__(self, engine: SwitchEngine, wire_path: list[str],
                 devices: list[str], programs: list[PackedProgram], *,
                 down: set[str]) -> None:
        self.engine = engine
        self._down = down             # shared with FleetRuntime.kill()
        self.retarget(wire_path, devices, programs)

    def retarget(self, wire_path: list[str], devices: list[str],
                 programs: list[PackedProgram]) -> None:
        """Point the executor at a (possibly different-length) deployment —
        the control loop's reinstall step.  Unlike ``swap``, the device set
        may change: that is exactly what a post-fault replan produces."""
        if len(devices) != len(programs):
            raise ValueError("one program per hosting device required")
        missing = [d for d in devices if d not in wire_path]
        if missing:
            raise ValueError(f"hosting device(s) {missing} not on wire path")
        self.wire_path = list(wire_path)
        self.devices = list(devices)
        self.programs: dict[str, PackedProgram] = dict(zip(devices, programs))

    def classify(self, batch: PacketBatch) -> PacketBatch:
        dead = [d for d in self.wire_path if d in self._down]
        if dead:
            raise DeviceFailure(dead[0], path=self.wire_path)
        for d in self.devices:
            batch = self.engine.classify(self.programs[d], batch)
        # a kill that lands mid-chain: the answers are still correct (tables
        # were intact), but real hardware would have dropped the packet at
        # the dead hop — model the drop so the retry path is exercised
        dead = [d for d in self.wire_path if d in self._down]
        if dead:
            raise DeviceFailure(dead[0], path=self.wire_path)
        return batch

    def swap(self, device_programs: list[PackedProgram]) -> None:
        """Same-device-set reprogram (the ``Executor`` protocol's swap).
        A changed device count means the deployment changed — that is a
        control-plane ``retarget``, not a swap."""
        if len(device_programs) != len(self.devices):
            raise ValueError("device count changed — retarget (replan) instead")
        self.programs = dict(zip(self.devices, list(device_programs)))

    def cache_size(self) -> int:
        return self.engine.cache_size()


class FleetRuntime:
    """Plan, serve, and heal a model zoo on a whole topology.

    Construction plans ``programs`` from ``src`` to ``dst`` with
    ``plan_zoo`` and builds the fleet executor behind a ``ZooServer``.
    Synchronous ``classify`` works immediately; ``async with
    fleet.serving():`` adds the ``AsyncZooServer`` front plus the
    ``ControlLoop`` heal cycle, and ``submit``/``submit_batch`` retry
    through heals on ``DeviceFailure``.
    """

    def __init__(self, network: Network, profile: PlaneProfile,
                 programs: list[TableProgram], *, src: str, dst: str,
                 mode: str | None = None, solver: str = "dp",
                 default_device: DeviceModel = DeviceModel(),
                 n_candidate_paths: int = 4,
                 engine: SwitchEngine | None = None) -> None:
        if not programs:
            raise ValueError("need at least one program to deploy")
        self.network = network
        self.profile = profile
        self.programs = list(programs)
        self.src, self.dst = src, dst
        self.solver = solver
        self.default_device = default_device
        self.n_candidate_paths = n_candidate_paths
        self.down: set[str] = set()
        # one jitted template for the entire fleet (see module docstring)
        self.engine = engine if engine is not None \
            else SwitchEngine(profile, mode=mode)
        plans, devices, progs = self._plan()
        self.plans: list[DeploymentPlan] = plans
        self.executor = FleetExecutor(self.engine, plans[0].path, devices,
                                      progs, down=self.down)
        self.zoo = ZooServer(profile, executor=self.executor)
        self.counters = None          # last serving session's ControlCounters
        self._server: AsyncZooServer | None = None
        self._control: ControlLoop | None = None

    # ------------------------------------------------------------- planning
    def _plan(self):
        kw = dict(solver=self.solver, default_device=self.default_device,
                  n_candidate_paths=self.n_candidate_paths)
        if self.down:
            plans = replan_zoo(self.programs, self.network, self.src,
                               self.dst, set(self.down), **kw)
        else:
            plans = plan_zoo(self.programs, self.network, self.src,
                             self.dst, **kw)
        devices, progs = build_zoo_device_programs(
            self.programs, plans, self.profile)
        return plans, devices, progs

    @property
    def path(self) -> list[str]:
        """The current serving wire path (all plans share it)."""
        return self.plans[0].path

    @property
    def runtime(self):
        return self.zoo.runtime

    # ------------------------------------------------------ fault injection
    def kill(self, device: str) -> None:
        """Mark a switch dead (scripted fault injection / chaos schedule)."""
        if self.network.kind.get(device) != "switch":
            raise ValueError(f"{device!r} is not a switch of this network")
        self.down.add(device)

    def revive(self, device: str) -> None:
        self.down.discard(device)

    # ------------------------------------- control-plane seam (HealableFleet)
    def failed_on_path(self) -> set[str]:
        return self.down & set(self.executor.wire_path)

    def replan_sync(self):
        """Re-solve the zoo on the surviving topology (blocking CPU work —
        the control loop runs this on a worker thread).  Raises
        ``RuntimeError`` when no feasible deployment survives."""
        return self._plan()

    def reinstall(self, plans, devices, programs) -> None:
        """Retarget the executor to a post-replan deployment (called by the
        control loop between drain and release — never under traffic)."""
        self.plans = list(plans)
        self.executor.retarget(plans[0].path, devices, programs)

    # -------------------------------------------------------------- serving
    def classify(self, features, *, mid: int = 0, vid=0) -> np.ndarray:
        """Synchronous classify through the fleet (admission-bucketed)."""
        return self.zoo.classify(features, mid=mid, vid=vid)

    def make_request(self, features, *, mid: int = 0, vid=0) -> PacketBatch:
        return self.zoo.make_request(features, mid=mid, vid=vid)

    @contextlib.asynccontextmanager
    async def serving(self, *, policy: BatchingPolicy | None = None,
                      probe_interval_s: float = 0.02):
        """Live-traffic session: ``AsyncZooServer`` front + ``ControlLoop``
        heal cycle.  Control counters flow through ``latency_stats()``."""
        if self._server is not None:
            raise RuntimeError("fleet is already serving")
        if policy is None:
            policy = SizeOrDeadlinePolicy(max_batch=64, max_wait_us=500.0)
        server = AsyncZooServer(self.zoo, policy=policy)
        control = ControlLoop(self, server,
                              probe_interval_s=probe_interval_s)
        self.counters = control.counters
        async with server:
            await control.start()
            self._server, self._control = server, control
            try:
                yield self
            finally:
                self._server = self._control = None
                await control.stop()

    @property
    def control(self) -> ControlLoop | None:
        return self._control

    async def submit(self, features, *, mid: int = 0, vid=0) -> AsyncResult:
        if self._server is None:
            raise RuntimeError(
                "fleet is not serving — use 'async with fleet.serving()'")
        return await self.submit_batch(
            self.make_request(features, mid=mid, vid=vid))

    async def submit_batch(self, pb: PacketBatch) -> AsyncResult:
        """Submit with self-healing: a dispatch that hits a dead device
        fails with ``DeviceFailure``; we heal (replan + drain + reinstall)
        and retry — the answer the caller finally sees is computed entirely
        on one consistent deployment, so it stays oracle-identical."""
        if self._server is None:
            raise RuntimeError(
                "fleet is not serving — use 'async with fleet.serving()'")
        # every retry heals at least one dead device off the path, so the
        # switch count bounds the retries a hostile schedule can force
        retries = self.network.n_switches + 1
        while True:
            try:
                return await self._server.submit_batch(pb)
            except DeviceFailure:
                if retries <= 0:
                    raise
                retries -= 1
                self._control.note_retry()
                await self._control.heal()

    def latency_stats(self) -> dict:
        if self._server is None:
            raise RuntimeError(
                "fleet is not serving — use 'async with fleet.serving()'")
        return self._server.latency_stats()

    # ----------------------------------------------------- netsim integration
    def serving_time(self) -> float:
        """Modeled per-request J_L of the current deployment (s)."""
        return acorn_serving_time(self.plans[0])

    def modeled_latencies(self, *, n: int = 1000,
                          arrival_rate_rps: float | None = None,
                          seed: int = 0) -> np.ndarray:
        """``netsim.simulate_serving`` samples for the current deployment,
        with the last serving session's heal windows applied as downtime —
        the availability model ``benchmarks/fleet_serve.py`` records."""
        windows = tuple(self.counters.downtime_windows) \
            if self.counters is not None else ()
        return simulate_serving(
            self.serving_time(), n=n, seed=seed,
            arrival_rate_rps=arrival_rate_rps, downtime_windows=windows)

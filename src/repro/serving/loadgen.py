"""Open-loop load generation for the async serving fronts.

Wire traffic does not wait for the switch: packets arrive on the arrival
process's schedule whether or not earlier ones were answered.  A
*closed-loop* client (fire, await, fire again) silently throttles itself
when the server slows down — the coordinated-omission trap that makes a
saturated server look fast.  This generator is **open-loop**: request
``i``'s arrival time is fixed up front from the process, ``n_clients``
client coroutines fire their assigned arrivals on schedule, and latency is
measured from the *scheduled arrival* to completion — queueing delay the
server (or a lagging event loop) causes is charged to the request, never
silently dropped from the distribution.

Arrival processes:

* ``"poisson"`` — i.i.d. exponential inter-arrivals at ``rate_rps``
  (memoryless line-rate traffic, the ACORN serving model);
* ``"burst"``   — ``burst``-sized arrival clumps whose gaps keep the same
  mean rate (exponential between clumps): the bursty edge traffic that a
  coalescing policy amortizes and a per-request policy drowns under.

The ``submit`` callable is anything awaitable per request (typically
``lambda i: srv.submit(...)``) — the generator is server-agnostic so
benchmarks can drive ``AsyncZooServer``, ``ContinuousZooServer``, or a
stub.  Percentiles cover successful requests; failures are counted, not
hidden (``benchmarks/serve_async.py`` records the full report row).
"""
from __future__ import annotations

import asyncio
import dataclasses

import numpy as np

__all__ = ["LoadReport", "arrival_times", "open_loop"]


@dataclasses.dataclass
class LoadReport:
    """One open-loop trial's outcome, coordinated-omission-free."""

    offered_rps: float
    achieved_rps: float       # completed requests / wall span
    requests: int
    errors: int
    duration_s: float
    p50_ms: float
    p99_ms: float
    p999_ms: float
    mean_ms: float

    def row(self) -> dict:
        """The JSON-trajectory row (``BENCH_serve.json``)."""
        return {k: (round(v, 4) if isinstance(v, float) else v)
                for k, v in dataclasses.asdict(self).items()}


def arrival_times(n: int, rate_rps: float, *, process: str = "poisson",
                  burst: int = 8, rng=None) -> np.ndarray:
    """Scheduled arrival offsets (seconds from t0) for ``n`` requests at a
    mean of ``rate_rps``, under the given arrival process."""
    if n < 1:
        raise ValueError(f"need n >= 1 requests, got {n}")
    if rate_rps <= 0:
        raise ValueError(f"need rate_rps > 0, got {rate_rps}")
    rng = np.random.default_rng(0) if rng is None else rng
    if process == "poisson":
        return rng.exponential(1.0 / rate_rps, n).cumsum()
    if process == "burst":
        if burst < 1:
            raise ValueError(f"need burst >= 1, got {burst}")
        n_bursts = -(-n // burst)
        gaps = rng.exponential(burst / rate_rps, n_bursts).cumsum()
        return np.repeat(gaps, burst)[:n]
    raise ValueError(f"unknown arrival process {process!r}")


async def open_loop(submit, *, rate_rps: float, n_requests: int,
                    n_clients: int = 8, process: str = "poisson",
                    burst: int = 8, seed: int = 0) -> LoadReport:
    """Drive ``await submit(i)`` open-loop and report the latency
    distribution.

    Arrivals are split round-robin across ``n_clients`` client coroutines
    (each client's schedule stays sorted, so it only ever sleeps forward);
    every request is fired as its own task at its scheduled time and never
    awaited before the next fires — offered load is what the schedule
    says, not what the server sustains.
    """
    if n_clients < 1:
        raise ValueError(f"need n_clients >= 1, got {n_clients}")
    rng = np.random.default_rng(seed)
    arrivals = arrival_times(n_requests, rate_rps, process=process,
                             burst=burst, rng=rng)
    loop = asyncio.get_running_loop()
    latencies: list[float | None] = [None] * n_requests
    errors = 0
    tasks: list[asyncio.Task] = []
    t0 = loop.time()

    async def fire(i: int) -> None:
        nonlocal errors
        try:
            await submit(i)
        except Exception:
            errors += 1
            return
        # from the *scheduled* arrival: a late fire or a slow server both
        # count as latency (no coordinated omission)
        latencies[i] = loop.time() - (t0 + arrivals[i])

    async def client(idxs: range) -> None:
        for i in idxs:
            delay = t0 + arrivals[i] - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            tasks.append(loop.create_task(fire(i)))

    await asyncio.gather(*[client(range(c, n_requests, n_clients))
                           for c in range(n_clients)])
    if tasks:
        await asyncio.gather(*tasks)
    span = loop.time() - t0
    ok = np.asarray([l for l in latencies if l is not None], float)
    if ok.size:
        p50, p99, p999 = (float(np.percentile(ok, q) * 1e3)
                          for q in (50, 99, 99.9))
        mean = float(ok.mean() * 1e3)
    else:
        p50 = p99 = p999 = mean = float("nan")
    return LoadReport(
        offered_rps=float(rate_rps),
        achieved_rps=ok.size / span if span > 0 else float("nan"),
        requests=n_requests, errors=errors, duration_s=span,
        p50_ms=p50, p99_ms=p99, p999_ms=p999, mean_ms=mean)

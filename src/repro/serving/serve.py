"""Serving fronts: LM prefill/decode steps + the in-network classifier zoo.

Same runtime-programmability discipline throughout: each step compiles once
per fixed shape; swapping model *weights* or *table entries* (new checkpoint,
new tenant, new model version) is an array update, zero retrace.
``ZooServer`` is the classifier-side serving front — a ``DataplaneRuntime``
hosting ``profile.max_versions`` resident versions per pipeline, with
install / evict / A-B traffic-split rollout as control-plane operations and
admission bucketing on every classify (ragged traffic costs at most one
trace per power-of-two bucket).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packets import PacketBatch
from repro.core.plane import PackedProgram, PlaneProfile, SwitchEngine
from repro.core.translator import TableProgram, translate
from repro.models.common import ArchConfig
from repro.models.transformer import decode_step, forward
from repro.runtime import DataplaneRuntime, Executor, SingleSwitchExecutor

__all__ = ["make_prefill_step", "make_decode_step", "ZooServer"]


def make_prefill_step(cfg: ArchConfig, *, q_chunk: int = 1024, unroll: bool = False):
    """prefill(params, tokens[, enc_inputs]) -> logits [B, S, V].

    q-chunked attention bounds the logits working set for 32k prefill."""

    def prefill(params, tokens, enc_inputs=None):
        return forward(params, tokens, cfg, enc_inputs=enc_inputs,
                       q_chunk=q_chunk, remat=False, unroll=unroll)

    return prefill


def make_decode_step(cfg: ArchConfig, *, unroll: bool = False):
    """step(params, state, tokens [B,1], pos) -> (logits [B,1,V], state)."""

    def step(params, state, tokens, pos):
        return decode_step(params, state, tokens, pos, cfg, unroll=unroll)

    return step


class ZooServer:
    """Stateful serving front over one ``DataplaneRuntime`` model zoo.

    The data plane compiles once per admission bucket (lazily); every
    subsequent ``install`` / ``evict`` / traffic shift is an entry-array
    update — the paper's §6 runtime reprogrammability, extended along the
    Appendix A VID axis.  Each install/evict also recompiles the exec image
    of *only the written slot* (``core/plane.py``), so serving classifies
    against precomputed kernel operands while the control-plane cost stays
    per-slot.  ``classify_split`` implements A/B rollout: the *request
    writer* shifts a traffic fraction to a new version by rewriting ``vid``
    in the requests; the plane — tables and image alike — is untouched.

    Execution is pluggable: the default is a ``SingleSwitchExecutor`` (one
    engine), but any ``repro.runtime`` executor already holding this zoo's
    programs can be passed in — the serving API is unchanged on top of a
    pipelined path or a 2D switch x port mesh.
    """

    def __init__(self, profile: PlaneProfile, *, mode: str | None = None,
                 executor: Executor | None = None) -> None:
        if executor is None:
            executor = SingleSwitchExecutor(profile, mode=mode)
        self.runtime = DataplaneRuntime(executor)
        self._profile = profile
        self.versions: dict[tuple[str, int], str] = {}  # (pipeline, vid) -> tag

    @property
    def executor(self) -> Executor:
        return self.runtime.executor

    @property
    def engine(self) -> SwitchEngine:
        """The owning plane (single-switch executors only) — compat accessor."""
        return self.executor.engine

    @property
    def packed(self) -> PackedProgram:
        return self.executor.packed

    @property
    def profile(self) -> PlaneProfile:
        return self._profile

    def install(self, model_or_program, *, vid: int, tag: str = "") -> int:
        """Install a trained model (or pre-translated program) into slot
        ``vid`` of its pipeline.  Returns the vid for chaining."""
        if isinstance(model_or_program, TableProgram):
            prog = model_or_program
            if prog.vid != vid:
                raise ValueError(
                    f"program targets vid {prog.vid} but install asked for "
                    f"slot {vid} — requests built from the program's metadata "
                    "would dispatch to the wrong slot"
                )
        else:
            prog = translate(model_or_program, vid=vid)
        self.runtime.install(prog, vid=vid)
        pipeline = "svm" if prog.kind == "svm" else "tree"
        self.versions[(pipeline, vid)] = tag or f"{prog.kind}-v{vid}"
        return vid

    def evict(self, *, vid: int, kind: str = "all") -> None:
        self.runtime.evict(vid=vid, kind=kind)
        for pipeline in ("tree", "svm"):
            if kind in (pipeline, "all"):
                self.versions.pop((pipeline, vid), None)

    def make_request(self, features, *, mid: int = 0, vid=0) -> PacketBatch:
        """Build a REQUEST batch sized to this zoo's plane profile.

        The one request-construction path shared by the synchronous
        ``classify`` and the async front (``AsyncZooServer.submit``), so
        both serve bit-identical packets by construction."""
        prof = self.profile
        return PacketBatch.make_request(
            features, mid=mid, vid=vid, max_features=prof.max_features,
            n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
            max_versions=prof.max_versions)

    def classify(self, features, *, mid: int, vid: int | np.ndarray,
                 device_out: bool = False) -> np.ndarray | PacketBatch:
        """Classify one request batch (admission-bucketed, any size).

        ``device_out=True`` returns the classified on-device ``PacketBatch``
        instead of forcing the per-batch host round-trip — runtime-stacked
        callers (and sharded executors, whose results live across port
        devices) keep results on device and convert only at the edge."""
        out = self.runtime.run(self.make_request(features, mid=mid, vid=vid))
        if device_out:
            return out
        return np.asarray(out.rslt)

    def classify_coalesced(self, requests) -> list[np.ndarray]:
        """Classify several per-client request batches as ONE dispatch.

        ``requests`` is a sequence of ``(features, mid, vid)`` triples; the
        batches are coalesced through the runtime's admission seam (one
        bucket, one executor call) and split back per client — the
        synchronous twin of one ``AsyncZooServer`` batch dispatch, with the
        same per-client results as calling ``classify`` once per triple
        (pinned in ``tests/test_async_serving.py``)."""
        pbs = [self.make_request(f, mid=m, vid=v) for f, m, v in requests]
        return [np.asarray(out.rslt) for out in self.runtime.run_coalesced(pbs)]

    def classify_split(self, features, *, mid: int,
                       split: dict[int, float]) -> tuple[np.ndarray, np.ndarray]:
        """A/B rollout step: route a deterministic fraction of requests to
        each version in ``split`` (vid -> fraction, summing to ~1).  Returns
        (results, per-packet vid) so callers can track cohort metrics."""
        if not split:
            raise ValueError("split needs at least one vid -> fraction entry")
        B = np.asarray(features).shape[0]
        vids_sorted = sorted(split)
        bounds = np.cumsum([split[v] for v in vids_sorted])
        if not np.isclose(bounds[-1], 1.0, atol=1e-6):
            raise ValueError(f"traffic fractions sum to {bounds[-1]}, not 1")
        # deterministic low-discrepancy assignment by packet index; clip so
        # a fraction sum of 1-eps (within isclose tolerance) can't index past
        # the last version
        u = (np.arange(B) + 0.5) / B
        idx = np.minimum(np.searchsorted(bounds, u), len(vids_sorted) - 1)
        vids = np.asarray(vids_sorted, np.int32)[idx]
        return self.classify(features, mid=mid, vid=vids), vids

    def cache_size(self) -> int:
        return self.runtime.cache_size()


def greedy_decode(params, state, first_token, pos0, cfg: ArchConfig, n_steps: int):
    """Serve-loop helper for examples/tests: greedy argmax continuation."""

    def body(carry, _):
        state, tok, pos = carry
        logits, state = decode_step(params, state, tok, pos, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(tok.dtype)
        return (state, nxt, pos + 1), nxt[:, 0]

    (_, _, _), toks = jax.lax.scan(body, (state, first_token, pos0), None,
                                   length=n_steps)
    return toks.T  # [B, n_steps]

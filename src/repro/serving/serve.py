"""Serving steps: fixed-shape prefill + one-token decode.

Same runtime-programmability discipline as the ACORN plane: the decode step
compiles once per (arch, batch, cache_len); swapping model *weights* (new
checkpoint, new tenant) is an array update, zero retrace.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.transformer import decode_step, forward

__all__ = ["make_prefill_step", "make_decode_step"]


def make_prefill_step(cfg: ArchConfig, *, q_chunk: int = 1024, unroll: bool = False):
    """prefill(params, tokens[, enc_inputs]) -> logits [B, S, V].

    q-chunked attention bounds the logits working set for 32k prefill."""

    def prefill(params, tokens, enc_inputs=None):
        return forward(params, tokens, cfg, enc_inputs=enc_inputs,
                       q_chunk=q_chunk, remat=False, unroll=unroll)

    return prefill


def make_decode_step(cfg: ArchConfig, *, unroll: bool = False):
    """step(params, state, tokens [B,1], pos) -> (logits [B,1,V], state)."""

    def step(params, state, tokens, pos):
        return decode_step(params, state, tokens, pos, cfg, unroll=unroll)

    return step


def greedy_decode(params, state, first_token, pos0, cfg: ArchConfig, n_steps: int):
    """Serve-loop helper for examples/tests: greedy argmax continuation."""

    def body(carry, _):
        state, tok, pos = carry
        logits, state = decode_step(params, state, tok, pos, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(tok.dtype)
        return (state, nxt, pos + 1), nxt[:, 0]

    (_, _, _), toks = jax.lax.scan(body, (state, first_token, pos0), None,
                                   length=n_steps)
    return toks.T  # [B, n_steps]

from repro.train.step import loss_fn, make_train_step, microbatch_plan
from repro.train.checkpoint import Checkpointer

__all__ = ["loss_fn", "make_train_step", "microbatch_plan", "Checkpointer"]

"""Fault-tolerant checkpointing: atomic, async, mesh-elastic.

Production framing (DESIGN.md §5):

* **atomic** — write to ``step_XXXX.tmp`` then ``os.rename``; a crash mid-
  write never corrupts the latest checkpoint.
* **async**  — a background thread serializes and writes; the train loop only
  blocks if a previous save is still in flight (one-deep pipeline).
* **mesh-elastic** — arrays are saved as *full logical* arrays keyed by tree
  path, so a restart may use a different mesh/pod count: restore just
  re-shards under the new mesh (tested in tests/test_checkpoint.py).
* **data-cursor** — the TokenPipeline cursor is checkpointed with the step,
  so restart neither replays nor skips batches.
* retention — keep the last ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            # npz cannot round-trip ml_dtypes; store losslessly as f32 and
            # cast back to the template dtype on restore.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    def fill(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        return arr.astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(fill, template)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state, extra: dict | None = None) -> None:
        # Materialize on host *before* handing to the writer thread so the
        # train loop can donate/overwrite device buffers immediately.
        payload = {
            "params": _flatten(params),
            "opt": _flatten(opt_state),
        }
        meta = {"step": int(step), "extra": extra or {}}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, payload, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, payload, meta)

    def _write(self, step: int, payload: dict, meta: dict) -> None:
        name = f"step_{step:010d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        for group, flat in payload.items():
            np.savez(os.path.join(tmp, group + ".npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, params_template, opt_template, *, step: int | None = None,
                shardings=None):
        """Returns (step, params, opt_state, extra). Re-shards under the
        caller's mesh when ``shardings=(pspec_tree, ospec_tree)`` is given —
        the mesh-elastic path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        pz = np.load(os.path.join(d, "params.npz"))
        oz = np.load(os.path.join(d, "opt.npz"))
        params = _unflatten(params_template, dict(pz))
        opt = _unflatten(opt_template, dict(oz))
        if shardings is not None:
            pshard, oshard = shardings
            params = jax.tree.map(jax.device_put, params, pshard)
            opt = jax.tree.map(jax.device_put, opt, oshard)
        return meta["step"], params, opt, meta["extra"]

"""Training step: CE loss, gradient accumulation, AdamW — one pjit body.

Gradient accumulation is a ``lax.scan`` over the microbatch axis (activation
memory = one microbatch; the lever that fits grok train_4k in 16 GB — see
EXPERIMENTS.md §Dry-run).  ``remat=True`` checkpoints each layer inside the
model scan, so backward recompute is layer-local.

``microbatch_plan`` picks n_micro from a per-device token budget — a perf
knob hillclimbed in §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.transformer import forward
from repro.optim.adamw import AdamWConfig, adamw_update

__all__ = ["loss_fn", "make_train_step", "microbatch_plan"]


def loss_fn(params, tokens, labels, cfg: ArchConfig, *, enc_inputs=None,
            q_chunk: int = 0, remat: bool = True, unroll: bool = False):
    logits = forward(params, tokens, cfg, enc_inputs=enc_inputs,
                     q_chunk=q_chunk, remat=remat, unroll=unroll)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)
    return -ll.mean()


def microbatch_plan(cfg: ArchConfig, seq_len: int, global_batch: int,
                    dp_total: int, *, tokens_per_device: int = 8192) -> int:
    """n_micro so each device sees <= tokens_per_device tokens per microstep."""
    per_dev_seqs = max(global_batch // dp_total, 1)
    seqs_per_micro = max(tokens_per_device // seq_len, 1)
    n_micro = max(per_dev_seqs // seqs_per_micro, 1)
    while global_batch % (n_micro) != 0:  # keep the reshape exact
        n_micro -= 1
    return max(n_micro, 1)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *, n_micro: int,
                    q_chunk: int = 0, remat: bool = True, has_enc: bool = False,
                    unroll: bool = False, grad_specs=None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch["tokens"]/["labels"]``: [n_micro, B_mb, S]; optional
    ``batch["enc_inputs"]``: [n_micro, B_mb, enc_seq, D] (whisper stub).

    ``grad_specs``: PartitionSpec tree for the gradient accumulator.  Without
    it GSPMD may replicate weight gradients (observed: full [D, F] f32 dW on
    every device) — constraining the accumulator pins dW to the parameter
    sharding.
    """

    def constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_specs)

    def grads_one(params, tokens, labels, enc):
        return jax.value_and_grad(loss_fn)(
            params, tokens, labels, cfg, enc_inputs=enc,
            q_chunk=q_chunk, remat=remat, unroll=unroll)

    def step(params, opt_state, batch):
        def micro(carry, xs):
            loss_sum, grads = carry
            enc = xs.get("enc_inputs") if has_enc else None
            loss, g = grads_one(params, xs["tokens"], xs["labels"], enc)
            grads = constrain(jax.tree.map(jnp.add, grads, constrain(g)))
            return (loss_sum + loss, grads), None

        zeros = constrain(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, grads), _ = jax.lax.scan(
            micro, (jnp.zeros((), jnp.float32), zeros), batch,
            unroll=True if unroll else 1)
        inv = 1.0 / n_micro
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = loss_sum * inv
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return step

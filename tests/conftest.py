"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py (and explicit subprocess tests) set the
512-device emulation.

The plane engines are session-scoped: ``SwitchEngine`` jit-compiles one trace
per (profile, batch shape), so sharing one engine across tests avoids
re-jitting the classification step per test (the dominant cost of the plane
test modules).  Tests that assert trace counts take deltas against
``cache_size()`` rather than absolute values, or build a private engine.
"""
import numpy as np
import pytest

from repro.core.mlmodels import Quantizer
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.data import load_dataset

# One profile for every single-engine plane test (test_plane, test_system,
# test_zoo) — must stay identical across modules so they share the jit cache.
PLANE_PROFILE = PlaneProfile(max_features=36, max_trees=5, max_layers=10,
                             max_entries_per_layer=256, max_leaves=256,
                             max_classes=8, max_hyperplanes=8, max_versions=4)


@pytest.fixture(scope="session")
def satdap():
    Xtr, ytr, Xte, yte = load_dataset("satdap", scale=0.25)
    q = Quantizer(8).fit(Xtr)
    return q.transform(Xtr), ytr, q.transform(Xte), yte


@pytest.fixture(scope="session")
def iris():
    Xtr, ytr, Xte, yte = load_dataset("iris")
    q = Quantizer(8).fit(Xtr)
    return q.transform(Xtr), ytr, q.transform(Xte), yte


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def plane_profile():
    return PLANE_PROFILE


@pytest.fixture(scope="session")
def plane_engine():
    return SwitchEngine(PLANE_PROFILE)

"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py (and explicit subprocess tests) set the
512-device emulation."""
import numpy as np
import pytest

from repro.core.mlmodels import Quantizer
from repro.data import load_dataset


@pytest.fixture(scope="session")
def satdap():
    Xtr, ytr, Xte, yte = load_dataset("satdap", scale=0.25)
    q = Quantizer(8).fit(Xtr)
    return q.transform(Xtr), ytr, q.transform(Xte), yte


@pytest.fixture(scope="session")
def iris():
    Xtr, ytr, Xte, yte = load_dataset("iris")
    q = Quantizer(8).fit(Xtr)
    return q.transform(Xtr), ytr, q.transform(Xte), yte


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""Async serving front: batching policies, coalesce seam, future demux.

Every coroutine here runs through ``asyncio.run(..., debug=True)`` —
asyncio's debug (strict) mode, which surfaces un-awaited coroutines, slow
callbacks, and futures resolved from the wrong loop; CI additionally exports
``PYTHONASYNCIODEBUG=1`` for the whole step.  Policies are tested purely
(no event loop): the ``BatchingPolicy`` protocol is synchronous by design.

Bit-identity of the async path against every executor substrate lives in
``tests/test_conformance.py``; this module pins the serving mechanics:
policy decisions, whole-request batching, demux offsets, drain-on-stop,
error propagation, and the latency accounting surface.
"""
import asyncio

import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree, LinearSVM
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile
from repro.core.translator import MID_SVM
from repro.runtime import (
    AdaptiveBucketPolicy,
    BatchingPolicy,
    ImmediatePolicy,
    SizeOrDeadlinePolicy,
    coalesce,
    split,
)
from repro.serving import AsyncZooServer, ZooServer


def run_async(coro):
    """All async tests run under asyncio debug (strict) mode."""
    return asyncio.run(coro, debug=True)


def _profile(V=2):
    return PlaneProfile(max_features=36, max_trees=4, max_layers=6,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=V)


@pytest.fixture(scope="module")
def zoo(satdap):
    Xtr, ytr, _, _ = satdap
    z = ZooServer(_profile())
    z.install(DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr),
              vid=0)
    z.install(LinearSVM(epochs=30).fit(Xtr, ytr), vid=0)
    return z


# ------------------------------------------------------------- policies
def test_immediate_policy_never_waits_never_coalesces():
    p = ImmediatePolicy()
    assert p.wait_us(1, 0.0) <= 0
    assert p.wait_us(1000, 1e6) <= 0
    assert p.drain(37) == 1      # one whole request per dispatch
    assert isinstance(p, BatchingPolicy)


def test_size_or_deadline_policy_semantics():
    p = SizeOrDeadlinePolicy(max_batch=16, max_wait_us=2_000)
    assert p.wait_us(16, 0.0) <= 0          # size trigger
    assert p.wait_us(40, 0.0) <= 0
    assert p.wait_us(3, 2_500.0) <= 0       # deadline trigger
    assert p.wait_us(3, 500.0) == pytest.approx(1_500.0)   # remaining budget
    assert p.drain(40) == 16                # batches cap at max_batch
    assert p.drain(3) == 3
    assert isinstance(p, BatchingPolicy)
    with pytest.raises(ValueError):
        SizeOrDeadlinePolicy(max_batch=0)
    with pytest.raises(ValueError):
        SizeOrDeadlinePolicy(max_wait_us=-1)


def test_adaptive_policy_widens_bucket_under_sustained_load():
    p = AdaptiveBucketPolicy(min_batch=1, max_batch=128, max_wait_us=1_000,
                             alpha=0.3)
    assert p.target_batch == 1              # idle: immediate-like
    assert p.wait_us(1, 0.0) <= 0
    for _ in range(12):                     # sustained ~50-packet dispatches
        p.note_dispatch(50, 500.0)
    assert p.target_batch == 64             # next power-of-two bucket up
    assert p.wait_us(10, 0.0) > 0           # now holds for a fuller bucket
    assert p.wait_us(64, 0.0) <= 0
    # load drops: one deadline flush below target snaps the estimate down —
    # a lone request after a burst must not keep paying the deadline
    p.note_dispatch(1, 1_000.0)
    assert p.target_batch == 1
    assert p.wait_us(1, 0.0) <= 0
    for _ in range(12):                     # EWMA path still decays too
        p.note_dispatch(50, 500.0)
    assert p.target_batch == 64
    for _ in range(40):
        p.note_dispatch(1, 0.0)             # below-deadline trickle
    assert p.target_batch == 1
    assert isinstance(p, BatchingPolicy)


def test_adaptive_policy_targets_are_admission_buckets():
    p = AdaptiveBucketPolicy(min_batch=1, max_batch=100, granularity=4,
                             alpha=1.0)
    p.note_dispatch(13, 0.0)
    assert p.target_batch == 16             # bucket_size(13, 4)
    p.note_dispatch(100, 0.0)
    # never above max_batch: drain() can't cut more, so a bucket-rounded
    # 128 target would be unreachable and every dispatch would wait out
    # the full deadline
    assert p.target_batch == 100
    assert p.wait_us(100, 0.0) <= 0
    with pytest.raises(ValueError):
        AdaptiveBucketPolicy(min_batch=8, max_batch=4)


# ------------------------------------------------------- coalesce seam
def test_coalesce_split_round_trip(satdap):
    _, _, Xte, _ = satdap
    prof = _profile()
    pbs = [PacketBatch.make_request(Xte[lo:hi], mid=0,
                                    max_features=prof.max_features,
                                    n_trees=prof.max_trees,
                                    n_hyperplanes=prof.max_hyperplanes)
           for lo, hi in ((0, 5), (5, 5), (5, 17))]   # middle one is empty
    flat, offsets = coalesce(pbs)
    assert offsets == (0, 5, 5, 17)
    assert flat.batch == 17
    parts = split(flat, offsets)
    assert [p.batch for p in parts] == [5, 0, 12]
    for part, pb in zip(parts, pbs):
        np.testing.assert_array_equal(np.asarray(part.features),
                                      np.asarray(pb.features))
    with pytest.raises(ValueError):
        coalesce([])
    with pytest.raises(ValueError):
        split(flat, (0, 3))


def test_classify_coalesced_matches_per_batch(zoo, satdap):
    """The sync twin of one async dispatch: coalesced results equal one
    classify call per client batch."""
    _, _, Xte, _ = satdap
    reqs = [(Xte[:9], 0, 0), (Xte[9:10], MID_SVM, 0), (Xte[10:31], 0, 0)]
    outs = zoo.classify_coalesced(reqs)
    for got, (f, m, v) in zip(outs, reqs):
        np.testing.assert_array_equal(got, zoo.classify(f, mid=m, vid=v))


# ------------------------------------------------------------ serving
def test_async_results_bit_identical_and_demuxed(zoo, satdap):
    """Concurrent ragged submits (tree + SVM traffic interleaved) demux to
    exactly the synchronous per-batch results."""
    _, _, Xte, _ = satdap
    chunks = [(Xte[0:7], 0, 0), (Xte[7:8], MID_SVM, 0), (Xte[8:29], 0, 0),
              (Xte[29:61], MID_SVM, 0), (Xte[61:64], 0, 0)]

    async def main():
        async with AsyncZooServer(
                zoo, policy=SizeOrDeadlinePolicy(max_batch=64,
                                                 max_wait_us=2_000)) as srv:
            return await asyncio.gather(
                *[srv.submit(f, mid=m, vid=v) for f, m, v in chunks])

    outs = run_async(main())
    for out, (f, m, v) in zip(outs, chunks):
        want = zoo.classify(f, mid=m, vid=v, device_out=True)
        np.testing.assert_array_equal(out.rslt, np.asarray(want.rslt))
        np.testing.assert_array_equal(out.codes, np.asarray(want.codes))
        np.testing.assert_array_equal(out.svm_acc, np.asarray(want.svm_acc))
        assert out.t_submit <= out.t_dispatch <= out.t_done
        assert out.latency_s >= out.queue_wait_s >= 0


def test_size_policy_coalesces_concurrent_submits(zoo, satdap):
    """Many small concurrent submits under a size-or-deadline policy land in
    far fewer dispatches; whole requests are never split."""
    _, _, Xte, _ = satdap

    async def main():
        async with AsyncZooServer(
                zoo, policy=SizeOrDeadlinePolicy(max_batch=32,
                                                 max_wait_us=50_000)) as srv:
            outs = await asyncio.gather(
                *[srv.submit(Xte[i:i + 2], mid=0, vid=0) for i in range(24)])
            return outs, srv.latency_stats()

    outs, stats = run_async(main())
    assert stats["requests"] == 24
    assert stats["dispatches"] <= 4, \
        f"48 packets under max_batch=32 should coalesce, got {stats}"
    assert stats["mean_batch_packets"] >= 12
    for i, out in enumerate(outs):
        assert out.rslt.shape == (2,)       # whole request, one future
        np.testing.assert_array_equal(
            out.rslt, zoo.classify(Xte[i:i + 2], mid=0, vid=0))


def test_empty_submit_resolves_immediately(zoo):
    async def main():
        async with AsyncZooServer(zoo) as srv:
            out = await srv.submit(np.zeros((0, 36), np.int32), mid=0, vid=0)
            return out, srv.latency_stats()

    out, stats = run_async(main())
    assert out.rslt.shape == (0,)
    assert out.codes.shape[0] == 0 and out.svm_acc.shape[0] == 0
    assert out.latency_s == 0.0
    # the short-circuit must not bypass accounting: an empty submit is an
    # accepted request (zero latency, zero wait) with no dispatch — rates
    # and percentiles cover every request the server answered
    assert stats["requests"] == 1
    assert stats["dispatches"] == 0
    assert stats["p50_ms"] == 0.0 and stats["p50_wait_ms"] == 0.0
    assert stats["mean_batch_packets"] == 0.0   # no dispatch log yet, no NaN


def test_stop_drains_pending_requests(zoo, satdap):
    """stop() flushes the queue through a final dispatch — no future is left
    pending, even with a deadline policy mid-wait."""
    _, _, Xte, _ = satdap

    async def main():
        srv = AsyncZooServer(zoo, policy=SizeOrDeadlinePolicy(
            max_batch=4096, max_wait_us=60_000_000))   # would wait a minute
        await srv.start()
        tasks = [asyncio.create_task(srv.submit(Xte[i:i + 3], mid=0, vid=0))
                 for i in range(5)]
        await asyncio.sleep(0.01)           # let submits enqueue
        await srv.stop()                    # drain overrides the deadline
        return await asyncio.gather(*tasks)

    outs = run_async(main())
    assert len(outs) == 5
    for i, out in enumerate(outs):
        np.testing.assert_array_equal(
            out.rslt, zoo.classify(Xte[i:i + 3], mid=0, vid=0))


def test_submit_without_start_raises(zoo, satdap):
    _, _, Xte, _ = satdap
    srv = AsyncZooServer(zoo)

    async def main():
        with pytest.raises(RuntimeError, match="not serving"):
            await srv.submit(Xte[:2], mid=0, vid=0)

    run_async(main())


def test_executor_failure_propagates_to_futures(satdap):
    """A dispatch that blows up inside the executor must fail that batch's
    futures with the original exception — and leave the loop serving."""
    _, _, Xte, _ = satdap
    prof = _profile()
    z = ZooServer(prof)

    class Boom(RuntimeError):
        pass

    async def main():
        async with AsyncZooServer(z) as srv:
            orig = srv.runtime.executor.classify
            srv.runtime.executor.classify = lambda pb: (_ for _ in ()).throw(
                Boom("kernel died"))
            with pytest.raises(Boom):
                await srv.submit(Xte[:4], mid=0, vid=0)
            srv.runtime.executor.classify = orig    # loop survived the error
            out = await srv.submit(Xte[:4], mid=0, vid=0)
            return out

    out = run_async(main())
    assert out.rslt.shape == (4,)


def test_broken_policy_fails_futures_not_the_loop(zoo, satdap):
    """BatchingPolicy is a user-implementable protocol: a policy that raises
    must fail the affected futures loudly and leave the dispatch loop
    serving — never kill the loop and hang every later submit."""
    _, _, Xte, _ = satdap

    class BrokenWait(ImmediatePolicy):
        def wait_us(self, queued_packets, oldest_age_us):
            raise ZeroDivisionError("bad policy math")

    class BrokenFeedback(ImmediatePolicy):
        def note_dispatch(self, packets, waited_us):
            raise KeyError("bad feedback hook")

    async def main():
        async with AsyncZooServer(zoo, policy=BrokenWait()) as srv:
            with pytest.raises(ZeroDivisionError):
                await srv.submit(Xte[:3], mid=0, vid=0)
            srv.policy = BrokenFeedback()
            with pytest.raises(KeyError):
                await srv.submit(Xte[:3], mid=0, vid=0)
            srv.policy = ImmediatePolicy()   # loop survived both failures
            return await srv.submit(Xte[:3], mid=0, vid=0)

    out = run_async(main())
    np.testing.assert_array_equal(out.rslt, zoo.classify(Xte[:3], mid=0,
                                                         vid=0))


def test_install_between_dispatches_under_live_traffic(zoo, satdap):
    """Runtime reprogrammability through the async front: an install between
    dispatches changes subsequent answers, zero retrace."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile()
    z = ZooServer(prof)
    z.install(DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr),
              vid=0)

    async def main():
        async with AsyncZooServer(z) as srv:
            before = await srv.submit(Xte[:16], mid=0, vid=1)
            srv.install(DecisionTree(max_depth=6, max_leaf_nodes=40)
                        .fit(Xtr, ytr), vid=1, tag="canary")
            after = await srv.submit(Xte[:16], mid=0, vid=1)
            return before, after

    before, after = run_async(main())
    assert (before.rslt == -1).all()        # slot was empty
    np.testing.assert_array_equal(after.rslt,
                                  z.classify(Xte[:16], mid=0, vid=1))
    assert z.cache_size() == 1              # one bucket trace, no recompile


def test_latency_stats_surface(zoo, satdap):
    _, _, Xte, _ = satdap

    async def main():
        async with AsyncZooServer(zoo) as srv:
            await asyncio.gather(
                *[srv.submit(Xte[i:i + 4], mid=0, vid=0) for i in range(6)])
            return srv.latency_stats()

    stats = run_async(main())
    assert stats["requests"] == 6
    assert stats["dispatches"] >= 1
    for key in ("p50_ms", "p99_ms", "p999_ms", "mean_ms", "p50_wait_ms",
                "mean_batch_packets"):
        assert stats[key] >= 0.0
    assert stats["p50_ms"] <= stats["p99_ms"] <= stats["p999_ms"]


# ------------------------------------------------- quiesce seam (control plane)
def test_drain_holds_dispatches_until_release(zoo, satdap):
    """The control plane's barrier: after drain(), submits queue but never
    dispatch; release() flushes them.  Nothing is dropped either side."""
    _, _, Xte, _ = satdap

    async def main():
        async with AsyncZooServer(zoo) as srv:
            await srv.drain()                      # idle server: returns fast
            task = asyncio.create_task(srv.submit(Xte[:4], mid=0, vid=0))
            await asyncio.sleep(0.05)
            held_pending = not task.done()         # held: future must wait
            held_dispatches = srv.latency_stats()["dispatches"]
            srv.release()
            out = await task
            return held_pending, held_dispatches, out

    held_pending, held_dispatches, out = run_async(main())
    assert held_pending, "request dispatched through an active hold"
    assert held_dispatches == 0
    np.testing.assert_array_equal(out.rslt, zoo.classify(Xte[:4], mid=0,
                                                         vid=0))


def test_drain_waits_for_inflight_dispatch(zoo, satdap):
    """drain() returns only after the in-flight executor call lands — the
    reinstall step never races a live classify."""
    _, _, Xte, _ = satdap

    async def main():
        async with AsyncZooServer(zoo) as srv:
            task = asyncio.create_task(srv.submit(Xte[:8], mid=0, vid=0))
            await asyncio.sleep(0)                 # let it reach the queue
            await srv.drain()
            # after drain, whatever was cut must be fully done
            inflight = srv._inflight
            srv.release()
            await task
            return inflight

    assert run_async(main()) == 0


def test_stop_releases_an_active_hold(zoo, satdap):
    """stop() must not deadlock on a held server: the final drain flushes
    queued requests even when the control plane never called release()."""
    _, _, Xte, _ = satdap

    async def main():
        srv = AsyncZooServer(zoo)
        await srv.start()
        srv.hold()
        task = asyncio.create_task(srv.submit(Xte[:4], mid=0, vid=0))
        await asyncio.sleep(0.01)
        await srv.stop()                           # releases + flushes
        return await task

    out = run_async(main())
    np.testing.assert_array_equal(out.rslt, zoo.classify(Xte[:4], mid=0,
                                                         vid=0))


def test_cancelled_dispatch_loop_fails_fast_and_stop_flushes(zoo, satdap):
    """Shutdown-race regression: the dispatch task dying out from under the
    queue (external cancel / loop teardown) used to let later submits
    enqueue onto a loop nobody runs — futures hung until the test timed
    out.  Now: submits after the death fail fast, and ``stop()``
    fail-or-flushes the stranded straggler so no future is left pending."""
    _, _, Xte, _ = satdap

    async def main():
        srv = AsyncZooServer(zoo, policy=SizeOrDeadlinePolicy(
            max_batch=4096, max_wait_us=60_000_000))   # straggler waits forever
        await srv.start()
        straggler = asyncio.create_task(srv.submit(Xte[:3], mid=0, vid=0))
        await asyncio.sleep(0.01)          # enqueued, parked on the deadline
        srv._task.cancel()                 # the loop dies under the queue
        await asyncio.sleep(0.01)
        with pytest.raises(RuntimeError, match="not serving"):
            await srv.submit(Xte[:3], mid=0, vid=0)    # used to hang here
        await srv.stop()                   # flushes the straggler
        return await asyncio.wait_for(straggler, timeout=5)

    out = run_async(asyncio.wait_for(main(), timeout=30))
    np.testing.assert_array_equal(out.rslt, zoo.classify(Xte[:3], mid=0,
                                                         vid=0))


def test_submit_stop_interleave_leaves_no_future_pending(zoo, satdap):
    """Submits racing ``stop()``: every future either resolves bit-identical
    or fails fast with the not-serving error — none hang (the whole
    interleave runs under a hard timeout and asyncio debug mode)."""
    _, _, Xte, _ = satdap

    async def main():
        srv = AsyncZooServer(zoo, policy=SizeOrDeadlinePolicy(
            max_batch=4096, max_wait_us=60_000_000))
        await srv.start()
        tasks = [asyncio.create_task(srv.submit(Xte[i:i + 2], mid=0, vid=0))
                 for i in range(6)]
        await asyncio.sleep(0)             # some enqueue before the stop
        stopper = asyncio.create_task(srv.stop())
        tasks += [asyncio.create_task(srv.submit(Xte[i:i + 2], mid=0, vid=0))
                  for i in range(6, 12)]   # these race the closing flag
        await stopper
        return await asyncio.gather(*tasks, return_exceptions=True)

    results = run_async(asyncio.wait_for(main(), timeout=30))
    assert len(results) == 12
    resolved = 0
    for i, r in enumerate(results):
        if isinstance(r, BaseException):
            assert isinstance(r, RuntimeError) and "not serving" in str(r)
        else:
            resolved += 1
            np.testing.assert_array_equal(
                r.rslt, zoo.classify(Xte[i:i + 2], mid=0, vid=0))
    assert resolved >= 6                   # the pre-stop submits all land


def test_stop_breaks_owned_hold_and_release_raises(zoo, satdap):
    """A control-plane drain owner whose server is stopped mid-hold must
    find out: stop() breaks the barrier so the final flush can run, and the
    owner's release() raises instead of silently resuming a server that
    already flushed through its half-done reinstall."""
    _, _, Xte, _ = satdap

    async def main():
        srv = AsyncZooServer(zoo)
        await srv.start()
        await srv.drain()                  # the control plane owns the barrier
        task = asyncio.create_task(srv.submit(Xte[:4], mid=0, vid=0))
        await asyncio.sleep(0.01)
        await srv.stop()                   # breaks the hold, flushes the queue
        out = await task
        with pytest.raises(RuntimeError, match="broken by stop"):
            srv.release()                  # the owner must be told
        # once surfaced, the broken flag is consumed — and a stopped server
        # refuses new barriers outright
        with pytest.raises(RuntimeError, match="drain unavailable"):
            await srv.drain()
        with pytest.raises(RuntimeError, match="hold unavailable"):
            srv.hold()
        return out

    out = run_async(main())
    np.testing.assert_array_equal(out.rslt, zoo.classify(Xte[:4], mid=0,
                                                         vid=0))


def test_hold_before_start_raises(zoo):
    srv = AsyncZooServer(zoo)
    with pytest.raises(RuntimeError):
        srv.hold()
    with pytest.raises(RuntimeError):
        srv.release()


def test_stats_sources_merge_into_latency_stats(zoo, satdap):
    """add_stats_source: named provider dicts ride latency_stats() — the
    control plane's counter path — and names must be unique."""
    _, _, Xte, _ = satdap
    srv = AsyncZooServer(zoo)
    srv.add_stats_source("control", lambda: {"replans": 3})
    with pytest.raises(ValueError):
        srv.add_stats_source("control", lambda: {})

    async def main():
        async with srv:
            empty = srv.latency_stats()            # merged before any traffic
            await srv.submit(Xte[:4], mid=0, vid=0)
            return empty, srv.latency_stats()

    empty, stats = run_async(main())
    assert empty["control"] == {"replans": 3}
    assert stats["control"] == {"replans": 3}
    assert stats["requests"] >= 1

"""Baseline representation models: Table 3 / Fig. 9 semantics."""
import numpy as np

from repro.core.baselines import (
    MAX_FEATURES,
    acorn_resources,
    dinc_resources,
    dinc_shrink_to_fit,
    leo_resources,
    switchtree_resources,
)
from repro.core.mlmodels import DecisionTree, Quantizer, accuracy
from repro.data import load_dataset


def _tree(n_feat=46, leaves=200, seed=0):
    Xtr, ytr, _, _ = load_dataset("nsl-kdd", scale=0.03, max_train=4000)
    q = Quantizer(8).fit(Xtr)
    Xq = q.transform(Xtr)[:, :n_feat]
    return DecisionTree(max_depth=12, max_leaf_nodes=leaves,
                        random_state=seed).fit(Xq, ytr), Xq, ytr


def test_table3_feature_limits():
    assert MAX_FEATURES["acorn"]["dt"] == 46
    assert MAX_FEATURES["leo"]["dt"] == 10
    assert MAX_FEATURES["switchtree"]["dt"] == 16
    assert MAX_FEATURES["dinc"]["rf"] == 20
    dt, _, _ = _tree(46)
    assert not switchtree_resources(dt).feasible   # 46 > 16
    assert not leo_resources(dt).feasible          # 46 > 10
    assert acorn_resources(dt).tcam_entries > 0


def test_leo_uses_more_tcam_than_acorn():
    dt, _, _ = _tree(46)
    a, l = acorn_resources(dt), leo_resources(dt)
    assert l.tcam_entries > 1.5 * a.tcam_entries  # paper: 2-3x


def test_acorn_sram_equals_leaves():
    dt, _, _ = _tree(46)
    assert acorn_resources(dt).sram_entries == dt.tree_.n_leaves


def test_dinc_decision_table_explodes():
    dt, _, _ = _tree(46, leaves=300)
    r = dinc_resources(dt, entry_cap=1 << 20)
    assert not r.feasible                           # factorial growth
    small, _, _ = _tree(4, leaves=8)
    assert dinc_resources(small).feasible


def test_dinc_shrink_underfits():
    """Paper §7.3: fitting DINC's table budget forces underfitting."""
    Xtr, ytr, Xte, yte = load_dataset("digits")
    q = Quantizer(8).fit(Xtr)
    Xq, Xteq = q.transform(Xtr), q.transform(Xte)
    m, rep, leaves = dinc_shrink_to_fit(
        lambda L: DecisionTree(max_depth=12, max_leaf_nodes=L),
        Xq, ytr, entry_cap=1 << 20)
    full = DecisionTree(max_depth=12, max_leaf_nodes=256).fit(Xq, ytr)
    assert rep.feasible
    assert accuracy(yte, m.predict(Xteq)) < accuracy(yte, full.predict(Xteq))


def test_acorn_tcam_shrinks_with_more_features():
    """Paper Fig. 9 trend: more features => fewer layers/nodes => fewer TCAM."""
    tc = {}
    for nf in (5, 46):
        Xtr, ytr, _, _ = load_dataset("nsl-kdd", scale=0.03, max_train=4000)
        q = Quantizer(8).fit(Xtr)
        Xq = q.transform(Xtr)[:, :nf]
        dt = DecisionTree(max_depth=12, max_leaf_nodes=200).fit(Xq, ytr)
        tc[nf] = acorn_resources(dt).tcam_entries
    assert tc[46] <= tc[5] * 1.3  # not growing with feature count

"""Int8 gradient compression: error bounds, error-feedback bias decay,
and end-to-end convergence with the compressed path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    compress_decompress,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)


def test_quantize_roundtrip_bounded_error(rng):
    x = jnp.asarray(rng.normal(size=(1000,)) * rng.uniform(0.01, 10))
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape, x.size)
    # per-chunk max-abs scaling: error <= scale/2 per element
    err = np.abs(np.asarray(x - y))
    smax = float(s.max())
    assert err.max() <= smax / 2 + 1e-7
    assert q.dtype == jnp.int8


def test_error_feedback_unbiased_over_time(rng):
    """With EF, the *sum* of transmitted gradients tracks the true sum —
    the residual never grows (the compression bias does not accumulate)."""
    g_true = jnp.asarray(rng.normal(size=(512,)))
    ef = jnp.zeros((512,), jnp.float32)
    sent = jnp.zeros((512,), jnp.float32)
    for _ in range(50):
        out, ef_tree = compress_decompress({"g": g_true}, {"g": ef})
        sent = sent + out["g"]
        ef = ef_tree["g"]
    drift = np.abs(np.asarray(sent / 50 - g_true))
    assert drift.max() < 1e-3          # long-run average == true gradient
    assert float(jnp.abs(ef).max()) < float(jnp.abs(g_true).max())


def test_training_converges_with_compression(rng):
    """Tiny least-squares: compressed-gradient SGD reaches the same loss."""
    A = jnp.asarray(rng.normal(size=(64, 8)))
    w_true = jnp.asarray(rng.normal(size=(8,)))
    y = A @ w_true

    def loss(w):
        return jnp.mean((A @ w - y) ** 2)

    for compressed in (False, True):
        w = jnp.zeros(8)
        ef = init_error_feedback({"w": w})
        for _ in range(300):
            g = jax.grad(loss)(w)
            if compressed:
                out, ef = compress_decompress({"w": g}, ef)
                g = out["w"]
            w = w - 0.05 * g
        final = float(loss(w))
        assert final < 1e-3, (compressed, final)


def test_wire_bytes_ratio():
    x = jnp.ones((4096,), jnp.float32)
    q, s = quantize_int8(x)
    wire = q.size * 1 + s.size * 4
    assert wire < x.size * 4 / 3.8     # ~3.9x smaller than f32

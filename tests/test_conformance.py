"""Cross-executor property-test harness (ISSUE-5 acceptance pin).

Seeded-numpy generators (PR-1 convention — no hypothesis) draw random
programs (DT / RF / SVM across V ∈ {1, 4, 8} zoo slots) and ragged packet
batches with passthrough and invalid-VID mixes; every drawn case must come
out **bit-identical** through

* all four ``repro.runtime`` executors (single / sequential-path /
  pipelined / sharded), admission-bucketed through ``DataplaneRuntime``, and
* the ``AsyncZooServer`` front (per-client chunks coalesced by a batching
  policy, demuxed back to futures),

against the ``kernels.ref`` oracle (``SwitchEngine(mode="ref")`` on the
unpadded batch).  ≥ 200 cases total.  The same draws also gate the fused
classify megakernel: every case re-runs through a
``SwitchEngine(mode="interpret")`` (one quantized ``classify_fused`` launch)
and must stay bit-identical to the oracle.

On failure the harness *shrinks*: classification is per-packet, so the first
mismatching packet is re-run alone (B = 1) against the oracle and a
single-packet repro string is printed —

    CONFORMANCE REPRO V=4 case=17 seed=28693 substrate=sharded field=rslt ...

rerun one case with ``CONFORMANCE_ONLY="V=4,case=17"``.

Cost control: executors, oracle engine, and the async server are built once
per V and **reprogrammed via swap()** each case (the paper's zero-retrace
reployment), so compiled traces amortize across all cases; ragged batch
sizes come from a fixed menu so the trace count stays O(log B) per
substrate.
"""
import asyncio
import dataclasses
import itertools
import os

import jax
import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.packets import PacketBatch, PacketType
from repro.core.plane import (
    PlaneProfile,
    SwitchEngine,
    empty_program,
    install_program,
)
from repro.core.planner import DeviceModel, replan_zoo
from repro.core.topology import fat_tree
from repro.core.translator import translate
from repro.runtime import DataplaneRuntime, SizeOrDeadlinePolicy
from repro.runtime.executors import (
    PipelinedExecutor,
    SequentialPathExecutor,
    ShardedExecutor,
    SingleSwitchExecutor,
)
from repro.serving import (
    AsyncZooServer,
    ContinuousZooServer,
    FleetRuntime,
    ZooServer,
)

N_CASES = {1: 72, 4: 72, 8: 60}          # 204 drawn cases total (>= 200)
N_FAULT_CASES = 8                        # topology-lane fault schedules
FLEET_V = 4                              # the fault lane's zoo width
SIZES = (1, 2, 3, 5, 7, 12, 17, 24, 33, 48)   # ragged batch menu
FIELDS = ("rslt", "codes", "svm_acc")
N_SEQ_DEV = 3                            # sequential-path hop count
N_FEATURES = 10


def _seed(V: int, case: int) -> int:
    return 7919 * V + case


def _profile(V: int) -> PlaneProfile:
    return PlaneProfile(max_features=N_FEATURES, max_trees=3, max_layers=6,
                        max_entries_per_layer=32, max_leaves=32,
                        max_classes=8, max_hyperplanes=8, max_versions=V)


def _fit_random_model(kind: str, rng: np.random.Generator, seed: int):
    """A random tiny model on random data — the program generator."""
    n_classes = int(rng.integers(2, 5))   # ovo SVM: <= 4*3/2 = 6 hyperplanes
    X = rng.integers(0, 256, (60, N_FEATURES)).astype(np.int32)
    y = rng.integers(0, n_classes, 60).astype(np.int64)
    y[:n_classes] = np.arange(n_classes)  # every class present
    if kind == "dt":
        return DecisionTree(max_depth=int(rng.integers(2, 5)),
                            max_leaf_nodes=int(rng.integers(6, 20))).fit(X, y)
    if kind == "rf":
        return RandomForest(n_estimators=int(rng.integers(2, 4)),
                            max_depth=int(rng.integers(2, 4)),
                            max_leaf_nodes=10, random_state=seed).fit(X, y)
    return LinearSVM(epochs=8, random_state=seed).fit(X, y)


def _split_stages(progs, profile, n_dev):
    """Contiguous stage split in path order (as in tests/test_runtime.py)."""
    dps = []
    for d in range(n_dev):
        packed = empty_program(profile)
        for prog in progs:
            chunks = np.array_split(np.arange(len(prog.stages())), n_dev)
            stages = set(chunks[d].tolist())
            if stages:
                packed = install_program(packed, prog, profile,
                                         stages=stages, vid=prog.vid)
        dps.append(packed)
    return dps


def _draw_zoo(rng, V: int, seed: int, profile: PlaneProfile):
    """1..min(V,3) random programs in distinct version slots + the
    monolithic full install (the oracle's program)."""
    n_prog = int(rng.integers(1, min(V, 3) + 1))
    vids = rng.choice(V, size=n_prog, replace=False)
    progs = []
    for v in vids:
        kind = str(rng.choice(["dt", "rf", "svm"]))
        model = _fit_random_model(kind, rng, seed)
        progs.append(translate(model, vid=int(v)))
    packed = empty_program(profile)
    for prog in progs:
        packed = install_program(packed, prog, profile, vid=prog.vid)
    return progs, packed


def _draw_case(V: int, case: int, profile: PlaneProfile):
    """One property draw: (seed, installed programs, full packed, traffic)."""
    seed = _seed(V, case)
    rng = np.random.default_rng(seed)
    progs, packed = _draw_zoo(rng, V, seed, profile)
    pb = _draw_traffic(rng, progs, V, profile)
    return seed, progs, packed, pb


def _draw_traffic(rng, progs, V: int, profile: PlaneProfile):
    """One ragged traffic batch aimed at the installed (MID, VID) pairs,
    with invalid-VID and passthrough mixes (shared by the executor lane and
    the topology fault lane)."""
    n_prog = len(progs)
    B = int(SIZES[rng.integers(len(SIZES))])
    X = rng.integers(0, 256, (B, N_FEATURES)).astype(np.int32)
    pick = rng.integers(0, n_prog, B)
    mids = np.asarray([progs[c].mid for c in pick], np.int32)
    pvids = np.asarray([progs[c].vid for c in pick], np.int32)
    # invalid-VID mix: out-of-range slots and empty (never-installed) slots
    # must all answer rslt = -1 through every substrate
    bad = rng.random(B) < 0.2
    bad_vids = rng.choice(np.asarray([-1, V, V + 3], np.int32), B)
    if n_prog < V:
        vids = np.asarray([p.vid for p in progs], np.int32)
        empty_slots = np.setdiff1d(np.arange(V, dtype=np.int32), vids)
        swap_in = rng.random(B) < 0.5
        bad_vids = np.where(swap_in, rng.choice(empty_slots, B), bad_vids)
    pvids = np.where(bad, bad_vids, pvids)
    pb = PacketBatch.make_request(X, mid=mids, vid=pvids,
                                  max_features=profile.max_features,
                                  n_trees=profile.max_trees,
                                  n_hyperplanes=profile.max_hyperplanes)
    # passthrough mix: FORWARD / RESPONSE packets with nonzero intermediates
    # must come out untouched (paper §6.1)
    ptype = np.where(rng.random(B) < 0.2, PacketType.FORWARD,
                     PacketType.REQUEST)
    ptype = np.where(rng.random(B) < 0.1, PacketType.RESPONSE, ptype)
    passthru = ptype != PacketType.REQUEST
    pb = dataclasses.replace(
        pb,
        ptype=np.asarray(ptype, np.int32),
        codes=np.asarray(np.where(passthru[:, None],
                                  rng.integers(0, 2**10, (B, profile.max_trees)),
                                  0), np.uint32),
        svm_acc=np.asarray(np.where(passthru[:, None],
                                    rng.integers(-50, 50,
                                                 (B, profile.max_hyperplanes)),
                                    0), np.int32),
        rslt=np.asarray(np.where(passthru, rng.integers(0, 8, B), -1),
                        np.int32),
    )
    return pb


def _repro_filter():
    """CONFORMANCE_ONLY="V=4,case=17" reruns exactly one drawn case."""
    spec = os.environ.get("CONFORMANCE_ONLY", "")
    out = {}
    for part in spec.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = int(v)
    return out


def _shrink_and_fail(V, case, seed, substrate, field, pb, out, want,
                     classify_one, *, fault=None):
    """Localize the first mismatching packet, re-run it alone, fail with a
    single-packet repro string.  ``fault`` tags the topology lane's fault
    schedule so the repro string pins it too."""
    got = np.asarray(getattr(out, field))
    exp = np.asarray(getattr(want, field))
    bad = np.argwhere(
        (got != exp).reshape(got.shape[0], -1).any(axis=1)).ravel()
    i = int(bad[0])
    pb1 = jax.tree.map(lambda x: np.asarray(x)[i:i + 1], pb)
    try:
        out1, want1 = classify_one(pb1)
        g1 = np.asarray(getattr(out1, field))
        w1 = np.asarray(getattr(want1, field))
        shrunk = "reproduces at B=1" if (g1 != w1).any() else \
            "does NOT reproduce at B=1 (batch-coupling bug)"
    except Exception as e:  # the shrink run itself may crash — still report
        shrunk = f"B=1 rerun raised {type(e).__name__}: {e}"
    fault_tag = "" if fault is None else f" fault={fault}"
    only_tag = f"V={V},case={case}" + \
        ("" if fault is None else f",fault={fault}")
    pytest.fail(
        f"CONFORMANCE REPRO V={V} case={case}{fault_tag} seed={seed} "
        f"substrate={substrate} field={field} packet={i}/{got.shape[0]} "
        f"mid={int(np.asarray(pb.mid)[i])} vid={int(np.asarray(pb.vid)[i])} "
        f"ptype={int(np.asarray(pb.ptype)[i])} "
        f"got={got[i]!r} want={exp[i]!r} [{shrunk}] — rerun with "
        f'CONFORMANCE_ONLY="{only_tag}"')


@pytest.fixture(scope="module", params=sorted(N_CASES), ids=lambda v: f"V{v}")
def harness(request):
    """Per-V substrate pool, reprogrammed via swap() for every drawn case."""
    V = request.param
    prof = _profile(V)
    empties = [empty_program(prof) for _ in range(N_SEQ_DEV)]
    single = SingleSwitchExecutor(prof, packed=empty_program(prof))
    executors = {
        "single": single,
        "sequential": SequentialPathExecutor(list(empties),
                                             n_classes=prof.max_classes),
        "pipelined": PipelinedExecutor([empty_program(prof)],
                                       n_classes=prof.max_classes, n_micro=2),
        "sharded": ShardedExecutor([empty_program(prof)],
                                   n_classes=prof.max_classes,
                                   n_ports=1, n_micro=2),
    }
    runtimes = {name: DataplaneRuntime(ex) for name, ex in executors.items()}
    zoo = ZooServer(prof, executor=single)    # shares the single jit cache
    oracle = SwitchEngine(prof, mode="ref")   # kernels.ref, unpadded shapes
    return V, prof, executors, runtimes, zoo, oracle


async def _serve_async(zoo, pb, rng, server_cls=AsyncZooServer):
    """Submit the case's traffic as 1-3 ragged client chunks through an
    async front (the coalescing server or the continuous slot-pool engine);
    return the demuxed results re-concatenated in order."""
    policy = SizeOrDeadlinePolicy(max_batch=32, max_wait_us=500.0)
    B = pb.batch
    n_chunks = int(rng.integers(1, min(3, B) + 1))
    cuts = sorted(rng.choice(np.arange(1, B), size=n_chunks - 1,
                             replace=False).tolist()) if n_chunks > 1 else []
    bounds = [0] + cuts + [B]
    chunks = [jax.tree.map(lambda x: np.asarray(x)[lo:hi], pb)
              for lo, hi in zip(bounds, bounds[1:])]
    # warm=False: the harness pre-warms the shared jit cache itself; the
    # warm path is pinned in tests/test_engine.py
    kw = {"n_slots": 2, "warm": False} \
        if server_cls is ContinuousZooServer else {}
    async with server_cls(zoo, policy=policy, **kw) as srv:
        outs = await asyncio.gather(
            *[srv.submit_batch(c) for c in chunks])
    return (np.concatenate([o.rslt for o in outs]),
            np.concatenate([o.codes for o in outs]),
            np.concatenate([o.svm_acc for o in outs]))


def test_conformance_cross_executor_and_async(harness):
    """>= 200 drawn cases: four executors + the async server, bit-identical
    to the kernels.ref oracle, passthrough and invalid VIDs included."""
    V, prof, executors, runtimes, zoo, oracle = harness
    only = _repro_filter()
    if only.get("V") not in (None, V):
        pytest.skip(f"CONFORMANCE_ONLY pins V={only['V']}")
    cases = ([only["case"]] if only.get("case") is not None
             else range(N_CASES[V]))
    for case in cases:
        seed, progs, packed, pb = _draw_case(V, case, prof)
        want = oracle.classify(packed, pb)

        executors["single"].swap([packed])
        executors["sequential"].swap(_split_stages(progs, prof, N_SEQ_DEV))
        executors["pipelined"].swap([packed])
        executors["sharded"].swap([packed])

        for name, rt in runtimes.items():
            out = rt.run(pb)
            for field in FIELDS:
                if not (np.asarray(getattr(out, field))
                        == np.asarray(getattr(want, field))).all():
                    def classify_one(pb1, _rt=rt):
                        return _rt.run(pb1), oracle.classify(packed, pb1)
                    _shrink_and_fail(V, case, seed, name, field, pb, out,
                                     want, classify_one)

        for aname, cls in (("async", AsyncZooServer),
                           ("continuous", ContinuousZooServer)):
            rng = np.random.default_rng(seed + 1)   # same chunking both fronts
            a_rslt, a_codes, a_acc = asyncio.run(
                _serve_async(zoo, pb, rng, cls))
            got_async = dataclasses.replace(pb, rslt=a_rslt, codes=a_codes,
                                            svm_acc=a_acc)
            for field in FIELDS:
                if not (np.asarray(getattr(got_async, field))
                        == np.asarray(getattr(want, field))).all():
                    def classify_one(pb1, _cls=cls):
                        r, c, a = asyncio.run(_serve_async(
                            zoo, pb1, np.random.default_rng(0), _cls))
                        return (dataclasses.replace(pb1, rslt=r, codes=c,
                                                    svm_acc=a),
                                oracle.classify(packed, pb1))
                    _shrink_and_fail(V, case, seed, aname, field, pb,
                                     got_async, want, classify_one)


def test_conformance_fused_megakernel(harness):
    """Fused-megakernel lane (ISSUE-9 acceptance pin): the same drawn cases,
    classified through the one-launch ``classify_fused`` kernel body
    (``mode="interpret"``) with its quantized install-time operand layouts,
    bit-identical to the ``kernels.ref`` oracle."""
    V, prof, _executors, _runtimes, _zoo, oracle = harness
    only = _repro_filter()
    if only.get("V") not in (None, V):
        pytest.skip(f"CONFORMANCE_ONLY pins V={only['V']}")
    eng = SwitchEngine(prof, mode="interpret")
    cases = ([only["case"]] if only.get("case") is not None
             else range(N_CASES[V]))
    for case in cases:
        seed, _progs, packed, pb = _draw_case(V, case, prof)
        want = oracle.classify(packed, pb)
        out = eng.classify(packed, pb)
        for field in FIELDS:
            if not (np.asarray(getattr(out, field))
                    == np.asarray(getattr(want, field))).all():
                def classify_one(pb1):
                    return (eng.classify(packed, pb1),
                            oracle.classify(packed, pb1))
                _shrink_and_fail(V, case, seed, "fused-interpret", field,
                                 pb, out, want, classify_one)


def test_conformance_draw_count():
    """The harness contract: at least 200 drawn cases across the V sweep."""
    assert sum(N_CASES.values()) >= 200


# --------------------------------------------------------------------------
# Topology lane: fault-injected whole-fleet serving (ISSUE-8 acceptance pin).
#
# Each case plans a random zoo onto a fat-tree with ``plan_zoo``, serves
# three traffic phases through ``FleetRuntime`` — before, during (submitted
# concurrently with 1-2 scripted device kills), and after the control loop's
# replan — and pins every phase bit-identical to the monolithic kernels.ref
# oracle.  Repro: CONFORMANCE_ONLY="V=4,case=3,fault=3".
# --------------------------------------------------------------------------
def _fleet_seed(case: int) -> int:
    return 104_729 + 13 * case


@pytest.fixture(scope="module")
def fleet_harness():
    """Shared template engine + oracle for every fault schedule: one jitted
    trace serves every device of every case's fleet."""
    prof = _profile(FLEET_V)
    return prof, SwitchEngine(prof), SwitchEngine(prof, mode="ref")


def _draw_fault_schedule(rng, progs, net, src, dst, dev, fleet):
    """1-2 killable on-path switches, pre-validated survivable: the edge
    switches next to the hosts are cut vertices (hosts_per_edge=1), so the
    schedule draws from the interior and keeps only combos the planner can
    replan around (capacity included, not just connectivity)."""
    interior = [d for d in fleet.path[2:-2]
                if net.kind[d] == "switch"]
    n_kill = int(rng.integers(1, 3))
    combos = list(itertools.combinations(interior, n_kill))
    if n_kill == 2:
        combos += list(itertools.combinations(interior, 1))
    rng.shuffle(combos)
    for combo in combos:
        try:
            replan_zoo(progs, net, src, dst, set(combo),
                       solver="dp", default_device=dev)
        except (RuntimeError, ValueError):
            continue
        return list(combo)
    raise AssertionError(
        f"no survivable fault schedule on path {fleet.path} — the draw "
        "should be impossible on a fat-tree interior")


async def _run_fleet_phases(fleet, phases, kills):
    """Serve the three phases live; the kills land while phase 'during' is
    in flight, so its answers cross the detect->replan->drain->reinstall
    cycle (DeviceFailure retries included)."""
    outs = []
    async with fleet.serving(probe_interval_s=0.005):
        outs.append(await fleet.submit_batch(phases[0]))      # before
        during = asyncio.create_task(fleet.submit_batch(phases[1]))
        await asyncio.sleep(0)           # let the submit reach the queue
        for d in kills:
            fleet.kill(d)
        outs.append(await during)                             # during
        outs.append(await fleet.submit_batch(phases[2]))      # after
        stats = fleet.latency_stats()
    return outs, stats


def test_conformance_fleet_fault_schedules(fleet_harness):
    """Seeded fault schedules: every response before/during/after the
    replan is bit-identical to the kernels.ref oracle, and the heal cycle's
    counters surface through latency_stats()."""
    prof, engine, oracle = fleet_harness
    only = _repro_filter()
    if only.get("fault") is not None:
        cases = [only["fault"]]
    elif only:
        pytest.skip("CONFORMANCE_ONLY pins a non-fault case")
    else:
        cases = range(N_FAULT_CASES)
    net = fat_tree(4)
    for case in cases:
        seed = _fleet_seed(case)
        rng = np.random.default_rng(seed)
        progs, packed = _draw_zoo(rng, FLEET_V, seed, _profile(FLEET_V))
        # endpoints in different pods, so the path crosses the core layer
        pods = rng.choice(4, size=2, replace=False)
        src, dst = f"h{pods[0]}_0_0", f"h{pods[1]}_0_0"
        # small per-device capacity spreads stages across hops when the
        # drawn zoo fits; fall back to Tofino-class if the plan is infeasible
        dev = DeviceModel(n_stages=int(rng.choice([4, 6, 20])))
        try:
            fleet = FleetRuntime(net, prof, progs, src=src, dst=dst,
                                 default_device=dev, engine=engine)
        except RuntimeError:
            dev = DeviceModel()
            fleet = FleetRuntime(net, prof, progs, src=src, dst=dst,
                                 default_device=dev, engine=engine)
        kills = _draw_fault_schedule(rng, progs, net, src, dst, dev, fleet)
        phases = [_draw_traffic(rng, progs, FLEET_V, prof) for _ in range(3)]
        want = [oracle.classify(packed, pb) for pb in phases]

        outs, stats = asyncio.run(_run_fleet_phases(fleet, phases, kills),
                                  debug=True)
        for phase, pb, out, exp in zip(("before", "during", "after"),
                                       phases, outs, want):
            got = dataclasses.replace(pb, rslt=out.rslt, codes=out.codes,
                                      svm_acc=out.svm_acc)
            for field in FIELDS:
                if not (np.asarray(getattr(got, field))
                        == np.asarray(getattr(exp, field))).all():
                    def classify_one(pb1):
                        return (fleet.runtime.run(pb1),
                                oracle.classify(packed, pb1))
                    _shrink_and_fail(FLEET_V, case, seed,
                                     f"fleet-{phase}", field, pb, got, exp,
                                     classify_one, fault=case)

        # the heal cycle actually ran, and the fleet routed around the kills
        ctl = stats["control"]
        assert ctl["failures_detected"] >= 1, ctl
        assert ctl["replans"] >= 1 and ctl["reinstalls"] >= 1, ctl
        assert ctl["drains"] >= 1 and ctl["heal_failures"] == 0, ctl
        assert not (set(kills) & set(fleet.path)), (kills, fleet.path)

"""Distributed plane: plan-sliced programs == single plane; pipelined shard_map
ring (subprocess with 4 emulated devices) == sequential reference."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.distributed_plane import build_device_programs, run_sequential
from repro.core.mlmodels import RandomForest
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.core.planner import DeviceModel, plan_program
from repro.core.topology import fat_tree
from repro.core.translator import translate

PROF = PlaneProfile(max_features=36, max_trees=4, max_layers=8,
                    max_entries_per_layer=64, max_leaves=64,
                    max_classes=8, max_hyperplanes=8)


def test_distributed_equals_single_plane(satdap):
    Xtr, ytr, Xte, _ = satdap
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30).fit(Xtr, ytr)
    prog = translate(rf)
    net = fat_tree(4)
    h = net.hosts()
    plan = plan_program(prog, net, h[0], h[-1],
                        default_device=DeviceModel(n_stages=4), solver="dp")
    assert len(plan.device_stages()) >= 3  # actually distributed
    devs, dps = build_device_programs(prog, plan, PROF)
    pb = PacketBatch.make_request(Xte, mid=prog.mid, max_features=36,
                                  n_trees=4, n_hyperplanes=8)
    out = run_sequential(dps, pb, n_classes=8)
    assert (np.asarray(out.rslt) == rf.predict(Xte)).all()
    eng = SwitchEngine(PROF)
    single = eng.classify(eng.install(eng.empty(), prog), pb)
    assert (np.asarray(out.rslt) == np.asarray(single.rslt)).all()


def test_intermediate_devices_leave_rslt_unset(satdap):
    """Until the device holding dt_predict is reached, RSLT stays -1 — the
    packet carries only intermediates (paper App. A)."""
    Xtr, ytr, Xte, _ = satdap
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30).fit(Xtr, ytr)
    prog = translate(rf)
    net = fat_tree(4)
    h = net.hosts()
    plan = plan_program(prog, net, h[0], h[-1],
                        default_device=DeviceModel(n_stages=4), solver="dp")
    devs, dps = build_device_programs(prog, plan, PROF)
    pb = PacketBatch.make_request(Xte[:32], mid=prog.mid, max_features=36,
                                  n_trees=4, n_hyperplanes=8)
    out = run_sequential(dps[:-1], pb, n_classes=8)
    assert (np.asarray(out.rslt) == -1).all()


PIPELINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np, jax
    from repro.core.distributed_plane import build_device_programs, PipelinedPlane
    from repro.core.mlmodels import RandomForest, Quantizer
    from repro.core.packets import PacketBatch
    from repro.core.plane import PlaneProfile
    from repro.core.planner import DeviceModel, plan_program
    from repro.core.topology import fat_tree
    from repro.core.translator import translate
    from repro.data import load_dataset

    Xtr, ytr, Xte, yte = load_dataset("satdap", scale=0.15)
    q = Quantizer(8).fit(Xtr)
    Xtrq, Xteq = q.transform(Xtr), q.transform(Xte)
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30).fit(Xtrq, ytr)
    prog = translate(rf)
    net = fat_tree(4); h = net.hosts()
    plan = plan_program(prog, net, h[0], h[-1],
                        default_device=DeviceModel(n_stages=4), solver="dp")
    prof = PlaneProfile(max_features=36, max_trees=4, max_layers=8,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8)
    devs, dps = build_device_programs(prog, plan, prof)
    n_micro, B = 4, 32
    Xm = Xteq[: n_micro * B]
    mbs = PacketBatch.make_request(Xm, mid=prog.mid, max_features=36,
                                   n_trees=4, n_hyperplanes=8)
    mbs = jax.tree.map(lambda x: x.reshape((n_micro, B) + x.shape[1:]), mbs)
    pp = PipelinedPlane(dps[: len(jax.devices())], n_classes=8) if len(dps) <= 4 \
        else None
    assert pp is not None, f"plan used {len(dps)} devices > 4"
    out = pp.run(mbs)
    got = np.asarray(out.rslt)
    flat = got.shape == (n_micro * B,)  # run() re-concatenates in order
    ok = flat and bool((got == rf.predict(Xm)).all())
    print(json.dumps({"ok": ok, "flat": flat, "n_dev": len(dps)}))
""")


@pytest.mark.slow
def test_pipelined_plane_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", PIPELINE_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=480)
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert res["ok"], res

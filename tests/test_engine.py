"""Continuous-batching dispatch engine: slot-pool overlap, warmed-bucket
cache, SLO-driven lane autoscaling, and the open-loop load generator.

Every coroutine runs through ``asyncio.run(..., debug=True)`` like the rest
of the serving suite.  Bit-identity of the engine against every executor
substrate rides the conformance harness (``tests/test_conformance.py``);
this module pins the engine *mechanics*: that dispatches actually overlap
(a thread barrier only two concurrent executor calls can release), that
every admission bucket is pre-traced before traffic and live dispatches
mint nothing, that lane scale events are quiesced and answer-preserving,
and that the loadgen charges latency from the scheduled arrival.
"""
import asyncio
import threading

import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree
from repro.core.plane import PlaneProfile
from repro.runtime import (
    ImmediatePolicy,
    SizeOrDeadlinePolicy,
    SloAutoscaler,
    bucket_ladder,
)
from repro.serving import (
    AsyncZooServer,
    ContinuousZooServer,
    LoadReport,
    ZooServer,
    arrival_times,
    open_loop,
)


def run_async(coro):
    """All async tests run under asyncio debug (strict) mode."""
    return asyncio.run(coro, debug=True)


def _profile(V=2):
    return PlaneProfile(max_features=36, max_trees=4, max_layers=6,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=V)


def _mk_zoo(satdap):
    Xtr, ytr, _, _ = satdap
    z = ZooServer(_profile())
    z.install(DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr),
              vid=0)
    return z


@pytest.fixture(scope="module")
def zoo(satdap):
    return _mk_zoo(satdap)


# ----------------------------------------------------------- slot pool
def test_continuous_results_bit_identical_and_demuxed(zoo, satdap):
    """Concurrent ragged submits through the slot pool demux to exactly the
    synchronous per-batch results — same invariant as the base server."""
    _, _, Xte, _ = satdap
    chunks = [(0, 7), (7, 8), (8, 29), (29, 61), (61, 64)]

    async def main():
        async with ContinuousZooServer(
                zoo, policy=SizeOrDeadlinePolicy(max_batch=64,
                                                 max_wait_us=2_000),
                n_slots=2) as srv:
            outs = await asyncio.gather(
                *[srv.submit(Xte[lo:hi], mid=0, vid=0)
                  for lo, hi in chunks])
            return outs, srv.latency_stats()

    outs, stats = run_async(main())
    for out, (lo, hi) in zip(outs, chunks):
        np.testing.assert_array_equal(
            out.rslt, zoo.classify(Xte[lo:hi], mid=0, vid=0))
        assert out.t_submit <= out.t_dispatch <= out.t_done
    assert stats["requests"] == len(chunks)
    assert stats["engine"]["slots"] == 2


def test_slot_pool_overlaps_dispatches(zoo, satdap):
    """The overlap the engine exists for, proven with a thread barrier that
    only releases when TWO executor calls are in flight at once: under the
    base one-at-a-time loop this would deadlock (and time out the
    barrier); under the slot pool both submits classify concurrently."""
    _, _, Xte, _ = satdap
    barrier = threading.Barrier(2, timeout=10)

    async def main():
        async with ContinuousZooServer(zoo, policy=ImmediatePolicy(),
                                       n_slots=2, warm=False) as srv:
            orig = srv.runtime.executor.classify

            def gated(pb):
                barrier.wait()      # released only by a concurrent peer
                return orig(pb)

            srv.runtime.executor.classify = gated
            try:
                outs = await asyncio.gather(
                    srv.submit(Xte[:2], mid=0, vid=0),
                    srv.submit(Xte[2:4], mid=0, vid=0))
            finally:
                srv.runtime.executor.classify = orig
            return outs, srv.latency_stats()

    outs, stats = run_async(asyncio.wait_for(main(), timeout=30))
    np.testing.assert_array_equal(outs[0].rslt,
                                  zoo.classify(Xte[:2], mid=0, vid=0))
    np.testing.assert_array_equal(outs[1].rslt,
                                  zoo.classify(Xte[2:4], mid=0, vid=0))
    assert stats["engine"]["peak_concurrent_dispatches"] == 2


def test_single_slot_never_overlaps(zoo, satdap):
    """n_slots bounds executor concurrency: with one slot the engine is
    continuous (cutting overlaps demux) but never runs two classifies."""
    _, _, Xte, _ = satdap

    async def main():
        async with ContinuousZooServer(zoo, policy=ImmediatePolicy(),
                                       n_slots=1, warm=False) as srv:
            await asyncio.gather(
                *[srv.submit(Xte[i:i + 2], mid=0, vid=0) for i in range(6)])
            return srv.latency_stats()

    stats = run_async(main())
    assert stats["engine"]["peak_concurrent_dispatches"] == 1
    assert stats["requests"] == 6


def test_continuous_stop_flushes_and_drain_quiesces(zoo, satdap):
    """The base server's guarantees survive the slot pool: stop() flushes a
    deadline-parked queue, and drain() waits for slot-queued work too."""
    _, _, Xte, _ = satdap

    async def main():
        srv = ContinuousZooServer(zoo, policy=SizeOrDeadlinePolicy(
            max_batch=4096, max_wait_us=60_000_000), n_slots=2, warm=False)
        await srv.start()
        tasks = [asyncio.create_task(srv.submit(Xte[i:i + 3], mid=0, vid=0))
                 for i in range(5)]
        await asyncio.sleep(0.01)
        await srv.drain()                   # all slots idle under the barrier
        inflight = srv._inflight
        srv.release()
        await srv.stop()                    # flushes through the closing cutter
        return inflight, await asyncio.gather(*tasks)

    inflight, outs = run_async(asyncio.wait_for(main(), timeout=30))
    assert inflight == 0
    for i, out in enumerate(outs):
        np.testing.assert_array_equal(
            out.rslt, zoo.classify(Xte[i:i + 3], mid=0, vid=0))


def test_engine_validation(zoo):
    with pytest.raises(ValueError, match="n_slots"):
        ContinuousZooServer(zoo, n_slots=0)
    with pytest.raises(ValueError, match="lane_pool"):
        ContinuousZooServer(zoo, autoscaler=SloAutoscaler(slo_p99_ms=1.0))
    with pytest.raises(ValueError, match="missing from lane_pool"):
        ContinuousZooServer(
            zoo, lane_pool={1: zoo.runtime.executor},
            autoscaler=SloAutoscaler(slo_p99_ms=1.0, lanes=(1, 2)))


# ------------------------------------------------- warmed-bucket cache
def test_warm_pretaces_every_bucket_before_traffic(satdap):
    """Before the first live submit the engine has driven every
    ``granularity * 2^k`` bucket up to the policy's max_batch through the
    run_host seam — live dispatches then mint zero new traces, and the
    zero-filled FORWARD warm traffic is semantically invisible."""
    _, _, Xte, _ = satdap
    z = _mk_zoo(satdap)

    async def main():
        async with ContinuousZooServer(
                z, policy=SizeOrDeadlinePolicy(max_batch=16,
                                               max_wait_us=500.0)) as srv:
            ladder = srv.warmed_buckets
            traces_after_warm = z.cache_size()
            outs = await asyncio.gather(
                *[srv.submit(Xte[i:i + 3], mid=0, vid=0) for i in range(5)])
            return ladder, traces_after_warm, z.cache_size(), outs

    ladder, warmed, after, outs = run_async(main())
    assert ladder == bucket_ladder(16, 1) == (1, 2, 4, 8, 16)
    assert warmed == len(ladder)            # one trace per bucket, all minted
    assert after == warmed, "a live dispatch minted a new compiled shape"
    for i, out in enumerate(outs):          # warm passthroughs changed nothing
        np.testing.assert_array_equal(
            out.rslt, z.classify(Xte[i:i + 3], mid=0, vid=0))


def test_warm_skipped_without_a_bounded_policy(zoo, satdap):
    """ImmediatePolicy has no max_batch: nothing to warm against, and the
    engine must not guess — warmed_buckets stays empty."""
    _, _, Xte, _ = satdap

    async def main():
        async with ContinuousZooServer(zoo, policy=ImmediatePolicy()) as srv:
            out = await srv.submit(Xte[:2], mid=0, vid=0)
            return srv.warmed_buckets, out

    ladder, out = run_async(main())
    assert ladder == ()
    np.testing.assert_array_equal(out.rslt, zoo.classify(Xte[:2], mid=0,
                                                         vid=0))


# --------------------------------------------------------- autoscaling
def test_slo_autoscaler_widens_and_narrows():
    a = SloAutoscaler(slo_p99_ms=10.0, lanes=(1, 2), window=4, patience=2,
                      narrow_margin=0.5, cooldown=0)
    assert a.lane == 1
    assert np.isnan(a.p99_ms)               # no evidence yet
    hot = [a.observe(50.0) for _ in range(10)]
    assert 2 in hot and a.lane == 2         # sustained over-SLO: widen
    assert all(d is None for d in [a.observe(50.0) for _ in range(10)]), \
        "already at the widest lane — no further decision"
    cold = [a.observe(1.0) for _ in range(10)]
    assert 1 in cold and a.lane == 1        # sustained under margin: narrow
    # mid-band traffic (between margin and SLO) holds the current lane
    assert all(d is None for d in [a.observe(7.0) for _ in range(20)])
    assert a.lane == 1


def test_slo_autoscaler_cooldown_blocks_flapping():
    a = SloAutoscaler(slo_p99_ms=10.0, lanes=(1, 2, 4), window=2,
                      patience=1, cooldown=50)
    assert any(a.observe(99.0) is not None for _ in range(4))
    assert a.lane == 2
    # still hot, but the next decision must wait out the cooldown — the
    # freshly-swapped lane gets time to settle before being judged
    assert all(a.observe(99.0) is None for _ in range(40))
    assert 4 in [a.observe(99.0) for _ in range(60)]
    assert a.lane == 4


def test_slo_autoscaler_validation():
    with pytest.raises(ValueError):
        SloAutoscaler(slo_p99_ms=0.0)
    with pytest.raises(ValueError):
        SloAutoscaler(slo_p99_ms=1.0, lanes=(2, 1))
    with pytest.raises(ValueError):
        SloAutoscaler(slo_p99_ms=1.0, lanes=(1, 1, 2))
    with pytest.raises(ValueError):
        SloAutoscaler(slo_p99_ms=1.0, narrow_margin=1.5)
    with pytest.raises(ValueError):
        SloAutoscaler(slo_p99_ms=1.0, patience=0)


def test_autoscaler_scales_lanes_bit_identically(satdap):
    """End-to-end scale event: an impossible SLO forces a widen, the engine
    pre-warms the incoming lane, quiesces, swaps — and every answer before,
    across, and after the swap equals the reference classify."""
    _, _, Xte, _ = satdap
    serving = _mk_zoo(satdap)               # lane 1: the serving zoo's executor
    lane2 = _mk_zoo(satdap)                 # lane 2: identically programmed
    ref = _mk_zoo(satdap)                   # never swapped: the answer oracle
    pool = {1: serving.runtime.executor, 2: lane2.runtime.executor}
    scaler = SloAutoscaler(slo_p99_ms=1e-6, lanes=(1, 2), window=4,
                           patience=1, cooldown=0)

    async def main():
        async with ContinuousZooServer(
                serving, policy=SizeOrDeadlinePolicy(max_batch=8,
                                                     max_wait_us=200.0),
                n_slots=2, lane_pool=pool, autoscaler=scaler) as srv:
            outs = []
            for i in range(12):             # sequential: decisions apply between
                outs.append(await srv.submit(Xte[i:i + 2], mid=0, vid=0))
            return outs, srv.lanes, srv.latency_stats()

    outs, lanes, stats = run_async(asyncio.wait_for(main(), timeout=60))
    assert lanes == 2, "an impossible SLO must have widened the mesh"
    assert stats["engine"]["lanes"] == 2
    assert stats["engine"]["scale_ups"] >= 1
    for i, out in enumerate(outs):          # bit-identical across the swap
        np.testing.assert_array_equal(
            out.rslt, ref.classify(Xte[i:i + 2], mid=0, vid=0))
    # the incoming lane was pre-warmed before the swap: its executor holds
    # the full bucket ladder even though it served only post-swap traffic
    assert lane2.cache_size() == len(bucket_ladder(8, 1))


def test_autoscaler_narrows_back_when_load_drops(satdap):
    """The reverse transition: a generous SLO over cheap traffic narrows the
    engine back to lane 1, releasing the wide mesh."""
    _, _, Xte, _ = satdap
    serving = _mk_zoo(satdap)
    lane2 = _mk_zoo(satdap)
    pool = {1: serving.runtime.executor, 2: lane2.runtime.executor}
    scaler = SloAutoscaler(slo_p99_ms=1e-6, lanes=(1, 2), window=4,
                           patience=1, cooldown=0)

    async def main():
        async with ContinuousZooServer(
                serving, policy=SizeOrDeadlinePolicy(max_batch=8,
                                                     max_wait_us=200.0),
                lane_pool=pool, autoscaler=scaler) as srv:
            for i in range(8):              # impossible SLO: widen to lane 2
                await srv.submit(Xte[i:i + 2], mid=0, vid=0)
            assert srv.lanes == 2
            scaler.slo_p99_ms = 1e9         # load "drops": everything is cheap
            for i in range(8):
                await srv.submit(Xte[i:i + 2], mid=0, vid=0)
            return srv.lanes, srv.latency_stats()

    lanes, stats = run_async(asyncio.wait_for(main(), timeout=60))
    assert lanes == 1
    assert stats["engine"]["scale_downs"] >= 1


# ------------------------------------------------------------- loadgen
def test_arrival_times_processes():
    rng = np.random.default_rng(0)
    t = arrival_times(1000, 100.0, rng=rng)
    assert t.shape == (1000,) and (np.diff(t) >= 0).all()
    assert t[-1] == pytest.approx(10.0, rel=0.25)     # mean rate respected
    b = arrival_times(1000, 100.0, process="burst", burst=8,
                      rng=np.random.default_rng(0))
    assert (np.diff(b) >= 0).all()
    # clumped: arrivals inside a burst share one timestamp
    assert np.unique(b).size <= -(-1000 // 8)
    assert b[-1] == pytest.approx(10.0, rel=0.35)     # same mean rate
    with pytest.raises(ValueError):
        arrival_times(0, 1.0)
    with pytest.raises(ValueError):
        arrival_times(1, 0.0)
    with pytest.raises(ValueError):
        arrival_times(1, 1.0, process="pareto")
    with pytest.raises(ValueError):
        arrival_times(1, 1.0, process="burst", burst=0)


def test_open_loop_counts_errors_and_orders_percentiles():
    async def main():
        calls = []

        async def submit(i):
            calls.append(i)
            if i % 5 == 0:
                raise RuntimeError("refused")
            await asyncio.sleep(0)

        report = await open_loop(submit, rate_rps=10_000.0, n_requests=50,
                                 n_clients=4, seed=3)
        with pytest.raises(ValueError):
            await open_loop(submit, rate_rps=1.0, n_requests=1, n_clients=0)
        return report, calls

    report, calls = run_async(main())
    assert isinstance(report, LoadReport)
    assert sorted(calls)[:50] == list(range(50))      # every arrival fired
    assert report.requests == 50
    assert report.errors == 10                        # failures counted...
    assert report.p50_ms <= report.p99_ms <= report.p999_ms  # ...not hidden
    assert report.offered_rps == 10_000.0
    assert report.achieved_rps > 0
    row = report.row()
    assert row["errors"] == 10 and isinstance(row["p99_ms"], float)


def test_open_loop_charges_latency_from_scheduled_arrival():
    """Coordinated omission: a server that stalls must see the stall in its
    tail, even though the generator fired on schedule.  A 50 ms stall on
    one request puts >= 50 ms in the max latency."""

    async def main():
        async def submit(i):
            await asyncio.sleep(0.05 if i == 7 else 0)

        return await open_loop(submit, rate_rps=1_000.0, n_requests=16,
                               n_clients=2, seed=0)

    report = run_async(main())
    assert report.errors == 0
    assert report.p999_ms >= 50.0, \
        "the stalled request's latency was omitted from the distribution"


def test_open_loop_drives_the_continuous_engine(zoo, satdap):
    """Integration: the generator drives a live ContinuousZooServer and the
    loadgen-side report agrees with the server's own accounting."""
    _, _, Xte, _ = satdap

    async def main():
        async with ContinuousZooServer(
                zoo, policy=SizeOrDeadlinePolicy(max_batch=16,
                                                 max_wait_us=500.0),
                n_slots=2, warm=False) as srv:
            async def submit(i):
                lo = (i * 3) % (Xte.shape[0] - 2)
                await srv.submit(Xte[lo:lo + 2], mid=0, vid=0)

            report = await open_loop(submit, rate_rps=2_000.0,
                                     n_requests=40, seed=11)
            return report, srv.latency_stats()

    report, stats = run_async(asyncio.wait_for(main(), timeout=60))
    assert report.errors == 0
    assert stats["requests"] == report.requests == 40
    assert stats["dispatches"] >= 1
    # loadgen latency includes the schedule; the server's own latency is a
    # lower bound on it
    assert report.p50_ms >= 0.0 and stats["p50_ms"] >= 0.0

"""Install-time program compilation (the exec image).

Pins the contract from ``docs/ARCHITECTURE.md``: classify binds precomputed
kernel operands with **zero** per-call operand prep (jaxpr-pinned, the analog
of ``test_classify_issues_single_tree_walk_launch``), the incremental
per-slot image updates in install/evict are bit-identical to a from-scratch
``build_exec_image``, and install/evict/swap cycles never drift classify
results away from a fresh engine holding the same programs — for
V ∈ {1, 4, 8} and on both the ref and interpret kernel paths.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.packets import PacketBatch
from repro.core.plane import (
    PlaneProfile,
    SwitchEngine,
    _classify_impl,
    build_exec_image,
)
from repro.core.translator import MID_SVM, translate
from repro.kernels import ops


def _profile(V: int) -> PlaneProfile:
    return PlaneProfile(max_features=36, max_trees=3, max_layers=6,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=V)


def _req(eng, X, *, mid=0, vid=0):
    prof = eng.profile
    return PacketBatch.make_request(
        X, mid=mid, vid=vid, max_features=prof.max_features,
        n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
        max_versions=prof.max_versions)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------- jaxpr pinning
def test_classify_binds_precomputed_operands_zero_prep_ops(satdap, plane_engine):
    """Acceptance: with the exec image bound, the classify jaxpr contains no
    table-shaped prep ops (one-hot fsel build, no-match padding, LUT
    re-layout) — every table operand flows straight into a kernel launch.
    ``use_image=False`` restores the per-call prep, which the same counter
    must see (so a detector regression can't silently pass)."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    dt = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    prog = translate(dt)
    packed = eng.install(eng.empty(), prog)
    pb = _req(eng, Xte[:32], mid=prog.mid)
    n_cls = eng.profile.max_classes
    count = lambda **kw: ops.count_operand_prep_ops(
        lambda pk, b: _classify_impl(pk, b, n_classes=n_cls,
                                     mode="interpret", **kw),
        packed, pb)
    assert count() == 0
    assert count(use_image=False) > 0
    # and the megakernel launch pin still holds with the image bound
    assert ops.count_pallas_launches(
        lambda pk, b: _classify_impl(pk, b, n_classes=n_cls, mode="interpret"),
        packed, pb) == 1  # the whole classify is one fused launch


# ----------------------------------------------- incremental == full rebuild
def test_incremental_slot_updates_match_full_rebuild(satdap):
    """install/evict touch only the written slot's image slice; after any
    sequence, the resident image equals a from-scratch build_exec_image."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(4)
    eng = SwitchEngine(prof)
    d0 = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    d1 = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    svm = LinearSVM(epochs=30).fit(Xtr, ytr)
    packed = eng.empty()
    for step in (lambda p: eng.install(p, translate(d0, vid=0)),
                 lambda p: eng.install(p, translate(svm, vid=2)),
                 lambda p: eng.install(p, translate(d1, vid=3)),
                 lambda p: eng.evict(p, vid=0),
                 lambda p: eng.install(p, translate(d1, vid=0)),
                 lambda p: eng.evict(p, vid=2, kind="svm"),
                 lambda p: eng.evict(p, vid=3, kind="tree")):
        packed = step(packed)
        _assert_trees_equal(packed.image, build_exec_image(packed, prof))


def test_legacy_program_without_image_recovers_on_install(satdap):
    """A PackedProgram with image=None (legacy pytree) gets a full image
    rebuild on the next install/evict instead of crashing or staying stale."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(2)
    eng = SwitchEngine(prof)
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    legacy = dataclasses.replace(eng.empty(), image=None)
    packed = eng.install(legacy, translate(dt, vid=1))
    _assert_trees_equal(packed.image, build_exec_image(packed, prof))
    legacy = dataclasses.replace(packed, image=None)
    evicted = eng.evict(legacy, vid=1)
    _assert_trees_equal(evicted.image, build_exec_image(evicted, prof))


# ------------------------------------------- cycle stability across V and mode
@pytest.mark.parametrize("V", [1, 4, 8])
def test_cycles_stay_bit_identical_to_fresh_engine(satdap, V):
    """Acceptance: three install/evict/swap cycles leave classify results
    bit-identical to a fresh engine holding the same final programs, and
    interpret-vs-ref parity holds throughout — for V ∈ {1, 4, 8}."""
    Xtr, ytr, Xte, _ = satdap
    X = Xte[:64]
    prof = _profile(V)
    d_a = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    d_b = RandomForest(n_estimators=2, max_depth=4, max_leaf_nodes=16,
                       random_state=0).fit(Xtr, ytr)
    svm = LinearSVM(epochs=30).fit(Xtr, ytr)
    final = {}   # vid -> program installed last
    outs = {}
    for mode in ("ref", "interpret"):
        eng = SwitchEngine(prof, mode=mode)
        packed = eng.empty()
        for cycle in range(3):
            vid = cycle % V
            packed = eng.evict(packed, vid=vid)                     # evict
            packed = eng.install(packed, translate(d_a, vid=vid))   # install
            packed = eng.install(packed, translate(d_b, vid=vid))   # swap
            packed = eng.install(packed, translate(svm, vid=vid))   # 2nd pipe
            final[vid] = (translate(d_b, vid=vid), translate(svm, vid=vid))
        rng = np.random.default_rng(5)
        vids = rng.integers(0, V, X.shape[0])
        resident = np.isin(vids, list(final))
        mids = np.where(rng.random(X.shape[0]) < 0.4, MID_SVM,
                        final[0][0].mid)
        pb = _req(eng, X, mid=mids, vid=vids)
        outs[mode] = np.asarray(eng.classify(packed, pb).rslt)

        # fresh engine, same final programs, one install each — bit-identical
        fresh = SwitchEngine(prof, mode=mode)
        fresh_packed = fresh.empty()
        for vid, (tree_prog, svm_prog) in final.items():
            fresh_packed = fresh.install(fresh_packed, tree_prog)
            fresh_packed = fresh.install(fresh_packed, svm_prog)
        want = np.asarray(fresh.classify(fresh_packed, pb).rslt)
        np.testing.assert_array_equal(outs[mode], want)
        # evicted slots answer -1
        assert (outs[mode][~resident] == -1).all()
    np.testing.assert_array_equal(outs["ref"], outs["interpret"])

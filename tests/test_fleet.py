"""Whole-topology fleet serving + routing invariants under node removal.

Three layers of ISSUE-8 pins:

* ``Network.without`` routing invariants across all four paper topologies —
  post-removal paths stay valid/loop-free and unreachable endpoints are
  *reported* (``None`` / ``[]``), never silently routed through dead nodes;
* ``FleetRuntime``/``FleetExecutor`` mechanics — plan-to-wire parity with
  the ref oracle, one shared jitted trace for any fleet size, swap vs
  retarget semantics, ``DeviceFailure`` on dead wire paths;
* the ``ControlLoop`` heal cycle — detect/replan/drain/reinstall counters
  through ``latency_stats()``, idempotent concurrent heals, honest
  ``RuntimeError`` when a cut vertex dies.  (Bit-identity across random
  fault schedules lives in the ``tests/test_conformance.py`` fault lane.)
"""
import asyncio

import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree, RandomForest
from repro.core.plane import (
    PlaneProfile,
    SwitchEngine,
    empty_program,
    install_program,
)
from repro.core.planner import DeviceModel
from repro.core.topology import bcube, dcell, fat_tree, jellyfish
from repro.core.translator import translate
from repro.runtime import DeviceFailure
from repro.serving import FleetRuntime
from repro.serving.fleet import FleetExecutor

TOPOLOGIES = [
    ("fat_tree", lambda: fat_tree(4)),
    ("dcell", lambda: dcell(3, 1)),
    ("bcube", lambda: bcube(3, 1)),
    ("jellyfish", lambda: jellyfish(16, 3, hosts=6, seed=3)),
]


def run_async(coro):
    return asyncio.run(coro, debug=True)


# ------------------------------------------------- routing invariants
@pytest.mark.parametrize(("name", "mk"), TOPOLOGIES,
                         ids=[n for n, _ in TOPOLOGIES])
def test_paths_stay_valid_after_node_removal(name, mk):
    """Random single-switch removals: every surviving path is loop-free,
    endpoint-anchored, edge-valid, and avoids the removed node."""
    net = mk()
    rng = np.random.default_rng(11)
    hosts = net.hosts()
    checked = 0
    for _ in range(10):
        src, dst = (str(x) for x in rng.choice(hosts, 2, replace=False))
        kill = {str(rng.choice(net.switches()))}
        sub = net.without(kill)
        paths = sub.k_shortest_paths(src, dst, 3)
        if not paths:
            # unreachable must be *reported*, consistently, on both APIs
            assert sub.shortest_path(src, dst) is None
            continue
        for p in paths:
            assert p[0] == src and p[-1] == dst
            assert len(set(p)) == len(p), f"loop in {p}"
            assert not (set(p) & kill), f"{p} routes through dead {kill}"
            for a, b in zip(p, p[1:]):
                assert b in sub.adj[a], f"edge {a}-{b} does not exist"
        checked += 1
    assert checked >= 3, f"too few reachable draws on {name}"


def test_without_reports_unreachable_endpoints():
    """Killing a host's only edge switch (hosts_per_edge=1 cut vertex) must
    disconnect it: None / [] — not a path through the dead switch."""
    net = fat_tree(4)
    src, dst = "h0_0_0", "h1_0_0"
    assert net.shortest_path(src, dst) is not None
    sub = net.without({"edge0_0"})
    assert sub.shortest_path(src, dst) is None
    assert sub.k_shortest_paths(src, dst, 4) == []


def test_without_validates_and_preserves():
    net = fat_tree(4)
    with pytest.raises(ValueError):
        net.without({"no_such_node"})
    sub = net.without({"core0"})
    assert "core0" not in sub.nodes
    assert all("core0" not in vs for vs in sub.adj.values())
    assert net.n_switches == sub.n_switches + 1   # original untouched
    assert "core0" in net.nodes


# ------------------------------------------------------ fleet mechanics
def _profile():
    return PlaneProfile(max_features=36, max_trees=4, max_layers=8,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=2)


@pytest.fixture(scope="module")
def fleet_setup(satdap):
    """Shared net/profile/programs/template-engine for every fleet test —
    one jit compile for the module, fixed B=16 so one bucket trace."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile()
    progs = [
        translate(DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr),
                  vid=0),
        translate(RandomForest(n_estimators=3, max_depth=3,
                               max_leaf_nodes=8).fit(Xtr, ytr), vid=1),
    ]
    prof_engine = SwitchEngine(prof)
    oracle = empty_program(prof)
    for p in progs:
        oracle = install_program(oracle, p, prof, vid=p.vid)
    return fat_tree(4), prof, progs, prof_engine, oracle, Xte[:16]


def _mk_fleet(fleet_setup, *, n_stages=4):
    net, prof, progs, engine, _, _ = fleet_setup
    return FleetRuntime(net, prof, progs, src="h0_0_0", dst="h2_0_0",
                        default_device=DeviceModel(n_stages=n_stages),
                        engine=engine)


def test_fleet_plan_spreads_and_matches_oracle(fleet_setup):
    """Small per-device capacity forces a multi-hop deployment; classify
    through the fleet equals the monolithic single-switch oracle for both
    zoo versions."""
    net, prof, progs, engine, oracle_packed, Xq = fleet_setup
    fleet = _mk_fleet(fleet_setup)
    assert len(fleet.executor.devices) >= 2          # genuinely distributed
    assert set(fleet.executor.devices) <= set(fleet.path)
    oracle = SwitchEngine(prof, mode="ref")
    for vid in (0, 1):
        want = np.asarray(oracle.classify(
            oracle_packed, fleet.make_request(Xq, mid=0, vid=vid)).rslt)
        np.testing.assert_array_equal(fleet.classify(Xq, mid=0, vid=vid),
                                      want)


def test_fleet_shares_one_trace_across_deployments(fleet_setup):
    """The P4-template analogue: fleets of different device counts reuse one
    compiled classify — per-device programs are arguments, not traces.  The
    executable cache holds at most 2 entries at a fixed batch shape (the
    host-resident first hop vs device-resident later hops), and adding
    fleets, devices, or deployments must not grow it."""
    net, prof, progs, engine, _, Xq = fleet_setup
    wide = _mk_fleet(fleet_setup, n_stages=4)    # several hosting devices
    tall = _mk_fleet(fleet_setup, n_stages=20)   # everything on one device
    assert len(wide.executor.devices) > len(tall.executor.devices)
    a = wide.classify(Xq, mid=0, vid=0)
    baseline = engine.cache_size()
    assert baseline <= 2              # one B=16 bucket trace, any fleet size
    b = tall.classify(Xq, mid=0, vid=0)
    np.testing.assert_array_equal(a, b)
    wide.classify(Xq, mid=0, vid=1)   # other zoo version: same trace too
    assert engine.cache_size() == baseline


def test_fleet_kill_raises_device_failure(fleet_setup):
    """A dead device anywhere on the wire path (hosting or not) fails the
    dispatch with DeviceFailure naming a dead hop."""
    fleet = _mk_fleet(fleet_setup)
    _, _, _, _, _, Xq = fleet_setup
    non_hosting = [d for d in fleet.path[1:-1]
                   if d not in fleet.executor.devices]
    victim = (non_hosting or fleet.executor.devices)[0]
    fleet.kill(victim)
    with pytest.raises(DeviceFailure) as ei:
        fleet.classify(Xq, mid=0, vid=0)
    assert ei.value.device in fleet.down
    assert ei.value.path == fleet.path
    fleet.revive(victim)
    fleet.classify(Xq, mid=0, vid=0)             # healthy again, no replan


def test_fleet_kill_validates_device(fleet_setup):
    fleet = _mk_fleet(fleet_setup)
    with pytest.raises(ValueError):
        fleet.kill("h0_0_0")                     # hosts aren't killable
    with pytest.raises(ValueError):
        fleet.kill("no_such_switch")


def test_fleet_executor_swap_vs_retarget(fleet_setup):
    """Protocol swap() keeps the device set; a changed count must be
    rejected (that's a control-plane retarget, not a swap)."""
    net, prof, progs, engine, oracle_packed, Xq = fleet_setup
    fleet = _mk_fleet(fleet_setup)
    ex = fleet.executor
    n = len(ex.devices)
    ex.swap([ex.programs[d] for d in ex.devices])            # same count: ok
    with pytest.raises(ValueError):
        ex.swap([empty_program(prof)] * (n + 1))
    with pytest.raises(ValueError):                          # off-path host
        ex.retarget(fleet.path, ["not_on_path"], [empty_program(prof)])
    with pytest.raises(ValueError):                          # count mismatch
        ex.retarget(fleet.path, ex.devices, [empty_program(prof)] * (n + 1))


def test_fleet_executor_is_runtime_executor(fleet_setup):
    from repro.runtime import Executor
    fleet = _mk_fleet(fleet_setup)
    assert isinstance(fleet.executor, Executor)
    assert isinstance(fleet.executor, FleetExecutor)
    assert fleet.executor.granularity == 1


# ------------------------------------------------------ heal cycle (async)
def test_fleet_heal_cycle_end_to_end(fleet_setup):
    """Kill a hosting interior switch under live traffic: the retried answer
    is identical, the new path avoids the corpse, and every control counter
    reflects exactly one detect->replan->drain->reinstall cycle."""
    net, prof, progs, engine, oracle_packed, Xq = fleet_setup
    fleet = _mk_fleet(fleet_setup)
    oracle = SwitchEngine(prof, mode="ref")
    want = np.asarray(oracle.classify(
        oracle_packed, fleet.make_request(Xq, mid=0, vid=1)).rslt)
    victims = [d for d in fleet.path[2:-2]]

    async def main():
        # long probe interval: this test exercises the *data-path* detection
        # (DeviceFailure -> heal -> retry), not the heartbeat
        async with fleet.serving(probe_interval_s=30.0):
            before = await fleet.submit(Xq, mid=0, vid=1)
            fleet.kill(victims[0])
            during = await fleet.submit(Xq, mid=0, vid=1)
            after = await fleet.submit(Xq, mid=0, vid=1)
            return before, during, after, fleet.latency_stats()

    before, during, after, stats = run_async(main())
    for out in (before, during, after):
        np.testing.assert_array_equal(out.rslt, want)
    assert victims[0] not in fleet.path
    assert victims[0] in fleet.down                  # still dead, just routed
    ctl = stats["control"]
    assert ctl["failures_detected"] == 1
    assert ctl["replans"] == ctl["drains"] == ctl["reinstalls"] == 1
    assert ctl["retries"] >= 1
    assert ctl["heal_failures"] == 0
    assert ctl["last_heal_ms"] > 0
    assert len(ctl["downtime_windows"]) == 1
    t0, t1 = ctl["downtime_windows"][0]
    assert 0 <= t0 < t1
    assert ctl["total_downtime_s"] == pytest.approx(t1 - t0)
    # the session's windows feed the netsim availability model
    lat = fleet.modeled_latencies(n=200, arrival_rate_rps=1000.0)
    assert lat.shape == (200,) and (lat > 0).all()


def test_fleet_heartbeat_detects_without_traffic(fleet_setup):
    """The probe task alone (no submits after the kill) must run the heal
    cycle — failure detection is not submit-driven only."""
    fleet = _mk_fleet(fleet_setup)
    victim = fleet.path[2]

    async def main():
        async with fleet.serving(probe_interval_s=0.01):
            fleet.kill(victim)
            for _ in range(200):                     # ~2 s ceiling
                await asyncio.sleep(0.01)
                if fleet.counters.reinstalls:
                    break
            return fleet.latency_stats()

    stats = run_async(main())
    assert stats["control"]["reinstalls"] >= 1
    assert victim not in fleet.path


def test_fleet_concurrent_heals_collapse(fleet_setup):
    """Many submitters racing one failure: the heal lock collapses them into
    a single replan/reinstall."""
    net, prof, progs, engine, oracle_packed, Xq = fleet_setup
    fleet = _mk_fleet(fleet_setup)
    oracle = SwitchEngine(prof, mode="ref")
    want = np.asarray(oracle.classify(
        oracle_packed, fleet.make_request(Xq, mid=0, vid=0)).rslt)

    async def main():
        async with fleet.serving(probe_interval_s=30.0):
            fleet.kill(fleet.path[2])
            outs = await asyncio.gather(
                *[fleet.submit(Xq, mid=0, vid=0) for _ in range(6)])
            return outs, fleet.latency_stats()

    outs, stats = run_async(main())
    for out in outs:
        np.testing.assert_array_equal(out.rslt, want)
    assert stats["control"]["replans"] == 1
    assert stats["control"]["reinstalls"] == 1


def test_fleet_cut_vertex_death_is_honest(fleet_setup):
    """Killing the src host's only edge switch leaves no surviving path: the
    submit must surface RuntimeError (replan infeasible), not hang and not
    fabricate answers."""
    fleet = _mk_fleet(fleet_setup)
    _, _, _, _, _, Xq = fleet_setup
    edge = fleet.path[1]

    async def main():
        async with fleet.serving(probe_interval_s=30.0):
            fleet.kill(edge)
            with pytest.raises(RuntimeError, match="no surviving path"):
                await fleet.submit(Xq, mid=0, vid=0)
            return fleet.latency_stats()

    stats = run_async(main())
    assert stats["control"]["heal_failures"] >= 1
    assert stats["control"]["reinstalls"] == 0


def test_fleet_serving_session_is_exclusive(fleet_setup):
    """One live session at a time; the control handle exists only inside."""
    fleet = _mk_fleet(fleet_setup)
    assert fleet.control is None
    assert fleet.runtime is fleet.zoo.runtime

    async def main():
        async with fleet.serving(probe_interval_s=30.0):
            assert fleet.control is not None
            with pytest.raises(RuntimeError, match="already serving"):
                async with fleet.serving():
                    pass
    run_async(main())
    assert fleet.control is None


def test_fleet_not_serving_raises(fleet_setup):
    fleet = _mk_fleet(fleet_setup)
    _, _, _, _, _, Xq = fleet_setup
    with pytest.raises(RuntimeError, match="not serving"):
        run_async(fleet.submit(Xq, mid=0, vid=0))
    with pytest.raises(RuntimeError, match="not serving"):
        fleet.latency_stats()


# --------------------------------------------- heal vs shutdown ownership
def test_fleet_heal_interrupted_by_shutdown_is_counted(fleet_setup):
    """A heal cycle that loses its server to shutdown mid-replan must raise
    cleanly — never reinstall onto a flushed server — and count as an
    interrupted heal, not a success and not a masked pass."""
    import threading

    fleet = _mk_fleet(fleet_setup)
    gate = threading.Event()
    orig_replan = fleet.replan_sync

    def slow_replan():
        gate.wait(timeout=10.0)         # park the heal inside its solve
        return orig_replan()

    fleet.replan_sync = slow_replan

    async def main():
        async with fleet.serving(probe_interval_s=30.0):
            control = fleet.control
            fleet.kill(fleet.path[2])
            heal = asyncio.create_task(control.heal())
            await asyncio.sleep(0.05)   # heal is off-loop, held at the gate
        # session exited: the server stopped while the heal still ran
        gate.set()
        with pytest.raises(RuntimeError, match="drain unavailable"):
            await asyncio.wait_for(heal, timeout=15)
        return control.counters

    counters = run_async(asyncio.wait_for(main(), timeout=30))
    assert counters.interrupted_heals == 1
    assert counters.replans == 1        # the solve finished...
    assert counters.drains == 0         # ...but the barrier was refused
    assert counters.reinstalls == 0, \
        "a reinstall must never land on a stopped server"


def test_fleet_heal_broken_barrier_during_reinstall_is_counted(fleet_setup):
    """The other shutdown interleaving: drain succeeds, then stop() breaks
    the heal's barrier while the reinstall runs.  release() raising inside
    heal() must surface as an interrupted heal — drained and replanned, but
    never counted as a completed reinstall."""
    from repro.runtime import ControlLoop

    fleet = _mk_fleet(fleet_setup)
    fleet.kill(fleet.path[2])

    class _StoppedUnderneath:
        """DrainableServer whose owned hold was broken by stop() between
        drain and release — exactly AsyncZooServer's post-stop behavior."""

        async def drain(self):
            pass

        def release(self):
            raise RuntimeError(
                "hold was broken by stop(): the server flushed and shut "
                "down while the control plane still owned the drain barrier")

        def add_stats_source(self, name, fn):
            pass

    async def main():
        control = ControlLoop(fleet, _StoppedUnderneath(),
                              probe_interval_s=30.0)
        await control.start()
        try:
            with pytest.raises(RuntimeError,
                               match="broken by stop.*while the reinstall"):
                await control.heal()
        finally:
            await control.stop()
        return control.counters

    counters = run_async(asyncio.wait_for(main(), timeout=30))
    assert counters.interrupted_heals == 1
    assert counters.replans == 1 and counters.drains == 1
    assert counters.reinstalls == 0

"""Fused classify megakernel: parity sweeps, quantization round-trips,
launch/prep-op count pins.

The quantized operand layouts (int16 feature ids / range bounds, int8 leaf
labels, bit-packed masks) are pure *layout* choices — every narrow operand
is upcast in-kernel before arithmetic — so quantized and f32 layouts must
decode **bit-identical** classifications.  These tests pin that, the
3-launches -> 1 fusion, and the jaxpr counters' scan-multiplier convention
the pins rely on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tiling
from repro.kernels.classify_fused import classify_fused_pallas_v


def _rand_fused(rng, B, T, E, F, V, L, P, C, H, levels, empty_slots=()):
    """Random source tables for one whole-classify call — the same
    distributions as the per-stage sweeps in ``test_kernels.py`` (a random
    ``PackedProgram`` without the plane around it)."""
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, levels, (B, F)), jnp.int32)
    vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
    shape = (V, L, T, E)
    cv = jnp.asarray(rng.integers(0, 2**6, shape), jnp.uint32)
    cm = jnp.asarray(rng.integers(0, 2**6, shape), jnp.uint32)
    fid = jnp.asarray(rng.integers(0, F, shape), jnp.int32)
    flo = jnp.asarray(rng.integers(0, levels - 1, shape), jnp.int32)
    fhi = flo + jnp.asarray(rng.integers(0, levels // 2, shape), jnp.int32)
    bit = jnp.asarray(rng.integers(0, 2, shape), jnp.uint32)
    valid = np.asarray(rng.random(shape) < 0.9)
    shift = jnp.asarray(rng.permutation(L), jnp.int32)
    pc = np.sort(rng.choice(2**16, size=(V * T * P,), replace=False)
                 .astype(np.uint32).reshape(V, T, P), axis=2)
    plab = rng.integers(0, C, (V, T, P)).astype(np.int32)
    pv = np.asarray(rng.random((V, T, P)) < 0.9)
    w = rng.random((V, T)).astype(np.float32)
    lut = rng.integers(-60_000, 60_000, (V, H, F, levels)).astype(np.int32)
    bias = jnp.zeros((V, H), jnp.int32)
    for v in empty_slots:
        valid[v] = False
        pv[v] = False
        lut[v] = 0           # an evicted slot's LUT is blanked too
    return (codes, feats, vid, cv, cm, fid, flo, fhi, bit,
            jnp.asarray(valid), shift, jnp.asarray(pc), jnp.asarray(plab),
            jnp.asarray(pv), jnp.asarray(w), jnp.asarray(lut), bias)


def _assert_triple_equal(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# V sweep covers the acceptance range {1, 4, 8}; B=300 exercises the
# off-block_b tail, E=130 pads past one 128-lane tile.
@pytest.mark.parametrize("B,T,E,F,V,L,P,C,H,levels,empty", [
    (7, 1, 3, 4, 1, 1, 4, 2, 1, 16, ()),
    (64, 4, 17, 13, 4, 5, 32, 5, 3, 64, ()),
    (300, 2, 130, 20, 2, 3, 16, 3, 2, 32, ()),
    (257, 3, 33, 21, 8, 8, 64, 6, 4, 64, (1, 5)),
    (33, 5, 64, 40, 1, 32, 128, 8, 8, 128, ()),
])
def test_classify_fused_sweep(rng, B, T, E, F, V, L, P, C, H, levels, empty):
    """Megakernel (interpret) vs jnp oracle vs the pre-fusion three-launch
    fallback — all bit-identical, including evicted zoo slots."""
    args = _rand_fused(rng, B, T, E, F, V, L, P, C, H, levels,
                       empty_slots=empty)
    r = ops.classify_fused_v(*args, C, mode="ref")
    p = ops.classify_fused_v(*args, C, mode="interpret")
    u = ops.classify_fused_v(*args, C, mode="unfused-interpret")
    _assert_triple_equal(r, p)
    _assert_triple_equal(r, u)
    # packets addressing an evicted slot keep their incoming codes untouched
    codes, vid = args[0], args[2]
    for v in empty:
        sel = np.asarray(vid) == v
        np.testing.assert_array_equal(np.asarray(p[0])[sel],
                                      np.asarray(codes)[sel])


@pytest.mark.parametrize("V", [1, 4, 8])
def test_quantized_round_trip(rng, V):
    """Property: quantized prep layouts decode bit-identical classifications
    vs the f32 layouts, and both match the oracle."""
    B, T, E, F, L, P, C, H, levels = 90, 3, 20, 11, 4, 32, 5, 3, 64
    args = _rand_fused(rng, B, T, E, F, V, L, P, C, H, levels)
    q = classify_fused_pallas_v(*args, C, quantize=True, interpret=True)
    f = classify_fused_pallas_v(*args, C, quantize=False, interpret=True)
    r = ref.classify_fused_v(*args, C)
    _assert_triple_equal(q, f)
    _assert_triple_equal(q, r)
    # the layouts really are narrow: this is what the round-trip is *of*
    prep = tiling.prep_classify_fused(*args[3:10], *args[11:17],
                                      quantize=True)
    assert prep.fid.dtype == jnp.int16
    assert prep.flo.dtype == jnp.int16 and prep.fhi.dtype == jnp.int16
    assert prep.plab.dtype == jnp.int8
    assert prep.bitpk.dtype == jnp.uint32 and prep.validpk.dtype == jnp.uint32


def test_quantized_int16_boundary_features(rng):
    """Feature values at the int16 ceiling (2^15 - 1, the feature_width=15
    profile limit): the i16 feature stream must compare exactly like the i32
    one through the walk's range compare.  (The svm stage is compared
    kernel-width vs kernel-width: values >= levels select no LUT level by
    the one-hot construction in *both* widths.)"""
    B, T, E, F, V, L, P, C, H, levels = 40, 2, 8, 6, 2, 3, 16, 3, 2, 32
    args = list(_rand_fused(rng, B, T, E, F, V, L, P, C, H, levels))
    top = 2**15 - 1
    feats = np.asarray(rng.integers(0, levels, (B, F)), np.int32)
    feats[::3] = top                       # boundary packets
    args[1] = jnp.asarray(feats)
    flo = np.asarray(rng.integers(0, top, (V, L, T, E)), np.int32)
    flo[..., ::2] = top                    # boundary entry rows
    fhi = np.minimum(flo + np.asarray(
        rng.integers(0, 100, (V, L, T, E)), np.int32), top)
    args[6], args[7] = jnp.asarray(flo), jnp.asarray(fhi)
    q = classify_fused_pallas_v(*args, C, quantize=True, interpret=True)
    f = classify_fused_pallas_v(*args, C, quantize=False, interpret=True)
    _assert_triple_equal(q, f)
    # the walk itself (boundary compares included) still matches the oracle
    np.testing.assert_array_equal(
        np.asarray(q[0]), np.asarray(ref.tree_walk_v(*args[:11])))


def test_all_masked_tcam_rows(rng):
    """Entry rows carrying the no-match padding convention (mask all bits
    against value 0) and fully-wildcarded rows (mask 0) survive bit-packing
    and quantization: parity with the oracle on both extremes."""
    B, T, E, F, V, L, P, C, H, levels = 50, 2, 8, 6, 2, 3, 16, 3, 2, 32
    args = list(_rand_fused(rng, B, T, E, F, V, L, P, C, H, levels))
    cv = np.zeros((V, L, T, E), np.uint32)
    cm = np.full((V, L, T, E), 0xFFFFFFFF, np.uint32)   # match nothing
    cm[..., ::2] = 0                                    # match everything
    args[3], args[4] = jnp.asarray(cv), jnp.asarray(cm)
    r = ops.classify_fused_v(*args, C, mode="ref")
    p = ops.classify_fused_v(*args, C, mode="interpret")
    _assert_triple_equal(r, p)


def test_empty_zoo_slot_round_trip(rng):
    """A fully-evicted slot (all-invalid entries and leaves) yields the
    no-model outputs in every width: codes pass through, label 0, sums 0."""
    B, T, E, F, V, L, P, C, H, levels = 30, 2, 8, 6, 3, 3, 16, 3, 2, 32
    args = _rand_fused(rng, B, T, E, F, V, L, P, C, H, levels,
                       empty_slots=(1,))
    q = classify_fused_pallas_v(*args, C, quantize=True, interpret=True)
    f = classify_fused_pallas_v(*args, C, quantize=False, interpret=True)
    r = ref.classify_fused_v(*args, C)
    _assert_triple_equal(q, f)
    _assert_triple_equal(q, r)
    codes, vid = args[0], args[2]
    sel = np.asarray(vid) == 1
    assert sel.any()
    np.testing.assert_array_equal(np.asarray(q[0])[sel],
                                  np.asarray(codes)[sel])
    assert (np.asarray(q[1])[sel] == 0).all()
    assert (np.asarray(q[2])[sel] == 0).all()


def test_fused_single_launch_and_fallback_counts(rng):
    """The acceptance pin: one classify = one ``pallas_call``.  The unfused
    fallback restores the pre-fusion 3 launches; layerwise restores L + 2."""
    B, T, E, F, V, L, P, C, H, levels = 16, 2, 8, 6, 2, 5, 16, 3, 2, 32
    args = _rand_fused(rng, B, T, E, F, V, L, P, C, H, levels)
    fused = ops.count_pallas_launches(
        lambda *a: ops.classify_fused_v(*a, C, mode="interpret"), *args)
    unfused = ops.count_pallas_launches(
        lambda *a: ops.classify_fused_v(*a, C, mode="unfused-interpret"),
        *args)
    layerwise = ops.count_pallas_launches(
        lambda *a: ops.classify_fused_v(*a, C, mode="layerwise-interpret"),
        *args)
    assert fused == 1
    assert unfused == 3
    assert layerwise == L + 2


def test_fused_prep_ops_zero_with_bound_image(rng):
    """With the install-time operand layout bound via ``prep=``, the fused
    classify traces to ZERO table-shaped (>= 3-D) prep equations — every
    operand flows from the jaxpr inputs straight into the launch."""
    B, T, E, F, V, L, P, C, H, levels = 16, 2, 8, 6, 2, 3, 16, 3, 2, 32
    args = _rand_fused(rng, B, T, E, F, V, L, P, C, H, levels)
    prep = tiling.prep_classify_fused(*args[3:10], *args[11:17],
                                      quantize=True)
    bound = ops.count_operand_prep_ops(
        lambda *a: classify_fused_pallas_v(*a, C, prep=prep, interpret=True),
        *args)
    unbound = ops.count_operand_prep_ops(
        lambda *a: classify_fused_pallas_v(*a, C, interpret=True), *args)
    assert bound == 0
    assert unbound > 0


def test_counters_multiply_through_scan_consistently(rng):
    """Both jaxpr counters share one traversal and the same convention: an
    equation (or launch) inside a ``lax.scan`` body counts once per
    iteration, through nested ``pjit`` too.  Pinned here because the fused
    launch/prep pins above are meaningless if the counters disagree."""
    x = jnp.asarray(rng.random((4, 4)), jnp.float32)

    def body(c, _):
        t = c[None, :, :] * jnp.ones((3, 4, 4), jnp.float32)   # 3-D prep op
        return c + t.sum(axis=0), None

    def once(c):
        return body(c, None)[0]

    def scanned(c):
        out, _ = jax.lax.scan(body, c, None, length=5)
        return out

    single = ops.count_operand_prep_ops(once, x)
    assert single > 0
    assert ops.count_operand_prep_ops(scanned, x) == 5 * single
    # nested pjit neither loses nor double-counts
    assert ops.count_operand_prep_ops(jax.jit(scanned), x) == 5 * single
    assert ops.count_operand_prep_ops(
        jax.jit(lambda c: scanned(c) + scanned(c)), x) == 10 * single


def test_bitpack_round_trip(rng):
    """``tiling.bitpack_last`` packs {0,1} tables 32/word little-endian; the
    kernel-side unpack is its exact inverse."""
    from repro.kernels.classify_fused import _unpack_bits
    bits = jnp.asarray(rng.integers(0, 2, (3, 5, 64)), jnp.uint32)
    packed = tiling.bitpack_last(bits)
    assert packed.shape == (3, 5, 2) and packed.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(_unpack_bits(packed, 2, 64)), np.asarray(bits))
    with pytest.raises(ValueError):
        tiling.bitpack_last(jnp.zeros((4, 33), jnp.uint32))

"""Pallas kernel sweeps: interpret-mode kernel bodies vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand_tcam(rng, B, T, E, F):
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    cv = jnp.asarray(rng.integers(0, 2**6, (T, E)), jnp.uint32)
    cm = jnp.asarray(rng.integers(0, 2**6, (T, E)), jnp.uint32)
    fid = jnp.asarray(rng.integers(0, F, (T, E)), jnp.int32)
    flo = jnp.asarray(rng.integers(0, 200, (T, E)), jnp.int32)
    fhi = flo + jnp.asarray(rng.integers(0, 100, (T, E)), jnp.int32)
    bit = jnp.asarray(rng.integers(0, 2, (T, E)), jnp.uint32)
    valid = jnp.asarray(rng.random((T, E)) < 0.9)
    return codes, feats, cv, cm, fid, flo, fhi, bit, valid


@pytest.mark.parametrize("B,T,E,F", [(7, 1, 3, 4), (64, 4, 17, 13),
                                     (257, 8, 64, 60), (33, 2, 128, 46)])
def test_tcam_match_sweep(rng, B, T, E, F):
    args = _rand_tcam(rng, B, T, E, F)
    shift = jnp.int32(rng.integers(0, 20))
    r = ref.tcam_match(*args, shift)
    p = ops.tcam_match(*args, shift, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


@pytest.mark.parametrize("B,H,F,L", [(5, 1, 3, 16), (64, 3, 14, 64),
                                     (130, 8, 46, 256), (16, 12, 8, 256)])
def test_svm_lookup_sweep(rng, B, H, F, L):
    feats = jnp.asarray(rng.integers(0, L, (B, F)), jnp.int32)
    lut = jnp.asarray(rng.integers(-60_000, 60_000, (H, F, L)), jnp.int32)
    bias = jnp.asarray(rng.integers(-10_000, 10_000, (H,)), jnp.int32)
    r = ref.svm_lookup(feats, lut, bias)
    p = ops.svm_lookup(feats, lut, bias, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


@pytest.mark.parametrize("B,T,P,C", [(9, 1, 4, 2), (70, 4, 32, 5),
                                     (300, 8, 256, 25)])
def test_forest_vote_sweep(rng, B, T, P, C):
    pc = np.sort(
        rng.choice(2**16, size=(T, P), replace=False).astype(np.uint32), axis=1)
    plab = rng.integers(0, C, (T, P)).astype(np.int32)
    pv = np.ones((T, P), bool)
    pv[:, -1] = False
    hit = rng.integers(0, P - 1, (B, T))
    codes = pc[np.arange(T)[None, :], hit]
    # some misses
    codes[: B // 4] = 0xFFFFFFFE
    w = rng.random(T).astype(np.float32)
    args = (jnp.asarray(codes), jnp.asarray(pc), jnp.asarray(plab),
            jnp.asarray(pv), jnp.asarray(w))
    r = ref.forest_predict_vote(*args, C)
    p = ops.forest_predict_vote(*args, C, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))
    np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(p[1]))


def _rand_tcam_v(rng, B, T, E, F, V, L=None, empty_slots=()):
    """Random version-indexed tables ([V, T, E] or, with L, [V, L, T, E]);
    ``empty_slots`` version indices get all-invalid entries (evicted zoo
    slots)."""
    shape = (V, T, E) if L is None else (V, L, T, E)
    cv = jnp.asarray(rng.integers(0, 2**6, shape), jnp.uint32)
    cm = jnp.asarray(rng.integers(0, 2**6, shape), jnp.uint32)
    fid = jnp.asarray(rng.integers(0, F, shape), jnp.int32)
    flo = jnp.asarray(rng.integers(0, 200, shape), jnp.int32)
    fhi = flo + jnp.asarray(rng.integers(0, 100, shape), jnp.int32)
    bit = jnp.asarray(rng.integers(0, 2, shape), jnp.uint32)
    valid = np.asarray(rng.random(shape) < 0.9)
    for v in empty_slots:
        valid[v] = False
    return cv, cm, fid, flo, fhi, bit, jnp.asarray(valid)


# Edge shapes: B=300/257 not a multiple of block_b=256, E=130/150 pads past
# 128 (E_pad=256), and a zoo where some version slots are empty (evicted).
@pytest.mark.parametrize("B,T,E,F,V,L,empty", [
    (7, 1, 3, 4, 1, 1, ()),
    (64, 4, 17, 13, 3, 5, ()),
    (300, 2, 130, 20, 2, 3, ()),       # B % block_b != 0, E pads past 128
    (257, 3, 33, 46, 4, 8, (1, 3)),    # empty version slots in the zoo
    (33, 5, 64, 60, 1, 32, ()),        # full-depth walk
])
def test_tree_walk_sweep(rng, B, T, E, F, V, L, empty):
    """Fused walk kernel (interpret) vs fused oracle vs layerwise scan."""
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
    tables = _rand_tcam_v(rng, B, T, E, F, V, L=L, empty_slots=empty)
    shift = jnp.asarray(rng.permutation(L), jnp.int32)
    args = (codes, feats, vid, *tables, shift)
    r = ref.tree_walk_v(*args)
    p = ops.tree_walk_v(*args, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
    lw = ops.tree_walk_v(*args, mode="layerwise-ref")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(lw))
    # packets addressing an empty slot keep their incoming codes untouched
    for v in empty:
        sel = np.asarray(vid) == v
        np.testing.assert_array_equal(np.asarray(p)[sel], np.asarray(codes)[sel])


def test_tree_walk_single_launch(rng):
    """The fused path issues exactly ONE tree-walk pallas_call per classify;
    the layerwise fallback issues L (one per scanned layer)."""
    B, T, E, F, V, L = 16, 2, 8, 6, 2, 7
    codes = jnp.asarray(rng.integers(0, 2**8, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
    tables = _rand_tcam_v(rng, B, T, E, F, V, L=L)
    shift = jnp.arange(L, dtype=jnp.int32)
    args = (codes, feats, vid, *tables, shift)
    fused = ops.count_pallas_launches(
        lambda *a: ops.tree_walk_v(*a, mode="interpret"), *args)
    layerwise = ops.count_pallas_launches(
        lambda *a: ops.tree_walk_v(*a, mode="layerwise-interpret"), *args)
    assert fused == 1
    assert layerwise == L


@pytest.mark.parametrize("B,T,E,F,V", [(300, 2, 130, 20, 2),   # pads past 128
                                       (257, 3, 150, 13, 3)])  # B off-block
def test_tcam_match_v_edge_shapes(rng, B, T, E, F, V):
    """Per-layer kernel parity on the same edge shapes (entry counts padding
    past one 128-lane tile, batches off the block_b grid, empty slot v=0)."""
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
    tables = _rand_tcam_v(rng, B, T, E, F, V, empty_slots=(0,))
    shift = jnp.int32(rng.integers(0, 20))
    args = (codes, feats, vid, *tables, shift)
    r = ref.tcam_match_v(*args)
    p = ops.tcam_match_v(*args, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


def test_forest_vote_v_empty_slot(rng):
    """A zoo with an evicted leaf-table slot: its packets vote label 0 with
    no valid leaves, identically in interpret and ref modes."""
    B, T, P, C, V = 70, 3, 32, 5, 3
    pc = np.sort(rng.choice(2**16, size=(V * T * P,), replace=False)
                 .astype(np.uint32).reshape(V, T, P), axis=2)
    plab = rng.integers(0, C, (V, T, P)).astype(np.int32)
    pv = np.ones((V, T, P), bool)
    pv[1] = False  # evicted slot
    vid = rng.integers(0, V, (B,))
    hit = rng.integers(0, P, (B, T))
    codes = pc[vid[:, None], np.arange(T)[None, :], hit]
    w = rng.random((V, T)).astype(np.float32)
    args = (jnp.asarray(codes), jnp.asarray(vid, jnp.int32), jnp.asarray(pc),
            jnp.asarray(plab), jnp.asarray(pv), jnp.asarray(w))
    r = ref.forest_predict_vote_v(*args, C)
    p = ops.forest_predict_vote_v(*args, C, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))
    np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(p[1]))
    assert (np.asarray(r[1])[np.asarray(vid) == 1] == 0).all()


@pytest.mark.parametrize("B,Hq,Hkv,D,S,dtype", [
    (2, 4, 4, 16, 33, jnp.float32),
    (3, 8, 2, 32, 128, jnp.float32),
    (1, 16, 8, 64, 700, jnp.bfloat16),
])
def test_decode_attn_sweep(rng, B, Hq, Hkv, D, S, dtype):
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    kvl = jnp.asarray(rng.integers(1, S + 1, (B,)), jnp.int32)
    r = np.asarray(ref.decode_attn(q, k, v, kvl), np.float32)
    p = np.asarray(ops.decode_attn(q, k, v, kvl, mode="interpret"), np.float32)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(r, p, atol=atol, rtol=1e-2)


def test_decode_attn_matches_full_softmax(rng):
    """ref oracle itself vs a trivially-correct dense softmax."""
    B, Hq, Hkv, D, S = 2, 6, 3, 8, 40
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    kvl = jnp.full((B,), S, jnp.int32)
    out = np.asarray(ref.decode_attn(q, k, v, kvl))
    G = Hq // Hkv
    for b in range(B):
        for h in range(Hq):
            kv_h = h // G
            logit = (np.asarray(q[b, h]) @ np.asarray(k[b, :, kv_h]).T) * D**-0.5
            pr = np.exp(logit - logit.max())
            pr /= pr.sum()
            want = pr @ np.asarray(v[b, :, kv_h])
            np.testing.assert_allclose(out[b, h], want, atol=1e-5)

"""Pallas kernel sweeps: interpret-mode kernel bodies vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand_tcam(rng, B, T, E, F):
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    cv = jnp.asarray(rng.integers(0, 2**6, (T, E)), jnp.uint32)
    cm = jnp.asarray(rng.integers(0, 2**6, (T, E)), jnp.uint32)
    fid = jnp.asarray(rng.integers(0, F, (T, E)), jnp.int32)
    flo = jnp.asarray(rng.integers(0, 200, (T, E)), jnp.int32)
    fhi = flo + jnp.asarray(rng.integers(0, 100, (T, E)), jnp.int32)
    bit = jnp.asarray(rng.integers(0, 2, (T, E)), jnp.uint32)
    valid = jnp.asarray(rng.random((T, E)) < 0.9)
    return codes, feats, cv, cm, fid, flo, fhi, bit, valid


@pytest.mark.parametrize("B,T,E,F", [(7, 1, 3, 4), (64, 4, 17, 13),
                                     (257, 8, 64, 60), (33, 2, 128, 46)])
def test_tcam_match_sweep(rng, B, T, E, F):
    args = _rand_tcam(rng, B, T, E, F)
    shift = jnp.int32(rng.integers(0, 20))
    r = ref.tcam_match(*args, shift)
    p = ops.tcam_match(*args, shift, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


@pytest.mark.parametrize("B,H,F,L", [(5, 1, 3, 16), (64, 3, 14, 64),
                                     (130, 8, 46, 256), (16, 12, 8, 256)])
def test_svm_lookup_sweep(rng, B, H, F, L):
    feats = jnp.asarray(rng.integers(0, L, (B, F)), jnp.int32)
    lut = jnp.asarray(rng.integers(-60_000, 60_000, (H, F, L)), jnp.int32)
    bias = jnp.asarray(rng.integers(-10_000, 10_000, (H,)), jnp.int32)
    r = ref.svm_lookup(feats, lut, bias)
    p = ops.svm_lookup(feats, lut, bias, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


@pytest.mark.parametrize("B,T,P,C", [(9, 1, 4, 2), (70, 4, 32, 5),
                                     (300, 8, 256, 25)])
def test_forest_vote_sweep(rng, B, T, P, C):
    pc = np.sort(
        rng.choice(2**16, size=(T, P), replace=False).astype(np.uint32), axis=1)
    plab = rng.integers(0, C, (T, P)).astype(np.int32)
    pv = np.ones((T, P), bool)
    pv[:, -1] = False
    hit = rng.integers(0, P - 1, (B, T))
    codes = pc[np.arange(T)[None, :], hit]
    # some misses
    codes[: B // 4] = 0xFFFFFFFE
    w = rng.random(T).astype(np.float32)
    args = (jnp.asarray(codes), jnp.asarray(pc), jnp.asarray(plab),
            jnp.asarray(pv), jnp.asarray(w))
    r = ref.forest_predict_vote(*args, C)
    p = ops.forest_predict_vote(*args, C, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))
    np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(p[1]))


@pytest.mark.parametrize("B,Hq,Hkv,D,S,dtype", [
    (2, 4, 4, 16, 33, jnp.float32),
    (3, 8, 2, 32, 128, jnp.float32),
    (1, 16, 8, 64, 700, jnp.bfloat16),
])
def test_decode_attn_sweep(rng, B, Hq, Hkv, D, S, dtype):
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    kvl = jnp.asarray(rng.integers(1, S + 1, (B,)), jnp.int32)
    r = np.asarray(ref.decode_attn(q, k, v, kvl), np.float32)
    p = np.asarray(ops.decode_attn(q, k, v, kvl, mode="interpret"), np.float32)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(r, p, atol=atol, rtol=1e-2)


def test_decode_attn_matches_full_softmax(rng):
    """ref oracle itself vs a trivially-correct dense softmax."""
    B, Hq, Hkv, D, S = 2, 6, 3, 8, 40
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    kvl = jnp.full((B,), S, jnp.int32)
    out = np.asarray(ref.decode_attn(q, k, v, kvl))
    G = Hq // Hkv
    for b in range(B):
        for h in range(Hq):
            kv_h = h // G
            logit = (np.asarray(q[b, h]) @ np.asarray(k[b, :, kv_h]).T) * D**-0.5
            pr = np.exp(logit - logit.max())
            pr /= pr.sum()
            want = pr @ np.asarray(v[b, :, kv_h])
            np.testing.assert_allclose(out[b, h], want, atol=1e-5)

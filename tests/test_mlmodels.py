"""ML substrate: CART/forest/SVM/quantizer/metrics unit + property tests.

Property-style cases are driven by seeded-numpy parametrization (no
hypothesis dependency in this container — equivalent coverage, reproducible
by seed).
"""
import numpy as np
import pytest

from repro.core.mlmodels import (
    DecisionTree,
    LinearSVM,
    Quantizer,
    RandomForest,
    accuracy,
    cohen_kappa,
    macro_f1,
    rfe_select,
)
from repro.data import make_classification


def test_quantizer_bounds_and_monotonic(rng):
    X = rng.normal(size=(200, 5)) * rng.uniform(0.1, 50, 5)
    q = Quantizer(8).fit(X)
    Xq = q.transform(X)
    assert Xq.min() >= 0 and Xq.max() <= 255
    # monotonic per column
    col = np.sort(X[:, 2])
    qc = q.transform(np.tile(X[0], (200, 1)).copy() * 0 + col[:, None])[:, 2]
    assert (np.diff(qc) >= 0).all()


@pytest.mark.parametrize(
    "seed", np.random.default_rng(42).integers(0, 1000, 20).tolist()
)
def test_tree_perfectly_fits_small_data(seed):
    rng = np.random.default_rng(seed)
    Xq = rng.integers(0, 256, (40, 4))
    # ensure no duplicate rows with conflicting labels
    Xq = np.unique(Xq, axis=0)
    y = rng.integers(0, 3, Xq.shape[0])
    dt = DecisionTree(max_depth=32, levels=256).fit(Xq, y)
    assert accuracy(y, dt.predict(Xq)) == 1.0


def test_tree_depth_and_leaf_bounds(satdap):
    Xtr, ytr, _, _ = satdap
    dt = DecisionTree(max_depth=4, max_leaf_nodes=9).fit(Xtr, ytr)
    assert dt.tree_.max_depth <= 4
    assert dt.tree_.n_leaves <= 9


def test_tree_path_codes_unique_per_leaf(satdap):
    Xtr, ytr, _, _ = satdap
    dt = DecisionTree(max_depth=10, max_leaf_nodes=64).fit(Xtr, ytr)
    t = dt.tree_
    leaves = t.leaves()
    codes = t.path[leaves]
    assert np.unique(codes).size == leaves.size  # prefix-free => zero-pad unique


def test_forest_beats_or_matches_single_tree(satdap):
    Xtr, ytr, Xte, yte = satdap
    dt = DecisionTree(max_depth=5, max_leaf_nodes=30).fit(Xtr, ytr)
    rf = RandomForest(n_estimators=7, max_depth=5, max_leaf_nodes=30,
                      random_state=3).fit(Xtr, ytr)
    assert accuracy(yte, rf.predict(Xte)) >= accuracy(yte, dt.predict(Xte)) - 0.05


def test_svm_ovo_and_ovr(iris):
    Xtr, ytr, Xte, yte = iris
    for mc in ("ovo", "ovr"):
        svm = LinearSVM(multi_class=mc, epochs=400).fit(Xtr, ytr)
        assert accuracy(yte, svm.predict(Xte)) > 0.8, mc


def test_metrics_agree_with_known_values():
    y = np.array([0, 0, 1, 1, 2, 2])
    p = np.array([0, 0, 1, 0, 2, 1])
    assert abs(accuracy(y, p) - 4 / 6) < 1e-9
    assert cohen_kappa(y, y) == 1.0
    assert 0.0 < cohen_kappa(y, p) < 1.0
    assert 0.0 < macro_f1(y, p) < 1.0


def test_rfe_selects_informative(rng):
    X, y = make_classification(600, 20, 2, n_informative=4, n_redundant=0,
                               seed=7)
    q = Quantizer(8).fit(X)
    Xq = q.transform(X)

    def imp(Xs, ys):
        dt = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(
            np.asarray(Xs, np.int64), ys)
        return dt.feature_importances_()

    keep = rfe_select(Xq, y, 8, imp)
    assert keep.size == 8
    dt_full = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xq, y)
    dt_sel = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xq[:, keep], y)
    # selected features retain most of the signal
    assert accuracy(y, dt_sel.predict(Xq[:, keep])) > 0.8 * accuracy(
        y, dt_full.predict(Xq))


def test_determinism(satdap):
    Xtr, ytr, Xte, _ = satdap
    a = RandomForest(n_estimators=3, max_depth=4, random_state=5).fit(Xtr, ytr)
    b = RandomForest(n_estimators=3, max_depth=4, random_state=5).fit(Xtr, ytr)
    assert (a.predict(Xte) == b.predict(Xte)).all()

"""Per-arch smoke tests + decode/forward consistency (the KV-cache oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config, smoke_config
from repro.models import decode_step, forward, init_decode_state, init_params
from repro.models.transformer import encode_kv

pytestmark = pytest.mark.slow  # per-arch LM-stack sweeps dominate suite time


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step on CPU; shapes + no NaNs."""
    cfg = smoke_config(arch)
    p = init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    enc = (jax.random.normal(jax.random.key(2), (B, cfg.enc_seq, cfg.d_model),
                             cfg.jdtype) if cfg.family == "encdec" else None)
    logits = forward(p, toks, cfg, enc_inputs=enc)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one real train step
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import make_train_step
    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(p, ocfg)
    step = make_train_step(cfg, ocfg, n_micro=2, has_enc=cfg.family == "encdec")
    batch = {
        "tokens": jnp.tile(toks[None], (2, 1, 1)),
        "labels": jnp.tile(toks[None], (2, 1, 1)),
    }
    if enc is not None:
        batch["enc_inputs"] = jnp.tile(enc[None], (2, 1, 1, 1))
    p2, opt2, metrics = jax.jit(step)(p, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "grok-1-314b",
                                  "recurrentgemma-2b", "rwkv6-7b",
                                  "whisper-tiny"])
def test_decode_matches_forward(arch):
    """Teacher forcing: step-by-step decode logits == full forward logits.
    This is the cache/recurrence correctness oracle for every family."""
    cfg = smoke_config(arch)
    if cfg.family == "moe":
        # capacity dropping depends on batch composition (prefill routes S*B
        # tokens, decode routes B) — inherent to capacity MoE; disable drops
        # so the cache/recurrence equivalence is exact.
        cfg = cfg.scaled(capacity_factor=float(cfg.n_experts))
    p = init_params(cfg, jax.random.key(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    enc = (jax.random.normal(jax.random.key(2), (B, cfg.enc_seq, cfg.d_model),
                             cfg.jdtype) if cfg.family == "encdec" else None)
    full = forward(p, toks, cfg, enc_inputs=enc, remat=False)

    state = init_decode_state(cfg, B, S)
    if cfg.family == "encdec":
        state["ek"], state["ev"] = encode_kv(p, enc, cfg)
    outs = []
    for t in range(S):
        lg, state = decode_step(p, state, toks[:, t:t + 1], jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        atol=0.12, rtol=0.05)


def test_local_attention_window_masks():
    cfg = smoke_config("recurrentgemma-2b")
    from repro.models.attention import gqa_attention
    B, S, H, D = 1, 12, 2, 8
    q = jax.random.normal(jax.random.key(0), (B, S, H, D))
    k = jax.random.normal(jax.random.key(1), (B, S, H, D))
    v = jax.random.normal(jax.random.key(2), (B, S, H, D))
    full = gqa_attention(q, k, v, causal=True, window=0)
    win = gqa_attention(q, k, v, causal=True, window=4)
    # early positions identical (window not binding), late differ
    np.testing.assert_allclose(np.asarray(full[:, :3]), np.asarray(win[:, :3]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(full[:, -1]), np.asarray(win[:, -1]))


def test_q_chunked_attention_equals_single_shot():
    from repro.models.attention import gqa_attention
    B, S, Hq, Hkv, D = 2, 32, 4, 2, 8
    q = jax.random.normal(jax.random.key(0), (B, S, Hq, D))
    k = jax.random.normal(jax.random.key(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.key(2), (B, S, Hkv, D))
    a = gqa_attention(q, k, v, causal=True)
    b = gqa_attention(q, k, v, causal=True, q_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_rwkv_chunked_equals_stepwise():
    """Chunkwise-parallel wkv == sequential recurrence (decode path)."""
    from repro.models import rwkv as R
    cfg = smoke_config("rwkv6-7b")
    p = init_params(cfg, jax.random.key(0))
    lp = jax.tree.map(lambda x: x[0], p["layers"])  # layer 0 params
    B, S, D = 1, 19, cfg.d_model
    H = cfg.n_heads
    x = jax.random.normal(jax.random.key(3), (B, S, D), cfg.jdtype) * 0.5
    y_chunk, st = R.time_mix(x, lp, None, n_heads=H, chunk=8)
    st2 = {"S": jnp.zeros((B, H, D // H, D // H), jnp.float32),
           "last": jnp.zeros((B, D), jnp.float32)}
    outs = []
    for t in range(S):
        y, st2 = R.time_mix_step(x[:, t:t + 1], lp, st2, n_heads=H)
        outs.append(y[:, 0])
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_step, np.float32),
                               atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(st["S"]), np.asarray(st2["S"]),
                               atol=5e-2, rtol=5e-2)


def test_param_counts_close_to_published():
    """6*N*D roofline inputs: param_count within 20% of the advertised size."""
    published = {
        "internlm2-1.8b": 1.8e9, "internlm2-20b": 20e9, "starcoder2-15b": 15e9,
        "granite-20b": 20e9, "grok-1-314b": 314e9, "rwkv6-7b": 7e9,
        "chameleon-34b": 34e9, "qwen3-moe-235b-a22b": 235e9,
        "recurrentgemma-2b": 2.7e9,
    }
    for arch, want in published.items():
        n = get_config(arch).param_count()
        # starcoder2 upstream uses a 2-matrix MLP; this framework uses SwiGLU
        # (3 matrices) uniformly, so its count runs ~1.47x the advertised 15B.
        hi = 1.55 if arch == "starcoder2-15b" else 1.45
        assert 0.7 * want < n < hi * want, f"{arch}: {n:.3g} vs {want:.3g}"


def test_shape_applicability():
    n_cells = 0
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = applicable(cfg, s)
            if s == "long_500k":
                assert ok == (a in ("recurrentgemma-2b", "rwkv6-7b")), (a, why)
            else:
                assert ok
            n_cells += 1
    assert n_cells == 40

"""Perf-lever equivalence: the §Perf optimizations must be semantics-free."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import gqa_attention
from repro.models.moe import moe_ffn


@pytest.mark.parametrize("cf", [1.0, 1.25, 2.0])
def test_moe_sort_equals_onehot(cf):
    key = jax.random.key(0)
    B, S, D, E, F, k = 2, 16, 32, 8, 64, 2
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    params = {
        "router": jax.random.normal(jax.random.key(1), (D, E)),
        "wg": jax.random.normal(jax.random.key(2), (E, D, F)) * 0.1,
        "wu": jax.random.normal(jax.random.key(3), (E, D, F)) * 0.1,
        "wd": jax.random.normal(jax.random.key(4), (E, F, D)) * 0.1,
    }
    y1, a1 = moe_ffn(x, params, top_k=k, capacity_factor=cf, impl="onehot")
    y2, a2 = moe_ffn(x, params, top_k=k, capacity_factor=cf, impl="sort")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_sort_gradients_match():
    key = jax.random.key(0)
    B, S, D, E, F, k = 1, 8, 16, 4, 32, 2
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    params = {
        "router": jax.random.normal(jax.random.key(1), (D, E)),
        "wg": jax.random.normal(jax.random.key(2), (E, D, F)) * 0.1,
        "wu": jax.random.normal(jax.random.key(3), (E, D, F)) * 0.1,
        "wd": jax.random.normal(jax.random.key(4), (E, F, D)) * 0.1,
    }

    def loss(impl):
        return lambda p: moe_ffn(x, p, top_k=k, capacity_factor=1.5,
                                 impl=impl)[0].sum()

    g1 = jax.grad(loss("onehot"))(params)
    g2 = jax.grad(loss("sort"))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)


@pytest.mark.parametrize("window,q_chunk", [(0, 0), (0, 8), (6, 0), (6, 8)])
def test_online_attention_equals_dense(window, q_chunk):
    q = jax.random.normal(jax.random.key(5), (2, 32, 4, 16))
    k = jax.random.normal(jax.random.key(6), (2, 32, 2, 16))
    v = jax.random.normal(jax.random.key(7), (2, 32, 2, 16))
    a = gqa_attention(q, k, v, causal=True, window=window, q_chunk=q_chunk)
    b = gqa_attention(q, k, v, causal=True, window=window, q_chunk=q_chunk,
                      k_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_online_attention_gradients_match():
    q = jax.random.normal(jax.random.key(5), (1, 16, 2, 8))
    k = jax.random.normal(jax.random.key(6), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.key(7), (1, 16, 2, 8))
    g1 = jax.grad(lambda q: gqa_attention(q, k, v, causal=True).sum())(q)
    g2 = jax.grad(lambda q: gqa_attention(q, k, v, causal=True,
                                          k_chunk=4).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)

"""Latency/overhead model sanity (paper §7.4/§7.6 semantics)."""
import numpy as np

from repro.core import packets
from repro.core.mlmodels import DecisionTree
from repro.core.netsim import (
    ServerModel,
    acorn_serving_time,
    forwarding_overhead,
    measure_inference_time,
    server_serving_time,
    serving_availability,
    simulate_serving,
)
from repro.core.planner import plan_program
from repro.core.topology import fat_tree
from repro.core.translator import translate


def test_acorn_faster_than_server(satdap):
    Xtr, ytr, Xte, _ = satdap
    dt = DecisionTree(max_depth=8, max_leaf_nodes=80).fit(Xtr, ytr)
    prog = translate(dt)
    net = fat_tree(4)
    h = net.hosts()
    plan = plan_program(prog, net, h[0], h[-1], solver="dp")
    t_acorn = acorn_serving_time(plan)
    t_pred = measure_inference_time(dt, Xte, n_requests=50)
    t_server = server_serving_time(
        t_pred, packets.request_bytes(prog.n_features, n_trees=1))
    # paper: 65-90% faster
    assert t_acorn < t_server
    assert t_acorn < 0.3e-3  # "requests served within 0.12 ms" ballpark


def test_request_response_size_asymmetry():
    rq = packets.request_bytes(46, n_trees=5)
    rs = packets.response_bytes()
    assert rq > rs  # stripping payload shrinks the response


def test_simulation_is_stable():
    s = simulate_serving(1e-4, n=500, seed=1)
    assert abs(np.median(s) - 1e-4) / 1e-4 < 0.05
    assert (s > 0).all()


def test_forwarding_overhead_bounds():
    r = forwarding_overhead()
    assert 0 < r["latency_overhead_frac"] <= 0.033  # paper: 2.7-3.3%
    assert 0.9 < r["goodput_frac"] < 1.0


# ----------------------------------------- fault-window downtime (ISSUE 8)
def test_simulate_serving_static_path_unchanged():
    """No windows, no arrival rate: bit-identical to the pre-fault model —
    the regression guard for existing callers (benchmarks/fig67_latency.py)."""
    a = simulate_serving(1e-4, n=500, seed=1)
    b = simulate_serving(1e-4, n=500, seed=1, downtime_windows=(),
                         arrival_rate_rps=None)
    np.testing.assert_array_equal(a, b)


def test_simulate_serving_fault_window_holds_requests():
    """A replan/drain window holds the requests that arrive inside it until
    the window closes; everyone else is untouched."""
    base, rate = 1e-4, 2000.0
    window = (0.05, 0.15)
    s, t = simulate_serving(base, n=800, seed=7, arrival_rate_rps=rate,
                            downtime_windows=(window,), return_arrivals=True)
    s0 = simulate_serving(base, n=800, seed=7, arrival_rate_rps=rate)
    inside = (t >= window[0]) & (t < window[1])
    assert inside.any() and (~inside).any()
    # held requests pay exactly the remainder of the window on top
    np.testing.assert_allclose(s[inside], s0[inside] + (window[1] - t[inside]))
    np.testing.assert_array_equal(s[~inside], s0[~inside])
    # worst-case held latency approaches the full window length
    assert s[inside].max() > 0.5 * (window[1] - window[0])


def test_serving_availability_reflects_downtime():
    """Availability (fraction within SLO) degrades when a fault window is
    injected and recovers without one."""
    base, rate, slo = 1e-4, 2000.0, 1e-3
    up = simulate_serving(base, n=1000, seed=3, arrival_rate_rps=rate)
    down = simulate_serving(base, n=1000, seed=3, arrival_rate_rps=rate,
                            downtime_windows=((0.1, 0.2),))
    assert serving_availability(up, slo) > 0.99
    assert serving_availability(down, slo) < serving_availability(up, slo)
    assert serving_availability(np.array([]), slo) == 1.0

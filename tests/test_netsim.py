"""Latency/overhead model sanity (paper §7.4/§7.6 semantics)."""
import numpy as np

from repro.core import packets
from repro.core.mlmodels import DecisionTree
from repro.core.netsim import (
    ServerModel,
    acorn_serving_time,
    forwarding_overhead,
    measure_inference_time,
    server_serving_time,
    simulate_serving,
)
from repro.core.planner import plan_program
from repro.core.topology import fat_tree
from repro.core.translator import translate


def test_acorn_faster_than_server(satdap):
    Xtr, ytr, Xte, _ = satdap
    dt = DecisionTree(max_depth=8, max_leaf_nodes=80).fit(Xtr, ytr)
    prog = translate(dt)
    net = fat_tree(4)
    h = net.hosts()
    plan = plan_program(prog, net, h[0], h[-1], solver="dp")
    t_acorn = acorn_serving_time(plan)
    t_pred = measure_inference_time(dt, Xte, n_requests=50)
    t_server = server_serving_time(
        t_pred, packets.request_bytes(prog.n_features, n_trees=1))
    # paper: 65-90% faster
    assert t_acorn < t_server
    assert t_acorn < 0.3e-3  # "requests served within 0.12 ms" ballpark


def test_request_response_size_asymmetry():
    rq = packets.request_bytes(46, n_trees=5)
    rs = packets.response_bytes()
    assert rq > rs  # stripping payload shrinks the response


def test_simulation_is_stable():
    s = simulate_serving(1e-4, n=500, seed=1)
    assert abs(np.median(s) - 1e-4) / 1e-4 < 0.05
    assert (s > 0).all()


def test_forwarding_overhead_bounds():
    r = forwarding_overhead()
    assert 0 < r["latency_overhead_frac"] <= 0.033  # paper: 2.7-3.3%
    assert 0.9 < r["goodput_frac"] < 1.0

"""SwitchEngine: jit-once runtime programmability + equivalence to CPU models.

Uses the session-scoped ``plane_engine`` fixture (one jit trace shared by the
whole module); trace-count assertions are therefore *deltas* — installs and
swaps must never add a trace for an already-seen batch shape.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.packets import PacketBatch, PacketType
from repro.core.translator import translate


def _req(X, prog, eng):
    prof = eng.profile
    return PacketBatch.make_request(
        X, mid=prog.mid, vid=prog.vid, max_features=prof.max_features,
        n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
        max_versions=prof.max_versions)


def test_plane_equals_cpu_and_never_retraces(satdap, plane_engine):
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    packed = eng.empty()

    dt = DecisionTree(max_depth=8, max_leaf_nodes=100).fit(Xtr, ytr)
    rf = RandomForest(n_estimators=5, max_depth=6, max_leaf_nodes=50).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    # warm the (single) trace for this batch shape, then count deltas
    eng.classify(packed, _req(Xte, translate(dt), eng))
    before = eng.cache_size()
    for model in (dt, rf, svm):
        prog = translate(model)
        packed = eng.install(packed, prog)
        out = eng.classify(packed, _req(Xte, prog, eng))
        got = np.asarray(out.rslt)
        want = model.predict(Xte)
        agree = (got == want).mean()
        if isinstance(model, LinearSVM):
            assert agree > 0.97  # fixed-point quantization slack
        else:
            assert agree == 1.0
    # runtime programmability: three installs, two pipelines, ZERO new traces
    assert eng.cache_size() == before


def test_both_pipelines_coexist(satdap, plane_engine):
    """Paper Fig. 5: a tree model and an SVM live in one data plane."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    rf = RandomForest(n_estimators=3, max_depth=5, max_leaf_nodes=40).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    prog_rf, prog_svm = translate(rf), translate(svm)
    packed = eng.install(eng.install(eng.empty(), prog_rf), prog_svm)
    out_rf = eng.classify(packed, _req(Xte, prog_rf, eng))
    out_svm = eng.classify(packed, _req(Xte, prog_svm, eng))
    assert (np.asarray(out_rf.rslt) == rf.predict(Xte)).all()
    assert (np.asarray(out_svm.rslt) == svm.predict(Xte)).mean() > 0.97


def test_forwarding_passthrough(satdap, plane_engine):
    """Non-request packets are untouched (classification never breaks
    forwarding — paper §6.1)."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    dt = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    prog = translate(dt)
    packed = eng.install(eng.empty(), prog)
    pb = _req(Xte[:16], prog, eng)
    pb = pb.__class__(**{**pb.__dict__,
                         "ptype": jnp.full((16,), PacketType.FORWARD, jnp.int32)})
    out = eng.classify(packed, pb)
    assert (np.asarray(out.rslt) == -1).all()


def test_model_version_swap_changes_predictions(satdap, plane_engine):
    """Two versions of a DT live in the zoo simultaneously; requests pick
    their version by VID, and installing v2 never disturbs v1 (the paper's
    runtime reprogrammability along the Appendix A VID axis)."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    d1 = DecisionTree(max_depth=3, max_leaf_nodes=8).fit(Xtr, ytr)
    d2 = DecisionTree(max_depth=8, max_leaf_nodes=100).fit(Xtr, ytr)
    p1, p2 = translate(d1, vid=1), translate(d2, vid=2)
    eng.classify(eng.empty(), _req(Xte, p1, eng))  # warm this batch shape
    before = eng.cache_size()
    packed = eng.install(eng.empty(), p1)
    out1 = eng.classify(packed, _req(Xte, p1, eng))
    packed = eng.install(packed, p2)  # runtime install of a second version
    out2 = eng.classify(packed, _req(Xte, p2, eng))
    assert (np.asarray(out1.rslt) == d1.predict(Xte)).all()
    assert (np.asarray(out2.rslt) == d2.predict(Xte)).all()
    # v1 is still resident and still answers v1 requests
    out1_again = eng.classify(packed, _req(Xte, p1, eng))
    assert (np.asarray(out1_again.rslt) == d1.predict(Xte)).all()
    assert eng.cache_size() == before

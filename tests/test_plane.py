"""SwitchEngine: jit-once runtime programmability + equivalence to CPU models.

Uses the session-scoped ``plane_engine`` fixture (one jit trace shared by the
whole module); trace-count assertions are therefore *deltas* — installs and
swaps must never add a trace for an already-seen batch shape.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.packets import PacketBatch, PacketType
from repro.core.translator import translate


def _req(X, prog, eng):
    prof = eng.profile
    return PacketBatch.make_request(
        X, mid=prog.mid, vid=prog.vid, max_features=prof.max_features,
        n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
        max_versions=prof.max_versions)


def test_plane_equals_cpu_and_never_retraces(satdap, plane_engine):
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    packed = eng.empty()

    dt = DecisionTree(max_depth=8, max_leaf_nodes=100).fit(Xtr, ytr)
    rf = RandomForest(n_estimators=5, max_depth=6, max_leaf_nodes=50).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    # warm the (single) trace for this batch shape, then count deltas
    eng.classify(packed, _req(Xte, translate(dt), eng))
    before = eng.cache_size()
    for model in (dt, rf, svm):
        prog = translate(model)
        packed = eng.install(packed, prog)
        out = eng.classify(packed, _req(Xte, prog, eng))
        got = np.asarray(out.rslt)
        want = model.predict(Xte)
        agree = (got == want).mean()
        if isinstance(model, LinearSVM):
            assert agree > 0.97  # fixed-point quantization slack
        else:
            assert agree == 1.0
    # runtime programmability: three installs, two pipelines, ZERO new traces
    assert eng.cache_size() == before


def test_both_pipelines_coexist(satdap, plane_engine):
    """Paper Fig. 5: a tree model and an SVM live in one data plane."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    rf = RandomForest(n_estimators=3, max_depth=5, max_leaf_nodes=40).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    prog_rf, prog_svm = translate(rf), translate(svm)
    packed = eng.install(eng.install(eng.empty(), prog_rf), prog_svm)
    out_rf = eng.classify(packed, _req(Xte, prog_rf, eng))
    out_svm = eng.classify(packed, _req(Xte, prog_svm, eng))
    assert (np.asarray(out_rf.rslt) == rf.predict(Xte)).all()
    assert (np.asarray(out_svm.rslt) == svm.predict(Xte)).mean() > 0.97


def test_forwarding_passthrough(satdap, plane_engine):
    """Non-request packets are untouched (classification never breaks
    forwarding — paper §6.1)."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    dt = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    prog = translate(dt)
    packed = eng.install(eng.empty(), prog)
    pb = _req(Xte[:16], prog, eng)
    pb = pb.__class__(**{**pb.__dict__,
                         "ptype": jnp.full((16,), PacketType.FORWARD, jnp.int32)})
    out = eng.classify(packed, pb)
    assert (np.asarray(out.rslt) == -1).all()


def test_mixed_batch_leaves_forward_packets_bit_identical(satdap, plane_engine):
    """Regression: a mixed REQUEST/FORWARD batch must leave FORWARD packets'
    codes/svm_acc intermediates AND rslt bit-identical — non-request traffic
    passes through untouched even when it shares a batch with requests."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    dt = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    prog = translate(dt)
    packed = eng.install(eng.empty(), prog)
    B = 64
    pb = _req(Xte[:B], prog, eng)
    rng = np.random.default_rng(7)
    fwd = rng.random(B) < 0.5
    # give the forwarded packets nonzero in-flight intermediates + rslt so an
    # overwrite (even with recomputed values) is detectable
    fwd_col = jnp.asarray(fwd)[:, None]
    pb = dataclasses.replace(
        pb,
        ptype=jnp.where(jnp.asarray(fwd), PacketType.FORWARD, PacketType.REQUEST),
        codes=jnp.where(fwd_col, jnp.asarray(
            rng.integers(0, 2**10, pb.codes.shape), jnp.uint32), pb.codes),
        svm_acc=jnp.where(fwd_col, jnp.asarray(
            rng.integers(-99, 99, pb.svm_acc.shape), jnp.int32), pb.svm_acc),
        rslt=jnp.where(jnp.asarray(fwd),
                       jnp.asarray(rng.integers(-1, 5, (B,)), jnp.int32),
                       pb.rslt),
    )
    out = eng.classify(packed, pb)
    np.testing.assert_array_equal(np.asarray(out.codes)[fwd],
                                  np.asarray(pb.codes)[fwd])
    np.testing.assert_array_equal(np.asarray(out.svm_acc)[fwd],
                                  np.asarray(pb.svm_acc)[fwd])
    np.testing.assert_array_equal(np.asarray(out.rslt)[fwd],
                                  np.asarray(pb.rslt)[fwd])
    # the REQUEST packets in the same batch still classify
    req = ~fwd
    assert (np.asarray(out.rslt)[req] == dt.predict(Xte[:B])[req]).all()


def test_layerwise_fallback_matches_fused(satdap):
    """mode="layerwise-ref" (pre-fusion per-layer scan) and the fused walk
    produce identical plane outputs."""
    from repro.core.plane import PlaneProfile, SwitchEngine

    Xtr, ytr, Xte, _ = satdap
    prof = PlaneProfile(max_features=36, max_trees=3, max_layers=6,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=2)
    dt = DecisionTree(max_depth=5, max_leaf_nodes=30).fit(Xtr, ytr)
    prog = translate(dt)
    outs = {}
    for mode in ("ref", "layerwise-ref"):
        eng = SwitchEngine(prof, mode=mode)
        packed = eng.install(eng.empty(), prog)
        out = eng.classify(packed, _req(Xte, prog, eng))
        outs[mode] = np.asarray(out.rslt)
    np.testing.assert_array_equal(outs["ref"], outs["layerwise-ref"])
    assert (outs["ref"] == dt.predict(Xte)).all()


def test_classify_issues_single_tree_walk_launch(satdap, plane_engine):
    """Acceptance: one classify = exactly ONE pallas_call (the fused
    megakernel), vs 3 on the unfused fallback (walk + vote + svm) and
    max_layers + 2 on the layerwise one."""
    from repro.core.plane import _classify_impl
    from repro.kernels import ops

    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    dt = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    prog = translate(dt)
    packed = eng.install(eng.empty(), prog)
    pb = _req(Xte[:32], prog, eng)
    n_cls = eng.profile.max_classes
    count = lambda mode: ops.count_pallas_launches(
        lambda pk, b: _classify_impl(pk, b, n_classes=n_cls, mode=mode),
        packed, pb)
    L = eng.profile.max_layers
    assert count("interpret") == 1
    assert count("unfused-interpret") == 3
    assert count("layerwise-interpret") == L + 2


def test_model_version_swap_changes_predictions(satdap, plane_engine):
    """Two versions of a DT live in the zoo simultaneously; requests pick
    their version by VID, and installing v2 never disturbs v1 (the paper's
    runtime reprogrammability along the Appendix A VID axis)."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    d1 = DecisionTree(max_depth=3, max_leaf_nodes=8).fit(Xtr, ytr)
    d2 = DecisionTree(max_depth=8, max_leaf_nodes=100).fit(Xtr, ytr)
    p1, p2 = translate(d1, vid=1), translate(d2, vid=2)
    eng.classify(eng.empty(), _req(Xte, p1, eng))  # warm this batch shape
    before = eng.cache_size()
    packed = eng.install(eng.empty(), p1)
    out1 = eng.classify(packed, _req(Xte, p1, eng))
    packed = eng.install(packed, p2)  # runtime install of a second version
    out2 = eng.classify(packed, _req(Xte, p2, eng))
    assert (np.asarray(out1.rslt) == d1.predict(Xte)).all()
    assert (np.asarray(out2.rslt) == d2.predict(Xte)).all()
    # v1 is still resident and still answers v1 requests
    out1_again = eng.classify(packed, _req(Xte, p1, eng))
    assert (np.asarray(out1_again.rslt) == d1.predict(Xte)).all()
    assert eng.cache_size() == before

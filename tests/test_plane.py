"""SwitchEngine: jit-once runtime programmability + equivalence to CPU models."""
import numpy as np
import jax.numpy as jnp

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.packets import PacketBatch, PacketType
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.core.translator import translate

PROF = PlaneProfile(max_features=36, max_trees=5, max_layers=10,
                    max_entries_per_layer=256, max_leaves=256,
                    max_classes=8, max_hyperplanes=8)


def _req(X, prog):
    return PacketBatch.make_request(
        X, mid=prog.mid, max_features=PROF.max_features,
        n_trees=PROF.max_trees, n_hyperplanes=PROF.max_hyperplanes)


def test_plane_equals_cpu_and_never_retraces(satdap):
    Xtr, ytr, Xte, _ = satdap
    eng = SwitchEngine(PROF)
    packed = eng.empty()

    dt = DecisionTree(max_depth=8, max_leaf_nodes=100).fit(Xtr, ytr)
    rf = RandomForest(n_estimators=5, max_depth=6, max_leaf_nodes=50).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    for model in (dt, rf, svm):
        prog = translate(model)
        packed = eng.install(packed, prog)
        out = eng.classify(packed, _req(Xte, prog))
        got = np.asarray(out.rslt)
        want = model.predict(Xte)
        agree = (got == want).mean()
        if isinstance(model, LinearSVM):
            assert agree > 0.97  # fixed-point quantization slack
        else:
            assert agree == 1.0
    # runtime programmability: three installs, two pipelines, ONE trace
    assert eng.cache_size() == 1


def test_both_pipelines_coexist(satdap):
    """Paper Fig. 5: a tree model and an SVM live in one data plane."""
    Xtr, ytr, Xte, _ = satdap
    eng = SwitchEngine(PROF)
    rf = RandomForest(n_estimators=3, max_depth=5, max_leaf_nodes=40).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    prog_rf, prog_svm = translate(rf), translate(svm)
    packed = eng.install(eng.install(eng.empty(), prog_rf), prog_svm)
    out_rf = eng.classify(packed, _req(Xte, prog_rf))
    out_svm = eng.classify(packed, _req(Xte, prog_svm))
    assert (np.asarray(out_rf.rslt) == rf.predict(Xte)).all()
    assert (np.asarray(out_svm.rslt) == svm.predict(Xte)).mean() > 0.97


def test_forwarding_passthrough(satdap):
    """Non-request packets are untouched (classification never breaks
    forwarding — paper §6.1)."""
    Xtr, ytr, Xte, _ = satdap
    eng = SwitchEngine(PROF)
    dt = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    packed = eng.install(eng.empty(), translate(dt))
    pb = _req(Xte[:16], translate(dt))
    pb = pb.__class__(**{**pb.__dict__,
                         "ptype": jnp.full((16,), PacketType.FORWARD, jnp.int32)})
    out = eng.classify(packed, pb)
    assert (np.asarray(out.rslt) == -1).all()


def test_model_version_swap_changes_predictions(satdap):
    Xtr, ytr, Xte, _ = satdap
    eng = SwitchEngine(PROF)
    d1 = DecisionTree(max_depth=3, max_leaf_nodes=8).fit(Xtr, ytr)
    d2 = DecisionTree(max_depth=8, max_leaf_nodes=100).fit(Xtr, ytr)
    p1, p2 = translate(d1, vid=1), translate(d2, vid=2)
    packed = eng.install(eng.empty(), p1)
    out1 = eng.classify(packed, _req(Xte, p1))
    packed = eng.install(packed, p2)  # runtime swap
    out2 = eng.classify(packed, _req(Xte, p2))
    assert (np.asarray(out1.rslt) == d1.predict(Xte)).all()
    assert (np.asarray(out2.rslt) == d2.predict(Xte)).all()
    assert eng.cache_size() == 1

"""planelint: the static contract checker (ARCHITECTURE 'Static contracts').

Pins, per rule PL001-PL005: a violating fixture fires with the right id and
line, the matching clean idiom stays silent, and a same-line
``# planelint: disable=...`` pragma suppresses.  Plus: the CLI's JSON schema
and exit codes, PL000 on unparsable files, PL003's static footprints
reproducing both ``kernels/budgets.py`` and the byte values quoted in the
``docs/ARCHITECTURE.md`` pinned-footprint table within 1%, and the shipped
tree linting clean end-to-end.
"""
import json
import pathlib
import re
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.lint import run_lint
from repro.analysis.lint.rules.pl003_vmem_budget import kernel_footprints
from repro.kernels.budgets import BUDGETS, VMEM_BYTES

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC_REPRO = REPO / "src" / "repro"


def lint_tree(tmp_path, files, rules=None, **kw):
    """Write ``{relpath: code}`` under tmp_path and lint the tree."""
    for rel, code in files.items():
        f = tmp_path / rel
        f.parent.mkdir(parents=True, exist_ok=True)
        f.write_text(textwrap.dedent(code))
    findings, checked = run_lint([tmp_path], rules, **kw)
    assert checked == len(files)
    return findings


def rule_ids(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------------ PL001
def test_pl001_fires_outside_runtime(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/rogue.py": """\
            from jax.experimental.shard_map import shard_map
        """,
    }, ["PL001"])
    assert rule_ids(findings) == ["PL001"]
    assert findings[0].line == 1
    assert findings[0].name == "shard-map-containment"


def test_pl001_runtime_and_docstrings_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        # runtime/ is the one allowed home
        "runtime/mesh.py": """\
            from jax.experimental.shard_map import shard_map

            def go(f):
                return shard_map(f, mesh=None, in_specs=(), out_specs=())
        """,
        # prose mentions see no AST nodes
        "core/doc.py": '''\
            """This module deliberately avoids shard_map (see runtime/)."""
            X = 1
        ''',
    }, ["PL001"])
    assert findings == []


def test_pl001_attribute_name_and_string_forms(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/a.py": """\
            import jax
            loop = jax.experimental.shard_map
        """,
        "serving/b.py": """\
            import jax
            loop = getattr(jax, "shard_map")
        """,
    }, ["PL001"])
    assert rule_ids(findings) == ["PL001", "PL001"]


# ------------------------------------------------------------------ PL002
_GLUE_BAD = """\
    import jax.numpy as jnp

    def coalesce(parts):
        return jnp.concatenate(parts)
"""


def test_pl002_fires_on_hot_path(tmp_path):
    for rel in ("serving/glue.py", "runtime/admission.py",
                "runtime/policies.py"):
        findings = lint_tree(tmp_path / rel.replace("/", "_"),
                             {rel: _GLUE_BAD}, ["PL002"])
        assert rule_ids(findings) == ["PL002"], rel
        assert "jnp.concatenate" in findings[0].message


def test_pl002_cold_modules_numpy_and_jit_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        # not a hot-path module: jnp glue is fine
        "core/maths.py": _GLUE_BAD,
        # numpy glue on the hot path is the sanctioned idiom
        "serving/host.py": """\
            import numpy as np

            def coalesce(parts):
                return np.concatenate(parts)
        """,
        # jnp inside a jit-compiled function is traced, not eager glue
        "serving/traced.py": """\
            import jax
            import jax.numpy as jnp

            @jax.jit
            def pack(a, b):
                return jnp.stack([a, b])
        """,
    }, ["PL002"])
    assert findings == []


def test_pl002_sees_aliases_and_dotted_chain(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/alias.py": """\
            from jax import numpy as xp

            def pad(x):
                return xp.pad(x, 3)
        """,
        "serving/dotted.py": """\
            import jax.numpy

            def glue(xs):
                return jax.numpy.asarray(xs)
        """,
    }, ["PL002"])
    assert rule_ids(findings) == ["PL002", "PL002"]


def test_pl002_pragma_suppresses_only_that_line(tmp_path):
    files = {
        "serving/mixed.py": """\
            import jax.numpy as jnp

            def pack(parts, x):
                y = jnp.asarray(x)  # planelint: disable=PL002
                return jnp.concatenate(parts)
        """,
    }
    findings = lint_tree(tmp_path, files, ["PL002"])
    assert len(findings) == 1 and findings[0].line == 5
    # and the pragma is visible again with pragmas off
    findings = run_lint([tmp_path], ["PL002"], respect_pragmas=False)[0]
    assert len(findings) == 2


# ------------------------------------------------------------------ PL003
def _pallas_src(body):
    return ("from jax.experimental import pallas as pl\n\n"
            "block_b, F_pad = 256, 128\n\n" + textwrap.dedent(body))


def test_pl003_over_budget(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/tree_walk.py": _pallas_src("""\
            out = pl.pallas_call(
                None,
                grid=(1,),
                in_specs=[pl.BlockSpec((4096, 4096), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            )
        """),
    }, ["PL003"])
    assert rule_ids(findings) == ["PL003"]
    assert "exceeds" in findings[0].message
    assert str(VMEM_BYTES) in findings[0].message


def test_pl003_drift_from_pinned(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/tcam_match.py": _pallas_src("""\
            out = pl.pallas_call(
                None,
                grid=(1,),
                in_specs=[pl.BlockSpec((block_b, F_pad), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            )
        """),
    }, ["PL003"])
    assert rule_ids(findings) == ["PL003"]
    assert "drifted" in findings[0].message


def test_pl003_unbudgeted_and_unknown_binding(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/mystery.py": _pallas_src("""\
            out = pl.pallas_call(None, grid=(1,), in_specs=[])
        """),
        "kernels/svm_lookup.py": _pallas_src("""\
            out = pl.pallas_call(
                None,
                grid=(1,),
                in_specs=[pl.BlockSpec((block_q, 8), lambda i: (i, 0))],
            )
        """),
    }, ["PL003"])
    msgs = {f.path.rsplit("/", 1)[-1]: f.message for f in findings}
    assert "unbudgeted" in msgs["mystery.py"]
    assert "block_q" in msgs["svm_lookup.py"]


def test_pl003_stale_manifest_entry(tmp_path):
    # a budgets.py with no sibling kernel modules: every entry is stale
    findings = lint_tree(tmp_path, {
        "kernels/budgets.py": "BUDGETS = {}\n",
    }, ["PL003"])
    stale = {re.search(r"'(\w+)'", f.message).group(1) for f in findings}
    assert stale == set(BUDGETS)


def test_pl003_shipped_kernels_match_manifest_and_doc():
    """The acceptance bar: recomputed static footprints equal the manifest
    pins and the byte values quoted in the ARCHITECTURE table within 1%."""
    doc = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    doc_rows = dict(re.findall(r"^\|\s*`(\w+)`\s*\|[^|]*\|\s*([\d,]+) B",
                               doc, re.M))
    assert set(doc_rows) == set(BUDGETS)
    for key, entry in BUDGETS.items():
        got = kernel_footprints(SRC_REPRO / "kernels" / f"{key}.py")
        assert set(got) == {key}, key
        fp = got[key]
        assert abs(fp - entry.pinned_bytes) <= entry.tolerance * \
            entry.pinned_bytes, (key, fp, entry.pinned_bytes)
        doc_bytes = int(doc_rows[key].replace(",", ""))
        assert abs(fp - doc_bytes) <= 0.01 * doc_bytes, (key, fp, doc_bytes)
        assert fp <= entry.budget_bytes


# ------------------------------------------------------------------ PL004
def test_pl004_fires_on_blocking_calls(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/loop.py": """\
            import queue
            import time

            async def dispatch(fut):
                time.sleep(0.002)
                x = fut.result()
                q = queue.Queue()
                return x
        """,
    }, ["PL004"])
    assert rule_ids(findings) == ["PL004"] * 3
    assert [f.line for f in findings] == [5, 6, 7]


def test_pl004_async_idioms_and_sync_helpers_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/ok.py": """\
            import asyncio
            import time

            async def dispatch(loop, work):
                await asyncio.sleep(0.002)
                out = await loop.run_in_executor(None, work)
                q = asyncio.Queue()
                return out, q

            def sync_worker():
                time.sleep(0.002)   # fine: runs on an executor thread

            async def outer():
                def helper(fut):
                    return fut.result()   # nested sync def is opaque
                return helper
        """,
    }, ["PL004"])
    assert findings == []


def test_pl004_from_import_and_alias(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/alias.py": """\
            from time import sleep
            import queue as q

            async def f():
                sleep(1)
                return q.SimpleQueue()
        """,
    }, ["PL004"])
    assert rule_ids(findings) == ["PL004", "PL004"]


# ------------------------------------------------------------------ PL005
def test_pl005_fires_in_plain_function(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/build.py": """\
            import jax

            def make(f):
                return jax.jit(f)
        """,
    }, ["PL005"])
    assert rule_ids(findings) == ["PL005"]
    assert "make()" in findings[0].message


def test_pl005_sanctioned_construction_sites(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/ok.py": """\
            import functools
            import jax

            step = jax.jit(sum)          # module level

            class Engine:
                def __init__(self, impl):
                    self._fn = jax.jit(impl)      # once per object

                def run_for(self, n):
                    fn = self._runs.get(n)
                    if fn is None:
                        # memo-table store: once per key
                        fn = self._runs[n] = jax.jit(self._build(n))
                    return fn

            @functools.lru_cache(maxsize=8)
            def blank_program(profile):
                return jax.jit(lambda x: x)       # memoized by decorator

            @jax.jit
            def traced(x):
                inner = jax.jit(lambda y: y)      # part of a trace
                return inner(x)
        """,
        # launchers build one jitted step per process by design
        "launch/serve.py": """\
            import jax

            def main():
                return jax.jit(sum)
        """,
    }, ["PL005"])
    assert findings == []


# ------------------------------------------------------- runner mechanics
def test_pl000_parse_error(tmp_path):
    findings = lint_tree(tmp_path, {"broken.py": "def f(:\n"})
    assert rule_ids(findings) == ["PL000"]
    assert findings[0].name == "parse-error"


def test_disable_all_pragma(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/x.py": """\
            import jax.numpy as jnp

            def f(xs):
                return jnp.stack(xs)  # planelint: disable=all
        """,
    })
    assert findings == []


def test_unknown_rule_raises():
    with pytest.raises(ValueError, match="PL999"):
        run_lint([SRC_REPRO], ["PL999"])


def test_rule_selection_by_name(tmp_path):
    findings = lint_tree(tmp_path, {"core/r.py": "x = shard_map\n"},
                         ["shard-map-containment"])
    assert rule_ids(findings) == ["PL001"]


# ------------------------------------------------------------------- CLI
def _cli(args, cwd=REPO):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, cwd=cwd, env=env)


def test_cli_json_schema_and_exit_codes(tmp_path):
    (tmp_path / "serving").mkdir()
    (tmp_path / "serving" / "bad.py").write_text(
        "import jax.numpy as jnp\n\n\ndef f(xs):\n"
        "    return jnp.concatenate(xs)\n")
    proc = _cli([str(tmp_path), "--format", "json"])
    assert proc.returncode == 1, proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["version"] == 1
    assert doc["files_checked"] == 1
    assert set(doc["rules"]) >= {"PL001", "PL002", "PL003", "PL004", "PL005"}
    (finding,) = doc["findings"]
    assert set(finding) == {"path", "line", "col", "rule", "name", "message"}
    assert finding["rule"] == "PL002" and finding["line"] == 5

    # text format carries path:line: and the rule id; same exit
    proc = _cli([str(tmp_path)])
    assert proc.returncode == 1
    assert f"bad.py:5:" in proc.stdout and "PL002" in proc.stdout

    # clean tree exits 0
    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "m.py").write_text("x = 1\n")
    proc = _cli([str(clean), "--format", "json"])
    assert proc.returncode == 0
    assert json.loads(proc.stdout)["findings"] == []

    # usage errors exit 2
    assert _cli([str(clean), "--rule", "PL999"]).returncode == 2
    assert _cli([str(tmp_path / "nope")]).returncode == 2


def test_cli_list_rules():
    proc = _cli(["--list-rules"])
    assert proc.returncode == 0
    for rid in ("PL001", "PL002", "PL003", "PL004", "PL005"):
        assert rid in proc.stdout


def test_cli_runs_without_jax_runtime():
    """The lint CLI must not import jax (it runs in bare CI steps and must
    never initialize an accelerator runtime to parse source files)."""
    code = ("import sys\n"
            "import repro.analysis.lint.rules\n"
            "assert 'jax' not in sys.modules, 'lint import pulled in jax'\n")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stderr


# ----------------------------------------------------------- end to end
def test_shipped_tree_is_clean():
    """The whole package lints clean — the CI gate, in-process."""
    findings, checked = run_lint([SRC_REPRO])
    assert checked > 50
    assert findings == [], "\n".join(f.format() for f in findings)

"""planelint: the static contract checker (ARCHITECTURE 'Static contracts').

Pins, per rule PL001-PL008: a violating fixture fires with the right id and
line, the matching clean idiom stays silent, and a same-line
``planelint: disable=...`` pragma suppresses.  Plus: the CLI's JSON schema
and exit codes, PL000 on unparsable files, PL003's static footprints
reproducing both ``kernels/budgets.py`` and the byte values quoted in the
``docs/ARCHITECTURE.md`` pinned-footprint table within 1%, and the shipped
tree linting clean end-to-end.

The whole-project engine (PR 7) gets its own sections: the PL006
oracle-parity legs on fixture trees and on the four shipped kernel entries,
PL007's cross-module jit-reachability and def-use exemptions, PL008 pragma
accounting, the incremental cache (warm runs parse nothing; an edit
re-parses exactly the reverse-import closure; cross-file fact drift
re-lints a byte-identical file), and ``--changed-only`` against a scripted
git repo.
"""
import json
import pathlib
import re
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.lint import iter_files, lint_project, run_lint
from repro.analysis.lint.rules.pl003_vmem_budget import kernel_footprints
from repro.analysis.lint.rules.pl006_oracle_parity import parity_report
from repro.kernels.budgets import BUDGETS, VMEM_BYTES

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC_REPRO = REPO / "src" / "repro"


def write_tree(tmp_path, files):
    """Write ``{relpath: code}`` under tmp_path (dedented)."""
    for rel, code in files.items():
        f = tmp_path / rel
        f.parent.mkdir(parents=True, exist_ok=True)
        f.write_text(textwrap.dedent(code))


def lint_tree(tmp_path, files, rules=None, **kw):
    """Write ``{relpath: code}`` under tmp_path and lint the tree."""
    write_tree(tmp_path, files)
    findings, checked = run_lint([tmp_path], rules, **kw)
    assert checked == len(files)
    return findings


def rule_ids(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------------ PL001
def test_pl001_fires_outside_runtime(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/rogue.py": """\
            from jax.experimental.shard_map import shard_map
        """,
    }, ["PL001"])
    assert rule_ids(findings) == ["PL001"]
    assert findings[0].line == 1
    assert findings[0].name == "shard-map-containment"


def test_pl001_runtime_and_docstrings_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        # runtime/ is the one allowed home
        "runtime/mesh.py": """\
            from jax.experimental.shard_map import shard_map

            def go(f):
                return shard_map(f, mesh=None, in_specs=(), out_specs=())
        """,
        # prose mentions see no AST nodes
        "core/doc.py": '''\
            """This module deliberately avoids shard_map (see runtime/)."""
            X = 1
        ''',
    }, ["PL001"])
    assert findings == []


def test_pl001_attribute_name_and_string_forms(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/a.py": """\
            import jax
            loop = jax.experimental.shard_map
        """,
        "serving/b.py": """\
            import jax
            loop = getattr(jax, "shard_map")
        """,
    }, ["PL001"])
    assert rule_ids(findings) == ["PL001", "PL001"]


# ------------------------------------------------------------------ PL002
_GLUE_BAD = """\
    import jax.numpy as jnp

    def coalesce(parts):
        return jnp.concatenate(parts)
"""


def test_pl002_fires_on_hot_path(tmp_path):
    for rel in ("serving/glue.py", "runtime/admission.py",
                "runtime/policies.py"):
        findings = lint_tree(tmp_path / rel.replace("/", "_"),
                             {rel: _GLUE_BAD}, ["PL002"])
        assert rule_ids(findings) == ["PL002"], rel
        assert "jnp.concatenate" in findings[0].message


def test_pl002_cold_modules_numpy_and_jit_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        # not a hot-path module: jnp glue is fine
        "core/maths.py": _GLUE_BAD,
        # numpy glue on the hot path is the sanctioned idiom
        "serving/host.py": """\
            import numpy as np

            def coalesce(parts):
                return np.concatenate(parts)
        """,
        # jnp inside a jit-compiled function is traced, not eager glue
        "serving/traced.py": """\
            import jax
            import jax.numpy as jnp

            @jax.jit
            def pack(a, b):
                return jnp.stack([a, b])
        """,
    }, ["PL002"])
    assert findings == []


def test_pl002_sees_aliases_and_dotted_chain(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/alias.py": """\
            from jax import numpy as xp

            def pad(x):
                return xp.pad(x, 3)
        """,
        "serving/dotted.py": """\
            import jax.numpy

            def glue(xs):
                return jax.numpy.asarray(xs)
        """,
    }, ["PL002"])
    assert rule_ids(findings) == ["PL002", "PL002"]


def test_pl002_pragma_suppresses_only_that_line(tmp_path):
    files = {
        "serving/mixed.py": """\
            import jax.numpy as jnp

            def pack(parts, x):
                y = jnp.asarray(x)  # planelint: disable=PL002
                return jnp.concatenate(parts)
        """,
    }
    findings = lint_tree(tmp_path, files, ["PL002"])
    assert len(findings) == 1 and findings[0].line == 5
    # and the pragma is visible again with pragmas off
    findings = run_lint([tmp_path], ["PL002"], respect_pragmas=False)[0]
    assert len(findings) == 2


# ------------------------------------------------------------------ PL003
def _pallas_src(body):
    return ("from jax.experimental import pallas as pl\n\n"
            "block_b, F_pad = 256, 128\n\n" + textwrap.dedent(body))


def test_pl003_over_budget(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/tree_walk.py": _pallas_src("""\
            out = pl.pallas_call(
                None,
                grid=(1,),
                in_specs=[pl.BlockSpec((4096, 4096), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            )
        """),
    }, ["PL003"])
    assert rule_ids(findings) == ["PL003"]
    assert "exceeds" in findings[0].message
    assert str(VMEM_BYTES) in findings[0].message


def test_pl003_drift_from_pinned(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/tcam_match.py": _pallas_src("""\
            out = pl.pallas_call(
                None,
                grid=(1,),
                in_specs=[pl.BlockSpec((block_b, F_pad), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            )
        """),
    }, ["PL003"])
    assert rule_ids(findings) == ["PL003"]
    assert "drifted" in findings[0].message


def test_pl003_unbudgeted_and_unknown_binding(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/mystery.py": _pallas_src("""\
            out = pl.pallas_call(None, grid=(1,), in_specs=[])
        """),
        "kernels/svm_lookup.py": _pallas_src("""\
            out = pl.pallas_call(
                None,
                grid=(1,),
                in_specs=[pl.BlockSpec((block_q, 8), lambda i: (i, 0))],
            )
        """),
    }, ["PL003"])
    msgs = {f.path.rsplit("/", 1)[-1]: f.message for f in findings}
    assert "unbudgeted" in msgs["mystery.py"]
    assert "block_q" in msgs["svm_lookup.py"]


def test_pl003_stale_manifest_entry(tmp_path):
    # a budgets.py with no sibling kernel modules: every entry is stale
    findings = lint_tree(tmp_path, {
        "kernels/budgets.py": "BUDGETS = {}\n",
    }, ["PL003"])
    stale = {re.search(r"'(\w+)'", f.message).group(1) for f in findings}
    assert stale == set(BUDGETS)


def test_pl003_shipped_kernels_match_manifest_and_doc():
    """The acceptance bar: recomputed static footprints equal the manifest
    pins and the byte values quoted in the ARCHITECTURE table within 1%."""
    doc = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    doc_rows = dict(re.findall(r"^\|\s*`(\w+)`\s*\|[^|]*\|\s*([\d,]+) B",
                               doc, re.M))
    assert set(doc_rows) == set(BUDGETS)
    for key, entry in BUDGETS.items():
        mod = entry.module or key
        got = kernel_footprints(SRC_REPRO / "kernels" / f"{mod}.py")
        assert key in got, (key, got)
        fp = got[key]
        assert abs(fp - entry.pinned_bytes) <= entry.tolerance * \
            entry.pinned_bytes, (key, fp, entry.pinned_bytes)
        doc_bytes = int(doc_rows[key].replace(",", ""))
        assert abs(fp - doc_bytes) <= 0.01 * doc_bytes, (key, fp, doc_bytes)
        assert fp <= entry.budget_bytes


# ------------------------------------------------------------------ PL004
def test_pl004_fires_on_blocking_calls(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/loop.py": """\
            import queue
            import time

            async def dispatch(fut):
                time.sleep(0.002)
                x = fut.result()
                q = queue.Queue()
                return x
        """,
    }, ["PL004"])
    assert rule_ids(findings) == ["PL004"] * 3
    assert [f.line for f in findings] == [5, 6, 7]


def test_pl004_async_idioms_and_sync_helpers_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/ok.py": """\
            import asyncio
            import time

            async def dispatch(loop, work):
                await asyncio.sleep(0.002)
                out = await loop.run_in_executor(None, work)
                q = asyncio.Queue()
                return out, q

            def sync_worker():
                time.sleep(0.002)   # fine: runs on an executor thread

            async def outer():
                def helper(fut):
                    return fut.result()   # nested sync def is opaque
                return helper
        """,
    }, ["PL004"])
    assert findings == []


def test_pl004_from_import_and_alias(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/alias.py": """\
            from time import sleep
            import queue as q

            async def f():
                sleep(1)
                return q.SimpleQueue()
        """,
    }, ["PL004"])
    assert rule_ids(findings) == ["PL004", "PL004"]


# ------------------------------------------------------------------ PL005
def test_pl005_fires_in_plain_function(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/build.py": """\
            import jax

            def make(f):
                return jax.jit(f)
        """,
    }, ["PL005"])
    assert rule_ids(findings) == ["PL005"]
    assert "make()" in findings[0].message


def test_pl005_sanctioned_construction_sites(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/ok.py": """\
            import functools
            import jax

            step = jax.jit(sum)          # module level

            class Engine:
                def __init__(self, impl):
                    self._fn = jax.jit(impl)      # once per object

                def run_for(self, n):
                    fn = self._runs.get(n)
                    if fn is None:
                        # memo-table store: once per key
                        fn = self._runs[n] = jax.jit(self._build(n))
                    return fn

            @functools.lru_cache(maxsize=8)
            def blank_program(profile):
                return jax.jit(lambda x: x)       # memoized by decorator

            @jax.jit
            def traced(x):
                inner = jax.jit(lambda y: y)      # part of a trace
                return inner(x)
        """,
        # launchers build one jitted step per process by design
        "launch/serve.py": """\
            import jax

            def main():
                return jax.jit(sum)
        """,
    }, ["PL005"])
    assert findings == []


# ------------------------------------------------------------------ PL006
# A minimal but fully-wired kernel tree: entry + ref oracle + ops dispatch
# + a conformance test whose import closure reaches the ops wrapper.
_PARITY_OK = {
    "kernels/tree_walk.py": """\
        def tree_walk_pallas_v(x):
            return x
    """,
    "kernels/ref.py": """\
        def tree_walk_v(x):
            return x
    """,
    "kernels/ops.py": """\
        from kernels import ref
        from kernels.tree_walk import tree_walk_pallas_v

        def tree_walk_v(x, mode="auto"):
            if mode == "ref":
                return ref.tree_walk_v(x)
            return tree_walk_pallas_v(x)
    """,
    "tests/test_conformance.py": """\
        from kernels import ops

        def test_parity(x):
            assert ops.tree_walk_v(x, mode="ref") is not None
    """,
}


def test_pl006_clean_when_fully_wired(tmp_path):
    assert lint_tree(tmp_path, _PARITY_OK, ["PL006"]) == []


def test_pl006_reports_each_missing_leg(tmp_path):
    # entry with no oracle, no dispatcher, no conformance wiring: all three
    # legs fail, anchored at the def line
    findings = lint_tree(tmp_path, {
        "kernels/tree_walk.py": """\
            def tree_walk_pallas_v(x):
                return x
        """,
        "kernels/ref.py": "X = 1\n",
        "kernels/ops.py": "X = 1\n",
    }, ["PL006"])
    assert rule_ids(findings) == ["PL006"] * 3
    assert all(f.line == 1 for f in findings)
    msgs = " ".join(f.message for f in findings)
    assert "no oracle" in msgs
    assert "not dispatched" in msgs
    assert "unreachable from the conformance gate" in msgs


def test_pl006_dispatch_must_call_both_paths(tmp_path):
    # the ops wrapper exists but short-circuits the ref oracle: the
    # mode='ref' swap is broken even though the name matches
    files = dict(_PARITY_OK)
    files["kernels/ops.py"] = """\
        from kernels.tree_walk import tree_walk_pallas_v

        def tree_walk_v(x, mode="auto"):
            return tree_walk_pallas_v(x)
    """
    findings = lint_tree(tmp_path, files, ["PL006"])
    assert rule_ids(findings) == ["PL006"]
    assert "not dispatched" in findings[0].message


def test_pl006_private_and_non_v_defs_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/tree_walk.py": """\
            def _pad_v(x):
                return x

            def helper(x):
                return x
        """,
    }, ["PL006"])
    assert findings == []


def test_pl006_pragma_suppresses(tmp_path):
    findings = lint_tree(tmp_path, {
        "kernels/tree_walk.py": """\
            def scratch_v(x):  # planelint: disable=PL006
                return x
        """,
    }, ["PL006"])
    assert findings == []


def test_pl006_shipped_entries_pass_all_legs():
    """The acceptance bar: all five shipped ``*_v`` kernel entries have a
    ref oracle, an ops dispatcher calling both paths, and a call chain from
    tests/test_conformance.py."""
    run = lint_project([SRC_REPRO])
    report = parity_report(run.project)
    assert set(report) == {
        "tree_walk_pallas_v", "forest_predict_vote_pallas_v",
        "svm_lookup_pallas_v", "tcam_match_pallas_v",
        "classify_fused_pallas_v"}
    for name, legs in report.items():
        assert legs["ref"], name
        assert legs["dispatch"], name
        assert legs["reachable"], name
        assert legs["conformance"].endswith("test_conformance.py"), name


# ------------------------------------------------------------------ PL007
def test_pl007_fires_in_jit_decorated_function(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/entry.py": """\
            import jax

            @jax.jit
            def classify(x):
                return float(x) * 2.0
        """,
    }, ["PL007"])
    assert rule_ids(findings) == ["PL007"]
    assert "float()" in findings[0].message
    assert "'x'" in findings[0].message and "classify()" in findings[0].message


def test_pl007_cross_module_reachability(tmp_path):
    # the hazard sits in a plain helper; only the *other* module's jit entry
    # makes it reachable — the per-file view PR 6 had cannot see this
    findings = lint_tree(tmp_path, {
        "kernels/helper.py": """\
            def scale(x):
                return float(x) * 2.0
        """,
        "core/entry.py": """\
            import jax
            from kernels.helper import scale

            @jax.jit
            def classify(x):
                return scale(x)
        """,
    }, ["PL007"])
    assert rule_ids(findings) == ["PL007"]
    assert findings[0].path.endswith("helper.py")


def test_pl007_taint_flows_through_assignment_and_item(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/entry.py": """\
            import jax

            @jax.jit
            def classify(x):
                y = x + 1
                z = y.item()
                return z
        """,
    }, ["PL007"])
    assert rule_ids(findings) == ["PL007"]
    assert ".item()" in findings[0].message


def test_pl007_static_flows_and_cold_functions_exempt(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/ok.py": """\
            import jax
            import numpy as np

            @jax.jit
            def classify(x, n_classes: int):
                b = int(x.shape[0])          # .shape is trace-time static
                w = int(len(x))              # len() likewise
                k = int(n_classes) + b + w   # annotated static scalar
                return x * k

            def host_stats(x):
                return float(np.mean(x))     # not jit-reachable: fine
        """,
    }, ["PL007"])
    assert findings == []


def test_pl007_wrapped_and_pallas_entries_count(tmp_path):
    # jax.jit(functools.partial(f, ...)) wraps f without a decorator
    findings = lint_tree(tmp_path, {
        "core/wrapped.py": """\
            import functools
            import jax

            def impl(x, mode):
                return x.item()

            step = jax.jit(functools.partial(impl, mode="fast"))
        """,
    }, ["PL007"])
    assert rule_ids(findings) == ["PL007"]


def test_pl007_np_asarray_and_pragma(tmp_path):
    files = {
        "core/entry.py": """\
            import jax
            import numpy as np

            @jax.jit
            def classify(x):
                h = np.asarray(x)  # planelint: disable=PL007
                return np.asarray(x + 1)
        """,
    }
    findings = lint_tree(tmp_path, files, ["PL007"])
    assert len(findings) == 1 and findings[0].line == 7
    assert "np.asarray()" in findings[0].message


# ------------------------------------------------------------------ PL008
def test_pl008_flags_stale_pragma(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/x.py": "y = 1  # planelint: disable=PL002\n",
    })
    assert rule_ids(findings) == ["PL008"]
    assert findings[0].line == 1
    assert "suppressed nothing" in findings[0].message


def test_pl008_working_pragma_is_not_stale(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/glue.py": """\
            import jax.numpy as jnp

            def f(xs):
                return jnp.stack(xs)  # planelint: disable=PL002
        """,
    })
    assert findings == []


def test_pl008_skips_rules_that_did_not_run(tmp_path):
    # a --rule PL001,PL008 pass cannot call a PL002 pragma dead; likewise
    # disable=all is only judged under the full registry
    findings = lint_tree(tmp_path, {
        "core/x.py": "y = 1  # planelint: disable=PL002\n",
        "core/z.py": "w = 1  # planelint: disable=all\n",
    }, ["PL001", "PL008"])
    assert findings == []


def test_pl008_flags_stale_disable_all_under_full_registry(tmp_path):
    # disable=all cannot mute the PL008 finding reporting it — otherwise a
    # stale blanket pragma would be unreportable by construction
    findings = lint_tree(tmp_path, {
        "core/z.py": "w = 1  # planelint: disable=all\n",
    })
    assert rule_ids(findings) == ["PL008"]
    assert "disable=all" in findings[0].message


def test_pl008_naming_pl008_keeps_a_dormant_pragma(tmp_path):
    findings = lint_tree(tmp_path, {
        "core/z.py": "w = 1  # planelint: disable=PL002,PL008\n",
    })
    assert findings == []


def test_pl008_skipped_with_no_pragmas(tmp_path):
    write_tree(tmp_path, {"core/x.py": "y = 1  # planelint: disable=PL002\n"})
    findings, _ = run_lint([tmp_path], respect_pragmas=False)
    assert findings == []


# ------------------------------------------------- incremental cache
_CHAIN = {
    # import chain a -> b -> c plus an island d carrying a finding
    "a.py": "import b\n\nA = b.B + 1\n",
    "b.py": "import c\n\nB = c.C + 1\n",
    "c.py": "C = 1\n",
    "d.py": "x = shard_map\n",
}


def test_cache_warm_run_parses_nothing(tmp_path):
    write_tree(tmp_path, _CHAIN)
    cache = tmp_path / "cache.json"
    cold = lint_project([tmp_path], cache_path=cache)
    assert sorted(cold.parsed) == ["a.py", "b.py", "c.py", "d.py"]
    assert cold.cached == 0
    warm = lint_project([tmp_path], cache_path=cache)
    assert warm.parsed == []
    assert warm.cached == 4
    # cached findings replay identically
    assert [f.rule for f in warm.findings] == [f.rule for f in cold.findings]
    assert rule_ids(warm.findings) == ["PL001"]


def test_cache_edit_reparses_reverse_import_closure(tmp_path):
    write_tree(tmp_path, _CHAIN)
    cache = tmp_path / "cache.json"
    lint_project([tmp_path], cache_path=cache)
    (tmp_path / "b.py").write_text("import c\n\nB = c.C + 2\n")
    run = lint_project([tmp_path], cache_path=cache)
    # b changed; a imports b; c and d are untouched and replay from cache
    assert sorted(run.parsed) == ["a.py", "b.py"]
    assert run.changed == ["b.py"]
    assert run.cached == 2
    assert rule_ids(run.findings) == ["PL001"]


def test_cache_cross_file_fact_drift_relints_clean_file(tmp_path):
    # k.py never changes, but an edit elsewhere makes k.scale jit-reachable:
    # the facts digest drifts and k re-lints, surfacing the PL007 hazard
    write_tree(tmp_path, {
        "k.py": "def scale(x):\n    return float(x)\n",
        "m.py": """\
            import jax
            import k

            @jax.jit
            def f(x):
                return x
        """,
    })
    cache = tmp_path / "cache.json"
    cold = lint_project([tmp_path], cache_path=cache)
    assert cold.findings == []
    (tmp_path / "m.py").write_text(textwrap.dedent("""\
        import jax
        import k

        @jax.jit
        def f(x):
            return k.scale(x)
    """))
    run = lint_project([tmp_path], cache_path=cache)
    assert rule_ids(run.findings) == ["PL007"]
    assert run.findings[0].path.endswith("k.py")
    assert "k.py" in run.parsed      # re-linted despite identical bytes


def test_cache_invalidated_by_rule_selection_change(tmp_path):
    write_tree(tmp_path, _CHAIN)
    cache = tmp_path / "cache.json"
    lint_project([tmp_path], cache_path=cache)
    run = lint_project([tmp_path], ["PL001"], cache_path=cache)
    assert len(run.parsed) == 4      # different rule set: wholesale re-run
    assert rule_ids(run.findings) == ["PL001"]


# ------------------------------------------------------- changed-only mode
def _git(cwd, *args):
    proc = subprocess.run(
        ["git", "-c", "user.email=t@t", "-c", "user.name=t", *args],
        cwd=cwd, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    return proc.stdout


def test_changed_only_scopes_report_and_parse_set(tmp_path):
    """The acceptance bar: a warmed ``--changed-only`` rerun re-parses only
    the edited file's reverse-import closure, and per-file findings outside
    the diff scope (d.py's committed PL001) are not reported."""
    write_tree(tmp_path, _CHAIN)
    _git(tmp_path, "init", "-q")
    _git(tmp_path, "add", "-A")
    _git(tmp_path, "commit", "-qm", "seed")
    cache = tmp_path / "cache.json"
    cold = lint_project([tmp_path], cache_path=cache)
    assert rule_ids(cold.findings) == ["PL001"]

    (tmp_path / "b.py").write_text("import c\n\nB = c.C + 2\n")
    run = lint_project([tmp_path], cache_path=cache, changed_only="HEAD")
    assert sorted(run.parsed) == ["a.py", "b.py"]
    assert run.findings == []        # d.py's finding is outside the diff
    assert {p.rsplit("/", 1)[-1] for p in run.reported_paths} == set()


def test_changed_only_still_reports_project_rules(tmp_path):
    # a kernel entry missing its oracle is a cross-file property: it is
    # reported even when the diff does not touch the kernel module
    write_tree(tmp_path, {
        "kernels/tree_walk.py": "def tree_walk_pallas_v(x):\n    return x\n",
        "kernels/ref.py": "X = 1\n",
        "kernels/ops.py": "X = 1\n",
        "other.py": "y = 1\n",
    })
    _git(tmp_path, "init", "-q")
    _git(tmp_path, "add", "-A")
    _git(tmp_path, "commit", "-qm", "seed")
    (tmp_path / "other.py").write_text("y = 2\n")
    run = lint_project([tmp_path], changed_only="HEAD")
    assert rule_ids(run.findings) == ["PL006"] * 3


def test_changed_only_without_git_falls_back_to_full_report(tmp_path):
    write_tree(tmp_path, _CHAIN)    # no git repo here or in any parent tmp
    run = lint_project([tmp_path], changed_only="HEAD")
    assert rule_ids(run.findings) == ["PL001"]


# ------------------------------------------------------- runner mechanics
def test_iter_files_skips_pycache_and_hidden(tmp_path):
    write_tree(tmp_path, {
        "a.py": "x = 1\n",
        "sub/ok.py": "y = 1\n",
        "sub/__pycache__/stale.py": "x = shard_map\n",
        ".hidden/secret.py": "x = shard_map\n",
    })
    names = sorted(p.name for p, _ in iter_files([tmp_path]))
    assert names == ["a.py", "ok.py"]
    findings, checked = run_lint([tmp_path])
    assert checked == 2 and findings == []

def test_pl000_parse_error(tmp_path):
    findings = lint_tree(tmp_path, {"broken.py": "def f(:\n"})
    assert rule_ids(findings) == ["PL000"]
    assert findings[0].name == "parse-error"


def test_disable_all_pragma(tmp_path):
    findings = lint_tree(tmp_path, {
        "serving/x.py": """\
            import jax.numpy as jnp

            def f(xs):
                return jnp.stack(xs)  # planelint: disable=all
        """,
    })
    assert findings == []


def test_unknown_rule_raises():
    with pytest.raises(ValueError, match="PL999"):
        run_lint([SRC_REPRO], ["PL999"])


def test_rule_selection_by_name(tmp_path):
    findings = lint_tree(tmp_path, {"core/r.py": "x = shard_map\n"},
                         ["shard-map-containment"])
    assert rule_ids(findings) == ["PL001"]


# ------------------------------------------------------------------- CLI
def _cli(args, cwd=REPO):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, cwd=cwd, env=env)


def test_cli_json_schema_and_exit_codes(tmp_path):
    (tmp_path / "serving").mkdir()
    (tmp_path / "serving" / "bad.py").write_text(
        "import jax.numpy as jnp\n\n\ndef f(xs):\n"
        "    return jnp.concatenate(xs)\n")
    proc = _cli([str(tmp_path), "--format", "json"])
    assert proc.returncode == 1, proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["version"] == 1
    assert doc["files_checked"] == 1
    assert set(doc["rules"]) >= {"PL001", "PL002", "PL003", "PL004", "PL005"}
    (finding,) = doc["findings"]
    assert set(finding) == {"path", "line", "col", "rule", "name", "message"}
    assert finding["rule"] == "PL002" and finding["line"] == 5

    # text format carries path:line: and the rule id; same exit
    proc = _cli([str(tmp_path)])
    assert proc.returncode == 1
    assert f"bad.py:5:" in proc.stdout and "PL002" in proc.stdout

    # clean tree exits 0
    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "m.py").write_text("x = 1\n")
    proc = _cli([str(clean), "--format", "json"])
    assert proc.returncode == 0
    assert json.loads(proc.stdout)["findings"] == []

    # usage errors exit 2
    assert _cli([str(clean), "--rule", "PL999"]).returncode == 2
    assert _cli([str(tmp_path / "nope")]).returncode == 2


def test_cli_list_rules():
    proc = _cli(["--list-rules"])
    assert proc.returncode == 0
    for rid in ("PL001", "PL002", "PL003", "PL004", "PL005",
                "PL006", "PL007", "PL008"):
        assert rid in proc.stdout


def test_cli_github_format_annotations(tmp_path):
    (tmp_path / "serving").mkdir()
    (tmp_path / "serving" / "bad.py").write_text(
        "import jax.numpy as jnp\n\n\ndef f(xs):\n"
        "    return jnp.concatenate(xs)\n")
    proc = _cli([str(tmp_path), "--format", "github"])
    assert proc.returncode == 1
    (ann,) = [l for l in proc.stdout.splitlines() if l.startswith("::error")]
    assert ann.startswith("::error file=")
    assert ",line=5," in ann and "title=planelint PL002" in ann
    assert "\n" not in ann.split("::")[-1]    # message newlines escaped


def test_cli_cache_flag_reports_parse_accounting(tmp_path):
    (tmp_path / "m.py").write_text("x = 1\n")
    cache = tmp_path / "cache.json"
    first = _cli([str(tmp_path / "m.py"), "--cache", str(cache)])
    assert first.returncode == 0
    assert "1 file(s) parsed, 0 served from cache" in first.stdout
    second = _cli([str(tmp_path / "m.py"), "--cache", str(cache)])
    assert "0 file(s) parsed, 1 served from cache" in second.stdout


def test_cli_runs_without_jax_runtime():
    """The lint CLI must not import jax (it runs in bare CI steps and must
    never initialize an accelerator runtime to parse source files)."""
    code = ("import sys\n"
            "import repro.analysis.lint.rules\n"
            "assert 'jax' not in sys.modules, 'lint import pulled in jax'\n")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stderr


# ----------------------------------------------------------- end to end
def test_shipped_tree_is_clean():
    """The whole package lints clean — the CI gate, in-process."""
    findings, checked = run_lint([SRC_REPRO])
    assert checked > 50
    assert findings == [], "\n".join(f.format() for f in findings)

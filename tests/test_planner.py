"""Planner: MILP == DP optimum, constraint satisfaction, failure replanning."""
import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.planner import (
    DeviceModel,
    plan_program,
    plan_zoo,
    replan,
    replan_zoo,
)
from repro.core.topology import bcube, dcell, fat_tree, jellyfish
from repro.core.translator import translate


@pytest.fixture(scope="module")
def models(satdap):
    Xtr, ytr, _, _ = satdap
    dt = DecisionTree(max_depth=8, max_leaf_nodes=80).fit(Xtr, ytr)
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30).fit(Xtr, ytr)
    svm = LinearSVM(epochs=60).fit(Xtr, ytr)
    return translate(dt), translate(rf), translate(svm)


@pytest.fixture(scope="module")
def net():
    return fat_tree(4)


def _ends(net):
    h = net.hosts()
    return h[0], h[-1]


@pytest.mark.slow
def test_dp_matches_milp_optimum(models, net):
    src, dst = _ends(net)
    for prog in models:
        for dev in (DeviceModel(), DeviceModel(n_stages=6)):
            a = plan_program(prog, net, src, dst, default_device=dev, solver="dp")
            b = plan_program(prog, net, src, dst, default_device=dev, solver="milp")
            assert abs(a.objective - b.objective) < 1e-6, prog.kind


def test_stage_order_follows_path(models, net):
    src, dst = _ends(net)
    prog = models[1]  # forest
    plan = plan_program(prog, net, src, dst,
                        default_device=DeviceModel(n_stages=4), solver="dp")
    pos = {d: plan.path.index(d) for d in set(plan.assignment.values())}
    specs = prog.stages()
    # within each tree, deeper layers never upstream of shallower ones
    by_tree = {}
    for i, d in plan.assignment.items():
        for t in specs[i].tables:
            if t.kind == "dt_layer":
                by_tree.setdefault(t.tree, []).append((t.layer, pos[d]))
    for t, pairs in by_tree.items():
        pairs.sort()
        ps = [p for _, p in pairs]
        assert ps == sorted(ps), f"tree {t} layer order broken"
    # predict/voting downstream of everything
    last_pos = max(pos[plan.assignment[i]] for i, s in enumerate(specs)
                   if any(t.kind == "dt_layer" for t in s.tables))
    for i, s in enumerate(specs):
        if any(t.kind in ("dt_predict", "multitree_voting") for t in s.tables):
            assert pos[plan.assignment[i]] >= last_pos


def test_svm_colocation(models, net):
    src, dst = _ends(net)
    prog = models[2]
    plan = plan_program(prog, net, src, dst,
                        default_device=DeviceModel(n_stages=6), solver="dp")
    specs = prog.stages()
    byh = {}
    for i, d in plan.assignment.items():
        for t in specs[i].tables:
            if t.kind == "svm_mul":
                byh.setdefault(t.hyperplane, set()).add(d)
    assert all(len(v) == 1 for v in byh.values())


def test_resource_limits_respected(models, net):
    src, dst = _ends(net)
    prog = models[1]
    dev = DeviceModel(n_stages=3)
    plan = plan_program(prog, net, src, dst, default_device=dev, solver="dp")
    per_dev = plan.device_stages()
    assert all(len(s) <= dev.n_stages for s in per_dev.values())


def test_infeasible_raises(models, net):
    src, dst = _ends(net)
    with pytest.raises(RuntimeError):
        plan_program(models[1], net, src, dst,
                     default_device=DeviceModel(n_stages=1), solver="dp")


def test_replan_avoids_failed_devices(models, net):
    src, dst = _ends(net)
    prog = models[1]  # forest, forced across several devices
    dev = DeviceModel(n_stages=4)
    plan = plan_program(prog, net, src, dst, default_device=dev, solver="dp")
    used = plan.breakdown["devices_used"]
    assert len(used) >= 2
    # fail a mid-path device (the host-adjacent edge switch is a cut vertex —
    # losing it correctly disconnects the host)
    failed = {used[1]}
    plan2 = replan(prog, net, src, dst, failed, default_device=dev, solver="dp")
    assert not (set(plan2.breakdown["devices_used"]) & failed)


def test_replan_infeasible_when_cut_vertex_dies(models, net):
    """Losing the host's only edge switch disconnects it — the planner must
    say so rather than hallucinate a path."""
    src, dst = _ends(net)
    plan = plan_program(models[0], net, src, dst, solver="dp")
    edge = plan.path[1]  # host-adjacent switch
    with pytest.raises(RuntimeError):
        replan(models[0], net, src, dst, {edge}, solver="dp")


@pytest.mark.parametrize("mk", [
    lambda: fat_tree(4), lambda: dcell(3, 1), lambda: bcube(3, 1),
    lambda: jellyfish(20, 3)])
def test_all_topologies_plannable(models, mk):
    net = mk()
    h = net.hosts()
    plan = plan_program(models[0], net, h[0], h[-1], solver="dp")
    assert plan.objective > 0 and plan.solve_time < 10.0  # paper Fig. 8 bound


def test_weights_shift_optimum(models, net):
    """Heavier overhead weight pushes the last stage earlier on the path."""
    src, dst = _ends(net)
    prog = models[0]
    lat = plan_program(prog, net, src, dst, weights=(1, 0, 0), solver="dp")
    ovh = plan_program(prog, net, src, dst, weights=(0, 0, 1), solver="dp")
    assert ovh.breakdown["last_pos"] <= lat.breakdown["last_pos"]


# ---------------------------------------------------- differential (ISSUE 5)
@pytest.fixture(scope="module")
def small_models(satdap):
    """Tiny models so the MILP stays fast across many randomized draws."""
    Xtr, ytr, _, _ = satdap
    dt = DecisionTree(max_depth=4, max_leaf_nodes=14).fit(Xtr, ytr)
    rf = RandomForest(n_estimators=3, max_depth=3, max_leaf_nodes=8).fit(Xtr, ytr)
    svm = LinearSVM(epochs=30).fit(Xtr, ytr)
    return [translate(dt), translate(rf), translate(svm)]


def _random_topology(rng):
    mk = [lambda: fat_tree(4),
          lambda: dcell(3, 1),
          lambda: bcube(3, 1),
          lambda: jellyfish(int(rng.integers(12, 22)), 3,
                            seed=int(rng.integers(0, 100)))]
    return mk[int(rng.integers(len(mk)))]()


def test_differential_milp_equals_dp_random(small_models):
    """Randomized topologies / endpoints / capacities: the paper's MILP and
    the beyond-paper DP must return equal-objective plans on every draw (or
    agree a draw is infeasible)."""
    rng = np.random.default_rng(1105)
    draws = 0
    attempts = 0
    while draws < 12 and attempts < 60:
        attempts += 1
        net = _random_topology(rng)
        hosts = net.hosts()
        src, dst = rng.choice(hosts, size=2, replace=False)
        dev = DeviceModel(n_stages=int(rng.integers(3, 9)))
        prog = small_models[int(rng.integers(len(small_models)))]
        kw = dict(default_device=dev, n_candidate_paths=2)
        try:
            a = plan_program(prog, net, src, dst, solver="dp", **kw)
        except RuntimeError:
            with pytest.raises(RuntimeError):   # infeasibility must agree
                plan_program(prog, net, src, dst, solver="milp", **kw)
            continue   # infeasible draws don't count toward the quota
        b = plan_program(prog, net, src, dst, solver="milp", **kw)
        assert abs(a.objective - b.objective) < 1e-9, (
            f"solver gap on draw {draws}: dp={a.objective} milp={b.objective} "
            f"({prog.kind}, n_stages={dev.n_stages}, {src}->{dst})")
        draws += 1
    assert draws >= 8, \
        f"only {draws} feasible differential draws out of {attempts}"


def test_replan_fault_injection_random(small_models):
    """Kill 1-2 devices of a live plan: the replan must exclude every failed
    device and still fit each survivor's stage capacity."""
    rng = np.random.default_rng(2211)
    injections = 0
    attempts = 0
    while injections < 8 and attempts < 40:
        attempts += 1
        net = _random_topology(rng)
        hosts = net.hosts()
        src, dst = rng.choice(hosts, size=2, replace=False)
        dev = DeviceModel(n_stages=int(rng.integers(3, 6)))
        prog = small_models[int(rng.integers(2))]   # dt / rf spread stages
        kw = dict(default_device=dev, n_candidate_paths=2)
        try:
            plan = plan_program(prog, net, src, dst, solver="dp", **kw)
        except RuntimeError:
            continue
        used = plan.breakdown["devices_used"]
        # never kill the host-adjacent edge switches — those are cut
        # vertices, covered by test_replan_infeasible_when_cut_vertex_dies
        killable = [d for d in used if d not in (plan.path[1], plan.path[-2])]
        if not killable:
            continue
        n_kill = min(len(killable), int(rng.integers(1, 3)))
        failed = set(rng.choice(killable, size=n_kill, replace=False))
        try:
            plan2 = replan(prog, net, src, dst, failed, solver="dp", **kw)
        except RuntimeError:
            continue   # path genuinely lost — exclusion honored by absence
        assert not (set(plan2.path) & failed), \
            f"replanned path routes through dead devices {failed}"
        assert not (set(plan2.assignment.values()) & failed), \
            f"replanned assignment uses dead devices {failed}"
        per_dev = plan2.device_stages()
        assert all(len(s) <= dev.n_stages for s in per_dev.values()), \
            "replanned placement overflows a device's stage capacity"
        injections += 1
    assert injections >= 4, \
        f"only {injections} usable fault-injection draws out of {attempts}"


# --------------------------------------------- post-fault properties (ISSUE 8)
def test_replan_searches_surviving_topology(models, net):
    """Exclusion must re-enumerate paths on the surviving network: with a
    single candidate path, killing its core switch used to make every
    candidate cross the dead device even though the fat-tree has three more
    cores — the replan must find one, not report infeasible."""
    src, dst = _ends(net)
    plan = plan_program(models[0], net, src, dst, solver="dp",
                        n_candidate_paths=1)
    interior = [d for d in plan.path[2:-2] if d.startswith(("core", "agg"))]
    failed = {interior[0]}
    plan2 = replan(models[0], net, src, dst, failed, solver="dp",
                   n_candidate_paths=1)
    assert not (set(plan2.path) & failed)


def test_replan_endpoint_failure_is_infeasible(models, net):
    src, dst = _ends(net)
    with pytest.raises(RuntimeError):
        replan(models[0], net, src, dst, {src}, solver="dp")


def test_replan_zoo_capacity_carryover_post_fault(small_models, net):
    """Zoo-wide replanning: no dead device anywhere in any version's plan,
    one shared surviving path, and the per-device slot budget holds for the
    stage total summed ACROSS versions (the carry-over invariant)."""
    src, dst = _ends(net)
    dev = DeviceModel(n_stages=6)
    progs = small_models[:2]   # vid is irrelevant to placement
    kw = dict(default_device=dev, solver="dp")
    plans = plan_zoo(progs, net, src, dst, **kw)
    used = sorted({d for p in plans for d in p.assignment.values()},
                  key=plans[0].path.index)
    killable = [d for d in used if d not in (plans[0].path[1],
                                             plans[0].path[-2])]
    failed = set(killable[:1]) or {plans[0].path[3]}
    plans2 = replan_zoo(progs, net, src, dst, failed, **kw)
    assert len({tuple(p.path) for p in plans2}) == 1   # still one wire path
    assert not (set(plans2[0].path) & failed)
    per_dev: dict[str, int] = {}
    for p in plans2:
        assert not (set(p.assignment.values()) & failed), \
            f"dead device reappears in a version's post-fault plan: {failed}"
        for d in p.assignment.values():
            per_dev[d] = per_dev.get(d, 0) + 1
    assert all(n <= dev.n_stages for n in per_dev.values()), \
        f"cross-version stage total overflows a device: {per_dev}"


def test_differential_milp_equals_dp_post_fault(small_models):
    """The solver-agreement property must also hold on post-fault problems:
    dp and milp agree on the replanned objective (or agree the post-fault
    draw is infeasible) across randomized kills."""
    rng = np.random.default_rng(3313)
    draws = 0
    attempts = 0
    while draws < 8 and attempts < 60:
        attempts += 1
        net = _random_topology(rng)
        hosts = net.hosts()
        src, dst = rng.choice(hosts, size=2, replace=False)
        dev = DeviceModel(n_stages=int(rng.integers(3, 9)))
        prog = small_models[int(rng.integers(len(small_models)))]
        kw = dict(default_device=dev, n_candidate_paths=2)
        try:
            plan = plan_program(prog, net, src, dst, solver="dp", **kw)
        except RuntimeError:
            continue
        killable = [d for d in plan.breakdown["devices_used"]
                    if d not in (plan.path[1], plan.path[-2])]
        if not killable:
            continue
        failed = {str(rng.choice(killable))}
        try:
            a = replan(prog, net, src, dst, failed, solver="dp", **kw)
        except RuntimeError:
            with pytest.raises(RuntimeError):   # infeasibility must agree
                replan(prog, net, src, dst, failed, solver="milp", **kw)
            continue
        b = replan(prog, net, src, dst, failed, solver="milp", **kw)
        assert abs(a.objective - b.objective) < 1e-9, (
            f"post-fault solver gap: dp={a.objective} milp={b.objective} "
            f"({prog.kind}, failed={failed}, {src}->{dst})")
        draws += 1
    assert draws >= 4, \
        f"only {draws} feasible post-fault differential draws of {attempts}"

"""Roofline analysis: HLO parsers + term model + 6*N*D validation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlocost import parse_hlo_cost
from repro.analysis.roofline import (
    HW,
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)
from repro.configs import SHAPES, get_config


def test_matmul_flops_exact():
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in ((64, 128), (128, 256), (256, 32))]
    txt = jax.jit(f).lower(*args).compile().as_text()
    c = parse_hlo_cost(txt)
    assert c["matmul_flops"] == 2 * 64 * 256 * 128 + 2 * 64 * 32 * 256


def test_batched_dot_flops():
    def g(x, w):
        return jnp.einsum("bij,bjk->bik", x, w)

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in ((4, 64, 128), (4, 128, 32))]
    txt = jax.jit(g).lower(*args).compile().as_text()
    assert parse_hlo_cost(txt)["matmul_flops"] == 2 * 4 * 64 * 32 * 128


def test_collective_parser_on_crafted_hlo():
    hlo = """
ENTRY %main () -> f32[] {
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[2048]{0} all-gather(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = f32[512]{0} collective-permute(%z), source_target_pairs={{0,1}}
}
"""
    c = collective_bytes_from_hlo(hlo)
    assert c["all-reduce"] == 2 * 4096 * 3 / 4
    assert c["all-gather"] == 4096 * 1 / 2
    assert c["collective-permute"] == 2048
    assert c["n_ops"] == 3


def test_roofline_terms_and_dominance():
    hw = HW()
    r = roofline_terms(hlo_flops=197e12, hlo_bytes=819e9,
                       collective_wire_bytes=256 * 50e9 * 2, chips=256, hw=hw)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 1.0) < 1e-9
    assert abs(r["collective_s"] - 2.0) < 1e-9
    assert r["dominant"] == "collective"


def test_model_flops_6nd():
    cfg = get_config("internlm2-20b")
    sp = SHAPES["train_4k"]
    mf = model_flops(cfg, sp.seq_len, sp.global_batch, "train")
    n = cfg.param_count()
    assert abs(mf - 6 * n * sp.seq_len * sp.global_batch) / mf < 1e-9
    # MoE uses active params
    moe = get_config("grok-1-314b")
    act = model_flops(moe, sp.seq_len, sp.global_batch, "train")
    tot = 6 * moe.param_count() * sp.seq_len * sp.global_batch
    assert act < 0.5 * tot


def test_decode_flops_one_token():
    cfg = get_config("internlm2-1.8b")
    sp = SHAPES["decode_32k"]
    mf = model_flops(cfg, sp.seq_len, sp.global_batch, "decode")
    assert abs(mf - 2 * cfg.param_count() * sp.global_batch) / mf < 1e-9

"""Runtime subsystem: executor parity, admission bucketing, compile counts.

Pins the ISSUE-4 contract:

* all four executors produce bit-identical ``rslt``/``codes``/``svm_acc``
  for the same zoo and traffic (V ∈ {1, 4}, passthrough packets included);
* admission turns ragged batch sizes into power-of-two buckets — results
  bit-identical to unpadded single-engine classify, at most one trace per
  bucket;
* ``PipelinedExecutor`` memoizes compiled pipelines per ``n_micro`` (the old
  ``PipelinedPlane`` single-slot thrash);
* no ``src/repro`` module outside ``runtime/`` constructs a ``shard_map``
  classify loop;
* the multi-device story (4-switch pipeline, 2x2 and 1x4 meshes) runs in a
  subprocess with 8 emulated devices, per the conftest 1-device rule.
"""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.packets import PacketBatch, PacketType
from repro.core.plane import (
    PlaneProfile,
    SwitchEngine,
    empty_program,
    install_program,
)
from repro.core.translator import MID_SVM, translate
from repro.runtime import (
    AdaptiveBucketPolicy,
    DataplaneRuntime,
    PipelinedExecutor,
    SequentialPathExecutor,
    ShardedExecutor,
    SingleSwitchExecutor,
    bucket_ladder,
    bucket_size,
)
from repro.serving import ZooServer


def _profile(V: int) -> PlaneProfile:
    return PlaneProfile(max_features=36, max_trees=4, max_layers=6,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=V)


def _split_stages(progs, profile, n_dev):
    """Hand-rolled path split: each program's stages cut into n_dev
    contiguous blocks in stage order (layers ascend along the path, predict
    and voting land on the last owning device) — a planner-free stand-in for
    build_device_programs."""
    dps = []
    for d in range(n_dev):
        packed = empty_program(profile)
        for prog in progs:
            chunks = np.array_split(np.arange(len(prog.stages())), n_dev)
            stages = set(chunks[d].tolist())
            if stages:
                packed = install_program(packed, prog, profile,
                                         stages=stages, vid=prog.vid)
        dps.append(packed)
    return dps


def _mixed_traffic(X, V, n_trees, n_hyperplanes, tree_mid):
    """Mixed-version REQUEST traffic with a passthrough cohort carrying
    nonzero intermediates (those must come out bit-identical)."""
    B = X.shape[0]
    rng = np.random.default_rng(7)
    vids = rng.integers(0, V, B)
    is_svm = rng.random(B) < 0.3
    svm_slots = max(1, min(V, 2))
    vids = np.where(is_svm, vids % svm_slots, vids)
    mids = np.where(is_svm, MID_SVM, tree_mid)
    pb = PacketBatch.make_request(X, mid=mids, vid=vids, max_features=36,
                                  n_trees=n_trees,
                                  n_hyperplanes=n_hyperplanes,
                                  max_versions=V)
    ptype = np.where(rng.random(B) < 0.2, PacketType.FORWARD,
                     PacketType.REQUEST)
    ptype = np.where(rng.random(B) < 0.1, PacketType.RESPONSE, ptype)
    passthru = ptype != PacketType.REQUEST
    codes = np.where(passthru[:, None],
                     rng.integers(0, 2**10, (B, n_trees)), 0)
    acc = np.where(passthru[:, None],
                   rng.integers(-50, 50, (B, n_hyperplanes)), 0)
    rslt = np.where(passthru, rng.integers(0, 8, B), -1)
    return dataclasses.replace(
        pb,
        ptype=np.asarray(ptype, np.int32),
        codes=np.asarray(codes, np.uint32),
        svm_acc=np.asarray(acc, np.int32),
        rslt=np.asarray(rslt, np.int32),
    ), passthru


@pytest.fixture(scope="module", params=[1, 4], ids=["V1", "V4"])
def zoo(request, satdap):
    """(profile, full PackedProgram, programs, traffic, expected) per V."""
    V = request.param
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(V)
    trees = [DecisionTree(max_depth=3 + v % 3, max_leaf_nodes=8 + 8 * v)
             .fit(Xtr, ytr) for v in range(V)]
    svms = [LinearSVM(epochs=30 + 20 * v).fit(Xtr, ytr)
            for v in range(max(1, min(V, 2)))]
    progs = ([translate(m, vid=v) for v, m in enumerate(trees)]
             + [translate(m, vid=v) for v, m in enumerate(svms)])
    packed = empty_program(prof)
    for prog in progs:
        packed = install_program(packed, prog, prof, vid=prog.vid)
    pb, passthru = _mixed_traffic(Xte[:96], V, prof.max_trees,
                                  prof.max_hyperplanes, progs[0].mid)
    eng = SwitchEngine(prof)
    want = eng.classify(packed, pb)
    return prof, packed, progs, pb, passthru, want


# ---------------------------------------------------------------- parity
def test_four_executor_parity(zoo):
    """The acceptance pin: same zoo + same traffic -> bit-identical
    rslt/codes/svm_acc through every executor, passthrough included."""
    prof, packed, progs, pb, passthru, want = zoo
    n_classes = prof.max_classes
    executors = {
        "single": SingleSwitchExecutor(prof, packed=packed),
        "sequential": SequentialPathExecutor(
            _split_stages(progs, prof, 3), n_classes=n_classes),
        "pipelined": PipelinedExecutor([packed], n_classes=n_classes,
                                       n_micro=4),
        "sharded": ShardedExecutor([packed], n_classes=n_classes,
                                   n_ports=1, n_micro=2),
    }
    for name, ex in executors.items():
        out = DataplaneRuntime(ex).run(pb)
        for field in ("rslt", "codes", "svm_acc"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out, field)),
                np.asarray(getattr(want, field)),
                err_msg=f"{name}.{field} diverges from the single plane")
        # passthrough cohort: runtime padding/trim never disturbed it either
        np.testing.assert_array_equal(
            np.asarray(out.rslt)[passthru],
            np.asarray(pb.rslt)[passthru],
            err_msg=f"{name} touched forwarded traffic")


def test_sequential_executor_matches_eager_shim(zoo):
    """The jitted chain and the deprecated eager run_sequential shim are the
    same function."""
    from repro.core.distributed_plane import run_sequential

    prof, packed, progs, pb, _, _ = zoo
    dps = _split_stages(progs, prof, 3)
    jitted = SequentialPathExecutor(dps, n_classes=prof.max_classes)
    eager = run_sequential(dps, pb, n_classes=prof.max_classes)
    out = jitted.classify(pb)
    for field in ("rslt", "codes", "svm_acc"):
        np.testing.assert_array_equal(np.asarray(getattr(out, field)),
                                      np.asarray(getattr(eager, field)))


# ------------------------------------------------------------- admission
def test_bucket_size_policy():
    assert [bucket_size(b) for b in (1, 2, 3, 7, 63, 64, 65)] == \
        [1, 2, 4, 8, 64, 64, 128]
    # granularity g: buckets are g * 2^k
    assert [bucket_size(b, 4) for b in (1, 4, 5, 96)] == [4, 4, 8, 128]
    assert bucket_size(1, 6) == 6 and bucket_size(13, 6) == 24
    with pytest.raises(ValueError):
        bucket_size(0)
    with pytest.raises(ValueError):
        bucket_size(8, 0)


def test_ragged_admission_bit_identical_one_trace_per_bucket(satdap):
    """B ∈ {1, 7, 63, 64, 65}: runtime results == unpadded single-engine
    classify bit-for-bit, and the executor compiles at most one trace per
    power-of-two bucket (4 distinct buckets for the 5 sizes)."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(1)
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    prog = translate(dt)
    packed = install_program(empty_program(prof), prog, prof)

    rt = DataplaneRuntime(SingleSwitchExecutor(prof, packed=packed))
    ref_eng = SwitchEngine(prof)   # private: unpadded shapes trace freely
    sizes = (1, 7, 63, 64, 65)
    for B in sizes:
        pb = PacketBatch.make_request(Xte[:B], mid=prog.mid, max_features=36,
                                      n_trees=prof.max_trees,
                                      n_hyperplanes=prof.max_hyperplanes)
        got = rt.run(pb)
        want = ref_eng.classify(packed, pb)
        for field in ("rslt", "codes", "svm_acc"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, field)),
                np.asarray(getattr(want, field)),
                err_msg=f"B={B} {field} diverges from unpadded classify")
    buckets = {rt.bucket(B) for B in sizes}
    assert buckets == {1, 8, 64, 128}
    assert rt.cache_size() == len(buckets), \
        "admission must compile at most one trace per bucket"
    # replaying every size adds zero traces
    for B in sizes:
        pb = PacketBatch.make_request(Xte[:B], mid=prog.mid, max_features=36,
                                      n_trees=prof.max_trees,
                                      n_hyperplanes=prof.max_hyperplanes)
        rt.run(pb)
    assert rt.cache_size() == len(buckets)


def test_admission_edge_cases_no_extra_traces(satdap):
    """ISSUE-5 regressions on the admission boundary, checked against the
    same trace-counting hook (``cache_size``) as the bucketing test:

    * B = 0 (the async front's empty submit) short-circuits — nothing
      classified, nothing traced;
    * B exactly on a bucket boundary pads nothing and costs one trace;
    * B = 1 right after a large batch gets its own small bucket instead of
      riding the big one — and the whole sequence stays within the
      O(log B_max) trace bound."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(1)
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    prog = translate(dt)
    packed = install_program(empty_program(prof), prog, prof)
    rt = DataplaneRuntime(SingleSwitchExecutor(prof, packed=packed))

    def req(B):
        X = np.tile(Xte, (B // max(Xte.shape[0], 1) + 1, 1))[:B] \
            if B else Xte[:0]
        return PacketBatch.make_request(X, mid=prog.mid, max_features=36,
                                        n_trees=prof.max_trees,
                                        n_hyperplanes=prof.max_hyperplanes)

    # ---- B = 0: empty submit returns the empty batch untouched, no trace
    empty = rt.run(req(0))
    assert empty.batch == 0
    assert rt.cache_size() == 0, "an empty batch must not reach the executor"
    assert np.asarray(rt.results(req(0))).shape == (0,)

    # ---- B on the bucket boundary: zero padding, one trace
    assert rt.bucket(64) == 64
    out = rt.run(req(64))
    assert out.batch == 64
    assert rt.cache_size() == 1

    # ---- B = 1 after a large batch: own bucket, no thrash on replay
    big = rt.run(req(512))
    assert big.batch == 512 and rt.bucket(512) == 512
    one = rt.run(req(1))
    assert one.batch == 1 and rt.bucket(1) == 1
    assert np.asarray(one.rslt)[0] == dt.predict(np.asarray(Xte[:1]))[0]
    assert rt.cache_size() == 3          # buckets {64, 512, 1}
    for B in (1, 64, 512, 1):            # replays mint nothing
        rt.run(req(B))
    assert rt.cache_size() == 3
    # O(log B) bound: traces never exceed log2(max bucket) + 1
    assert rt.cache_size() <= int(np.log2(512)) + 1


def test_bucket_ladder_is_the_trace_bound():
    """The ladder enumerates exactly the shapes admission can produce up to
    max_batch — its length IS the O(log B) trace bound serving fronts warm
    against."""
    assert bucket_ladder(1) == (1,)
    assert bucket_ladder(5) == (1, 2, 4, 8)
    assert bucket_ladder(8) == (1, 2, 4, 8)
    assert bucket_ladder(65) == (1, 2, 4, 8, 16, 32, 64, 128)
    assert bucket_ladder(5, 4) == (4, 8)          # granularity floors the rungs
    for max_batch, g in ((1, 1), (7, 1), (64, 1), (65, 1), (13, 4)):
        ladder = bucket_ladder(max_batch, g)
        assert ladder[-1] == bucket_size(max_batch, g)
        assert all(b == 2 * a for a, b in zip(ladder, ladder[1:]))
        assert len(ladder) <= int(np.log2(max(max_batch, 2))) + 2


def test_warm_pretaces_ladder_then_live_traffic_compiles_nothing(satdap):
    """``DataplaneRuntime.warm`` drives every bucket through the run_host
    hot path once; afterwards arbitrary ragged live sizes mint zero new
    traces — the continuous engine's no-first-touch-compile guarantee."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(1)
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    prog = translate(dt)
    packed = install_program(empty_program(prof), prog, prof)
    rt = DataplaneRuntime(SingleSwitchExecutor(prof, packed=packed))

    def req(B):
        X = np.tile(Xte, (B // max(Xte.shape[0], 1) + 1, 1))[:B]
        return PacketBatch.make_request(X, mid=prog.mid, max_features=36,
                                        n_trees=prof.max_trees,
                                        n_hyperplanes=prof.max_hyperplanes)

    ladder = rt.warm(req, 65)
    assert ladder == bucket_ladder(65, 1)
    assert rt.cache_size() == len(ladder)
    for B in (1, 3, 7, 63, 65, 100, 128):         # live ragged traffic
        out = rt.run_host(req(B))
        assert out.batch == B
    assert rt.cache_size() == len(ladder), \
        "a live dispatch compiled a shape the warm ladder should have owned"


def test_adaptive_policy_snapback_keeps_trace_bound(satdap):
    """Burst -> widen -> deadline flush -> snap back, driven through a real
    runtime: the target rides admission buckets the whole way, the snap
    lands back on the small bucket (no per-dispatch deadline tax on the
    trickle), and the full trajectory stays inside O(log B) traces."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(1)
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    prog = translate(dt)
    packed = install_program(empty_program(prof), prog, prof)
    rt = DataplaneRuntime(SingleSwitchExecutor(prof, packed=packed))
    policy = AdaptiveBucketPolicy(min_batch=1, max_batch=64,
                                  max_wait_us=1_000.0, alpha=0.5)

    def req(B):
        X = np.tile(Xte, (B // max(Xte.shape[0], 1) + 1, 1))[:B]
        return PacketBatch.make_request(X, mid=prog.mid, max_features=36,
                                        n_trees=prof.max_trees,
                                        n_hyperplanes=prof.max_hyperplanes)

    def dispatch(queued, waited_us):
        b = policy.drain(queued)
        rt.run_host(req(b))
        policy.note_dispatch(b, waited_us)
        return policy.target_batch

    targets = [dispatch(48, 500.0) for _ in range(8)]   # sustained burst
    assert targets[-1] == 64, "sustained load must widen to the top bucket"
    # load drops: ONE deadline flush below target snaps the estimate down
    assert dispatch(2, 1_500.0) == 2
    assert policy.wait_us(2, 0.0) <= 0                  # trickle cuts at once
    targets += [dispatch(1, 100.0) for _ in range(4)]
    assert targets[-1] == 1
    # every target along the widen/snap trajectory was an admission bucket
    assert all(t == bucket_size(t, 1) for t in targets)
    # the whole trajectory minted only the buckets it actually dispatched
    assert rt.cache_size() == 3                         # {64, 2, 1}
    assert rt.cache_size() <= int(np.log2(64)) + 1


# ----------------------------------------------- pipelined compile thrash
def test_pipelined_memoizes_per_n_micro(satdap):
    """Alternating microbatch counts reuses each compiled pipeline instead
    of rebuilding (the old PipelinedPlane kept one slot and thrashed it)."""
    Xtr, ytr, Xte, _ = satdap
    prof = _profile(1)
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    packed = install_program(empty_program(prof), translate(dt), prof)
    ex = PipelinedExecutor([packed], n_classes=prof.max_classes)

    import jax
    X = Xte[:32]
    pb = PacketBatch.make_request(X, mid=0, max_features=36,
                                  n_trees=prof.max_trees,
                                  n_hyperplanes=prof.max_hyperplanes)
    def mbs(n_micro):
        return jax.tree.map(
            lambda x: x.reshape((n_micro, X.shape[0] // n_micro)
                                + x.shape[1:]), pb)

    want = dt.predict(X)
    for n_micro in (2, 4, 2, 4, 2):
        out = ex.run(mbs(n_micro))
        assert (np.asarray(out.rslt) == want).all()
    assert set(ex._runs) == {2, 4}, "one compiled pipeline per n_micro"
    assert ex.cache_size() == 2, \
        "revisiting an n_micro must reuse its pipeline, not rebuild"


# ------------------------------------------------------------ device_out
def test_zooserver_device_out_skips_host_round_trip(satdap):
    import jax

    Xtr, ytr, Xte, _ = satdap
    zoo = ZooServer(_profile(1))
    zoo.install(DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr),
                vid=0)
    X = Xte[:40]
    host = zoo.classify(X, mid=0, vid=0)
    dev = zoo.classify(X, mid=0, vid=0, device_out=True)
    assert isinstance(dev, PacketBatch)
    assert isinstance(dev.rslt, jax.Array)
    assert dev.batch == X.shape[0]
    np.testing.assert_array_equal(host, np.asarray(dev.rslt))


# ------------------------------------------------- shard_map containment
def test_no_shard_map_outside_runtime(tmp_path):
    """Only repro.runtime may construct a shard_map classify loop — now a
    thin wrapper over planelint rule PL001 (the single source of truth;
    ARCHITECTURE 'Static contracts'): the shipped tree must be clean, and
    the rule must actually fire on an out-of-runtime offender."""
    from repro.analysis.lint import run_lint

    root = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    findings, checked = run_lint([root], ["PL001"])
    assert checked > 0
    assert not findings, "shard_map classify loops must live in " \
        f"repro/runtime: {[f.format() for f in findings]}"

    # The rule is live: a fixture module outside runtime/ is one finding.
    bad = tmp_path / "serving" / "rogue.py"
    bad.parent.mkdir()
    bad.write_text("from jax.experimental.shard_map import shard_map\n")
    findings, _ = run_lint([tmp_path])
    assert [f.rule for f in findings] == ["PL001"]
    assert findings[0].line == 1


# ------------------------------------------------------- multi-device
MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import numpy as np, jax
    from repro.core.mlmodels import DecisionTree, RandomForest, Quantizer
    from repro.core.packets import PacketBatch, PacketType
    from repro.core.plane import (PlaneProfile, SwitchEngine, empty_program,
                                  install_program)
    from repro.core.translator import translate
    from repro.data import load_dataset
    from repro.runtime import (DataplaneRuntime, PipelinedExecutor,
                               SequentialPathExecutor, ShardedExecutor,
                               SingleSwitchExecutor)

    assert len(jax.devices()) == 8, jax.devices()
    Xtr, ytr, Xte, yte = load_dataset("satdap", scale=0.15)
    q = Quantizer(8).fit(Xtr)
    Xtrq, Xteq = q.transform(Xtr), q.transform(Xte)
    prof = PlaneProfile(max_features=36, max_trees=4, max_layers=8,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=2)
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30,
                      random_state=0).fit(Xtrq, ytr)
    d1 = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtrq, ytr)
    progs = [translate(rf, vid=0), translate(d1, vid=1)]

    def split(n_dev):
        dps = []
        for d in range(n_dev):
            packed = empty_program(prof)
            for prog in progs:
                chunks = np.array_split(np.arange(len(prog.stages())), n_dev)
                st = set(chunks[d].tolist())
                if st:
                    packed = install_program(packed, prog, prof,
                                             stages=st, vid=prog.vid)
            dps.append(packed)
        return dps

    full = empty_program(prof)
    for prog in progs:
        full = install_program(full, prog, prof, vid=prog.vid)

    B = 192
    X = np.tile(Xteq, (B // Xteq.shape[0] + 1, 1))[:B]
    rng = np.random.default_rng(5)
    vids = rng.integers(0, 2, B)
    mids = np.where(vids == 0, progs[0].mid, progs[1].mid)
    pb = PacketBatch.make_request(X, mid=mids, vid=vids,
                                  max_features=36, n_trees=4,
                                  n_hyperplanes=8, max_versions=2)
    ptype = np.where(rng.random(B) < 0.2, PacketType.FORWARD,
                     PacketType.REQUEST).astype(np.int32)
    pb = dataclasses.replace(pb, ptype=ptype)

    eng = SwitchEngine(prof)
    want = eng.classify(full, pb)

    runtimes = {
        "single": DataplaneRuntime(SingleSwitchExecutor(prof, packed=full)),
        "seq4": DataplaneRuntime(SequentialPathExecutor(
            split(4), n_classes=8)),
        "pipe4x1": DataplaneRuntime(PipelinedExecutor(
            split(4), n_classes=8, n_micro=4)),
        "shard2x2": DataplaneRuntime(ShardedExecutor(
            split(2), n_classes=8, n_ports=2, n_micro=2)),
        "shard1x4": DataplaneRuntime(ShardedExecutor(
            [full], n_classes=8, n_ports=4, n_micro=1)),
    }
    res = {}
    for name, rt in runtimes.items():
        out = rt.run(pb)
        ok = all(
            bool((np.asarray(getattr(out, f))
                  == np.asarray(getattr(want, f))).all())
            for f in ("rslt", "codes", "svm_acc"))
        # ragged re-admission on the same runtime: a second bucket at most
        out2 = rt.run(jax.tree.map(lambda x: x[:100], pb))
        ok2 = bool((np.asarray(out2.rslt)
                    == np.asarray(want.rslt)[:100]).all())
        res[name] = bool(ok and ok2)
    print(json.dumps(res))
""")


@pytest.mark.slow
def test_runtime_parity_multi_device_subprocess():
    """Full multi-device story on 8 emulated devices (subprocess per the
    conftest 1-device rule): 4-hop sequential path, 4x1 pipeline, 2x2 and
    1x4 (switch x port) meshes — all bit-identical to the single plane."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(res.values()), res

"""Sharding rules: every arch gets valid, divisible specs on both meshes —
without touching jax device state (duck-typed mesh)."""
import types

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import param_specs, state_specs
from repro.models.transformer import init_decode_state, init_params_shape

import jax


def _mesh(shape, axes):
    return types.SimpleNamespace(axis_names=axes, devices=np.empty(shape))

MESHES = [
    _mesh((16, 16), ("data", "model")),
    _mesh((2, 16, 16), ("pod", "data", "model")),
]


def _check(shapes, specs, mesh):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat_sh = jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    n_sharded = 0
    for sd, spec in zip(flat_sh, flat_sp):
        assert len(spec) <= len(sd.shape), (sd.shape, spec)
        for dim, ax in zip(sd.shape, spec):
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                assert dim % mesh_shape[a] == 0, (sd.shape, spec)
                n_sharded += 1
    return n_sharded


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = init_params_shape(cfg)
    specs = param_specs(cfg, mesh)
    n = _check(shapes, specs, mesh)
    assert n > 0  # something actually shards


@pytest.mark.parametrize("arch", ["internlm2-20b", "chameleon-34b", "rwkv6-7b",
                                  "recurrentgemma-2b"])
def test_state_specs_divisible_and_cache_sharded(arch):
    cfg = get_config(arch)
    mesh = MESHES[0]
    shapes = jax.eval_shape(lambda: init_decode_state(cfg, 128, 32768))
    specs = state_specs(cfg, mesh, False, batch=128, cache_len=32768)
    _check(shapes, specs, mesh)
    if cfg.family in ("dense", "moe"):
        # split-KV default: the big cache must be sharded over model somehow
        k_spec = jax.tree.leaves(
            {"k": specs["k"]}, is_leaf=lambda x: isinstance(x, P))[0]
        assert "model" in [a for ax in k_spec if ax for a in
                           (ax if isinstance(ax, tuple) else (ax,))]


def test_batch1_long_context_degrades_gracefully():
    cfg = get_config("rwkv6-7b")
    mesh = MESHES[0]
    shapes = jax.eval_shape(lambda: init_decode_state(cfg, 1, 16))
    specs = state_specs(cfg, mesh, False, batch=1, cache_len=16)
    _check(shapes, specs, mesh)  # no divisibility violations at batch 1

"""End-to-end behaviour: the paper's full workflow on one network.

train (Python model) -> translate -> ILP plan -> distribute entries ->
classify in-network -> agree with the server-side model (Cohen's kappa = 1
for trees — paper Tables 4/5's headline property).
"""
import numpy as np
import pytest

from repro.core.distributed_plane import build_device_programs, run_sequential
from repro.core.mlmodels import (
    DecisionTree,
    LinearSVM,
    RandomForest,
    cohen_kappa,
)
from repro.core.netsim import acorn_serving_time
from repro.core.packets import PacketBatch
from repro.core.planner import DeviceModel, plan_program, replan
from repro.core.topology import fat_tree
from repro.core.translator import translate

pytestmark = pytest.mark.slow  # full train->plan->deploy->classify workflows


# Profile comes from the session-scoped plane_profile fixture (conftest) so
# this module shares the plane_engine jit cache with the other plane tests.
def _deploy_and_classify(model, net, src, dst, Xte, dev, prof):
    prog = translate(model)
    plan = plan_program(prog, net, src, dst, default_device=dev, solver="dp")
    _, dps = build_device_programs(prog, plan, prof)
    pb = PacketBatch.make_request(Xte, mid=prog.mid,
                                  max_features=prof.max_features,
                                  n_trees=prof.max_trees,
                                  n_hyperplanes=prof.max_hyperplanes)
    out = run_sequential(dps, pb, n_classes=prof.max_classes)
    return np.asarray(out.rslt), plan


def test_full_workflow_all_model_types(satdap, plane_profile):
    Xtr, ytr, Xte, yte = satdap
    net = fat_tree(4)
    h = net.hosts()
    dev = DeviceModel(n_stages=6)

    dt = DecisionTree(max_depth=8, max_leaf_nodes=90).fit(Xtr, ytr)
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=40).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)

    for model, exact in ((dt, True), (rf, True), (svm, False)):
        got, plan = _deploy_and_classify(model, net, h[0], h[1], Xte, dev,
                                         plane_profile)
        want = model.predict(Xte)
        k = cohen_kappa(got, want)
        if exact:
            assert k == 1.0, type(model).__name__
        else:
            assert k > 0.9
        assert acorn_serving_time(plan) < 1e-3


def test_failure_recovery_end_to_end(satdap, plane_profile):
    """A switch dies: replan, reinstall, answers unchanged (beyond paper §9)."""
    Xtr, ytr, Xte, _ = satdap
    net = fat_tree(4)
    h = net.hosts()
    dev = DeviceModel(n_stages=4)
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=40).fit(Xtr, ytr)
    prog = translate(rf)
    plan = plan_program(prog, net, h[0], h[-1], default_device=dev, solver="dp")
    used = plan.breakdown["devices_used"]
    failed = {used[1]}  # mid-path device (edge switches are cut vertices)
    plan2 = replan(prog, net, h[0], h[-1], failed, default_device=dev, solver="dp")
    assert not (set(plan2.breakdown["devices_used"]) & failed)
    _, dps = build_device_programs(prog, plan2, plane_profile)
    pb = PacketBatch.make_request(Xte, mid=prog.mid,
                                  max_features=plane_profile.max_features,
                                  n_trees=plane_profile.max_trees,
                                  n_hyperplanes=plane_profile.max_hyperplanes)
    out = run_sequential(dps, pb, n_classes=plane_profile.max_classes)
    assert (np.asarray(out.rslt) == rf.predict(Xte)).all()


def test_multi_tenant_two_models_one_network(satdap, plane_engine):
    """Two tenants (a forest and an SVM) share the same plane (paper §9
    multi-tenancy): both classify correctly from the same installed state."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    rf = RandomForest(n_estimators=3, max_depth=5, max_leaf_nodes=40).fit(Xtr, ytr)
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    packed = eng.install(eng.install(eng.empty(), translate(rf)), translate(svm))
    rf_pb = PacketBatch.make_request(Xte, mid=1, max_features=36, n_trees=5,
                                     n_hyperplanes=8)
    svm_pb = PacketBatch.make_request(Xte, mid=2, max_features=36, n_trees=5,
                                      n_hyperplanes=8)
    eng.classify(packed, rf_pb)  # warm this batch shape (shared session engine)
    before = eng.cache_size()
    assert (np.asarray(eng.classify(packed, rf_pb).rslt) == rf.predict(Xte)).all()
    assert (np.asarray(eng.classify(packed, svm_pb).rslt)
            == svm.predict(Xte)).mean() > 0.97
    assert eng.cache_size() == before

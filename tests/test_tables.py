"""Table-type unit + property tests (paper §4, §6).

Property-style cases are driven by seeded-numpy parametrization / exhaustive
sweeps (no hypothesis dependency in this container — equivalent coverage).
"""
import numpy as np
import pytest

from repro.core.mlmodels import LinearSVM, RandomForest
from repro.core.tables import (
    DtLayerTable,
    DtPredictTable,
    SvmPredictTable,
    VotingTable,
    range_to_prefixes,
    tcam_entries_for_le_range,
)


# ---------------------------------------------------------------- prefixes
def test_prefix_expansion_exact_cover():
    """Expanded prefixes match exactly the integers in [lo, hi] — the TCAM
    correctness invariant behind every entry count in the paper.  Seeded
    random [lo, hi] pairs plus the degenerate corners."""
    rng = np.random.default_rng(0)
    pairs = [tuple(sorted(p)) for p in rng.integers(0, 256, (200, 2)).tolist()]
    pairs += [(0, 0), (0, 255), (255, 255), (127, 128)]
    for lo, hi in pairs:
        pref = range_to_prefixes(lo, hi, 8)
        for x in range(256):
            hit = any((x & m) == v for v, m in pref)
            assert hit == (lo <= x <= hi), (lo, hi, x)


def test_le_range_at_most_width_prefixes():
    for t in range(256):  # exhaustive over the 8-bit threshold domain
        assert tcam_entries_for_le_range(t, 8) <= 8


def test_prefix_empty_range():
    assert range_to_prefixes(5, 4, 8) == []


# ---------------------------------------------------------------- dt_layer
def test_dt_layer_priority_and_fallthrough():
    # node at depth 1, path bit0=1: test feature 0 <= 10
    tbl = DtLayerTable(
        layer=1, tree=0,
        code_value=np.array([1, 1], np.uint32),
        code_mask=np.array([1, 1], np.uint32),
        fid=np.array([0, 0], np.int32),
        f_lo=np.array([0, 0], np.int32),
        f_hi=np.array([10, 255], np.int32),
        priority=np.array([1, 0], np.int32),
        set_bit=np.array([0, 1], np.uint8),
    )
    codes = np.array([1, 1, 0], np.uint32)        # third packet: code miss
    feats = np.array([[5], [50], [5]], np.int32)
    out = tbl.lookup(codes, feats)
    assert out[0] == 1          # <=10 -> bit1 stays 0
    assert out[1] == 1 | (1 << 1)  # catch-all -> bit1 set
    assert out[2] == 0          # code mismatch: falls through unchanged


def test_dt_predict_rejects_duplicate_codes():
    with pytest.raises(ValueError):
        DtPredictTable(tree=0, codes=np.array([3, 3], np.uint32),
                       labels=np.array([0, 1], np.int32))


# ------------------------------------------------------------------ voting
@pytest.mark.parametrize("n_classes", [2, 3, 4])
@pytest.mark.parametrize("n_trees", [1, 2, 3, 4])
@pytest.mark.parametrize("seed", [0, 17])
def test_voting_table_matches_forest_vote(n_classes, n_trees, seed):
    rng = np.random.default_rng(seed)
    votes = rng.integers(0, n_classes, size=(50, n_trees))
    vt = VotingTable.build(n_trees, n_classes)
    rf = RandomForest.__new__(RandomForest)
    rf.n_classes_ = n_classes
    rf.tree_weights = None
    rf.trees_ = [None] * n_trees
    assert (vt.lookup(votes) == rf.vote(votes)).all()


def test_voting_table_fallback_when_huge():
    vt = VotingTable.build(16, 10, max_materialized=1000)  # 10^16 entries
    assert vt.table is None and vt.n_entries == 0
    votes = np.tile(np.arange(16) % 10, (3, 1))
    assert vt.lookup(votes).shape == (3,)


# --------------------------------------------------------------- svm tables
def test_svm_predict_table_matches_vote_fn(iris):
    Xtr, ytr, Xte, _ = iris
    svm = LinearSVM(epochs=50).fit(Xtr, ytr)
    tbl = SvmPredictTable.build(np.asarray(svm.pairs_, np.int32),
                                svm.n_classes_, svm.votes_from_signs)
    signs = svm.decision_signs(Xte)
    assert (tbl.lookup(signs) == svm.votes_from_signs(signs)).all()
    # computed fallback gives the same answers
    tbl2 = SvmPredictTable(svm.n_hyperplanes, svm.n_classes_,
                           np.asarray(svm.pairs_, np.int32), None)
    assert (tbl2.lookup(signs) == tbl.lookup(signs)).all()

"""Topology builders match the published size formulas (paper Table 6)."""
import pytest

from repro.core.topology import bcube, dcell, fat_tree, jellyfish


@pytest.mark.parametrize("k", [4, 8])
def test_fat_tree_counts(k):
    net = fat_tree(k)
    assert net.n_switches == 5 * k * k // 4
    assert net.n_hosts == k * (k // 2)  # hosts_per_edge=1


def test_dcell_counts():
    net = dcell(3, 1)  # DCell_1: (3+1) cells of 3 servers
    assert net.n_hosts == 12
    assert net.n_switches == 4


@pytest.mark.parametrize("n,k", [(3, 1), (4, 1)])
def test_bcube_counts(n, k):
    net = bcube(n, k)
    assert net.n_hosts == n ** (k + 1)
    assert net.n_switches == (k + 1) * n**k


def test_jellyfish_regular():
    net = jellyfish(20, 3, hosts=4)
    assert net.n_switches == 20
    degs = [len([v for v in net.adj[s] if net.kind[v] == "switch"])
            for s in net.switches()]
    assert max(degs) <= 4 and min(degs) >= 2  # d=3 modulo host attach + patching


def test_paths_are_simple_and_connected():
    net = fat_tree(4)
    h = net.hosts()
    paths = net.k_shortest_paths(h[0], h[-1], 4)
    assert len(paths) >= 2
    for p in paths:
        assert p[0] == h[0] and p[-1] == h[-1]
        assert len(set(p)) == len(p)  # loop-free
        for a, b in zip(p, p[1:]):
            assert b in net.adj[a]
    assert sorted(len(p) for p in paths) == [len(p) for p in paths]

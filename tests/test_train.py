"""Training substrate: grad-accum equivalence, AdamW, checkpoint fault
tolerance, data-pipeline resumability."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data import TokenPipeline
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.train.checkpoint import Checkpointer
from repro.train.step import loss_fn, make_train_step


def _setup(arch="internlm2-1.8b"):
    cfg = smoke_config(arch)
    p = init_params(cfg, jax.random.key(0))
    tp = TokenPipeline(vocab_size=cfg.vocab, seq_len=16, global_batch=8)
    return cfg, p, tp


def test_grad_accum_equals_single_batch():
    """n_micro=4 microbatches produce the same update as one big batch."""
    cfg, p, tp = _setup()
    ocfg = AdamWConfig(lr=1e-3)
    b = tp.next_batch()
    toks = jnp.asarray(b["tokens"])
    labs = jnp.asarray(b["labels"])
    opt = adamw_init(p, ocfg)

    s1 = make_train_step(cfg, ocfg, n_micro=1)
    s4 = make_train_step(cfg, ocfg, n_micro=4)
    p1, _, m1 = jax.jit(s1)(p, opt, {"tokens": toks[None], "labels": labs[None]})
    p4, _, m4 = jax.jit(s4)(
        p, adamw_init(p, ocfg),
        {"tokens": toks.reshape(4, 2, -1), "labels": labs.reshape(4, 2, -1)})
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), p1, p4)
    assert max(jax.tree.leaves(d)) < 3e-2  # bf16 params: one-ulp scale


def test_loss_decreases():
    cfg, p, tp = _setup()
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    opt = adamw_init(p, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, n_micro=1))
    losses = []
    for _ in range(15):
        b = tp.next_batch()
        p, opt, m = step(p, opt, {"tokens": jnp.asarray(b["tokens"])[None],
                                  "labels": jnp.asarray(b["labels"])[None]})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_adamw_matches_reference_math():
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0], jnp.float32)}
    g = {"w": jnp.asarray([0.5, 0.5], jnp.float32)}
    opt = adamw_init(p, ocfg)
    p2, opt2, _ = adamw_update(g, opt, p, ocfg)
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.05 * 0.25 / (1 - 0.95)
    want = 1.0 - 0.1 * lr_schedule(ocfg, jnp.int32(1)) / ocfg.lr * ocfg.lr * (
        m / (np.sqrt(v) + ocfg.eps)) / 1.0
    # simpler: direct formula
    lr = float(lr_schedule(ocfg, jnp.int32(1)))
    want = 1.0 - lr * (m / (np.sqrt(v) + ocfg.eps))
    np.testing.assert_allclose(float(p2["w"][0]), want, rtol=1e-5)


def test_bf16_state_halves_memory():
    cfg, p, _ = _setup()
    o32 = adamw_init(p, AdamWConfig(state_dtype="float32"))
    o16 = adamw_init(p, AdamWConfig(state_dtype="bfloat16"))
    b32 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(o32["m"]))
    b16 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(o16["m"]))
    assert b16 * 2 == b32


def test_checkpoint_roundtrip_and_retention(tmp_path):
    cfg, p, tp = _setup()
    ocfg = AdamWConfig()
    opt = adamw_init(p, ocfg)
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3):
        tp.next_batch()
        ck.save(s, p, opt, extra={"data": tp.state_dict()})
    assert ck.all_steps() == [2, 3]  # retention
    step, p2, opt2, extra = ck.restore(p, opt)
    assert step == 3 and extra["data"]["cursor"] == 3
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                      np.asarray(b, dtype=np.float32))
    # resume: pipeline continues exactly where it left off
    tp2 = TokenPipeline(vocab_size=cfg.vocab, seq_len=16, global_batch=8)
    tp2.load_state_dict(extra["data"])
    np.testing.assert_array_equal(tp2.next_batch()["tokens"],
                                  tp.next_batch()["tokens"])


def test_checkpoint_atomic_no_partial(tmp_path):
    cfg, p, _ = _setup()
    opt = adamw_init(p, AdamWConfig())
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(7, p, opt)
    ck.wait()
    names = os.listdir(tmp_path)
    assert "step_0000000007" in names
    assert not any(n.endswith(".tmp") for n in names)


def test_pipeline_elastic_resharding():
    """Same global stream under a different shard layout (elastic scaling)."""
    tp_all = TokenPipeline(vocab_size=100, seq_len=8, global_batch=8)
    full = tp_all.batch_at(5)["tokens"]
    shards = [
        TokenPipeline(vocab_size=100, seq_len=8, global_batch=8,
                      shard=i, num_shards=4).batch_at(5)["tokens"]
        for i in range(4)
    ]
    np.testing.assert_array_equal(full, np.concatenate(shards, axis=0))

"""Translator correctness: the table program IS the model (paper §4).

The central invariant: walking the generated dt_layer tables layer by layer
with the numpy oracle, then exact-matching dt_predict, reproduces
``DecisionTree.predict`` bit-for-bit — including early-leaf fall-through
(prefix-freeness, see tables.py docstring).  Seeded-numpy parametrization
drives random trees (no hypothesis dependency in this container).
"""
import numpy as np
import pytest

from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.translator import translate
from repro.data import make_classification


def _run_tree_tables(prog, tree_idx, Xq):
    codes = np.zeros(Xq.shape[0], np.uint32)
    for tbl in prog.dt_layers[tree_idx]:
        codes = tbl.lookup(codes, Xq)
    return prog.dt_predicts[tree_idx].lookup(codes), codes


_DT_CASES = [
    # (seed, n_classes, depth) — seeded sweep over the hypothesis ranges
    (int(s), int(c), int(d))
    for s, c, d in zip(
        np.random.default_rng(7).integers(0, 10_000, 25),
        np.random.default_rng(8).integers(2, 6, 25),
        np.random.default_rng(9).integers(2, 9, 25),
    )
]


@pytest.mark.parametrize("seed,n_classes,depth", _DT_CASES)
def test_dt_tables_equal_model(seed, n_classes, depth):
    X, y = make_classification(300, 6, n_classes, seed=seed)
    Xq = np.clip((X * 16 + 128).astype(np.int64), 0, 255)
    dt = DecisionTree(max_depth=depth, max_leaf_nodes=40).fit(Xq, y)
    prog = translate(dt)
    got, codes = _run_tree_tables(prog, 0, Xq)
    want = dt.predict(Xq)
    assert (got == want).all()
    # status codes match the model's own decision-path codes
    _, want_codes = dt.decision_path_codes(Xq)
    assert (codes == want_codes.astype(np.uint32)).all()


def test_rf_tables_equal_model(satdap):
    Xtr, ytr, Xte, _ = satdap
    rf = RandomForest(n_estimators=5, max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    prog = translate(rf)
    votes = np.stack(
        [_run_tree_tables(prog, t, Xte)[0] for t in range(prog.n_trees)], axis=1)
    got = prog.voting.lookup(votes)
    assert (got == rf.predict(Xte)).all()


def test_svm_tables_equal_model(satdap):
    Xtr, ytr, Xte, _ = satdap
    svm = LinearSVM(epochs=100).fit(Xtr, ytr)
    prog = translate(svm)
    H, F = svm.n_hyperplanes, svm.n_features_
    sums = np.array(prog.svm_bias, np.int64)[None, :].repeat(Xte.shape[0], 0)
    for m in prog.svm_muls:
        sums[:, m.hyperplane] += m.lookup(Xte[:, m.feature])
    signs = (sums >= 0).astype(np.int64)
    got = prog.svm_predict.lookup(signs)
    # fixed-point signs match float signs except within quantization slack
    agree = (got == svm.predict(Xte)).mean()
    assert agree > 0.97


def test_stage_accounting(satdap):
    Xtr, ytr, _, _ = satdap
    rf = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30).fit(Xtr, ytr)
    prog = translate(rf)
    specs = prog.stages()
    # trees two-per-block (paper Fig. 5): block stages then predict + voting
    layer_stages = [s for s in specs if any(t.kind == "dt_layer" for t in s.tables)]
    assert all(len(s.tables) <= 2 for s in layer_stages)
    assert specs[-2].tables[0].kind == "dt_predict"
    assert specs[-1].tables[0].kind == "multitree_voting"
    # svm stages never straddle hyperplanes (colocation integrity)
    svm = LinearSVM(epochs=30).fit(Xtr, ytr)
    sprog = translate(svm)
    for s in sprog.stages():
        assert len({t.hyperplane for t in s.tables if t.kind == "svm_mul"}) <= 1


def test_translate_rejects_unknown():
    with pytest.raises(TypeError):
        translate(object())

"""Model zoo: per-packet (MID, VID) dispatch over a multi-version data plane.

Covers the Appendix A VID axis end to end: ≥4 concurrent versions on one
engine, mixed batches bit-identical to single-model references, compile-once
across install/swap/evict cycles, empty-slot and out-of-range VID semantics,
version-indexed kernel parity (Pallas interpret vs ref), and the distributed
per-version deployment (plan_zoo + merged per-device zoos).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed_plane import build_zoo_device_programs, run_sequential
from repro.core.mlmodels import DecisionTree, LinearSVM, RandomForest
from repro.core.packets import PacketBatch
from repro.core.plane import PlaneProfile, SwitchEngine
from repro.core.planner import DeviceModel, plan_zoo
from repro.core.topology import fat_tree
from repro.core.translator import MID_SVM, translate
from repro.kernels import ops, ref


def _req(eng, X, *, mid=0, vid=0, validate=True):
    prof = eng.profile
    return PacketBatch.make_request(
        X, mid=mid, vid=vid, max_features=prof.max_features,
        n_trees=prof.max_trees, n_hyperplanes=prof.max_hyperplanes,
        max_versions=prof.max_versions if validate else None)


# ------------------------------------------------------------------ dispatch
def test_four_versions_mixed_batch_matches_references(satdap, plane_engine):
    """One engine, four resident tree versions + two SVM versions; a single
    mixed batch dispatches per packet by (MID, VID) and every packet's answer
    equals its own model's CPU prediction."""
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    trees = [
        DecisionTree(max_depth=3, max_leaf_nodes=8).fit(Xtr, ytr),
        DecisionTree(max_depth=8, max_leaf_nodes=100).fit(Xtr, ytr),
        RandomForest(n_estimators=5, max_depth=5, max_leaf_nodes=40,
                     random_state=0).fit(Xtr, ytr),
        RandomForest(n_estimators=3, max_depth=6, max_leaf_nodes=50,
                     random_state=1).fit(Xtr, ytr),
    ]
    svms = [LinearSVM(epochs=100).fit(Xtr, ytr),
            LinearSVM(epochs=30).fit(Xtr, ytr)]
    packed = eng.empty()
    for v, m in enumerate(trees):
        packed = eng.install(packed, translate(m, vid=v))
    for v, m in enumerate(svms):
        packed = eng.install(packed, translate(m, vid=v))

    B = Xte.shape[0]
    rng = np.random.default_rng(3)
    vids = rng.integers(0, 4, B)
    is_svm = rng.random(B) < 0.3
    vids = np.where(is_svm, vids % 2, vids)
    mids = np.where(is_svm, MID_SVM, np.array([translate(m).mid for m in trees])[vids])
    pb = _req(eng, Xte, mid=mids, vid=vids)
    got = np.asarray(eng.classify(packed, pb).rslt)

    tree_preds = np.stack([m.predict(Xte) for m in trees])
    svm_preds = np.stack([m.predict(Xte) for m in svms])
    want = tree_preds[vids, np.arange(B)]
    svm_vids = np.where(is_svm, vids, 0)
    got_svm, want_svm = got[is_svm], svm_preds[svm_vids, np.arange(B)][is_svm]
    # trees are bit-exact; SVM has fixed-point quantization slack
    assert (got[~is_svm] == want[~is_svm]).all()
    assert (got_svm == want_svm).mean() > 0.97
    # and each version individually, pure batches, bit-identical to the
    # single-model reference output
    for v, m in enumerate(trees):
        out = eng.classify(packed, _req(eng, Xte, mid=translate(m).mid, vid=v))
        assert (np.asarray(out.rslt) == m.predict(Xte)).all(), f"vid {v}"


def test_install_swap_evict_cycles_zero_retrace(satdap):
    """cache_size() == 1 across three full install → swap → evict cycles
    (the paper's §6 compile-once property along the VID axis)."""
    Xtr, ytr, Xte, _ = satdap
    prof = PlaneProfile(max_features=36, max_trees=2, max_layers=6,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=4)
    eng = SwitchEngine(prof)
    X = Xte[:128]
    d_a = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    d_b = DecisionTree(max_depth=5, max_leaf_nodes=30).fit(Xtr, ytr)
    packed = eng.empty()
    for cycle in range(3):
        vid = cycle % prof.max_versions
        packed = eng.install(packed, translate(d_a), vid=vid)        # install
        out = eng.classify(packed, _req(eng, X, vid=vid))
        assert (np.asarray(out.rslt) == d_a.predict(X)).all()
        packed = eng.install(packed, translate(d_b), vid=vid)        # swap
        out = eng.classify(packed, _req(eng, X, vid=vid))
        assert (np.asarray(out.rslt) == d_b.predict(X)).all()
        packed = eng.evict(packed, vid=vid)                          # evict
        out = eng.classify(packed, _req(eng, X, vid=vid))
        assert (np.asarray(out.rslt) == -1).all()
    assert eng.cache_size() == 1


# ------------------------------------------------------- empty / invalid VID
def test_empty_slot_returns_no_match(satdap, plane_engine):
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    packed = eng.install(eng.empty(), translate(dt), vid=0)
    # tree slot 3 never installed; SVM slot 0 never installed either
    assert (np.asarray(eng.classify(packed, _req(eng, Xte, vid=3)).rslt) == -1).all()
    assert (np.asarray(
        eng.classify(packed, _req(eng, Xte, mid=MID_SVM, vid=0)).rslt) == -1).all()


def test_out_of_range_vid_rejected_or_no_match(satdap, plane_engine):
    Xtr, ytr, Xte, _ = satdap
    eng = plane_engine
    V = eng.profile.max_versions
    dt = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    packed = eng.install(eng.empty(), translate(dt), vid=0)
    # install boundary: slot index must exist
    with pytest.raises(ValueError):
        eng.install(packed, translate(dt), vid=V)
    with pytest.raises(ValueError):
        eng.evict(packed, vid=-1)
    # request boundary: make_request validates when capacity is known
    with pytest.raises(ValueError):
        _req(eng, Xte, vid=V)
    # classify boundary: a hand-built batch with a rogue VID gets -1, not
    # another version's tables
    pb = _req(eng, Xte, vid=0, validate=False)
    pb = dataclasses.replace(pb, vid=jnp.full((Xte.shape[0],), V + 3, jnp.int32))
    assert (np.asarray(eng.classify(packed, pb).rslt) == -1).all()


# ------------------------------------------------------------ kernel parity
@pytest.mark.parametrize("B,T,E,F,V", [(33, 2, 17, 13, 1), (64, 4, 33, 20, 3),
                                       (129, 5, 64, 36, 8)])
def test_tcam_match_v_interpret_matches_ref(rng, B, T, E, F, V):
    codes = jnp.asarray(rng.integers(0, 2**12, (B, T)), jnp.uint32)
    feats = jnp.asarray(rng.integers(0, 256, (B, F)), jnp.int32)
    vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
    cv = jnp.asarray(rng.integers(0, 2**6, (V, T, E)), jnp.uint32)
    cm = jnp.asarray(rng.integers(0, 2**6, (V, T, E)), jnp.uint32)
    fid = jnp.asarray(rng.integers(0, F, (V, T, E)), jnp.int32)
    flo = jnp.asarray(rng.integers(0, 200, (V, T, E)), jnp.int32)
    fhi = flo + jnp.asarray(rng.integers(0, 100, (V, T, E)), jnp.int32)
    bit = jnp.asarray(rng.integers(0, 2, (V, T, E)), jnp.uint32)
    valid = jnp.asarray(rng.random((V, T, E)) < 0.9)
    shift = jnp.int32(rng.integers(0, 20))
    args = (codes, feats, vid, cv, cm, fid, flo, fhi, bit, valid, shift)
    r = ref.tcam_match_v(*args)
    p = ops.tcam_match_v(*args, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
    # per-version slices equal the single-version oracle (the V=1 contract)
    for v in range(V):
        rv = ref.tcam_match(codes, feats, cv[v], cm[v], fid[v], flo[v],
                            fhi[v], bit[v], valid[v], shift)
        sel = np.asarray(vid) == v
        np.testing.assert_array_equal(np.asarray(r)[sel], np.asarray(rv)[sel])


@pytest.mark.parametrize("B,H,F,L,V", [(16, 3, 7, 32, 1), (65, 8, 14, 64, 4)])
def test_svm_lookup_v_interpret_matches_ref(rng, B, H, F, L, V):
    feats = jnp.asarray(rng.integers(0, L, (B, F)), jnp.int32)
    vid = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
    lut = jnp.asarray(rng.integers(-60_000, 60_000, (V, H, F, L)), jnp.int32)
    bias = jnp.asarray(rng.integers(-10_000, 10_000, (V, H)), jnp.int32)
    r = ref.svm_lookup_v(feats, vid, lut, bias)
    p = ops.svm_lookup_v(feats, vid, lut, bias, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
    for v in range(V):
        rv = ref.svm_lookup(feats, lut[v], bias[v])
        sel = np.asarray(vid) == v
        np.testing.assert_array_equal(np.asarray(r)[sel], np.asarray(rv)[sel])


@pytest.mark.parametrize("B,T,P,C,V", [(40, 2, 16, 4, 1), (70, 4, 32, 5, 4)])
def test_forest_vote_v_interpret_matches_ref(rng, B, T, P, C, V):
    pc = np.sort(rng.choice(2**16, size=(V * T * P,), replace=False)
                 .astype(np.uint32).reshape(V, T, P), axis=2)
    plab = rng.integers(0, C, (V, T, P)).astype(np.int32)
    pv = np.ones((V, T, P), bool)
    pv[:, :, -1] = False
    vid = rng.integers(0, V, (B,))
    hit = rng.integers(0, P - 1, (B, T))
    codes = pc[vid[:, None], np.arange(T)[None, :], hit]
    codes[: B // 4] = 0xFFFFFFFE  # some misses
    w = rng.random((V, T)).astype(np.float32)
    args = (jnp.asarray(codes), jnp.asarray(vid, jnp.int32), jnp.asarray(pc),
            jnp.asarray(plab), jnp.asarray(pv), jnp.asarray(w))
    r = ref.forest_predict_vote_v(*args, C)
    p = ops.forest_predict_vote_v(*args, C, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))
    np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(p[1]))


def test_engine_interpret_mode_matches_ref_mode(satdap):
    """Whole-plane parity: the Pallas kernel bodies (interpreter) drive the
    same multi-version dispatch as the XLA ref path."""
    Xtr, ytr, Xte, _ = satdap
    prof = PlaneProfile(max_features=36, max_trees=2, max_layers=5,
                        max_entries_per_layer=64, max_leaves=32,
                        max_classes=8, max_hyperplanes=8, max_versions=2)
    d0 = DecisionTree(max_depth=3, max_leaf_nodes=8).fit(Xtr, ytr)
    d1 = DecisionTree(max_depth=4, max_leaf_nodes=16).fit(Xtr, ytr)
    svm = LinearSVM(epochs=30).fit(Xtr, ytr)
    X = Xte[:32]
    outs = {}
    for mode in ("ref", "interpret"):
        eng = SwitchEngine(prof, mode=mode)
        packed = eng.empty()
        packed = eng.install(packed, translate(d0, vid=0))
        packed = eng.install(packed, translate(d1, vid=1))
        packed = eng.install(packed, translate(svm, vid=1))
        vids = np.arange(X.shape[0]) % 2
        mids = np.where(np.arange(X.shape[0]) % 3 == 0, MID_SVM, 0)
        vids = np.where(mids == MID_SVM, 1, vids)
        pb = _req(eng, X, mid=mids, vid=vids)
        outs[mode] = np.asarray(eng.classify(packed, pb).rslt)
    np.testing.assert_array_equal(outs["ref"], outs["interpret"])


# ------------------------------------------------------------- distributed
@pytest.mark.slow
def test_distributed_zoo_versions_on_different_devices(satdap):
    """plan_zoo assigns each version's stages under capacity carry-over, so
    versions land on *different* devices of one path; the merged per-device
    zoos classify a mixed-VID batch identically to the CPU models."""
    Xtr, ytr, Xte, _ = satdap
    prof = PlaneProfile(max_features=36, max_trees=4, max_layers=8,
                        max_entries_per_layer=64, max_leaves=64,
                        max_classes=8, max_hyperplanes=8, max_versions=3)
    rf0 = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30,
                       random_state=0).fit(Xtr, ytr)
    rf1 = RandomForest(n_estimators=4, max_depth=5, max_leaf_nodes=30,
                       random_state=1).fit(Xtr, ytr)
    d2 = DecisionTree(max_depth=6, max_leaf_nodes=40).fit(Xtr, ytr)
    progs = [translate(rf0, vid=0), translate(rf1, vid=1), translate(d2, vid=2)]
    net = fat_tree(4)
    h = net.hosts()
    plans = plan_zoo(progs, net, h[0], h[-1],
                     default_device=DeviceModel(n_stages=12), solver="dp")
    assert all(p.path == plans[0].path for p in plans)
    # capacity carry-over forced the versions apart
    owners = [frozenset(p.device_stages()) for p in plans]
    assert len(set(owners)) > 1
    devs, dps = build_zoo_device_programs(progs, plans, prof)
    B = Xte.shape[0]
    vids = np.arange(B) % 3
    mids = np.where(vids == 2, 0, 1)
    pb = PacketBatch.make_request(Xte, mid=mids, vid=vids, max_features=36,
                                  n_trees=4, n_hyperplanes=8, max_versions=3)
    out = run_sequential(dps, pb, n_classes=8)
    got = np.asarray(out.rslt)
    want = np.where(vids == 0, rf0.predict(Xte),
                    np.where(vids == 1, rf1.predict(Xte), d2.predict(Xte)))
    assert (got == want).all()
